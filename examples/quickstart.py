"""Quickstart: Byzantine-robust aggregation in five minutes.

1. build a stack of agent gradients, corrupt f of them,
2. compare every gradient filter against the undefended mean,
3. run 30 Byzantine-robust training steps on a tiny LM and serve from it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.aggregators import clipped, make_spec
from repro.core.attacks import apply_attack, make_byzantine_mask
from repro.data import SyntheticLM
from repro.optim import adamw, constant
from repro.serving import generate
from repro.training import ByzantineConfig, train_loop

# --- 1. aggregator specs on a raw gradient stack -----------------------
n, f, d = 12, 3, 64
key = jax.random.PRNGKey(0)
center = jnp.linspace(-1.0, 1.0, d)
grads = center + 0.1 * jax.random.normal(key, (n, d))
mask = make_byzantine_mask(n, f)
attacked = apply_attack("sign_flip", key, grads, mask)

print(f"{n} agents, {f} Byzantine (sign-flip attack)\n")
print(f"{'aggregator':24s} {'dist to honest center':>22s}")
for name in ["mean", "krum", "coordinate_median", "trimmed_mean",
             "geometric_median", "cge", "bulyan", "mda"]:
    spec = make_spec(name, f=f, n=n)        # typed, validated at build time
    out = spec.aggregate(attacked)
    print(f"{spec.describe():24s} {float(jnp.linalg.norm(out - center)):22.4f}")

# specs compose: clip outlier rows to norm 10, THEN trimmed-mean the rest
composed = clipped(make_spec("trimmed_mean", f=f, n=n), tau=10.0)
out = composed.aggregate(attacked)
print(f"{composed.describe():24s} {float(jnp.linalg.norm(out - center)):22.4f}")

# --- 2. Byzantine-robust training end to end ---------------------------
cfg = get_config("paper-100m-smoke").replace(vocab_size=64)
ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8, per_agent_batch=2)
bz = ByzantineConfig(n_agents=8, f=2,
                     aggregator=make_spec("trimmed_mean", f=2, n=8),
                     attack="sign_flip")
print("\ntraining a smoke-scale LM under attack (trimmed-mean defence):")
params, hist = train_loop(cfg, bz, adamw(constant(3e-3)), ds, steps=30,
                          log_every=10)

# --- 3. serve from the trained weights ---------------------------------
prompt = {"tokens": ds.batch(jax.random.PRNGKey(1), 0)["tokens"][0, :, :8]}
print("\ngreedy continuation:", generate(cfg, params, prompt, 6).tolist())
