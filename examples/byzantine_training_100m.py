"""End-to-end driver: train the ~100M-parameter ``paper-100m`` config for a
few hundred steps under an active Byzantine attack, with a gradient filter
defending, checkpointing, and a final serving check.

This is the survey's experimental setting at modern scale: n agents run
D-SGD (here AdamW server-side), f of them are adversarial, the server
aggregates with a Table-2 gradient filter.

Full run (a few hours on this CPU container; minutes on one TPU host):
  PYTHONPATH=src python examples/byzantine_training_100m.py --steps 300

Quick validation:
  PYTHONPATH=src python examples/byzantine_training_100m.py \
      --steps 30 --seq-len 64 --per-agent-batch 1
"""
import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--per-agent-batch", type=int, default=2)
    ap.add_argument("--n-agents", type=int, default=8)
    ap.add_argument("--f", type=int, default=2)
    ap.add_argument("--filter", default="phocas")
    ap.add_argument("--attack", default="alie")
    ap.add_argument("--momentum-alpha", type=float, default=0.2)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt_100m")
    ap.add_argument("--history-out", default="artifacts/history_100m.json")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, num_params
    from repro.core.aggregators import make_spec
    from repro.data import SyntheticLM
    from repro.optim import adamw, cosine_warmup
    from repro.serving import generate
    from repro.training import ByzantineConfig, train_loop

    cfg = get_config("paper-100m")
    print(f"arch {cfg.name}: {num_params(cfg)/1e6:.1f}M params")
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                     n_agents=args.n_agents,
                     per_agent_batch=args.per_agent_batch, regime="noniid")
    bz = ByzantineConfig(
        n_agents=args.n_agents, f=args.f,
        aggregator=make_spec(args.filter, f=args.f, n=args.n_agents),
        attack=args.attack, momentum_alpha=args.momentum_alpha, remat=True)
    opt = adamw(cosine_warmup(3e-4, max(args.steps // 20, 5), args.steps))
    params, hist = train_loop(cfg, bz, opt, ds, steps=args.steps,
                              log_every=max(args.steps // 30, 1),
                              ckpt_dir=args.ckpt_dir,
                              ckpt_every=max(args.steps // 3, 1))
    if args.history_out:
        with open(args.history_out, "w") as fh:
            json.dump(hist, fh, indent=1)

    # serve a continuation of the learnable stream
    prompt = {"tokens": ds.batch(jax.random.PRNGKey(42), 0)
              ["tokens"][0, :, :32]}
    out = generate(cfg, params, prompt, 8)
    print("greedy continuation ids:", out[0].tolist())
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(started {hist[0]['loss']:.4f}) under attack={args.attack} "
          f"defence={args.filter}")


if __name__ == "__main__":
    main()
