"""Decentralized (peer-to-peer) fault-tolerant optimization — survey §3.3.5.

Eight agents with quadratic costs run p2p DGD over different topologies;
two Byzantine agents broadcast poisoned estimates.  Compare the plain
Metropolis mixing against Local-Filtering dynamics and Comparative
Elimination, and demonstrate the Wu et al. data-injection attack detection.

Run:  PYTHONPATH=src python examples/p2p_consensus.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.p2p import (complete_graph, data_injection_attack,
                            detect_injection, is_r_s_robust, p2p_dgd_run,
                            ring_graph, vertex_connectivity)

key = jax.random.PRNGKey(0)
n, d, f = 8, 3, 2
targets = 0.3 * jax.random.normal(key, (n, d))
grad_fn = lambda i, x: x - targets[i]
x0 = jnp.zeros((n, d)) + 2.0
byz = jnp.arange(n) < f
hm = jnp.mean(targets[f:], axis=0)

graphs = {"complete": complete_graph(n), "ring(k=2)": ring_graph(n, 2)}
print("graph properties:")
for name, adj in graphs.items():
    print(f"  {name:10s} connectivity={vertex_connectivity(adj)} "
          f"(2f+1={2*f+1} needed for f-total robustness)")

print("\nByzantine broadcast (constant 50.0), honest error to optimum:")
byz_fn = lambda k, t, s: jnp.full_like(s, 50.0)
print(f"{'graph':12s} {'plain':>8s} {'lf':>8s} {'ce':>8s}")
for name, adj in graphs.items():
    errs = []
    for combine in ("plain", "lf", "ce"):
        traj = p2p_dgd_run(adj, grad_fn, x0, 100, f=f, combine=combine,
                           byz_mask=byz, byz_fn=byz_fn)
        errs.append(float(jnp.max(jnp.linalg.norm(traj[-1][f:] - hm,
                                                  axis=-1))))
    print(f"{name:12s} {errs[0]:8.3f} {errs[1]:8.3f} {errs[2]:8.3f}")

print("\ndata-injection attack (Wu et al. [114]) + detection:")
atk = data_injection_attack(10.0 * jnp.ones((d,)))
byz1 = jnp.arange(n) < 1
traj = p2p_dgd_run(complete_graph(n), grad_fn, x0, 60, combine="plain",
                   byz_mask=byz1, byz_fn=atk, key=key)
scores = detect_injection(traj, complete_graph(n))
flagged = [int(np.argmax(scores[i])) for i in range(1, n)]
print(f"  every honest agent flags its most-suspicious neighbour: {flagged}"
      f"  (adversary is agent 0)")
