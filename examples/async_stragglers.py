"""Straggler mitigation under asynchronous training: plain quorum drop vs
gradient coding.

A cluster of 8 agents trains a smoke-scale LM while two agents are heavy
stragglers (Pareto-tailed slowdowns).  Three mitigation strategies on the
SAME fault schedule (same seed -> identical latency samples):

  1. barrier     — synchronous full barrier (quorum = n): every step waits
                   for the slowest agent, so virtual time explodes;
  2. quorum-drop — bounded-staleness async (quorum = 6): stragglers' work is
                   often dropped or arrives stale and down-weighted;
  3. coded       — same quorum, but data is replicated (parallel regime,
                   Draco r=2): whenever the quorum is missed, the
                   repetition code recovers the batch gradient from the
                   agents that DID deliver (survey §3.3.3 meets §4 asynchrony);
  4. zeno_pp     — same quorum, but the delay-adaptive Zeno++-style score
                   filter (a STATEFUL AggregatorSpec: the server's
                   descent-direction EMA is threaded through the jitted
                   step) additionally screens what the quorum delivers.

The last run records a flight-recorder trace (repro.obs): the JSONL +
Chrome-trace/Perfetto exports land next to this script (or under
``--trace-dir``) and the per-agent suspicion report is pretty-printed —
the two Pareto stragglers surface at the top of the table.

Run:  PYTHONPATH=src python examples/async_stragglers.py [--trace-dir DIR]
"""
import argparse
import os

import jax.numpy as jnp

from repro.configs import get_config
from repro.core.aggregators import make_spec
from repro.obs import Recorder
from repro.obs.report import render_report
from repro.data import SyntheticLM
from repro.optim import adamw, constant
from repro.simulator import SimConfig, Straggler, async_train_loop
from repro.training import ByzantineConfig

STEPS = 40
FAULTS = (Straggler(dist="pareto", scale=1.1, agents=(0, 1)),)
MEAN = make_spec("mean", n=8)

cfg = get_config("paper-100m-smoke").replace(vocab_size=64, dtype="float32")
ds_iid = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8, per_agent_batch=2)
ds_par = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8, per_agent_batch=2,
                     regime="parallel")

RUNS = {
    "barrier (sync, quorum=8)": dict(
        ds=ds_iid, bz=ByzantineConfig(n_agents=8, f=0, aggregator=MEAN),
        sim=SimConfig(faults=FAULTS, quorum=None, seed=0)),
    "quorum-drop (async, quorum=6)": dict(
        ds=ds_iid, bz=ByzantineConfig(n_agents=8, f=0, aggregator=MEAN),
        sim=SimConfig(faults=FAULTS, quorum=6, max_staleness=3, seed=0)),
    "coded (async + Draco r=2)": dict(
        ds=ds_par, bz=ByzantineConfig(n_agents=8, f=0, draco_r=2),
        sim=SimConfig(faults=FAULTS, quorum=6, max_staleness=3, seed=0)),
    "zeno_pp (async, delay-adaptive)": dict(
        ds=ds_iid, bz=ByzantineConfig(
            n_agents=8, f=0,
            aggregator=make_spec("zeno_pp", xi=0.5, ema=0.2, n=8)),
        sim=SimConfig(faults=FAULTS, quorum=6, max_staleness=3, seed=0)),
}

ap = argparse.ArgumentParser()
ap.add_argument("--trace-dir", default=os.path.dirname(__file__) or ".",
                help="where the recorded trace JSONL/Perfetto land")
args = ap.parse_args()

print(f"{'strategy':32s} {'final loss':>10s} {'virtual time':>13s} "
      f"{'mean staleness':>15s}")
last_name = list(RUNS)[-1]
os.makedirs(args.trace_dir, exist_ok=True)
trace_path = os.path.join(args.trace_dir, "async_stragglers_trace.jsonl")
for name, kw in RUNS.items():
    recorder = None
    if name == last_name:                  # flight-record the final run
        recorder = Recorder(trace_path, meta={"example": "async_stragglers",
                                              "strategy": name})
    _, hist = async_train_loop(cfg, kw["bz"], adamw(constant(3e-3)),
                               kw["ds"], STEPS, sim=kw["sim"],
                               log_every=STEPS, log_fn=lambda *_: None,
                               recorder=recorder)
    last = hist[-1]
    stal = float(jnp.mean(jnp.asarray([m["staleness_mean"] for m in hist])))
    print(f"{name:32s} {last['loss']:10.4f} {last['vclock']:13.1f} "
          f"{stal:15.2f}")
    if recorder is not None:
        perfetto = recorder.dump_chrome_trace(
            os.path.join(args.trace_dir, "async_stragglers_trace.json"))
        recorder.close()
        print(f"\nflight-recorder trace -> {trace_path}"
              f"\nperfetto export       -> {perfetto}\n")
        print(render_report(recorder.events))

print("\nsame loss target, but the async strategies finish in a fraction of "
      "the barrier's virtual time; coding additionally recovers the exact "
      "batch gradient on quorum misses.")
