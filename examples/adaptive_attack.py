"""Defense-aware attacks vs defenses with memory (survey §5's hardest
setting: the adversary who SEES the defense).

A cluster of 8 agents (2 Byzantine) trains a smoke-scale LM under four
matchups on identical data:

  1. krum | static catalogue — krum filters every static attack from the
     zoo (`core/attacks/gradient.py`) exactly: the poisoned rows lose the
     pairwise-distance vote bitwise, training matches the clean run;
  2. krum | spec_alie — the defense-aware attacker holds the SPEC (it is
     a typed object) and line-searches, inside jit, the largest
     variance-aligned poison that still wins krum's vote: same defense,
     measurably degraded training;
  3. centered_clip | spec_alie + min_max — the history filter: every row
     is iteratively re-clipped to radius tau around the server center
     carried ACROSS rounds (`init_state`/`update_state`), so even a
     poison calibrated against centered_clip itself moves the estimate by
     at most iters * tau per step — training holds near clean;
  4. the last run is flight-recorded (repro.obs): the per-agent suspicion
     report reconstructs WHO was being clipped from the effective
     clip-weight telemetry — the two Byzantine agents surface on top.

Run:  PYTHONPATH=src python examples/adaptive_attack.py [--trace-dir DIR]
"""
import argparse
import os

from repro.configs.base import ArchConfig
from repro.core.aggregators import make_spec
from repro.data import SyntheticLM
from repro.obs import Recorder
from repro.obs.report import render_report
from repro.optim import adamw, constant
from repro.training import ByzantineConfig, train_loop

N, F, STEPS = 8, 2, 30

CFG = ArchConfig(name="demo", family="dense", num_layers=2, d_model=64,
                 num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                 head_dim=16, dtype="float32")

KRUM = dict(rule="krum", hyper={})
CCLIP = dict(rule="centered_clip", hyper={"tau": 1.0})

RUNS = [
    ("krum          | clean", KRUM, "none", {}),
    ("krum          | alie (static, z=3)", KRUM, "alie", {"z": 3.0}),
    ("krum          | sign_flip (static)", KRUM, "sign_flip",
     {"scale": 4.0}),
    ("krum          | spec_alie (ADAPTIVE)", KRUM, "spec_alie", {}),
    ("centered_clip | clean", CCLIP, "none", {}),
    ("centered_clip | min_max (ADAPTIVE)", CCLIP, "min_max", {}),
    ("centered_clip | spec_alie (ADAPTIVE)", CCLIP, "spec_alie", {}),
]

ap = argparse.ArgumentParser()
ap.add_argument("--trace-dir", default=os.path.dirname(__file__) or ".",
                help="where the recorded trace JSONL lands")
args = ap.parse_args()
os.makedirs(args.trace_dir, exist_ok=True)
trace_path = os.path.join(args.trace_dir, "adaptive_attack_trace.jsonl")

print(f"{'matchup':38s} {'final loss':>10s}")
recorder = None
for i, (name, defense, attack, hyper) in enumerate(RUNS):
    spec = make_spec(defense["rule"], f=F, n=N, **defense["hyper"])
    bz = ByzantineConfig(n_agents=N, f=F, aggregator=spec, attack=attack,
                         attack_hyper=hyper)
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=N,
                     per_agent_batch=4)
    if i == len(RUNS) - 1:                 # flight-record the final run
        recorder = Recorder(trace_path,
                            meta={"example": "adaptive_attack",
                                  "matchup": name})
    _, hist = train_loop(CFG, bz, adamw(constant(3e-3)), ds, steps=STEPS,
                         log_every=STEPS, log_fn=lambda *_: None,
                         recorder=recorder if i == len(RUNS) - 1 else None)
    print(f"{name:38s} {hist[-1]['loss']:10.4f}")

recorder.close()
print(f"\nflight-recorder trace -> {trace_path}\n")
print(render_report(recorder.events))
print("\nkrum is sound against the whole static catalogue yet falls to the"
      "\nspec-aware line search; the carried clip center bounds what ANY"
      "\nper-round poison can do, and its clip-weight telemetry still"
      "\nfingers the attackers (agents 0, 1 above).")
