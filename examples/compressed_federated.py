"""Compressed robust exchange (PR 9): sign-SGD and int8 arenas under a
Byzantine federation.

A federation of 8 clients trains a smoke-scale LM while two clients are
Byzantine (``large_value`` gradient attack) and the network drops
messages.  Four exchanges on the SAME fault schedule:

  1. mean/fp32          — undefended full-precision baseline (breaks);
  2. trimmed_mean/fp32  — robust but full-precision (4 B/coordinate);
  3. trimmed_mean/int8  — the quantized flat arena: per-row symmetric
                          int8 codes + one f32 scale, dequantized INSIDE
                          the aggregation tile (~4x fewer wire bytes);
  4. sign_sgd/fp32      — 1-bit sign exchange, exact integer majority
                          vote at the server (~32x fewer wire bytes).

The last run records a flight-recorder trace (repro.obs): compression
does not blind the telemetry — delivery, staleness and selection-weight
read-outs ride the pre-quantization arena, so the report renders the
same tables it would for a full-precision run.

Run:  PYTHONPATH=src python examples/compressed_federated.py [--trace-dir DIR]
"""
import argparse
import math
import os

from repro.configs import get_config
from repro.core.aggregators import make_spec
from repro.data import SyntheticLM
from repro.obs import Recorder
from repro.obs.report import render_report
from repro.optim import adamw, constant
from repro.simulator import MessageDrop, SimConfig, Straggler, \
    async_train_loop
from repro.training import ByzantineConfig

STEPS = 40
N, F = 8, 2
FAULTS = (Straggler(dist="lognormal", scale=0.5),
          MessageDrop(p=0.1))

cfg = get_config("paper-100m-smoke").replace(vocab_size=64, dtype="float32")


def wire_bytes(p, kind):
    """bytes/round/client for a P-coordinate update."""
    return {"fp32": 4 * p, "int8": p + 4, "sign": math.ceil(p / 8)}[kind]


RUNS = {
    "mean / fp32 (undefended)": dict(
        bz=ByzantineConfig(n_agents=N, f=F, attack="large_value",
                           aggregator=make_spec("mean", f=F, n=N)),
        wire="fp32"),
    "trimmed_mean / fp32": dict(
        bz=ByzantineConfig(n_agents=N, f=F, attack="large_value",
                           aggregator=make_spec("trimmed_mean", f=F, n=N)),
        wire="fp32"),
    "trimmed_mean / int8 arena": dict(
        bz=ByzantineConfig(n_agents=N, f=F, attack="large_value",
                           aggregator=make_spec("trimmed_mean", f=F, n=N),
                           agg_dtype="int8"),
        wire="int8"),
    "sign_sgd / 1-bit vote": dict(
        bz=ByzantineConfig(n_agents=N, f=F, attack="large_value",
                           aggregator=make_spec("sign_sgd", f=F, n=N)),
        wire="sign"),
}

ap = argparse.ArgumentParser()
ap.add_argument("--trace-dir", default=os.path.dirname(__file__) or ".",
                help="where the recorded trace JSONL/Perfetto land")
args = ap.parse_args()

os.makedirs(args.trace_dir, exist_ok=True)
trace_path = os.path.join(args.trace_dir, "compressed_federated_trace.jsonl")
last_name = list(RUNS)[-1]

print(f"{'exchange':28s} {'final loss':>10s} {'wire B/coord':>13s} "
      f"{'vs fp32':>8s}")
for name, kw in RUNS.items():
    recorder = None
    if name == last_name:                  # flight-record the sign run
        recorder = Recorder(trace_path,
                            meta={"example": "compressed_federated",
                                  "strategy": name})
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=N,
                     per_agent_batch=2)
    _, hist = async_train_loop(cfg, kw["bz"], adamw(constant(3e-3)), ds,
                               STEPS,
                               sim=SimConfig(faults=FAULTS, quorum=6,
                                             max_staleness=3, seed=0),
                               log_every=STEPS, log_fn=lambda *_: None,
                               recorder=recorder)
    per_coord = wire_bytes(1024, kw["wire"]) / 1024
    ratio = 4.0 / per_coord
    print(f"{name:28s} {hist[-1]['loss']:10.4f} {per_coord:13.3f} "
          f"{ratio:7.1f}x")
    if recorder is not None:
        perfetto = recorder.dump_chrome_trace(
            os.path.join(args.trace_dir, "compressed_federated_trace.json"))
        recorder.close()
        print(f"\nflight-recorder trace -> {trace_path}"
              f"\nperfetto export       -> {perfetto}\n")
        print(render_report(recorder.events))

print("\nthe robust compressed exchanges hold the attack off at a fraction "
      "of the wire bytes (the undefended mean stays stuck at init loss); "
      "the flight recorder keeps full delivery/staleness telemetry "
      "despite the 1-bit exchange — sign_sgd's vote weighs every arrived "
      "row, so its sel_rate read-out is participation, not selection.")
