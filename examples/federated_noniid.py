"""Federated setting (survey §3.4): each agent has its OWN data distribution
D_i.  Three honest lessons from the literature, demonstrated live:

1. PURE DATA POISONING (label flips, no gradient manipulation): the mean is
   dragged by the poisoned agents; coordinate-wise/geometric medians shrug
   it off.
2. HETEROGENEITY HURTS SELECTION FILTERS: Krum picks ONE agent's gradient —
   under non-iid data that discards most of the signal (the survey's
   federated-learning caveat; RSA/RFA [66, 83] were designed for exactly
   this).  The mean-family robust filters (trimmed mean, Phocas) degrade
   far less.
3. MEMBERSHIP CHURN IS THE FEDERATED NORM: phones join, drop and rejoin.
   An elastic-n spec (``n=elastic(...)``, ``f=frac(...)``) re-specializes
   its trim counts and Byzantine budget to the LIVE roster per bucket —
   paying at most one compile per bucket — where a static spec must
   dilute the shrunken roster with imputed ghost rows.

Run:  PYTHONPATH=src python examples/federated_noniid.py
"""
from repro.configs import get_config
from repro.core.aggregators import elastic, frac, make_spec
from repro.core.tracecount import TRACE_COUNTS
from repro.data import SyntheticLM
from repro.optim import adamw, constant
from repro.simulator import Churn, Join, SimConfig
from repro.training import ByzantineConfig, train_loop

CFG = get_config("paper-100m-smoke").replace(vocab_size=64)
STEPS = 120


def run(filter_name, attack="none", poison=False, regime="noniid"):
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8,
                     per_agent_batch=2, regime=regime)
    bz = ByzantineConfig(n_agents=8, f=2,
                         aggregator=make_spec(filter_name, f=2, n=8),
                         attack=attack)
    _, hist = train_loop(CFG, bz, adamw(constant(3e-3)), ds, steps=STEPS,
                         poison_labels=poison, log_fn=lambda *_: None)
    return hist[-1]["loss"]


# device churn: agent 7 only onboards at step 20; four agents drop and
# rejoin stochastically throughout (the federated availability pattern)
CHURN = SimConfig(faults=(Join(agents=(7,), at=20),
                          Churn(rate=0.1, mean_out=3.0,
                                agents=(2, 3, 4, 5))),
                  quorum=4, max_staleness=2, seed=1)


def run_churn(elastic_spec: bool):
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8,
                     per_agent_batch=2, regime="noniid")
    if elastic_spec:
        agg = make_spec("trimmed_mean", f=frac(0.25),
                        n=elastic(8, buckets=(4, 6, 8)))
    else:
        agg = make_spec("trimmed_mean", f=2, n=8)
    bz = ByzantineConfig(n_agents=8, f=2, aggregator=agg,
                         attack="sign_flip")
    before = TRACE_COUNTS["async_step"] + TRACE_COUNTS["train_step"]
    _, hist = train_loop(CFG, bz, adamw(constant(3e-3)), ds, steps=STEPS,
                         sim=CHURN, log_fn=lambda *_: None)
    compiles = (TRACE_COUNTS["async_step"] + TRACE_COUNTS["train_step"]
                - before)
    live = [m["n_live"] for m in hist]
    return hist[-1]["loss"], compiles, min(live), max(live)


if __name__ == "__main__":
    print("1) label-flip poisoning only (f=2/8 poisoned agents, non-iid):\n")
    print(f"{'defence':22s} {'final honest loss':>18s}")
    for name in ("mean", "coordinate_median", "geometric_median",
                 "trimmed_mean"):
        print(f"{name:22s} {run(name, poison=True):18.4f}")

    print("\n2) heterogeneity vs selection filters (no attack, non-iid):\n")
    print(f"{'defence':22s} {'final honest loss':>18s}")
    for name in ("mean", "trimmed_mean", "phocas", "krum"):
        print(f"{name:22s} {run(name):18.4f}")
    print("\n   (krum selects a single agent's gradient -> it cannot fit")
    print("    all 8 non-iid streams; the survey's §3.4 heterogeneous-data")
    print("    formulation and RSA/RFA-style methods target exactly this)")

    print("\n3) membership churn (join at step 20 + stochastic drop/rejoin,")
    print("   f=2/8 sign-flip attackers, non-iid):\n")
    print(f"{'spec':34s} {'loss':>8s} {'compiles':>9s} {'live range':>11s}")
    for name, use_elastic in (("trimmed_mean f=2 (static n=8)", False),
                              ("trimmed_mean f=frac(.25) elastic", True)):
        loss, compiles, lo, hi = run_churn(use_elastic)
        print(f"{name:34s} {loss:8.4f} {compiles:9d} {lo:6d}-{hi}")
    print("\n   (the elastic spec re-derives trim counts and f per live-")
    print("    roster bucket — at most one compile per bucket — while the")
    print("    static spec keeps its n=8 plan and imputes departed rows)")
