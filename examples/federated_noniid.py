"""Federated setting (survey §3.4): each agent has its OWN data distribution
D_i.  Two honest lessons from the literature, demonstrated live:

1. PURE DATA POISONING (label flips, no gradient manipulation): the mean is
   dragged by the poisoned agents; coordinate-wise/geometric medians shrug
   it off.
2. HETEROGENEITY HURTS SELECTION FILTERS: Krum picks ONE agent's gradient —
   under non-iid data that discards most of the signal (the survey's
   federated-learning caveat; RSA/RFA [66, 83] were designed for exactly
   this).  The mean-family robust filters (trimmed mean, Phocas) degrade
   far less.

Run:  PYTHONPATH=src python examples/federated_noniid.py
"""
from repro.configs import get_config
from repro.core.aggregators import make_spec
from repro.data import SyntheticLM
from repro.optim import adamw, constant
from repro.training import ByzantineConfig, train_loop

CFG = get_config("paper-100m-smoke").replace(vocab_size=64)
STEPS = 120


def run(filter_name, attack="none", poison=False, regime="noniid"):
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8,
                     per_agent_batch=2, regime=regime)
    bz = ByzantineConfig(n_agents=8, f=2,
                         aggregator=make_spec(filter_name, f=2, n=8),
                         attack=attack)
    _, hist = train_loop(CFG, bz, adamw(constant(3e-3)), ds, steps=STEPS,
                         poison_labels=poison, log_fn=lambda *_: None)
    return hist[-1]["loss"]


if __name__ == "__main__":
    print("1) label-flip poisoning only (f=2/8 poisoned agents, non-iid):\n")
    print(f"{'defence':22s} {'final honest loss':>18s}")
    for name in ("mean", "coordinate_median", "geometric_median",
                 "trimmed_mean"):
        print(f"{name:22s} {run(name, poison=True):18.4f}")

    print("\n2) heterogeneity vs selection filters (no attack, non-iid):\n")
    print(f"{'defence':22s} {'final honest loss':>18s}")
    for name in ("mean", "trimmed_mean", "phocas", "krum"):
        print(f"{name:22s} {run(name):18.4f}")
    print("\n   (krum selects a single agent's gradient -> it cannot fit")
    print("    all 8 non-iid streams; the survey's §3.4 heterogeneous-data")
    print("    formulation and RSA/RFA-style methods target exactly this)")
