"""Algorithmic redundancy / gradient coding — survey §3.3.3.

The parallel setting: the server assigns the SAME data shard to r agents
(Draco repetition code).  Majority voting recovers EXACT gradients under up
to (r-1)/2 Byzantine agents per group — contrast with the approximate
guarantees of gradient filters.  DETOX trades vote groups for robust
bucket aggregation; randomized reactive redundancy amortizes the cost.

Run:  PYTHONPATH=src python examples/gradient_coding.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.redundancy import init_reactive
from repro.core.redundancy.reactive import (check_and_aggregate,
                                            plain_aggregate)
from repro.data import SyntheticLM
from repro.optim import adamw, constant
from repro.training import ByzantineConfig, train_loop

cfg = get_config("paper-100m-smoke").replace(vocab_size=64)
ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8, per_agent_batch=2,
                 regime="parallel")

print("Draco repetition coding (r=4, f=1, large-value attack):")
bz = ByzantineConfig(n_agents=8, f=1, draco_r=4, attack="large_value")
_, hist = train_loop(cfg, bz, adamw(constant(3e-3)), ds, steps=40,
                     log_every=20)
print(f"  -> converged to {hist[-1]['loss']:.4f} "
      "(exact recovery: coding, not filtering)\n")

print("Randomized reactive redundancy [44] (fixed Byzantine agent):")
n, d = 8, 16
truth = jnp.ones((d,))
state = init_reactive(n)
g = jnp.tile(truth, (n, 1)).at[5].set(-100.0)
print(f"  active agents before check: {int(jnp.sum(state.active))}")
agg, state = check_and_aggregate(g, state, lambda i: truth)
print(f"  after one checking iteration: active="
      f"{int(jnp.sum(state.active))}, detected={state.detected}")
out = plain_aggregate(jnp.tile(truth, (n, 1)).at[5].set(999.0), state)
print(f"  subsequent plain iterations ignore it: max err "
      f"{float(jnp.max(jnp.abs(out - truth))):.2e}")
