"""Kernel-dispatch parity suite: ``impl="pallas"`` vs the gather-path
reference, for every kernelized rule x {plain, masked, weighted}.

The exactness bar comes from the survey's resilience story (CGE's provable
(f, eps) guarantees, the approximate-BFT line): a kernel that silently
disagrees with the reference rule voids the guarantee, so agreement is
asserted BIT-FOR-BIT for fp32 wherever the two paths share the reference
arithmetic:

  * coordinate_median / trimmed_mean — identical order statistics (the
    kernels pin the reduce order to the reference's, see coord_stats);
  * krum — one-hot application returns exactly the selected row's bits;
  * multi_krum / m_krum / mda / bulyan — selection-ORDER-preserving
    application (kernels/wsum.ordered_apply): the picked rows are summed
    in exactly the dense reference's order with the reduce and the
    divisor compilation pinned (optimization_barrier), so the multi-row
    averages are bit-for-bit too, across plain AND the imputation-free
    masked/weighted paths;
  * cge — the SELECTION is asserted bit-for-bit; the eager dense
    reference's gather+reduce fuses non-deterministically across XLA
    program boundaries, so the averaged output is asserted to ulp-level
    tolerance (the selected SET is what the (f, eps) guarantee depends
    on).

bfloat16 stacks are asserted to bf16-resolution tolerance.  Fuzzing is
seeded ``jax.random`` grids (no ``hypothesis`` here — not installed; the
importorskip pattern is reserved for optional deps) over odd/even n and
tile-aligned / non-multiple-of-block d, plus fault-schedule-driven quorum
masks from the async simulator and retrace counters proving fixed-shape
masks never recompile the kernel path and the flat-arena loops add ZERO
compiles over the per-leaf loops under churn + fault schedules.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import FlatPlan, make_spec, pallas_available
from repro.kernels import ref
from repro.kernels.coord_stats import coord_stat
from repro.kernels.masked import masked_coord_stat
from repro.kernels.ops import _pad_d
from repro.kernels.pairwise import gram
from repro.kernels.select import cge_select, krum_select

RULES = ["coordinate_median", "trimmed_mean", "krum", "cge",
         "multi_krum", "m_krum", "mda", "bulyan", "sign_sgd", "sparse_mean"]
# non-power-of-2 selection counts so the division-compilation pinning is
# exercised (a power-of-2 divisor would hide a reciprocal-multiply drift)
HYPER = {"multi_krum": {"m": 3}, "m_krum": {"m": 3}}
NS = [9, 12]                       # odd / even agent counts
DS = [512, 771]                    # exact tile / non-multiple-of-block
DTYPES = [jnp.float32, jnp.bfloat16]
MODES = ["plain", "masked", "weighted"]
SEEDS = [0, 1]
F = 2

# rules whose pallas OUTPUT is bit-for-bit with the gather path in fp32
# (cge: selection bitwise, application within ulp — see module docstring)
BITWISE_RULES = {"coordinate_median", "trimmed_mean", "krum",
                 "multi_krum", "m_krum", "mda", "bulyan",
                 "sign_sgd", "sparse_mean"}


def spec_pair(rule, n):
    """(pallas, gather) spec twins for one fuzz case."""
    hyper = HYPER.get(rule, {})
    return (make_spec(rule, f=F, impl="pallas", n=n, **hyper),
            make_spec(rule, f=F, impl="gather", n=n, **hyper))


def data(n, d, dtype, seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 2.0
    return g.astype(dtype)


def mode_args(mode, n, seed):
    """(mask, weights) for one fuzz case; masks always keep >= n - F rows."""
    if mode == "plain":
        return None, None
    k1, k2 = jax.random.split(jax.random.PRNGKey(100 + seed))
    drop = jax.random.choice(k1, n, shape=(F,), replace=False)
    mask = jnp.ones((n,), bool).at[drop].set(False)
    if mode == "masked":
        return mask, None
    w = jax.random.uniform(k2, (n,), minval=0.3, maxval=1.0)
    return mask, w


def assert_agree(out, ref_out, dtype, rule):
    a, b = np.asarray(out), np.asarray(ref_out)
    assert a.dtype == b.dtype
    if dtype == jnp.float32 and rule in BITWISE_RULES:
        np.testing.assert_array_equal(a, b)
    elif dtype == jnp.float32:
        np.testing.assert_allclose(a, b, rtol=3e-6, atol=3e-6)
    else:                                      # bf16 resolution
        np.testing.assert_allclose(a.astype(np.float32),
                                   b.astype(np.float32),
                                   rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# 1. spec-level parity: impl="pallas" vs impl="gather", all modes


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("d", DS)
@pytest.mark.parametrize("rule", RULES)
def test_pallas_matches_gather_spec(rule, n, d, dtype, mode):
    pa, ga = spec_pair(rule, n)
    for seed in SEEDS:
        g = data(n, d, dtype, seed)
        mask, w = mode_args(mode, n, seed)
        out = pa.aggregate(g, mask=mask, weights=w)
        expect = ga.aggregate(g, mask=mask, weights=w)
        assert_agree(out, expect, dtype, rule)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("rule", ["coordinate_median", "trimmed_mean"])
def test_pallas_matches_gather_on_pytrees(rule, mode):
    """The fused masked kernel path also runs on raveled pytrees (the
    training loops' actual gradient structure)."""
    n = 10
    grads = {"a": data(n, 5 * 7, jnp.float32, 3).reshape(n, 5, 7),
             "b": {"c": data(n, 11, jnp.float32, 4)}}
    mask, w = mode_args(mode, n, 0)
    out = make_spec(rule, f=F, impl="pallas").aggregate(
        grads, mask=mask, weights=w)
    expect = make_spec(rule, f=F, impl="gather").aggregate(
        grads, mask=mask, weights=w)
    for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_masked_semantics_of_the_new_default_are_pinned():
    """The default impl moved from "fused" to "auto" (-> pallas for the
    kernelized rules).  Pallas follows the GATHER masked semantics
    (impute-then-scale); for coordinate-wise rules fused is numerically
    the same path, but for weight-decomposable rules (krum, cge) fused
    folds the weights into the selection instead — so default-built
    krum/cge specs CHANGED masked behavior with this PR.  This test makes
    that switch loud: default == pallas == gather, and fused remains the
    intentionally different historical estimator reachable via
    impl="fused" (ByzantineConfig's default)."""
    n = 10
    g = data(n, 640, jnp.float32, 21)
    mask, w = mode_args("weighted", n, 4)
    for rule in ("krum", "cge"):
        default = make_spec(rule, f=F, n=n)
        assert default.impl == "pallas"
        out_d = default.aggregate(g, mask=mask, weights=w)
        out_g = make_spec(rule, f=F, impl="gather", n=n).aggregate(
            g, mask=mask, weights=w)
        out_f = make_spec(rule, f=F, impl="fused", n=n).aggregate(
            g, mask=mask, weights=w)
        assert_agree(out_d, out_g, jnp.float32, rule)
        assert float(jnp.max(jnp.abs(out_d - out_f))) > 1e-3, (
            f"{rule}: fused masked semantics unexpectedly collapsed into "
            "the gather/pallas semantics — update the make_spec docstring")
    # coordinate-wise rules: all three impls agree bit-for-bit
    for rule in ("coordinate_median", "trimmed_mean"):
        outs = [make_spec(rule, f=F, impl=i, n=n).aggregate(
            g, mask=mask, weights=w) for i in ("pallas", "gather", "fused")]
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      np.asarray(outs[1]), err_msg=rule)
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      np.asarray(outs[2]), err_msg=rule)


def test_cge_selection_is_bitwise():
    """What the (f, eps) guarantee rests on: the kernel eliminates exactly
    the rows the dense reference eliminates."""
    for n, d, seed in [(9, 512, 0), (12, 771, 1), (16, 1300, 2)]:
        g = data(n, d, jnp.float32, seed)
        gp, _ = _pad_d(g)
        w_kernel = cge_select(gram(gp), n - F)
        w_ref = ref.cge_select_ref(g, n - F)
        np.testing.assert_array_equal(np.asarray(w_kernel),
                                      np.asarray(w_ref), err_msg=str((n, d)))


def test_selection_survives_nonfinite_adversary():
    """An inf-coordinate gradient (the unbounded Byzantine row this
    library exists to defend against) turns its d2 row NaN; NaN compares
    False against everything, so a naive comparison-rank would hand EVERY
    NaN row rank 0 and silently average multiple rows.  The kernels must
    keep the selection cardinality exact and pick only finite rows."""
    n, d, f = 8, 512, 2
    g = data(n, d, jnp.float32, 12)
    g = g.at[1, 7].set(jnp.inf).at[5, 3].set(-jnp.inf)   # 2 hostile rows
    gp, _ = _pad_d(g)
    gr = gram(gp)
    w_krum = np.asarray(krum_select(gr, f))
    assert w_krum.sum() == 1.0 and set(np.unique(w_krum)) <= {0.0, 1.0}
    assert w_krum[1] == 0.0 and w_krum[5] == 0.0         # finite row wins
    w_cge = np.asarray(cge_select(gr, n - f))
    assert w_cge.sum() == n - f and set(np.unique(w_cge)) <= {0.0, 1.0}
    assert w_cge[1] == 0.0 and w_cge[5] == 0.0           # inf norms dropped
    # and through the spec engine: the aggregate stays finite
    for rule in ("krum", "cge"):
        out = make_spec(rule, f=f, impl="pallas", n=n).aggregate(g)
        assert bool(jnp.all(jnp.isfinite(out))), rule


def test_krum_selection_is_bitwise():
    for n, d, seed in [(9, 512, 0), (12, 771, 1), (16, 1300, 2)]:
        g = data(n, d, jnp.float32, seed)
        gp, _ = _pad_d(g)
        w_kernel = krum_select(gram(gp), F)
        w_ref = ref.krum_select_ref(g, F)
        np.testing.assert_array_equal(np.asarray(w_kernel),
                                      np.asarray(w_ref), err_msg=str((n, d)))


def test_selection_family_survives_nonfinite_adversary():
    """The selection family under inf-coordinate hostile rows: NaN
    distances (inf - inf) order LAST at the d2 level, candidate-
    constrained tie-breaks can never re-pick a removed row, and the
    one-hot applications where-zero rejected rows — so the kernels stay
    finite even where the DENSE references break (multi_krum's one score
    pass and mda's argmin both degrade to index/enumeration order once
    NaN poisons every comparison), which is why this is asserted against
    the defense contract, not against gather."""
    from repro.kernels.select import iterative_order, multi_krum_order
    n, d, f = 8, 512, 2
    g = data(n, d, jnp.float32, 12)
    g = g.at[1, 7].set(jnp.inf).at[5, 3].set(-jnp.inf)   # 2 hostile rows
    mask, w = mode_args("weighted", n, 3)
    for rule, hyper in [("multi_krum", {"m": 3}), ("m_krum", {"m": 3}),
                        ("bulyan", {}), ("mda", {})]:
        spec = make_spec(rule, f=f, impl="pallas", n=n, **hyper)
        out = spec.aggregate(g)
        assert bool(jnp.all(jnp.isfinite(out))), rule
    # masked/weighted: the imputed ghost row inherits the (poisoned)
    # delivered mean, so only the selection rules that keep < n - f rows
    # can still dodge every hostile row (mda must keep n - f and cannot)
    for rule, hyper in [("multi_krum", {"m": 3}), ("m_krum", {"m": 3}),
                        ("bulyan", {})]:
        spec = make_spec(rule, f=f, impl="pallas", n=n, **hyper)
        out = spec.aggregate(g, mask=mask, weights=w)
        assert bool(jnp.all(jnp.isfinite(out))), rule
    gp, _ = _pad_d(g)
    gr = gram(gp)
    for m in (2, 3):
        order = np.asarray(multi_krum_order(gr, f, m))
        assert sorted(order[order < n]) == list(range(m))
        order = np.asarray(iterative_order(gr, f, m))
        assert sorted(order[order < n]) == list(range(m))


# ---------------------------------------------------------------------------
# 2. raw-kernel parity vs the pure-jnp oracles in kernels/ref.py


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("stat,b", [("median", 0), ("trimmed_mean", 2)])
def test_coord_stat_matches_oracle(n, stat, b):
    g = data(n, 1024, jnp.float32, 5)
    out = coord_stat(g, stat, b=b)
    expect = (ref.median_from_sorted if stat == "median"
              else lambda s: ref.trimmed_mean_from_sorted(s, b))(
                  jnp.sort(g, axis=0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("stat,b", [("median", 0), ("trimmed_mean", 2)])
def test_masked_coord_stat_matches_oracle(n, stat, b, dtype):
    g = data(n, 1024, dtype, 6)
    mask, _ = mode_args("masked", n, 7)
    w = jax.random.uniform(jax.random.PRNGKey(8), (n,), minval=0.2,
                           maxval=1.0) * mask
    wn = w / jnp.sum(w)
    out = masked_coord_stat(g, mask.astype(jnp.float32), wn, stat, b=b)
    expect = ref.masked_stat_ref(g, mask, wn, stat, b=b)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


# ---------------------------------------------------------------------------
# 3. caps-driven auto-selection (the acceptance criterion)


def test_make_spec_auto_selects_pallas():
    for rule in RULES:
        assert pallas_available(rule), rule
        assert make_spec(rule, n=12, f=F).impl == "pallas", rule
    # non-kernelized rules keep the fused default ...
    for rule in ("mean", "geometric_median", "rfa", "median_of_means",
                 "zeno", "zeno_pp", "cgc", "phocas", "mean_around_median"):
        assert make_spec(rule, f=1).impl == "fused", rule
    # ... wrappers never kernelize themselves (the inner spec does)
    from repro.core.aggregators import clipped
    spec = clipped(make_spec("trimmed_mean", f=F), tau=1.0)
    assert spec.impl == "fused" and spec.inner.impl == "pallas"


def test_bulyan_pallas_gated_on_krum_base():
    """Only bulyan's classic krum base is Gram-derivable: impl="auto"
    silently keeps fused for other bases, explicit pallas raises at BUILD
    time (not inside jit)."""
    assert make_spec("bulyan", f=1).impl == "pallas"
    assert make_spec("bulyan", f=1, base="krum").impl == "pallas"
    assert make_spec("bulyan", f=1, base="mean").impl == "fused"
    with pytest.raises(ValueError, match="non-kernelized"):
        make_spec("bulyan", f=1, impl="pallas", base="mean")


def test_impl_override_and_validation():
    assert make_spec("trimmed_mean", f=F, impl="fused").impl == "fused"
    assert make_spec("trimmed_mean", f=F, impl="gather").impl == "gather"
    spec = make_spec("trimmed_mean", f=F).with_impl("gather")
    assert spec.impl == "gather"
    assert spec.with_impl("auto").impl == "pallas"
    with pytest.raises(ValueError, match="pallas"):
        make_spec("geometric_median", f=F, impl="pallas")
    with pytest.raises(ValueError, match="impl must be"):
        make_spec("trimmed_mean", f=F, impl="vectorized")


# ---------------------------------------------------------------------------
# 4. async-loop fault masks: parity along a simulated fault trace, and
#    fixed shapes => the jitted kernel path never retraces


def _fault_trace_weights(n, steps):
    from repro.simulator.async_loop import (SimConfig, plan_arrivals,
                                            staleness_weights)
    from repro.simulator.faults import CrashRecover, MessageDrop, Straggler
    sim = SimConfig(faults=(Straggler(dist="lognormal", scale=0.6),
                            CrashRecover(rate=0.15, mean_down=2.0),
                            MessageDrop(p=0.15)),
                    quorum=max(2, n - 3), max_staleness=3, seed=11)
    atrace = plan_arrivals(sim, n, steps)
    return atrace, staleness_weights(sim, atrace)


@pytest.mark.parametrize("rule", RULES)
def test_parity_under_async_fault_masks(rule):
    """Every step of a chaos trace (stragglers + crash/recover + message
    drops): the kernel path agrees with the gather path on exactly the
    quorum masks and staleness discounts the async loop would feed it."""
    n, d, steps = 8, 640, 12
    pa = make_spec(rule, f=2, impl="pallas", n=n)
    ga = make_spec(rule, f=2, impl="gather", n=n)
    atrace, contrib_w = _fault_trace_weights(n, steps)
    g = data(n, d, jnp.float32, 9)
    for t in range(steps):
        mask = jnp.asarray(atrace.contrib[t])
        if not bool(mask.any()):
            continue
        w = jnp.asarray(contrib_w[t])
        out = pa.aggregate(g, mask=mask, weights=w)
        expect = ga.aggregate(g, mask=mask, weights=w)
        assert_agree(out, expect, jnp.float32, rule)


def test_async_loop_end_to_end_parity():
    """The tentpole, end to end: the async training loop under a fault
    schedule produces BIT-IDENTICAL parameters with impl="pallas" and
    impl="gather" aggregators — the kernel path is a drop-in for the
    reference inside the jitted step (threaded state, quorum masks,
    staleness weights and all)."""
    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.optim import adamw, constant
    from repro.simulator.async_loop import SimConfig, async_train_loop
    from repro.simulator.faults import MessageDrop, Straggler
    from repro.training.step import ByzantineConfig

    cfg = get_config("paper-100m-smoke").replace(vocab_size=64,
                                                 dtype="float32")
    sim = SimConfig(faults=(Straggler(dist="lognormal", scale=0.7),
                            MessageDrop(p=0.15)),
                    quorum=6, max_staleness=3, seed=5)
    results = {}
    for impl in ("pallas", "gather"):
        ds = SyntheticLM(vocab_size=64, seq_len=8, n_agents=8,
                         per_agent_batch=1)
        bz = ByzantineConfig(
            n_agents=8, f=2, attack="sign_flip",
            aggregator=make_spec("trimmed_mean", f=2, impl=impl, n=8))
        # _force_general: every step runs the masked/weighted kernel path
        # (the path under test) and the sync fast path never compiles
        params, hist = async_train_loop(
            cfg, bz, adamw(constant(1e-3)), ds, steps=3, sim=sim,
            log_every=1, log_fn=lambda *_: None, _force_general=True)
        results[impl] = (params, hist)
    pa, ga = results["pallas"], results["gather"]
    for x, y in zip(jax.tree.leaves(pa[0]), jax.tree.leaves(ga[0])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert [m["loss"] for m in pa[1]] == [m["loss"] for m in ga[1]]


def test_fault_masks_do_not_retrace():
    """The masked kernels take the quorum mask and discounts as traced
    operands: 10 different fault-mask rows must reuse ONE compilation."""
    n, d = 8, 640
    spec = make_spec("trimmed_mean", f=2, impl="pallas", n=n)
    traces = []

    @jax.jit
    def step(g, mask, w):
        traces.append(1)                     # python side effect: tracing
        return spec.aggregate(g, mask=mask, weights=w)

    g = data(n, d, jnp.float32, 10)
    atrace, contrib_w = _fault_trace_weights(n, 10)
    for t in range(10):
        step(g, jnp.asarray(atrace.contrib[t]),
             jnp.asarray(contrib_w[t])).block_until_ready()
    assert len(traces) == 1, f"kernel path retraced {len(traces)} times"


# ---------------------------------------------------------------------------
# 5. the zero-copy flat pipeline: imputation-free masked kernels, the
#    flat-arena engine, and the compile-count gate on the real loops


def _collect_shapes(jaxpr, banned=("select_n", "broadcast_in_dim")):
    """Output shapes of every banned-primitive eqn OUTSIDE kernel bodies
    (recursion stops at pallas_call: the tile-level where IS the fusion —
    what must never exist is a full-size imputed copy feeding the
    kernel)."""
    import jax.core as jcore
    hits = []

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                continue
            if eqn.primitive.name in banned:
                hits.extend(tuple(v.aval.shape) for v in eqn.outvars)
            for val in eqn.params.values():
                for sub in (val if isinstance(val, (list, tuple)) else
                            (val,)):
                    if isinstance(sub, jcore.ClosedJaxpr):
                        walk(sub.jaxpr)
                    elif isinstance(sub, jcore.Jaxpr):
                        walk(sub)
    walk(jaxpr.jaxpr)
    return hits


@pytest.mark.parametrize("rule", ["krum", "cge", "multi_krum", "bulyan",
                                  "coordinate_median", "sign_sgd",
                                  "sparse_mean"])
def test_masked_pallas_is_imputation_free(rule):
    """The acceptance gate of the masked selection family: no full-size
    broadcast or where precedes the kernel call — the imputed (n, d)
    stack is never materialized.  The same detector run on the gather
    path DOES fire (it imputes at tree level), proving the check bites."""
    n, d = 8, 640
    g = data(n, d, jnp.float32, 4)
    mask, w = mode_args("weighted", n, 5)

    def big(spec):
        jaxpr = jax.make_jaxpr(
            lambda g, m, w: spec.aggregate(g, mask=m, weights=w))(g, mask, w)
        return [s for s in _collect_shapes(jaxpr)
                if len(s) == 2 and s[0] == n and s[1] >= d]

    pa = make_spec(rule, f=2, impl="pallas", n=n)
    assert not big(pa), f"{rule}: imputed (n, d) copy materialized: {big(pa)}"
    ga = make_spec(rule, f=2, impl="gather", n=n)
    if rule == "sign_sgd":
        # the arrived-only vote never imputes, even at gather level — its
        # engine fallback materializes the (n, d) masked vote product
        # instead, so the teeth check looks for that
        jaxpr = jax.make_jaxpr(
            lambda g, m, w: ga.aggregate(g, mask=m, weights=w))(g, mask, w)
        muls = [s for s in _collect_shapes(jaxpr, banned=("mul",))
                if len(s) == 2 and s[0] == n and s[1] >= d]
        assert muls, "detector lost its teeth: gather vote product not seen"
    else:
        assert big(ga), "detector lost its teeth: gather imputation not seen"


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("rule", RULES)
def test_aggregate_flat_matches_tree_engine(rule, mode, dtype):
    """spec.aggregate_flat on the pre-raveled arena == spec.aggregate on
    the tree, bit-for-bit, for both dense impls — the loops' flat
    pipeline cannot change a single bit of the n-static paths.  bf16
    covers the agg_dtype exchange trees of the async loop (the masked
    scale must round through the arena dtype exactly like the tree
    engine's per-leaf rounding)."""
    n, d = 9, 640
    g = data(n, d, dtype, 6)
    tree = {"a": g[:, :123].reshape(n, 3, 41), "b": {"c": g[:, 123:]}}
    mask, w = mode_args(mode, n, 7)
    for impl in ("pallas", "gather"):
        spec = make_spec(rule, f=F, impl=impl, n=n, **HYPER.get(rule, {}))
        assert spec.flat_capable
        expect = spec.aggregate(tree, mask=mask, weights=w)
        plan = FlatPlan.for_tree(tree)
        assert jnp.dtype(plan.uniform_dtype) == jnp.dtype(dtype)
        vec = spec.aggregate_flat(plan.ravel(tree), mask=mask, weights=w)
        got = plan.unravel(vec)
        for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(expect)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"{rule}/{impl}")


def test_flat_capability_boundaries():
    from repro.core.aggregators import clipped
    assert not make_spec("trimmed_mean", f=1, impl="fused").flat_capable
    assert not clipped(make_spec("krum", f=1), tau=1.0).flat_capable
    assert not make_spec("zeno_pp", f=1).flat_capable      # stateful
    assert not make_spec("mean", f=0, impl="gather").flat_capable  # custom
    with pytest.raises(ValueError, match="flat"):
        make_spec("trimmed_mean", f=1, impl="fused").aggregate_flat(
            jnp.zeros((4, 8)))


def test_unravel_plan_is_cached_and_bitwise():
    """tree_unravel_like now rides the shared FlatPlan: offsets computed
    once per structure (same object on repeat calls), output bitwise
    identical to the legacy per-call np.prod loop."""
    from repro.core.aggregators import tree_unravel_like
    n = 6
    proto = {"a": jnp.zeros((n, 3, 5), jnp.bfloat16),
             "b": [jnp.zeros((n, 7), jnp.float32)]}
    assert FlatPlan.for_tree(proto) is FlatPlan.for_tree(proto)
    plan = FlatPlan.for_tree(proto)
    assert plan.total == 22 and plan.offsets == (0, 15)
    vec = jax.random.normal(jax.random.PRNGKey(0), (22,))
    out = tree_unravel_like(vec, proto)
    np.testing.assert_array_equal(
        np.asarray(out["a"]),
        np.asarray(vec[:15].reshape(3, 5).astype(jnp.bfloat16)))
    np.testing.assert_array_equal(np.asarray(out["b"][0]),
                                  np.asarray(vec[15:]))


def test_masked_pallas_mixed_dtype_warns_once():
    """Satellite (updated): PAIRWISE kernels need one exchange dtype for
    the whole row (the Gram couples every column), so a mixed-dtype tree
    still falls back to the imputed tree path — and says so, exactly once
    (deduped against jax's warning-filter churn), numerically on the
    documented law.  Coordwise rules no longer warn: they route per-dtype
    SEGMENTS through the masked kernel (see the next test)."""
    from repro.core import aggregators as A
    n = 8
    grads = {"a": data(n, 64, jnp.float32, 8),
             "b": data(n, 40, jnp.bfloat16, 9)}
    mask, w = mode_args("weighted", n, 2)
    spec = make_spec("krum", f=2, impl="pallas", n=n)
    # the dedup set is process-global: clear this test's keys so the
    # assertion is independent of what ran before in the same process
    for key in [k for k in A._WARNED_ONCE
                if k[0] == "masked-pallas-mixed-dtype"]:
        A._WARNED_ONCE.discard(key)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = spec.aggregate(grads, mask=mask, weights=w)
        spec.aggregate(grads, mask=mask, weights=w)      # second call
    hits = [r for r in rec if "mixed dtypes" in str(r.message)]
    assert len(hits) == 1, [str(r.message) for r in rec]
    expect = make_spec("krum", f=2, impl="gather",
                       n=n).aggregate(grads, mask=mask, weights=w)
    for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_masked_pallas_mixed_dtype_coordwise_segments():
    """Coordwise rules stopped warning on mixed-dtype trees: each
    uniform-dtype SEGMENT rides the masked kernel (the per-coordinate law
    never couples columns, so splitting is exact) and the result matches
    the gather reference leaf-for-leaf — no warning fired."""
    from repro.core import aggregators as A
    n = 8
    grads = {"a": data(n, 64, jnp.float32, 8),
             "b": data(n, 40, jnp.bfloat16, 9)}
    mask, w = mode_args("weighted", n, 2)
    for key in [k for k in A._WARNED_ONCE
                if k[0] == "masked-pallas-mixed-dtype"]:
        A._WARNED_ONCE.discard(key)
    for rule in ("coordinate_median", "trimmed_mean", "sign_sgd",
                 "sparse_mean"):
        spec = make_spec(rule, f=2, impl="pallas", n=n)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            out = spec.aggregate(grads, mask=mask, weights=w)
        assert not [r for r in rec if "mixed dtypes" in str(r.message)], rule
        expect = make_spec(rule, f=2, impl="gather", n=n).aggregate(
            grads, mask=mask, weights=w)
        for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=rule)


def test_flat_loops_add_zero_recompiles_under_churn_and_faults():
    """The tracecount gate of the flat pipeline: a 200-step run under
    membership churn + stragglers + message drops, aggregated by an
    elastic PALLAS spec through the flat-arena async loop, compiles the
    step at most once per bucket — exactly the per-leaf loops' historical
    bound, so the arena threading added ZERO compiles."""
    from repro.configs import get_config
    from repro.core.aggregators import elastic, frac
    from repro.core.tracecount import TRACE_COUNTS
    from repro.data import SyntheticLM
    from repro.optim import adamw, constant
    from repro.simulator import (Churn, Join, MessageDrop, SimConfig,
                                 Straggler, async_train_loop)
    from repro.training import ByzantineConfig

    cfg = get_config("paper-100m-smoke").replace(vocab_size=32,
                                                 dtype="float32")
    ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=8, per_agent_batch=1)
    el = elastic(8, buckets=(4, 6, 8))
    spec = make_spec("krum", f=frac(0.25), n=el)
    for b in el.buckets:
        assert spec.respecialize(b).impl == "pallas"
        assert spec.respecialize(b).flat_capable
    bz = ByzantineConfig(n_agents=8, f=2, aggregator=spec,
                         attack="sign_flip")
    sim = SimConfig(faults=(Join(agents=(7,), at=10),
                            Churn(rate=0.2, mean_out=2.0,
                                  agents=(1, 2, 3, 4)),
                            Straggler(dist="lognormal", scale=0.5),
                            MessageDrop(p=0.1)),
                    quorum=3, max_staleness=3, seed=0)
    before = TRACE_COUNTS["async_step"]
    before_sync = TRACE_COUNTS["train_step"]
    _, h = async_train_loop(cfg, bz, adamw(constant(1e-3)), ds, steps=200,
                            sim=sim, log_every=100, log_fn=lambda *_: None)
    assert np.isfinite(h[-1]["loss"])
    used = TRACE_COUNTS["async_step"] - before
    used_sync = TRACE_COUNTS["train_step"] - before_sync
    assert used + used_sync <= len(el.buckets) + 1, (used, used_sync)


# ---------------------------------------------------------------------------
# 6. compressed exchange: quantized arenas (int8 / fp8 + per-row scale
#    sidecar), the scaled in-tile-dequant kernels, and the zero-total
#    weight guards


from repro.core.flat import (QUANT_DTYPES, dequantize_rows,  # noqa: E402
                             fake_quantize, quantize_rows)

SCALED_RULES = ["coordinate_median", "trimmed_mean", "sign_sgd",
                "sparse_mean"]
QDTYPES = sorted(QUANT_DTYPES)


@pytest.mark.parametrize("qdt", QDTYPES)
def test_quantize_roundtrip_is_the_dequant_law(qdt):
    """quantize_rows -> dequantize_rows IS fake_quantize, bit-for-bit:
    the sidecar decode ``codes * scale[:, None]`` is THE parity oracle
    every in-tile dequant is asserted against."""
    n, d = 12, 771
    g = data(n, d, jnp.float32, 13)
    codes, qs = quantize_rows(g, jnp.dtype(qdt))
    assert codes.dtype == jnp.dtype(qdt) and qs.shape == (n,)
    assert bool(jnp.all(qs > 0))
    deq = dequantize_rows(codes, qs)
    np.testing.assert_array_equal(
        np.asarray(deq), np.asarray(fake_quantize(g, jnp.dtype(qdt))))
    if qdt == "int8":
        # symmetric round-to-nearest: error bounded by half a code step
        err = np.abs(np.asarray(deq) - np.asarray(g))
        assert float(np.max(err / np.asarray(qs)[:, None])) <= 0.5 + 1e-6


@pytest.mark.parametrize("qdt", QDTYPES)
def test_quantize_zero_row_guard(qdt):
    """An all-zero gradient row (a frozen / just-joined agent) must not
    divide by its zero amax: scale pins to 1.0, codes to 0, decode to 0."""
    g = jnp.zeros((4, 640), jnp.float32).at[1].set(
        data(1, 640, jnp.float32, 14)[0])
    codes, qs = quantize_rows(g, jnp.dtype(qdt))
    assert np.isfinite(np.asarray(qs)).all()
    np.testing.assert_array_equal(np.asarray(qs[0]), 1.0)
    np.testing.assert_array_equal(
        np.asarray(dequantize_rows(codes, qs)[0]), 0.0)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("qdt", QDTYPES)
@pytest.mark.parametrize("rule", SCALED_RULES)
def test_scaled_flat_matches_gather_dequant(rule, qdt, mode):
    """The tentpole parity gate: a quantized arena + per-row scale through
    ``impl="pallas"`` (dequant inside the tile) agrees BIT-FOR-BIT with
    ``impl="gather"`` (engine-level dequant), which itself agrees with
    running the rule on the explicitly dequantized rows — across odd/even
    n and the plain/masked/weighted modes."""
    for n in NS:
        g = data(n, 771, jnp.float32, 3)
        codes, qs = quantize_rows(g, jnp.dtype(qdt))
        mask, w = mode_args(mode, n, 5)
        pa = make_spec(rule, f=F, impl="pallas", n=n)
        ga = make_spec(rule, f=F, impl="gather", n=n)
        out = pa.aggregate_flat(codes, mask=mask, weights=w, scale=qs)
        expect = ga.aggregate_flat(codes, mask=mask, weights=w, scale=qs)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect),
                                      err_msg=f"{rule}/{qdt}/{mode}/n={n}")
        ref_out = ga.aggregate_flat(dequantize_rows(codes, qs),
                                    mask=mask, weights=w)
        np.testing.assert_array_equal(np.asarray(expect),
                                      np.asarray(ref_out),
                                      err_msg=f"{rule}/{qdt}/{mode}/n={n}")


@pytest.mark.parametrize("rule", SCALED_RULES)
def test_scaled_masked_pallas_is_dequant_copy_free(rule):
    """The acceptance gate of the int8/fp8 arena: NO dequantized (n, P)
    f32 copy is materialized outside the kernel — the cast and the
    scale-multiply live inside the tile.  The same detector run on the
    gather path DOES fire (it dequantizes at engine level), proving the
    check bites."""
    n, d = 8, 640
    g = data(n, d, jnp.float32, 4)
    codes, qs = quantize_rows(g, jnp.dtype("int8"))
    mask, w = mode_args("weighted", n, 5)
    banned = ("convert_element_type", "mul", "select_n", "broadcast_in_dim")

    def big(spec):
        jaxpr = jax.make_jaxpr(
            lambda c, s, m, w: spec.aggregate_flat(c, mask=m, weights=w,
                                                   scale=s))(codes, qs,
                                                             mask, w)
        return [s for s in _collect_shapes(jaxpr, banned=banned)
                if len(s) == 2 and s[0] == n and s[1] >= d
                ]

    pa = make_spec(rule, f=F, impl="pallas", n=n)
    assert not big(pa), (
        f"{rule}: dequantized (n, P) copy materialized: {big(pa)}")
    ga = make_spec(rule, f=F, impl="gather", n=n)
    assert big(ga), "detector lost its teeth: gather dequant not seen"


def test_scaled_fallback_rules_warn_once_and_stay_on_law():
    """Rules WITHOUT a scaled kernel (krum here) still accept a quantized
    arena through the engine-level dequant fallback — with a one-time
    warning naming the in-tile-capable rules — and stay bit-for-bit on
    the dequantize-then-aggregate law."""
    from repro.core import aggregators as A
    n = 8
    g = data(n, 640, jnp.float32, 15)
    codes, qs = quantize_rows(g, jnp.dtype("int8"))
    mask, w = mode_args("weighted", n, 6)
    spec = make_spec("krum", f=F, impl="pallas", n=n)
    for key in [k for k in A._WARNED_ONCE if k[0] == "flat-scaled-dequant"]:
        A._WARNED_ONCE.discard(key)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = spec.aggregate_flat(codes, mask=mask, weights=w, scale=qs)
        spec.aggregate_flat(codes, mask=mask, weights=w, scale=qs)
    hits = [r for r in rec if "no scaled" in str(r.message)]
    assert len(hits) == 1, [str(r.message) for r in rec]
    expect = spec.aggregate_flat(dequantize_rows(codes, qs),
                                 mask=mask, weights=w)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_masked_zero_total_weight_is_finite_zero():
    """Satellite pin: with every delivered weight zero (reachable under
    sparse/dropout weighting: coord_sent * dataset_size can vanish) the
    masked engine's tot/cnt scale used to go 0/eps-garbage — it now
    returns an exact finite zero, on the tree AND flat paths."""
    n, d = 8, 640
    g = data(n, d, jnp.float32, 2)
    mask = jnp.ones((n,), bool).at[jnp.arange(4)].set(False)
    w0 = jnp.zeros((n,))
    for rule in ("coordinate_median", "trimmed_mean", "sign_sgd",
                 "sparse_mean"):
        for impl in ("pallas", "gather"):
            spec = make_spec(rule, f=F, impl=impl, n=n)
            out = spec.aggregate(g, mask=mask, weights=w0)
            np.testing.assert_array_equal(np.asarray(out),
                                          np.zeros((d,), np.float32),
                                          err_msg=f"{rule}/{impl}/tree")
            vec = spec.aggregate_flat(g, mask=mask, weights=w0)
            np.testing.assert_array_equal(np.asarray(vec),
                                          np.zeros((d,), np.float32),
                                          err_msg=f"{rule}/{impl}/flat")


def test_quantized_flat_loop_compiles_once_per_bucket():
    """The compressed acceptance gate: the SAME 200-step churn + fault
    run as above, now with an int8 exchange dtype (agg_dtype="int8") —
    per-row quantize at ravel, scaled in-tile-dequant kernels at
    aggregate — still compiles at most once per elastic bucket: the
    quantize/scale threading added ZERO compiles."""
    from repro.configs import get_config
    from repro.core.aggregators import elastic, frac
    from repro.core.tracecount import TRACE_COUNTS
    from repro.data import SyntheticLM
    from repro.optim import adamw, constant
    from repro.simulator import (Churn, Join, MessageDrop, SimConfig,
                                 Straggler, async_train_loop)
    from repro.training import ByzantineConfig

    cfg = get_config("paper-100m-smoke").replace(vocab_size=32,
                                                 dtype="float32")
    ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=8, per_agent_batch=1)
    el = elastic(8, buckets=(4, 6, 8))
    spec = make_spec("trimmed_mean", f=frac(0.25), n=el)
    for b in el.buckets:
        assert spec.respecialize(b).impl == "pallas"
        assert spec.respecialize(b).flat_capable
    bz = ByzantineConfig(n_agents=8, f=2, aggregator=spec,
                         attack="sign_flip", agg_dtype="int8")
    sim = SimConfig(faults=(Join(agents=(7,), at=10),
                            Churn(rate=0.2, mean_out=2.0,
                                  agents=(1, 2, 3, 4)),
                            Straggler(dist="lognormal", scale=0.5),
                            MessageDrop(p=0.1)),
                    quorum=3, max_staleness=3, seed=0)
    before = TRACE_COUNTS["async_step"]
    before_sync = TRACE_COUNTS["train_step"]
    _, h = async_train_loop(cfg, bz, adamw(constant(1e-3)), ds, steps=200,
                            sim=sim, log_every=100, log_fn=lambda *_: None)
    assert np.isfinite(h[-1]["loss"])
    used = TRACE_COUNTS["async_step"] - before
    used_sync = TRACE_COUNTS["train_step"] - before_sync
    assert used + used_sync <= len(el.buckets) + 1, (used, used_sync)


# ---------------------------------------------------------------------------
# 10. centered_clip's fused MAC (PR 10): the kernel computes one
# fixed-point step of centered clipping; the scalar clip-radius stage
# stays outside (cross-tile row norms), so the kernel law is exactly
# (1 - sum lam) v + lam^T X — pinned here against that expression, with
# the lam > 0 gate keeping dead-row inf/NaN out of the accumulate


def test_clipped_weighted_sum_matches_the_law():
    from repro.kernels import clipped_weighted_sum

    n, d = 10, 512
    g = data(n, d, jnp.float32, 0)
    v = jax.random.normal(jax.random.PRNGKey(5), (d,))
    lam = jax.random.uniform(jax.random.PRNGKey(6), (n,),
                             minval=0.0, maxval=0.12)
    lam = lam.at[jnp.array([1, 4])].set(0.0)
    # a zeroed-lam row carrying non-finite payload must not leak
    g = g.at[1].set(jnp.inf).at[4].set(jnp.nan)
    out = clipped_weighted_sum(lam, g, v, interpret=True)
    xf = jnp.where((lam > 0.0)[:, None], g, 0.0)
    ref_out = (1.0 - jnp.sum(lam)) * v + lam @ xf
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("mode", MODES)
def test_centered_clip_flat_pallas_matches_gather(mode):
    """impl="pallas" routes centered_clip's per-iteration MAC through the
    fused kernel (explicit opt-in — auto keeps the dense body); the full
    fixed-point iterate must agree with the gather engine to fp32
    accumulation tolerance on tile-aligned P and fall back BIT-FOR-BIT
    on non-multiple-of-tile P (shared dense body)."""
    n = 12
    for d, bitwise in ((512, False), (771, True)):
        g = data(n, d, jnp.float32, 1)
        v = jax.random.normal(jax.random.PRNGKey(7), (d,))
        mask, w = mode_args(mode, n, 1)
        sp = make_spec("centered_clip", f=F, n=n, tau=1.0, impl="pallas")
        sg = make_spec("centered_clip", f=F, n=n, tau=1.0, impl="gather")
        st = {"server_grad": v}
        op = np.asarray(sp.aggregate_flat(g, mask=mask, weights=w,
                                          state=st))
        og = np.asarray(sg.aggregate_flat(g, mask=mask, weights=w,
                                          state=st))
        if bitwise:
            np.testing.assert_array_equal(op, og)
        else:
            np.testing.assert_allclose(op, og, rtol=3e-6, atol=3e-6)
