"""Kernel-dispatch parity suite: ``impl="pallas"`` vs the gather-path
reference, for every kernelized rule x {plain, masked, weighted}.

The exactness bar comes from the survey's resilience story (CGE's provable
(f, eps) guarantees, the approximate-BFT line): a kernel that silently
disagrees with the reference rule voids the guarantee, so agreement is
asserted BIT-FOR-BIT for fp32 wherever the two paths share the reference
arithmetic:

  * coordinate_median / trimmed_mean — identical order statistics (the
    kernels pin the reduce order to the reference's, see coord_stats);
  * krum — one-hot application returns exactly the selected row's bits;
  * cge — the SELECTION mask is asserted bit-for-bit; the application sums
    the selected rows in index order while the dense reference sums them
    in norm order, so the averaged output is asserted to ulp-level
    tolerance (FP addition is not associative; the selected SET is what
    the (f, eps) guarantee depends on).

bfloat16 stacks are asserted to bf16-resolution tolerance.  Fuzzing is
seeded ``jax.random`` grids (no ``hypothesis`` here — not installed; the
importorskip pattern is reserved for optional deps) over odd/even n and
tile-aligned / non-multiple-of-block d, plus fault-schedule-driven quorum
masks from the async simulator and a retrace counter proving fixed-shape
masks never recompile the kernel path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import make_spec, pallas_available
from repro.kernels import ref
from repro.kernels.coord_stats import coord_stat
from repro.kernels.masked import masked_coord_stat
from repro.kernels.ops import _pad_d
from repro.kernels.pairwise import gram
from repro.kernels.select import cge_select, krum_select

RULES = ["coordinate_median", "trimmed_mean", "krum", "cge"]
NS = [9, 12]                       # odd / even agent counts
DS = [512, 771]                    # exact tile / non-multiple-of-block
DTYPES = [jnp.float32, jnp.bfloat16]
MODES = ["plain", "masked", "weighted"]
SEEDS = [0, 1]
F = 2

# rules whose pallas OUTPUT is bit-for-bit with the gather path in fp32
# (cge: selection bitwise, application within ulp — see module docstring)
BITWISE_RULES = {"coordinate_median", "trimmed_mean", "krum"}


def data(n, d, dtype, seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 2.0
    return g.astype(dtype)


def mode_args(mode, n, seed):
    """(mask, weights) for one fuzz case; masks always keep >= n - F rows."""
    if mode == "plain":
        return None, None
    k1, k2 = jax.random.split(jax.random.PRNGKey(100 + seed))
    drop = jax.random.choice(k1, n, shape=(F,), replace=False)
    mask = jnp.ones((n,), bool).at[drop].set(False)
    if mode == "masked":
        return mask, None
    w = jax.random.uniform(k2, (n,), minval=0.3, maxval=1.0)
    return mask, w


def assert_agree(out, ref_out, dtype, rule):
    a, b = np.asarray(out), np.asarray(ref_out)
    assert a.dtype == b.dtype
    if dtype == jnp.float32 and rule in BITWISE_RULES:
        np.testing.assert_array_equal(a, b)
    elif dtype == jnp.float32:
        np.testing.assert_allclose(a, b, rtol=3e-6, atol=3e-6)
    else:                                      # bf16 resolution
        np.testing.assert_allclose(a.astype(np.float32),
                                   b.astype(np.float32),
                                   rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# 1. spec-level parity: impl="pallas" vs impl="gather", all modes


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("d", DS)
@pytest.mark.parametrize("rule", RULES)
def test_pallas_matches_gather_spec(rule, n, d, dtype, mode):
    pa = make_spec(rule, f=F, impl="pallas", n=n)
    ga = make_spec(rule, f=F, impl="gather", n=n)
    for seed in SEEDS:
        g = data(n, d, dtype, seed)
        mask, w = mode_args(mode, n, seed)
        out = pa.aggregate(g, mask=mask, weights=w)
        expect = ga.aggregate(g, mask=mask, weights=w)
        assert_agree(out, expect, dtype, rule)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("rule", ["coordinate_median", "trimmed_mean"])
def test_pallas_matches_gather_on_pytrees(rule, mode):
    """The fused masked kernel path also runs on raveled pytrees (the
    training loops' actual gradient structure)."""
    n = 10
    grads = {"a": data(n, 5 * 7, jnp.float32, 3).reshape(n, 5, 7),
             "b": {"c": data(n, 11, jnp.float32, 4)}}
    mask, w = mode_args(mode, n, 0)
    out = make_spec(rule, f=F, impl="pallas").aggregate(
        grads, mask=mask, weights=w)
    expect = make_spec(rule, f=F, impl="gather").aggregate(
        grads, mask=mask, weights=w)
    for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_masked_semantics_of_the_new_default_are_pinned():
    """The default impl moved from "fused" to "auto" (-> pallas for the
    kernelized rules).  Pallas follows the GATHER masked semantics
    (impute-then-scale); for coordinate-wise rules fused is numerically
    the same path, but for weight-decomposable rules (krum, cge) fused
    folds the weights into the selection instead — so default-built
    krum/cge specs CHANGED masked behavior with this PR.  This test makes
    that switch loud: default == pallas == gather, and fused remains the
    intentionally different historical estimator reachable via
    impl="fused" (ByzantineConfig's default)."""
    n = 10
    g = data(n, 640, jnp.float32, 21)
    mask, w = mode_args("weighted", n, 4)
    for rule in ("krum", "cge"):
        default = make_spec(rule, f=F, n=n)
        assert default.impl == "pallas"
        out_d = default.aggregate(g, mask=mask, weights=w)
        out_g = make_spec(rule, f=F, impl="gather", n=n).aggregate(
            g, mask=mask, weights=w)
        out_f = make_spec(rule, f=F, impl="fused", n=n).aggregate(
            g, mask=mask, weights=w)
        assert_agree(out_d, out_g, jnp.float32, rule)
        assert float(jnp.max(jnp.abs(out_d - out_f))) > 1e-3, (
            f"{rule}: fused masked semantics unexpectedly collapsed into "
            "the gather/pallas semantics — update the make_spec docstring")
    # coordinate-wise rules: all three impls agree bit-for-bit
    for rule in ("coordinate_median", "trimmed_mean"):
        outs = [make_spec(rule, f=F, impl=i, n=n).aggregate(
            g, mask=mask, weights=w) for i in ("pallas", "gather", "fused")]
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      np.asarray(outs[1]), err_msg=rule)
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      np.asarray(outs[2]), err_msg=rule)


def test_cge_selection_is_bitwise():
    """What the (f, eps) guarantee rests on: the kernel eliminates exactly
    the rows the dense reference eliminates."""
    for n, d, seed in [(9, 512, 0), (12, 771, 1), (16, 1300, 2)]:
        g = data(n, d, jnp.float32, seed)
        gp, _ = _pad_d(g)
        w_kernel = cge_select(gram(gp), n - F)
        w_ref = ref.cge_select_ref(g, n - F)
        np.testing.assert_array_equal(np.asarray(w_kernel),
                                      np.asarray(w_ref), err_msg=str((n, d)))


def test_selection_survives_nonfinite_adversary():
    """An inf-coordinate gradient (the unbounded Byzantine row this
    library exists to defend against) turns its d2 row NaN; NaN compares
    False against everything, so a naive comparison-rank would hand EVERY
    NaN row rank 0 and silently average multiple rows.  The kernels must
    keep the selection cardinality exact and pick only finite rows."""
    n, d, f = 8, 512, 2
    g = data(n, d, jnp.float32, 12)
    g = g.at[1, 7].set(jnp.inf).at[5, 3].set(-jnp.inf)   # 2 hostile rows
    gp, _ = _pad_d(g)
    gr = gram(gp)
    w_krum = np.asarray(krum_select(gr, f))
    assert w_krum.sum() == 1.0 and set(np.unique(w_krum)) <= {0.0, 1.0}
    assert w_krum[1] == 0.0 and w_krum[5] == 0.0         # finite row wins
    w_cge = np.asarray(cge_select(gr, n - f))
    assert w_cge.sum() == n - f and set(np.unique(w_cge)) <= {0.0, 1.0}
    assert w_cge[1] == 0.0 and w_cge[5] == 0.0           # inf norms dropped
    # and through the spec engine: the aggregate stays finite
    for rule in ("krum", "cge"):
        out = make_spec(rule, f=f, impl="pallas", n=n).aggregate(g)
        assert bool(jnp.all(jnp.isfinite(out))), rule


def test_krum_selection_is_bitwise():
    for n, d, seed in [(9, 512, 0), (12, 771, 1), (16, 1300, 2)]:
        g = data(n, d, jnp.float32, seed)
        gp, _ = _pad_d(g)
        w_kernel = krum_select(gram(gp), F)
        w_ref = ref.krum_select_ref(g, F)
        np.testing.assert_array_equal(np.asarray(w_kernel),
                                      np.asarray(w_ref), err_msg=str((n, d)))


# ---------------------------------------------------------------------------
# 2. raw-kernel parity vs the pure-jnp oracles in kernels/ref.py


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("stat,b", [("median", 0), ("trimmed_mean", 2)])
def test_coord_stat_matches_oracle(n, stat, b):
    g = data(n, 1024, jnp.float32, 5)
    out = coord_stat(g, stat, b=b)
    expect = (ref.median_from_sorted if stat == "median"
              else lambda s: ref.trimmed_mean_from_sorted(s, b))(
                  jnp.sort(g, axis=0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("stat,b", [("median", 0), ("trimmed_mean", 2)])
def test_masked_coord_stat_matches_oracle(n, stat, b, dtype):
    g = data(n, 1024, dtype, 6)
    mask, _ = mode_args("masked", n, 7)
    w = jax.random.uniform(jax.random.PRNGKey(8), (n,), minval=0.2,
                           maxval=1.0) * mask
    wn = w / jnp.sum(w)
    out = masked_coord_stat(g, mask.astype(jnp.float32), wn, stat, b=b)
    expect = ref.masked_stat_ref(g, mask, wn, stat, b=b)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


# ---------------------------------------------------------------------------
# 3. caps-driven auto-selection (the acceptance criterion)


def test_make_spec_auto_selects_pallas():
    for rule in RULES:
        assert pallas_available(rule), rule
        assert make_spec(rule, n=12, f=F).impl == "pallas", rule
    # non-kernelized rules keep the fused default ...
    for rule in ("mean", "mda", "geometric_median", "bulyan", "zeno_pp"):
        assert make_spec(rule, f=1).impl == "fused", rule
    # ... wrappers never kernelize themselves (the inner spec does)
    from repro.core.aggregators import clipped
    spec = clipped(make_spec("trimmed_mean", f=F), tau=1.0)
    assert spec.impl == "fused" and spec.inner.impl == "pallas"


def test_impl_override_and_validation():
    assert make_spec("trimmed_mean", f=F, impl="fused").impl == "fused"
    assert make_spec("trimmed_mean", f=F, impl="gather").impl == "gather"
    spec = make_spec("trimmed_mean", f=F).with_impl("gather")
    assert spec.impl == "gather"
    assert spec.with_impl("auto").impl == "pallas"
    with pytest.raises(ValueError, match="pallas"):
        make_spec("geometric_median", f=F, impl="pallas")
    with pytest.raises(ValueError, match="impl must be"):
        make_spec("trimmed_mean", f=F, impl="vectorized")


# ---------------------------------------------------------------------------
# 4. async-loop fault masks: parity along a simulated fault trace, and
#    fixed shapes => the jitted kernel path never retraces


def _fault_trace_weights(n, steps):
    from repro.simulator.async_loop import (SimConfig, plan_arrivals,
                                            staleness_weights)
    from repro.simulator.faults import CrashRecover, MessageDrop, Straggler
    sim = SimConfig(faults=(Straggler(dist="lognormal", scale=0.6),
                            CrashRecover(rate=0.15, mean_down=2.0),
                            MessageDrop(p=0.15)),
                    quorum=max(2, n - 3), max_staleness=3, seed=11)
    atrace = plan_arrivals(sim, n, steps)
    return atrace, staleness_weights(sim, atrace)


@pytest.mark.parametrize("rule", RULES)
def test_parity_under_async_fault_masks(rule):
    """Every step of a chaos trace (stragglers + crash/recover + message
    drops): the kernel path agrees with the gather path on exactly the
    quorum masks and staleness discounts the async loop would feed it."""
    n, d, steps = 8, 640, 12
    pa = make_spec(rule, f=2, impl="pallas", n=n)
    ga = make_spec(rule, f=2, impl="gather", n=n)
    atrace, contrib_w = _fault_trace_weights(n, steps)
    g = data(n, d, jnp.float32, 9)
    for t in range(steps):
        mask = jnp.asarray(atrace.contrib[t])
        if not bool(mask.any()):
            continue
        w = jnp.asarray(contrib_w[t])
        out = pa.aggregate(g, mask=mask, weights=w)
        expect = ga.aggregate(g, mask=mask, weights=w)
        assert_agree(out, expect, jnp.float32, rule)


def test_async_loop_end_to_end_parity():
    """The tentpole, end to end: the async training loop under a fault
    schedule produces BIT-IDENTICAL parameters with impl="pallas" and
    impl="gather" aggregators — the kernel path is a drop-in for the
    reference inside the jitted step (threaded state, quorum masks,
    staleness weights and all)."""
    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.optim import adamw, constant
    from repro.simulator.async_loop import SimConfig, async_train_loop
    from repro.simulator.faults import MessageDrop, Straggler
    from repro.training.step import ByzantineConfig

    cfg = get_config("paper-100m-smoke").replace(vocab_size=64,
                                                 dtype="float32")
    sim = SimConfig(faults=(Straggler(dist="lognormal", scale=0.7),
                            MessageDrop(p=0.15)),
                    quorum=6, max_staleness=3, seed=5)
    results = {}
    for impl in ("pallas", "gather"):
        ds = SyntheticLM(vocab_size=64, seq_len=8, n_agents=8,
                         per_agent_batch=1)
        bz = ByzantineConfig(
            n_agents=8, f=2, attack="sign_flip",
            aggregator=make_spec("trimmed_mean", f=2, impl=impl, n=8))
        # _force_general: every step runs the masked/weighted kernel path
        # (the path under test) and the sync fast path never compiles
        params, hist = async_train_loop(
            cfg, bz, adamw(constant(1e-3)), ds, steps=3, sim=sim,
            log_every=1, log_fn=lambda *_: None, _force_general=True)
        results[impl] = (params, hist)
    pa, ga = results["pallas"], results["gather"]
    for x, y in zip(jax.tree.leaves(pa[0]), jax.tree.leaves(ga[0])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert [m["loss"] for m in pa[1]] == [m["loss"] for m in ga[1]]


def test_fault_masks_do_not_retrace():
    """The masked kernels take the quorum mask and discounts as traced
    operands: 10 different fault-mask rows must reuse ONE compilation."""
    n, d = 8, 640
    spec = make_spec("trimmed_mean", f=2, impl="pallas", n=n)
    traces = []

    @jax.jit
    def step(g, mask, w):
        traces.append(1)                     # python side effect: tracing
        return spec.aggregate(g, mask=mask, weights=w)

    g = data(n, d, jnp.float32, 10)
    atrace, contrib_w = _fault_trace_weights(n, 10)
    for t in range(10):
        step(g, jnp.asarray(atrace.contrib[t]),
             jnp.asarray(contrib_w[t])).block_until_ready()
    assert len(traces) == 1, f"kernel path retraced {len(traces)} times"
