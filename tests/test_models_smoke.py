"""Per-architecture smoke tests: a REDUCED variant of the same family runs
one forward + one Byzantine train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import forward_train, init_params, loss_fn
from repro.optim import constant, sgd
from repro.training import ByzantineConfig, make_train_step

ALL = ASSIGNED_ARCHS + ["paper-100m"]


def smoke_batch(cfg, key, n_agents=0, b=2, t=16):
    lead = (n_agents, b) if n_agents else (b,)
    batch = {
        "tokens": jax.random.randint(key, lead + (t,), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, lead + (t,), 0, cfg.vocab_size),
    }
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "vision":
        batch["vision_embeds"] = 0.02 * jax.random.normal(
            key, lead + (cfg.frontend_tokens, cfg.d_model)).astype(dt)
    if cfg.frontend == "audio":
        batch["audio_embeds"] = 0.02 * jax.random.normal(
            key, lead + (cfg.encoder_seq, cfg.d_model)).astype(dt)
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_reduced_config_limits(arch):
    cfg = get_config(arch + "-smoke")
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch + "-smoke")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = smoke_batch(cfg, key)
    logits, aux = forward_train(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size), arch
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    loss = loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss)), arch


@pytest.mark.parametrize("arch", ALL)
def test_one_byzantine_train_step(arch):
    cfg = get_config(arch + "-smoke")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    bz = ByzantineConfig(n_agents=4, f=1, filter_name="coordinate_median",
                         attack="sign_flip")
    opt = sgd(constant(1e-2))
    step = jax.jit(make_train_step(cfg, bz, opt))
    batch = smoke_batch(cfg, key, n_agents=4)
    params2, opt_state, _, metrics = step(params, opt.init(params), None,
                                          batch, key)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    diff = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(params),
                               jax.tree.leaves(params2)))
    assert diff > 0.0
