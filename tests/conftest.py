import os
import sys

# src/ layout import without install; tests run on the single host CPU device
# (the 512-device pin lives ONLY in repro.launch.dryrun / subprocess tests).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
