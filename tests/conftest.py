import os
import sys

import pytest

# src/ layout import without install; tests run on the single host CPU device
# (the 512-device pin lives ONLY in repro.launch.dryrun / subprocess tests).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_collection_modifyitems(config, items):
    """End-to-end churn fuzz cases (seeded training runs under membership
    schedules) are auto-marked ``slow`` so the tier-1 `-m "not slow"` lane
    stays fast; the dedicated slow/membership CI jobs run them."""
    for item in items:
        if "churn_fuzz" in item.name or "full_leaderboard" in item.name:
            item.add_marker(pytest.mark.slow)
