"""Gradient-filter behaviour: survey Table 2 semantics + the Blanchard
impossibility (mean tolerates no Byzantine agent) + attack/defence matrix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import apply_attack, make_byzantine_mask
from repro.core.filters import FILTERS, compose
from repro.core.filters.dense import pairwise_sq_dists

N, F, D = 12, 2, 40
KEY = jax.random.PRNGKey(0)

ROBUST = ["krum", "multi_krum", "m_krum", "coordinate_median",
          "trimmed_mean", "phocas", "mean_around_median",
          "geometric_median", "median_of_means", "mda", "cge", "cgc",
          "bulyan", "rfa"]


def honest_cluster(key, n=N, d=D, sigma=0.1):
    center = jnp.linspace(-1, 1, d)
    return center + sigma * jax.random.normal(key, (n, d)), center


@pytest.mark.parametrize("name", ROBUST + ["mean"])
def test_shapes_and_finite(name):
    g, _ = honest_cluster(KEY)
    out = FILTERS[name](g, F)
    assert out.shape == (D,)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("name", ROBUST)
def test_close_to_center_without_attack(name):
    g, center = honest_cluster(KEY)
    out = FILTERS[name](g, F)
    assert float(jnp.linalg.norm(out - center)) < 0.5


@pytest.mark.parametrize("name", ROBUST)
@pytest.mark.parametrize("attack", ["large_value", "sign_flip", "gaussian"])
def test_robust_filters_bound_attack(name, attack):
    """With f of n corrupted by crude attacks, a robust filter stays near the
    honest center while the attacked mean does not.  Norm-based filters
    (CGE/CGC) cannot reject same-norm sign-flips — their guarantee is a
    positively-aligned descent direction (survey §3.3.2), asserted instead."""
    g, center = honest_cluster(KEY)
    mask = make_byzantine_mask(N, F)
    ga = apply_attack(attack, jax.random.PRNGKey(1), g, mask)
    out = FILTERS[name](ga, F)
    err = float(jnp.linalg.norm(out - center))
    err_mean = float(jnp.linalg.norm(FILTERS["mean"](ga, F) - center))
    if name in ("cge", "cgc") and attack == "sign_flip":
        align = float(out @ center) / float(center @ center)
        assert align > 0.3, (name, attack, align)
    else:
        assert err < 1.0, (name, attack, err)
    if attack == "large_value":
        assert err < err_mean / 100


def test_blanchard_impossibility():
    """[6]: no linear aggregation tolerates a single Byzantine agent — one
    adversary can steer the mean to an arbitrary point."""
    g, center = honest_cluster(KEY)
    target = 1e6 * jnp.ones((D,))
    bad = N * target - jnp.sum(g[1:], axis=0)
    ga = g.at[0].set(bad)
    out = FILTERS["mean"](ga, 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(target),
                               rtol=1e-3)
    # while e.g. krum ignores it entirely
    robust = FILTERS["krum"](ga, 1)
    assert float(jnp.linalg.norm(robust - center)) < 1.0


def test_krum_outputs_an_input():
    g, _ = honest_cluster(KEY)
    out = FILTERS["krum"](g, F)
    d = jnp.min(jnp.linalg.norm(g - out[None], axis=-1))
    assert float(d) < 1e-6


def test_multi_krum_variants_agree_on_clean_data():
    g, _ = honest_cluster(KEY, sigma=0.01)
    a = FILTERS["multi_krum"](g, F, m=3)
    b = FILTERS["m_krum"](g, F, m=3)
    assert float(jnp.linalg.norm(a - b)) < 0.2


def test_trimmed_mean_bounds():
    g, _ = honest_cluster(KEY)
    out = FILTERS["trimmed_mean"](g, F)
    lo = jnp.min(g, axis=0)
    hi = jnp.max(g, axis=0)
    assert bool(jnp.all(out >= lo - 1e-6)) and bool(jnp.all(out <= hi + 1e-6))


def test_cge_keeps_small_norms():
    g, center = honest_cluster(KEY)
    ga = g.at[0].set(1e4 * jnp.ones((D,)))
    out = FILTERS["cge"](ga, 1)
    assert float(jnp.linalg.norm(out - center)) < 1.0


def test_cgc_clips_norms():
    g, center = honest_cluster(KEY)
    ga = g.at[0].set(1e4 * jnp.ones((D,)))
    out = FILTERS["cgc"](ga, 1)
    norms = jnp.linalg.norm(ga, axis=-1)
    tau = jnp.sort(norms)[N - 2]
    assert float(jnp.linalg.norm(out)) <= float(tau) + 1e-3


def test_geometric_median_breakdown():
    """Geometric median tolerates up to 1/2 corrupted points."""
    g, center = honest_cluster(KEY)
    ga = g.at[:F].set(1e6)
    out = FILTERS["geometric_median"](ga, F, iters=64)
    assert float(jnp.linalg.norm(out - center)) < 1.0


def test_mda_selects_min_diameter_subset():
    g, center = honest_cluster(KEY, sigma=0.05)
    ga = g.at[0].set(50.0).at[1].set(-50.0)
    out = FILTERS["mda"](ga, 2)
    assert float(jnp.linalg.norm(out - center)) < 0.5


def test_bulyan_needs_theta_and_defeats_alie():
    n, f = 15, 2                       # n >= 4f+3 for guarantees
    g = jnp.linspace(-1, 1, D) + 0.1 * jax.random.normal(KEY, (n, D))
    mask = make_byzantine_mask(n, f)
    ga = apply_attack("alie", jax.random.PRNGKey(2), g, mask)
    out = FILTERS["bulyan"](ga, f)
    center = jnp.mean(g[f:], axis=0)
    assert float(jnp.linalg.norm(out - center)) < 0.6


def test_zeno_scores_out_liars():
    g, center = honest_cluster(KEY)
    ga = g.at[:F].set(-5.0 * center[None])
    out = FILTERS["zeno"](ga, F, server_grad=center)
    assert float(jnp.linalg.norm(out - center)) < 0.5


def test_ensemble_combinator():
    g, center = honest_cluster(KEY)
    ens = compose("krum", "coordinate_median", "cge")
    mask = make_byzantine_mask(N, F)
    ga = apply_attack("sign_flip", KEY, g, mask)
    out = ens(ga, F)
    assert float(jnp.linalg.norm(out - center)) < 1.0


def test_pairwise_dists_zero_diag_and_symmetry():
    g, _ = honest_cluster(KEY)
    d2 = pairwise_sq_dists(g)
    assert float(jnp.max(jnp.abs(jnp.diag(d2)))) == 0.0
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2.T), rtol=1e-5)
