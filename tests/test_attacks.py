import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import ATTACKS, apply_attack, make_byzantine_mask

N, F, D = 10, 3, 16
KEY = jax.random.PRNGKey(0)


@pytest.fixture
def g():
    return jax.random.normal(KEY, (N, D))


@pytest.mark.parametrize("name", sorted(ATTACKS))
def test_honest_rows_untouched(name, g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack(name, jax.random.PRNGKey(1), g, mask)
    np.testing.assert_array_equal(np.asarray(ga[F:]), np.asarray(g[F:]))


@pytest.mark.parametrize("name", [a for a in sorted(ATTACKS)
                                  if a not in ("none", "mimic")])
def test_byzantine_rows_changed(name, g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack(name, jax.random.PRNGKey(1), g, mask)
    assert float(jnp.max(jnp.abs(ga[:F] - g[:F]))) > 1e-6


def test_sign_flip_direction(g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack("sign_flip", KEY, g, mask)
    mu = jnp.mean(g[F:], axis=0)
    np.testing.assert_allclose(np.asarray(ga[0]), np.asarray(-mu), rtol=1e-5)


def test_alie_stays_within_spread(g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack("alie", KEY, g, mask)
    mu = jnp.mean(g[F:], axis=0)
    sd = jnp.std(g[F:], axis=0)
    dev = jnp.abs(ga[0] - mu) / (sd + 1e-9)
    assert float(jnp.max(dev)) < 2.0        # z=1.5 default


def test_ipm_negative_inner_product(g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack("ipm", KEY, g, mask)
    mu = jnp.mean(g[F:], axis=0)
    assert float(ga[0] @ mu) < 0


def test_mimic_copies_victim(g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack("mimic", KEY, g, mask)
    np.testing.assert_array_equal(np.asarray(ga[0]), np.asarray(g[N - 1]))


def test_mobile_mask():
    m = make_byzantine_mask(8, 3, fixed=False, key=jax.random.PRNGKey(7))
    assert int(jnp.sum(m)) == 3
