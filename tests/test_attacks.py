import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import make_spec
from repro.core.attacks import (ADAPTIVE_ATTACKS, ATTACKS, apply_attack,
                                calibrate_alie_z, get_attack, honest_moments,
                                make_adaptive_attack, make_byzantine_mask)

N, F, D = 10, 3, 16
KEY = jax.random.PRNGKey(0)


@pytest.fixture
def g():
    return jax.random.normal(KEY, (N, D))


@pytest.mark.parametrize("name", sorted(ATTACKS))
def test_honest_rows_untouched(name, g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack(name, jax.random.PRNGKey(1), g, mask)
    np.testing.assert_array_equal(np.asarray(ga[F:]), np.asarray(g[F:]))


@pytest.mark.parametrize("name", [a for a in sorted(ATTACKS)
                                  if a not in ("none", "mimic")])
def test_byzantine_rows_changed(name, g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack(name, jax.random.PRNGKey(1), g, mask)
    assert float(jnp.max(jnp.abs(ga[:F] - g[:F]))) > 1e-6


def test_sign_flip_direction(g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack("sign_flip", KEY, g, mask)
    mu = jnp.mean(g[F:], axis=0)
    np.testing.assert_allclose(np.asarray(ga[0]), np.asarray(-mu), rtol=1e-5)


def test_alie_stays_within_spread(g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack("alie", KEY, g, mask)
    mu = jnp.mean(g[F:], axis=0)
    sd = jnp.std(g[F:], axis=0)
    dev = jnp.abs(ga[0] - mu) / (sd + 1e-9)
    assert float(jnp.max(dev)) < 2.0        # z=1.5 default


def test_ipm_negative_inner_product(g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack("ipm", KEY, g, mask)
    mu = jnp.mean(g[F:], axis=0)
    assert float(ga[0] @ mu) < 0


def test_mimic_copies_victim(g):
    mask = make_byzantine_mask(N, F)
    ga = apply_attack("mimic", KEY, g, mask)
    np.testing.assert_array_equal(np.asarray(ga[0]), np.asarray(g[N - 1]))


def test_mobile_mask():
    m = make_byzantine_mask(8, 3, fixed=False, key=jax.random.PRNGKey(7))
    assert int(jnp.sum(m)) == 3


def test_honest_moments_is_the_masked_moment_law(g):
    """The shared helper the static AND adaptive attacks calibrate from:
    fp32 mean/std over the non-Byzantine rows, eps-stabilized — pinned to
    the plain formula so the attack family stays mutually consistent."""
    mask = make_byzantine_mask(N, F)
    mu, sd = honest_moments(g, mask)
    ref_mu = jnp.mean(g[F:], axis=0)
    ref_sd = jnp.sqrt(jnp.var(g[F:], axis=0) + 1e-12)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(ref_mu), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sd), np.asarray(ref_sd), rtol=1e-6)
    # and the calibrated attacks plant exactly mu - z * sd (alie contract)
    ga = apply_attack("alie", KEY, g, mask)
    np.testing.assert_allclose(np.asarray(ga[0]), np.asarray(mu - 1.5 * sd),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# defense-aware attacks (core.attacks.adaptive)

AN, AF, AD = 12, 2, 32


@pytest.fixture
def ag():
    return jax.random.normal(jax.random.PRNGKey(3), (AN, AD)) * 0.5 + 1.0


def _run_adaptive(name, spec, g, byz, defense_vec=None, steps=1):
    atk = make_adaptive_attack(name, spec)
    st = atk.init_state()

    @jax.jit
    def one(key, g, st):
        return atk(key, g, byz, st, defense_vec)

    out = g
    for t in range(steps):
        out, st = one(jax.random.PRNGKey(100 + t), g, st)
    return out, st


@pytest.mark.parametrize("name", sorted(ADAPTIVE_ATTACKS))
def test_adaptive_honest_rows_untouched(name, ag):
    byz = make_byzantine_mask(AN, AF)
    spec = make_spec("trimmed_mean", f=AF, n=AN)
    out, _ = _run_adaptive(name, spec, ag, byz)
    np.testing.assert_array_equal(np.asarray(out[AF:]), np.asarray(ag[AF:]))
    assert float(jnp.max(jnp.abs(out[:AF] - ag[:AF]))) > 1e-6


def test_calibrated_z_sits_inside_the_trim_window():
    """trimmed_mean(n=12, f=2) trims 2 rows per side; the calibrated z is
    small enough to ride inside the kept window yet well above the
    degenerate classical value."""
    spec = make_spec("trimmed_mean", f=AF, n=AN)
    z = calibrate_alie_z(spec)
    assert 0.3 < z < 1.5, z


def test_spec_aware_attacks_beat_static_on_krum(ag):
    """THE acceptance contrast: krum filters the static attacks exactly
    (it selects the same honest row, displacement literally zero) while
    the spec-aware line-searched poisons ride inside its selection set and
    displace the estimate.  A defense that is sound against yesterday's
    attack catalogue is NOT sound against an adversary holding the spec."""
    byz = make_byzantine_mask(AN, AF)
    spec = make_spec("krum", f=AF, n=AN, impl="gather")
    clean = spec.aggregate(ag)

    def disp(stack):
        return float(jnp.linalg.norm(spec.aggregate(stack) - clean))

    for name, hyper in (("alie", {"z": 1.5}), ("alie", {"z": 3.0}),
                        ("ipm", {"epsilon": 0.5}), ("large_value", {}),
                        ("sign_flip", {})):
        ga = get_attack(name, **hyper)(jax.random.PRNGKey(100), ag, byz)
        assert disp(ga) == 0.0, (name, hyper)
    for name in ("spec_alie", "min_max"):
        out, _ = _run_adaptive(name, spec, ag, byz)
        assert disp(out) > 1.0, name


@pytest.mark.parametrize("rule,hyper", [("multi_krum", {"m": 4}),
                                        ("mda", {})])
def test_spec_aware_attacks_outdisplace_static(rule, hyper, ag):
    """Selection defenses with averaging: the line-searched poisons
    displace the estimate measurably further than the whole static
    catalogue's best shot."""
    byz = make_byzantine_mask(AN, AF)
    spec = make_spec(rule, f=AF, n=AN, impl="gather", **hyper)
    clean = spec.aggregate(ag)

    def disp(stack):
        return float(jnp.linalg.norm(spec.aggregate(stack) - clean))

    static = max(
        disp(get_attack(name, **h)(jax.random.PRNGKey(100), ag, byz))
        for name, h in (("alie", {"z": 1.5}), ("alie", {"z": 3.0}),
                        ("ipm", {"epsilon": 0.5}), ("large_value", {}),
                        ("sign_flip", {})))
    for name in ("spec_alie", "min_max"):
        out, _ = _run_adaptive(name, spec, ag, byz)
        assert disp(out) > 1.25 * static, (name, disp(out), static)


def test_slow_drift_ramps_below_the_radar(ag):
    """Each round's bias sits inside the honest spread (z_t <= z_cap), the
    sign pattern is locked across rounds (so the bias accumulates), and
    the ramp grows monotonically until the cap."""
    byz = make_byzantine_mask(AN, AF)
    spec = make_spec("trimmed_mean", f=AF, n=AN)
    atk = make_adaptive_attack("slow_drift", spec)
    st = atk.init_state()
    mu, sd = honest_moments(ag, byz)
    devs, signs = [], []
    for t in range(70):
        out, st = atk(jax.random.PRNGKey(t), ag, byz, st)
        z_eff = (out[0] - mu) / sd
        devs.append(float(jnp.max(jnp.abs(z_eff))))
        signs.append(np.sign(np.asarray(z_eff)))
    assert devs[0] < devs[10] < devs[40]         # the ramp
    assert max(devs) <= 1.5 + 1e-4               # never beyond z_cap
    for s in signs[1:]:
        np.testing.assert_array_equal(s, signs[0])   # locked direction


def test_centered_clip_holds_under_adaptive_attacks(ag):
    """The history-filter defense the adaptive attacks were built to
    punish everything else with: centered_clip's carried center bounds the
    per-round displacement by iters * tau, so even the spec-aware poisons
    (compiled against centered_clip itself) keep the estimate near the
    honest mean — while the undefended mean is dragged an order of
    magnitude further."""
    byz = make_byzantine_mask(AN, AF)
    hm = jnp.sum(jnp.where(byz[:, None], 0, ag), 0) / (AN - AF)
    spec = make_spec("centered_clip", f=AF, n=AN, tau=1.0)
    st = {"server_grad": hm}
    clean = float(jnp.linalg.norm(spec.aggregate(ag, state=st) - hm))
    mean_spec = make_spec("mean", f=0, n=AN)
    for name in ("spec_alie", "min_max", "slow_drift"):
        out, _ = _run_adaptive(name, spec, ag, byz, defense_vec=hm)
        dev = float(jnp.linalg.norm(spec.aggregate(out, state=st) - hm))
        assert dev <= 2.0 * max(clean, 1e-3), (name, dev, clean)
        out_mean, _ = _run_adaptive("min_max", mean_spec, ag, byz)
        broken = float(jnp.linalg.norm(mean_spec.aggregate(out_mean) - hm))
        assert broken > 5.0 * dev, (name, broken, dev)


def test_adaptive_attack_refused_by_sync_step():
    """Defense-aware attacks need the aggregate-state thread that only the
    async loop carries — the sync step must refuse loudly, not silently
    run the attack without its state."""
    from repro.optim import adamw, constant
    from repro.training import ByzantineConfig, make_train_step
    bz = ByzantineConfig(n_agents=AN, f=AF, aggregator="trimmed_mean",
                         attack="spec_alie")
    with pytest.raises(NotImplementedError, match="defense-aware"):
        make_train_step(None, bz, adamw(constant(1e-3)))
