"""The convergence leaderboard (benchmarks/bench_convergence.py) as a test
surface: the grid covers what the PR promises, the artifact gate catches
the failures it claims to catch, and (slow lane) representative full-grid
cells actually run and hold their acceptance contrasts end to end.
"""
import importlib.util
import os

import numpy as np
import pytest

_BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                      "bench_convergence.py")


def _load():
    spec = importlib.util.spec_from_file_location("bench_convergence",
                                                  _BENCH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def bench():
    return _load()


def test_full_grid_covers_the_promised_cells(bench):
    """The full grid crosses every catalogue rule with every attack in
    sync, and tracks the memory rules + trimmed_mean under the adaptive
    attacks in BOTH fault regimes."""
    cells = set(bench.grid(quick=False))
    for rule in bench.FULL_RULES:
        for attack in bench.FULL_ATTACKS:
            assert ("sync", attack, rule) in cells
    for regime in ("stragglers", "churn"):
        for rule in bench.MEMORY_RULES + ("trimmed_mean",):
            assert (regime, "none", rule) in cells        # the baseline
            for attack in bench.ADAPTIVE:
                assert (regime, attack, rule) in cells
    # smoke is a strict subset of full
    assert set(bench.grid(quick=True)) <= cells


def _cell(regime, attack, rule, loss, compiles=None):
    return {"regime": regime, "attack": attack, "rule": rule,
            "final_loss": loss, "suspicion_acc": None, "compiles": compiles}


def test_artifact_gate_catches_each_violation_class(bench):
    """check_artifact is CI's acceptance oracle — pin all three violation
    classes and the clean case."""
    ok = {"rows": [
        _cell("sync", "none", "mean", 0.5),
        _cell("sync", "min_max", "mean", 4.0),
        _cell("sync", "none", "centered_clip", 0.5),
        _cell("sync", "min_max", "centered_clip", 0.9),
        _cell("churn", "none", "centered_clip", 0.5, compiles=2),
    ]}
    assert bench.check_artifact(ok) == []
    # the undefended mean shrugging off an attack is itself a red flag
    # (the attack column would be vacuous)
    weak = {"rows": [_cell("sync", "none", "mean", 0.5),
                     _cell("sync", "min_max", "mean", 0.6)]}
    assert any("NOT broken" in v for v in bench.check_artifact(weak))
    # a memory rule beyond 2x clean under any attack fails the PR contract
    degraded = {"rows": [_cell("sync", "none", "server_momentum", 0.5),
                         _cell("sync", "spec_alie", "server_momentum", 1.2)]}
    assert any("degraded" in v for v in bench.check_artifact(degraded))
    # churn cells above the elastic bucket budget fail
    blown = {"rows": [_cell("churn", "none", "centered_clip", 0.5,
                            compiles=len(bench.BUCKETS) + 1)]}
    assert any("compile budget" in v for v in bench.check_artifact(blown))


def test_full_leaderboard_representative_cells(bench, tmp_path):
    """Slow lane (auto-marked by name): run full-grid-only cells at the
    full step count and hold the PR's headline claims end to end —
    krum's loss degrades under the spec-aware poison it admits, and the
    memory rules hold within 2x of clean under the adaptive attacks in
    every regime, inside the churn compile budget."""
    steps = 60
    krum_clean = bench.run_cell("krum", "none", "sync", steps)
    krum_adapt = bench.run_cell("krum", "spec_alie", "sync", steps)
    assert np.isfinite(krum_adapt["final_loss"])
    assert krum_adapt["final_loss"] > 1.2 * krum_clean["final_loss"], (
        krum_clean, krum_adapt)
    for regime in ("sync", "stragglers", "churn"):
        for rule in bench.MEMORY_RULES:
            clean = bench.run_cell(rule, "none", regime, steps)
            hit = bench.run_cell(rule, "slow_drift", regime, steps)
            bound = 2.0 * max(clean["final_loss"], bench.LOSS_FLOOR)
            assert hit["final_loss"] <= bound, (regime, rule, clean, hit)
            if regime == "churn":
                assert hit["compiles"] <= len(bench.BUCKETS)


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q", "-m", "not slow"]))
