"""Resilience notations (§3.5) as empirical measurements."""
import numpy as np

from repro.core.resilience import (estimate_alpha_f, estimate_delta_c,
                                   measure_f_eps)
from repro.core.redundancy.properties import make_redundant_quadratics


def test_alpha_f_positive_for_known_resilient_filters():
    """Table 2: Krum, coordinate-wise median, geometric median, Bulyan are
    (alpha, f)-resilient — E<V, g> must stay positive."""
    for name in ("krum", "coordinate_median", "geometric_median", "bulyan"):
        alpha, ok = estimate_alpha_f(name, n=10, f=2, trials=24)
        assert ok, name
        assert 0.0 <= alpha < 90.0, (name, alpha)


def test_mean_is_not_resilient():
    # scaled sign-flip (scale > (n-f)/f) drives E<mean, g> negative — the
    # Blanchard impossibility in (alpha, f) terms
    _, ok = estimate_alpha_f("mean", n=10, f=2, trials=24,
                             attack="sign_flip", attack_hyper={"scale": 8.0})
    assert not ok


def test_delta_c_ordering():
    """A robust aggregator's constant c is orders of magnitude below the
    undefended mean's."""
    c_med = estimate_delta_c("coordinate_median", n=10, f=2, trials=24)
    c_mean = estimate_delta_c("mean", n=10, f=2, trials=24,
                              attacks=("large_value",))
    assert c_mean > 1e3 * c_med


def test_f_eps_measurement_on_quadratics():
    Hs, xs, common = make_redundant_quadratics(8, 4, eps=0.0)
    honest = list(range(2, 8))
    assert measure_f_eps(common, Hs, xs, honest) < 1e-6
    off = common + 0.5
    d = measure_f_eps(off, Hs, xs, honest)
    assert abs(d - 0.5 * np.sqrt(4)) < 1e-6
