"""Executable solvability theory — 2f-redundancy and (2f, eps)-redundancy."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.redundancy import (check_2f_eps_redundancy,
                                   check_2f_redundancy, hausdorff_distance,
                                   quadratic_argmin)
from repro.core.redundancy.properties import make_redundant_quadratics


def test_hausdorff_points_and_sets():
    X = np.array([[0.0, 0.0]])
    Y = np.array([[3.0, 4.0]])
    assert float(hausdorff_distance(X, Y)) == 5.0
    A = np.array([[0.0], [1.0]])
    B = np.array([[0.0], [2.0]])
    assert float(hausdorff_distance(A, B)) == 1.0


def test_common_minimizer_gives_exact_2f_redundancy():
    Hs, xs, common = make_redundant_quadratics(8, 3, eps=0.0)
    holds, worst = check_2f_redundancy(Hs, xs, f=2, max_subsets=200)
    assert holds, worst
    np.testing.assert_allclose(quadratic_argmin(Hs, xs), common, atol=1e-8)


def test_perturbed_minimizers_break_exact_redundancy():
    Hs, xs, _ = make_redundant_quadratics(8, 3, eps=1.0)
    holds, worst = check_2f_redundancy(Hs, xs, f=2, max_subsets=200)
    assert not holds
    assert worst > 1e-3


@settings(max_examples=10, deadline=None)
@given(st.floats(0.0, 2.0))
def test_eps_redundancy_scales_with_perturbation(eps):
    Hs, xs, _ = make_redundant_quadratics(6, 3, eps=eps, seed=3)
    eps_hat = check_2f_eps_redundancy(Hs, xs, f=1, max_subsets=60)
    # Hausdorff gap between subset argmins is O(eps) with modest constant
    assert eps_hat <= 6.0 * eps + 1e-6


def test_monotone_in_f():
    Hs, xs, _ = make_redundant_quadratics(8, 3, eps=0.5, seed=1)
    e1 = check_2f_eps_redundancy(Hs, xs, f=1, max_subsets=60)
    e2 = check_2f_eps_redundancy(Hs, xs, f=2, max_subsets=60)
    assert e2 >= e1 - 1e-9      # dropping more agents can only widen the gap
