"""Multi-device SPMD integration: the dry-run machinery must lower+compile
smoke-scale configs for a (2,2) single-pod and (2,2,2) multi-pod host mesh.
Runs in a subprocess so the 8-device XLA flag never leaks into this process.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro.launch.dryrun_lib import analyze, lower_combo
from repro.launch.mesh import make_host_mesh
from repro.configs import get_config
from repro.training.step import ByzantineConfig

results = {}
for multi in (False, True):
    mesh = make_host_mesh(2, 2, multi_pod=multi)
    for arch in sys.argv[1].split(","):
        cfg = get_config(arch + "-smoke")
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if shape == "long_500k" and not cfg.subquadratic:
                continue
            if shape == "long_500k" and cfg.is_encdec:
                continue
            bz = ByzantineConfig(n_agents=8, f=1)
            lowered = lower_combo(cfg, shape, mesh, multi, bz=bz)
            compiled = lowered.compile()
            rec = analyze(lowered, compiled, {})
            key = f"{arch}|{shape}|{'512' if multi else '256'}"
            results[key] = {"flops": rec["flops"],
                            "coll": rec["collective_bytes_total"]}
print("RESULTS_JSON:" + json.dumps(results))
"""


def run_subprocess(archs):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, ",".join(archs)],
        capture_output=True, text=True, env=env, timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS_JSON:")][-1]
    return json.loads(line[len("RESULTS_JSON:"):])


@pytest.mark.slow
def test_dryrun_families_compile_on_host_mesh():
    """One arch per family (smoke scale), all shapes, both meshes."""
    res = run_subprocess(["paper-100m", "mixtral-8x22b", "mamba2-130m",
                          "zamba2-7b", "whisper-small", "qwen2-vl-72b"])
    # every lowered program must have compiled and report positive flops
    assert len(res) >= 2 * (3 + 4 + 4 + 4 + 3 + 3)
    for k, v in res.items():
        assert v["flops"] > 0, k
    # training must communicate (aggregation along the agent axis)
    assert res["paper-100m|train_4k|256"]["coll"] > 0
