"""The unified AggregatorSpec API (repro.core.aggregators).

1. Equivalence suite: ``spec.aggregate`` is BIT-FOR-BIT identical to the
   legacy string API (``tree_aggregate`` / ``tree_masked_aggregate`` /
   ``filter_weights``) for every Table-2 rule, in both impls, with and
   without mask/weights.
2. Build-time hygiene: unknown hyper keys raise at spec construction,
   impl-only keys are split once, state must arrive via ``state=``.
3. State protocol + the delay-adaptive ``zeno_pp`` rule (registered solely
   through ``register_aggregator`` — no constants, no dispatch chains).
4. Composition wrappers (clipped / bucketed / staleness_discounted).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as legacy
from repro.core.aggregators import (AggregatorDeprecationWarning, REGISTRY,
                                    bucketed, clipped, get_aggregator_def,
                                    list_aggregators, make_spec,
                                    staleness_discounted)

NAMES = ["mean", "krum", "multi_krum", "m_krum", "cge", "cgc", "mda",
         "coordinate_median", "trimmed_mean", "phocas", "mean_around_median",
         "geometric_median", "rfa", "median_of_means", "bulyan", "zeno"]

# the parity tests exercise the deprecated API on purpose
pytestmark = pytest.mark.filterwarnings(
    "ignore::repro.core.aggregators.AggregatorDeprecationWarning")

N = 12


@pytest.fixture(scope="module")
def grads():
    key = jax.random.PRNGKey(0)
    return {
        "a": jax.random.normal(key, (N, 5, 7)),
        "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (N, 11))},
    }


@pytest.fixture(scope="module")
def server_grad(grads):
    return jax.tree.map(lambda l: l[0] * 0.1, grads)


def assert_trees_bitwise_equal(a, b, ctx=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.dtype == y.dtype, ctx
        assert np.array_equal(np.asarray(x), np.asarray(y)), ctx


# ---------------------------------------------------------------------------
# 1. equivalence: spec API == legacy string API, bit for bit


@pytest.mark.parametrize("impl", ["gather", "fused"])
@pytest.mark.parametrize("name", NAMES)
def test_spec_equals_legacy_sync(name, impl, grads, server_grad):
    f = 2
    hyper = {"server_grad": server_grad} if name == "zeno" else {}
    state = {"server_grad": server_grad} if name == "zeno" else None
    ref = legacy.tree_aggregate(name, grads, f, impl=impl, **hyper)
    out = make_spec(name, f=f, impl=impl, n=N).aggregate(grads, state=state)
    assert_trees_bitwise_equal(ref, out, (name, impl))


@pytest.mark.parametrize("impl", ["gather", "fused"])
@pytest.mark.parametrize("name", NAMES)
@pytest.mark.parametrize("with_weights", [False, True])
def test_spec_equals_legacy_masked(name, impl, with_weights, grads,
                                   server_grad):
    f = 2
    mask = jnp.asarray([True] * 9 + [False] * 3)
    weights = jnp.linspace(1.0, 0.4, N) if with_weights else None
    hyper = {"server_grad": server_grad} if name == "zeno" else {}
    state = {"server_grad": server_grad} if name == "zeno" else None
    ref = legacy.tree_masked_aggregate(name, grads, f, mask,
                                       weights=weights, impl=impl, **hyper)
    out = make_spec(name, f=f, impl=impl, n=N).aggregate(
        grads, mask=mask, weights=weights, state=state)
    assert_trees_bitwise_equal(ref, out, (name, impl, with_weights))


@pytest.mark.parametrize("name", ["mean", "krum", "cge", "mda", "zeno"])
def test_spec_weights_equal_legacy(name, grads, server_grad):
    hyper = {"server_grad": server_grad} if name == "zeno" else {}
    state = {"server_grad": server_grad} if name == "zeno" else None
    ref = legacy.filter_weights(name, grads, 2, **hyper)
    out = make_spec(name, f=2).weights(grads, state=state)
    assert np.array_equal(np.asarray(ref), np.asarray(out)), name


def test_legacy_api_warns(grads):
    with pytest.warns(AggregatorDeprecationWarning):
        legacy.tree_aggregate("mean", grads, 0)
    with pytest.warns(AggregatorDeprecationWarning):
        legacy.filter_weights("mean", grads, 0)


def test_spec_under_jit(grads):
    spec = make_spec("trimmed_mean", f=2, n=N)
    out = jax.jit(lambda g: spec.aggregate(g))(grads)
    assert jax.tree.structure(out) == jax.tree.structure(
        jax.tree.map(lambda l: l[0], grads))


# ---------------------------------------------------------------------------
# 2. build-time hyper hygiene


def test_unknown_hyper_raises_at_build():
    with pytest.raises(ValueError, match="unknown hyper-parameter"):
        make_spec("krum", f=2, bogus=1)
    with pytest.raises(ValueError, match="unknown hyper-parameter"):
        make_spec("trimmed_mean", f=2, betta=0.2)   # typo caught early


def test_unknown_aggregator_raises():
    with pytest.raises(KeyError, match="unknown aggregator"):
        make_spec("krummm", f=2)


def test_impl_keys_split_once():
    spec = make_spec("trimmed_mean", f=2, beta=0.25, native_dtype=True)
    assert spec.hyper == (("beta", 0.25),)
    assert spec.impl_hyper == (("native_dtype", True),)
    # the gather path never sees impl-only keys (no re-filtering needed)
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 6))
    a = spec.with_impl("gather").aggregate(g)
    b = make_spec("trimmed_mean", f=2, beta=0.25,
                  impl="gather").aggregate(g)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_state_key_as_hyper_raises():
    with pytest.raises(ValueError, match="STATE"):
        make_spec("zeno", f=2, server_grad=jnp.zeros((4,)))


def test_stateful_without_state_raises(grads):
    with pytest.raises(ValueError, match="stateful"):
        make_spec("zeno", f=2).aggregate(grads)


def test_spec_is_hashable_and_frozen():
    spec = make_spec("krum", f=2)
    hash(spec)                                   # static jit closure key
    with pytest.raises(Exception):
        spec.f = 3


def test_capability_flags_cover_catalogue():
    for name in NAMES:
        caps = get_aggregator_def(name).caps
        assert caps.masked_capable, name
        assert (caps.coordwise or caps.weight_decomposable
                or caps.iterative), name
    assert set(list_aggregators("table2")) == set(NAMES)
    # derived legacy views stay consistent with the registry
    assert legacy.COORDWISE == {n for n in NAMES
                               if get_aggregator_def(n).caps.coordwise}


# ---------------------------------------------------------------------------
# 3. state protocol + zeno_pp (the ROADMAP delay-adaptive follow-up)


def test_zeno_state_protocol(grads, server_grad):
    spec = make_spec("zeno", f=2, ema=0.5)
    proto = jax.tree.map(lambda l: l[0], grads)
    state = spec.init_state(proto)
    state["server_grad"] = server_grad
    agg = spec.aggregate(grads, state=state)
    new = spec.update_state(state, agg)
    # ema=0.5 moves the server gradient toward the aggregate
    for v0, v1, a in zip(jax.tree.leaves(state["server_grad"]),
                         jax.tree.leaves(new["server_grad"]),
                         jax.tree.leaves(agg)):
        np.testing.assert_allclose(np.asarray(v1),
                                   0.5 * np.asarray(v0)
                                   + 0.5 * np.asarray(a, np.float32),
                                   rtol=1e-6)


def test_zeno_pp_registered_solely_via_registry():
    assert "zeno_pp" in REGISTRY
    # NOT in the dense catalogue nor in any legacy capability constant:
    from repro.core.filters import FILTERS
    assert "zeno_pp" not in FILTERS
    assert "zeno_pp" not in (legacy.COORDWISE | legacy.WEIGHTED
                             | legacy.ITERATIVE)
    caps = get_aggregator_def("zeno_pp").caps
    assert caps.stateful and caps.masked_capable


def test_zeno_pp_rejects_misaligned_rows():
    key = jax.random.PRNGKey(3)
    d = 32
    center = jnp.linspace(-1.0, 1.0, d)
    g = center[None] + 0.05 * jax.random.normal(key, (10, d))
    g = g.at[:2].set(-8.0 * center[None])          # 2 adversarial rows
    spec = make_spec("zeno_pp", f=2, xi=0.5)
    state = {"server_grad": center}                # aligned server estimate
    out = spec.aggregate(g, state=state)
    honest_mean = jnp.mean(g[2:], axis=0)
    assert float(jnp.linalg.norm(out - honest_mean)) < 0.1
    # stale rows face a stricter test: same rows, heavy staleness discount
    w = jnp.ones((10,)).at[2].set(1e-3)
    out_w = spec.aggregate(g, weights=w, state=state)
    assert bool(jnp.all(jnp.isfinite(out_w)))


def test_zeno_pp_bootstrap_is_robust():
    """An attack active from step 0 (server EMA still zero) must not reach
    the aggregate: the bootstrap scores against the coordinate-wise median
    of the delivered rows, so the adversary cannot seed the EMA with its
    own direction (self-poisoning)."""
    key = jax.random.PRNGKey(4)
    d = 32
    center = jnp.linspace(-1.0, 1.0, d)
    g = center[None] + 0.05 * jax.random.normal(key, (10, d))
    g = g.at[:2].set(-4.0 * center[None])          # sign-flip from step 0
    spec = make_spec("zeno_pp", f=2, xi=0.5)
    state = spec.init_state(jnp.zeros((d,)))       # v = 0: bootstrap round
    out = spec.aggregate(g, state=state)
    honest_mean = jnp.mean(g[2:], axis=0)
    assert float(jnp.linalg.norm(out - honest_mean)) < 0.1
    # the EMA that follows is therefore honest-aligned, not attack-aligned
    new = spec.update_state(state, out)
    v = new["server_grad"]
    assert float(v @ center) > 0.0


# ---------------------------------------------------------------------------
# 4. composition wrappers are specs


def test_clipped_bounds_large_rows():
    d = 16
    center = jnp.ones((d,)) * 0.1
    g = center[None] + 0.01 * jax.random.normal(jax.random.PRNGKey(5),
                                                (8, d))
    g = g.at[0].set(1e6)                           # one huge row
    spec = clipped(make_spec("mean"), tau=1.0)
    out = spec.aggregate(g)
    assert float(jnp.linalg.norm(out - center)) < 0.5


def test_bucketed_equals_manual_group_mean(grads):
    inner = make_spec("coordinate_median", f=2)
    spec = bucketed(inner, group_size=2)
    out = spec.aggregate(grads)
    manual = jax.tree.map(
        lambda l: jnp.mean(l.astype(jnp.float32).reshape(
            (N // 2, 2) + l.shape[1:]), axis=1).astype(l.dtype), grads)
    ref = inner.with_f(min(2, (N // 2 - 1) // 2)).aggregate(manual)
    assert_trees_bitwise_equal(out, ref)


def test_bucketed_rejects_masked(grads):
    spec = bucketed(make_spec("mean"), group_size=2)
    with pytest.raises(ValueError, match="masked"):
        spec.aggregate(grads, mask=jnp.ones((N,), bool))


def test_staleness_discounted_matches_manual_weights(grads):
    inner = make_spec("trimmed_mean", f=2)
    spec = staleness_discounted(inner, weighting="exp", gamma=0.5)
    stal = jnp.asarray([0., 0., 1., 2., 3., 0., 1., 0., 2., 0., 4., 0.])
    mask = jnp.asarray([True] * 10 + [False] * 2)
    out = spec.aggregate(grads, mask=mask, weights=stal)
    ref = inner.aggregate(grads, mask=mask, weights=0.5 ** stal)
    assert_trees_bitwise_equal(out, ref)


def test_wrappers_nest(grads):
    spec = clipped(bucketed(make_spec("trimmed_mean", f=2), group_size=2),
                   tau=10.0)
    out = spec.aggregate(grads)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in jax.tree.leaves(out))
    assert "clipped" in spec.describe() and "bucketed" in spec.describe()


def test_zeno_without_validation_source_raises_loudly():
    """init_state for classic Zeno (ema=0) must not hand back a frozen
    all-zero server gradient — the defense would silently degrade."""
    with pytest.raises(ValueError, match="validation"):
        make_spec("zeno", f=2).init_state(jnp.zeros((4,)))
    # ema > 0: self-maintained EMA state is fine
    st = make_spec("zeno", f=2, ema=0.3).init_state(jnp.zeros((4,)))
    assert "server_grad" in st


def test_wrapper_over_stateful_inner_threads_nested_state(grads):
    spec = clipped(make_spec("zeno", f=2, ema=0.5), tau=50.0)
    assert spec.stateful
    with pytest.raises(ValueError, match="stateful"):
        spec.aggregate(grads)                        # guard on the OUTER spec
    proto = jax.tree.map(lambda l: l[0], grads)
    state = spec.init_state(proto)                   # nests the inner state
    agg = spec.aggregate(grads, state=state)
    new = spec.update_state(state, agg)
    moved = sum(float(jnp.sum(jnp.abs(l))) for l in
                jax.tree.leaves(new["inner"]["server_grad"]))
    assert moved > 0.0


def test_impl_hyper_reaches_through_wrappers():
    spec = clipped(make_spec("trimmed_mean", f=2), tau=5.0)
    deep = spec.with_impl_hyper_if_supported(native_dtype=True)
    assert deep.inner.impl_hyper == (("native_dtype", True),)
    assert deep.impl_hyper == ()                     # wrapper declares none


def test_legacy_shim_tolerates_native_dtype_everywhere(grads):
    """The legacy gather path stripped native_dtype for every rule — the
    shim must keep that tolerance (only the spec API proper is strict)."""
    out = legacy.tree_aggregate("krum", grads, 2, impl="gather",
                                native_dtype=True)
    ref = legacy.tree_aggregate("krum", grads, 2, impl="gather")
    assert_trees_bitwise_equal(out, ref)


def test_async_loop_rejects_staleness_aware_spec():
    from repro.simulator.async_loop import make_async_step
    from repro.training.step import ByzantineConfig
    bz = ByzantineConfig(n_agents=8, f=2, aggregator=staleness_discounted(
        make_spec("trimmed_mean", f=2)))
    with pytest.raises(ValueError, match="staleness"):
        make_async_step(None, bz, None)
    # ...including when the staleness wrapper is NESTED inside another
    nested = clipped(staleness_discounted(make_spec("mean", f=2)), tau=5.0)
    assert nested.staleness_aware
    bz2 = ByzantineConfig(n_agents=8, f=2, aggregator=nested)
    with pytest.raises(ValueError, match="raw staleness"):
        make_async_step(None, bz2, None)


def test_config_rejects_mismatched_spec():
    """The defense must agree with the declared threat model: an explicit
    aggregator built for a different f (or n) raises at config time."""
    from repro.training.step import ByzantineConfig
    bz = ByzantineConfig(n_agents=8, f=2, aggregator=make_spec("krum"))
    with pytest.raises(ValueError, match="f=0"):
        bz.resolve_spec()
    bz = ByzantineConfig(n_agents=8, f=2,
                         aggregator=make_spec("krum", f=2, n=16))
    with pytest.raises(ValueError, match="n=16"):
        bz.resolve_spec()


def test_resilience_estimator_rejects_mismatched_spec():
    from repro.core.resilience import estimate_alpha_f
    with pytest.raises(ValueError, match="f=0"):
        estimate_alpha_f(make_spec("krum"), n=10, f=2, trials=2)
    with pytest.raises(ValueError, match="BUILDING"):
        estimate_alpha_f(make_spec("krum", f=2), n=10, f=2, trials=2,
                         iters=3)


def test_stateful_spec_rejects_group_size_knob():
    """group_size/reshard only exist on the synchronous step; a stateful
    spec forces the general async path, so the combination must raise
    rather than silently drop the grouping."""
    import repro.data as _data
    from repro.simulator.async_loop import async_train_loop
    from repro.training.step import ByzantineConfig
    from repro.configs import get_config
    cfg = get_config("paper-100m-smoke").replace(vocab_size=64)
    ds = _data.SyntheticLM(vocab_size=64, seq_len=8, n_agents=8,
                           per_agent_batch=1)
    bz = ByzantineConfig(n_agents=8, f=2, group_size=2,
                         aggregator=make_spec("zeno_pp", f=2, n=8))
    with pytest.raises(NotImplementedError, match="stateless"):
        async_train_loop(cfg, bz, None, ds, steps=1,
                         log_fn=lambda *_: None)


def test_legacy_nu_alias_still_accepted(grads):
    out = legacy.tree_aggregate("geometric_median", grads, 2, nu=1e-6)
    ref = legacy.tree_aggregate("geometric_median", grads, 2, eps=1e-6)
    assert_trees_bitwise_equal(out, ref)


def test_legacy_constants_match_historical_values():
    assert legacy.COORDWISE == {"coordinate_median", "trimmed_mean",
                                "phocas", "mean_around_median"}
    assert legacy.WEIGHTED == {"mean", "krum", "multi_krum", "m_krum",
                               "cge", "cgc", "mda", "zeno"}
    assert legacy.ITERATIVE == {"geometric_median", "rfa",
                                "median_of_means"}


def test_register_new_rule_is_one_decorator():
    """Extensibility contract: a brand-new rule needs ONE registration call
    and is immediately a first-class spec."""
    from repro.core.aggregators import AggregatorCaps, register_aggregator
    name = "test_only_first_row"
    if name not in REGISTRY:
        @register_aggregator(name, caps=AggregatorCaps())
        def _first_row(spec, grads, mask, weights, state):
            return jax.tree.map(lambda l: l[0], grads)
    g = {"x": jnp.arange(6.0).reshape(3, 2)}
    out = make_spec(name).aggregate(g)
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.asarray(g["x"][0]))
