"""End-to-end system behaviour: the full stack (config -> model -> data ->
Byzantine train step -> optimizer -> checkpoint -> serving) in one scenario,
mirroring a production deployment at CPU scale."""
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import restore
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.optim import adamw, constant
from repro.serving import generate
from repro.training import ByzantineConfig, train_loop


def test_full_stack_byzantine_training_and_serving():
    cfg = get_config("paper-100m-smoke").replace(vocab_size=64)
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8,
                     per_agent_batch=2, regime="iid")
    bz = ByzantineConfig(n_agents=8, f=2, filter_name="phocas",
                         attack="ipm", momentum_alpha=0.2)
    with tempfile.TemporaryDirectory() as d:
        params, hist = train_loop(cfg, bz, adamw(constant(3e-3)), ds,
                                  steps=80, ckpt_dir=d, ckpt_every=40,
                                  log_fn=lambda *_: None)
        assert hist[-1]["loss"] < hist[0]["loss"]
        # restore round-trips
        restored, step = restore(d, {"params": params})
        assert step == 80
        # the trained model serves: greedy continuation of the learnable
        # stream (iid regime: every agent's stream steps by base_step=7)
        b = ds.batch(jax.random.PRNGKey(3), 99)
        prompt = {"tokens": b["tokens"][0, :, :16]}
        out = generate(cfg, restored["params"], prompt, 4)
        expect = (prompt["tokens"][:, -1:] + ds.base_step * (
            1 + jnp.arange(4)[None, :])) % 64
        acc = float(jnp.mean((out == expect.astype(out.dtype)) * 1.0))
        assert acc > 0.5, f"served continuation accuracy {acc}"
