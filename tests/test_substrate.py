"""Data pipeline, optimizers, schedules, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.data import SyntheticLM, label_flip
from repro.optim import (adamw, apply_updates, constant, cosine_warmup,
                         diminishing, inverse_sqrt, sgd)


def test_synthetic_data_structure_and_determinism():
    ds = SyntheticLM(vocab_size=97, seq_len=16, n_agents=4,
                     per_agent_batch=2, regime="noniid")
    key = jax.random.PRNGKey(0)
    a = ds.batch(key, 0)
    b = ds.batch(key, 0)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    # labels are the next-token shift of tokens
    np.testing.assert_array_equal(np.asarray(a["labels"][..., :-1]),
                                  np.asarray(a["tokens"][..., 1:]))
    # noniid: per-agent constant steps, all different
    steps = (a["tokens"][:, :, 1] - a["tokens"][:, :, 0]) % 97
    assert len(set(np.asarray(steps[:, 0]).tolist())) == 4


def test_parallel_regime_identical_shards():
    ds = SyntheticLM(vocab_size=97, seq_len=16, n_agents=4,
                     per_agent_batch=2, regime="parallel")
    b = ds.batch(jax.random.PRNGKey(1), 0)
    for i in range(1, 4):
        np.testing.assert_array_equal(np.asarray(b["tokens"][0]),
                                      np.asarray(b["tokens"][i]))


def test_label_flip_only_hits_byzantine():
    ds = SyntheticLM(vocab_size=96, seq_len=8, n_agents=4, per_agent_batch=2)
    b = ds.batch(jax.random.PRNGKey(2), 0)
    mask = jnp.arange(4) < 1
    fb = label_flip(b, mask, 96)
    np.testing.assert_array_equal(np.asarray(fb["labels"][1:]),
                                  np.asarray(b["labels"][1:]))
    assert not np.array_equal(np.asarray(fb["labels"][0]),
                              np.asarray(b["labels"][0]))


def test_schedules():
    t = jnp.asarray(0)
    assert float(constant(0.5)(t)) == 0.5
    dim = diminishing(1.0, 1.0)
    # appendix A.2: eta_t = 1/(1+t); sum diverges, sum of squares converges
    vals = [float(dim(jnp.asarray(i))) for i in range(5)]
    np.testing.assert_allclose(vals, [1, 0.5, 1 / 3, 0.25, 0.2], rtol=1e-6)
    cw = cosine_warmup(1.0, 10, 100)
    assert float(cw(jnp.asarray(5))) == 0.5
    assert float(cw(jnp.asarray(100))) < 1e-6
    isq = inverse_sqrt(1.0, warmup=4)
    assert float(isq(jnp.asarray(2))) == 0.5


def test_sgd_momentum_and_adamw_reduce_quadratic():
    x0 = {"x": jnp.asarray([5.0, -3.0])}
    # heavy-ball needs lr(1+..)/(1-beta) inside the stability region
    for opt in (sgd(constant(0.02), momentum=0.9),
                adamw(constant(0.3))):
        params = x0
        state = opt.init(params)
        for _ in range(120):
            grads = jax.tree.map(lambda p: p, params)     # grad of ||x||^2/2
            upd, state = opt.update(grads, state, params)
            params = apply_updates(params, upd)
        assert float(jnp.linalg.norm(params["x"])) < 0.15


def test_checkpoint_roundtrip_and_latest():
    tree = {"w": jnp.ones((3, 2), jnp.bfloat16),
            "opt": {"step": jnp.asarray(7, jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, tree)
        save(d, 5, tree)
        assert latest_step(d) == 5
        restored, step = restore(d, tree)
        assert step == 5
        assert restored["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(restored["opt"]["step"]), 7)
