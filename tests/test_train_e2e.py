"""End-to-end Byzantine training: reproduces the survey's central empirical
claims on a small LM (CPU, <2 min total)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ArchConfig
from repro.data import SyntheticLM
from repro.optim import adamw, constant
from repro.training import ByzantineConfig, train_loop

CFG = ArchConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                 num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                 head_dim=16, dtype="float32")
DS = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8, per_agent_batch=4)
OPT = lambda: adamw(constant(3e-3))
STEPS = 50


def run(bz, steps=STEPS, ds=DS, poison=False):
    _, hist = train_loop(CFG, bz, OPT(), ds, steps=steps, log_every=steps,
                         poison_labels=poison, log_fn=lambda *_: None)
    return hist[-1]["loss"]


def test_clean_training_converges():
    loss = run(ByzantineConfig(n_agents=8, f=0, filter_name="mean"))
    assert loss < 1.0


def test_attacked_mean_fails_but_filter_survives():
    atk = dict(attack="sign_flip", attack_hyper={"scale": 4.0})
    l_mean = run(ByzantineConfig(n_agents=8, f=2, filter_name="mean", **atk))
    l_tm = run(ByzantineConfig(n_agents=8, f=2, filter_name="trimmed_mean",
                               **atk))
    assert l_tm < 1.0
    assert l_mean > l_tm + 0.5


@pytest.mark.parametrize("filter_name", ["krum", "coordinate_median", "cge"])
def test_filters_survive_large_value_attack(filter_name):
    bz = ByzantineConfig(n_agents=8, f=2, filter_name=filter_name,
                         attack="large_value")
    assert run(bz) < 1.5, filter_name


def test_median_of_means_survives_with_group_majority():
    """MoM needs k > 2f clean-majority groups (k=6 groups of 2, f=2)."""
    ds12 = SyntheticLM(vocab_size=64, seq_len=32, n_agents=12,
                       per_agent_batch=4)
    bz = ByzantineConfig(n_agents=12, f=2, filter_name="median_of_means",
                         attack="large_value")
    _, hist = train_loop(CFG, bz, OPT(), ds12, steps=STEPS,
                         log_fn=lambda *_: None)
    assert hist[-1]["loss"] < 1.5


def test_geometric_median_bounded_not_exact():
    """The survey's (f, eps)-resilience, not exact recovery: under a
    coordinated point-mass attack the geometric median's output is biased by
    O(diameter of honest gradients) — training is BOUNDED (unlike the mean,
    which diverges) but not necessarily near-clean.  [45, 68]"""
    atk = dict(attack="large_value")
    l_gm = run(ByzantineConfig(n_agents=8, f=2,
                               filter_name="geometric_median", **atk))
    l_mean = run(ByzantineConfig(n_agents=8, f=2, filter_name="mean", **atk))
    # NOTE: AdamW's per-coordinate normalization already bounds the damage
    # of huge gradients (the mean stalls rather than exploding here), so the
    # assertion is bounded-and-strictly-better, not explosion
    assert l_gm < 6.0
    assert l_gm < l_mean


def test_gather_and_fused_train_identically():
    atk = dict(attack="sign_flip")
    la = run(ByzantineConfig(n_agents=8, f=2, filter_name="cge",
                             impl="gather", **atk), steps=20)
    lb = run(ByzantineConfig(n_agents=8, f=2, filter_name="cge",
                             impl="fused", **atk), steps=20)
    assert abs(la - lb) < 1e-3


def test_worker_momentum_helps_krum_under_alie():
    """Survey §3.3.4: momentum reduces honest variance -> distance-based
    filters recover (Karimireddy et al., El-Mhamdi et al.)."""
    atk = dict(attack="alie", attack_hyper={"z": 3.0})
    base = ByzantineConfig(n_agents=8, f=2, filter_name="krum", **atk)
    l_raw = run(base, steps=60)
    l_mom = run(ByzantineConfig(n_agents=8, f=2, filter_name="krum",
                                momentum_alpha=0.2, **atk), steps=60)
    assert l_mom < l_raw + 0.5      # momentum never hurts materially
    assert l_mom < 1.5


def test_draco_coded_training_is_exact():
    """Parallel regime + repetition coding: Draco recovers the exact clean
    gradient under attack (<= (r-1)/2 Byzantine per group)."""
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8,
                     per_agent_batch=4, regime="parallel")
    atk = dict(attack="large_value")
    l_draco = run(ByzantineConfig(n_agents=8, f=1, draco_r=4, **atk), ds=ds)
    assert l_draco < 1.0


def test_label_poisoning_with_median():
    bz = ByzantineConfig(n_agents=8, f=2, filter_name="coordinate_median")
    loss = run(bz, poison=True)
    assert loss < 1.5


def test_perf_variants_still_converge():
    """§Perf knobs (EXPERIMENTS.md) must not change training semantics:
    median-of-means grouping, bf16 exchange, per-layer remat."""
    atk = dict(attack="sign_flip", attack_hyper={"scale": 4.0})
    # group_size must keep a majority of clean groups: n=8, f=2 adjacent ->
    # groups of 2 give k=4 with 1 corrupted group (groups of 4 would leave
    # only k=2, no majority — that's median-of-means' k > 2f condition)
    for kw in ({"group_size": 2, "filter_name": "coordinate_median"},
               {"agg_dtype": "bfloat16"},
               {"remat": True}):
        bz = ByzantineConfig(n_agents=8, f=2,
                             **{"filter_name": "trimmed_mean", **kw}, **atk)
        loss = run(bz)
        assert loss < 1.5, kw


def test_group_size_beyond_majority_fails():
    """Sanity of the k > 2f condition: k=2 groups with both Byzantine agents
    in one group CANNOT be defended — median-of-means' own threshold."""
    bz = ByzantineConfig(n_agents=8, f=2, filter_name="coordinate_median",
                         group_size=4, attack="sign_flip",
                         attack_hyper={"scale": 4.0})
    assert run(bz) > 1.5
