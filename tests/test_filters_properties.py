"""Property-based tests (hypothesis) on filter invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.filters import FILTERS

SET = settings(max_examples=25, deadline=None)


def grads_strategy(min_n=6, max_n=14, min_d=2, max_d=24):
    return st.integers(min_n, max_n).flatmap(
        lambda n: st.integers(min_d, max_d).flatmap(
            lambda d: st.integers(0, 2 ** 31 - 1).map(
                lambda seed: (np.random.default_rng(seed)
                              .normal(size=(n, d)).astype(np.float32)))))


COORD = ["coordinate_median", "trimmed_mean", "phocas", "mean_around_median"]
TRANSLATION_EQUIVARIANT = COORD + ["krum", "geometric_median", "mda",
                                   "multi_krum", "m_krum", "bulyan", "mean",
                                   "median_of_means"]


@SET
@given(grads_strategy())
def test_coordinate_filters_within_bounds(g):
    n = g.shape[0]
    f = max((n - 3) // 4, 1)
    for name in COORD:
        out = np.asarray(FILTERS[name](jnp.asarray(g), f))
        assert (out >= g.min(0) - 1e-5).all(), name
        assert (out <= g.max(0) + 1e-5).all(), name


@SET
@given(grads_strategy(), st.integers(0, 2 ** 31 - 1))
def test_translation_equivariance(g, seed):
    n, d = g.shape
    f = max((n - 3) // 4, 1)
    c = np.random.default_rng(seed).normal(size=(d,)).astype(np.float32)
    for name in TRANSLATION_EQUIVARIANT:
        a = np.asarray(FILTERS[name](jnp.asarray(g + c), f))
        b = np.asarray(FILTERS[name](jnp.asarray(g), f)) + c
        scale = max(np.abs(g).max(), np.abs(c).max(), 1.0)
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3 * scale,
                                   err_msg=name)


@SET
@given(grads_strategy(), st.integers(0, 2 ** 31 - 1))
def test_permutation_invariance(g, seed):
    n = g.shape[0]
    f = max((n - 3) // 4, 1)
    perm = np.random.default_rng(seed).permutation(n)
    # (mda excluded: near-tied subset diameters make its argmin selection
    # legitimately permutation-sensitive at float precision)
    for name in ["coordinate_median", "trimmed_mean", "geometric_median",
                 "krum", "mean", "cgc"]:
        a = np.asarray(FILTERS[name](jnp.asarray(g[perm]), f))
        b = np.asarray(FILTERS[name](jnp.asarray(g), f))
        scale = max(np.abs(g).max(), 1.0)
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4 * scale,
                                   err_msg=name)


@SET
@given(grads_strategy())
def test_krum_returns_an_input_row(g):
    n = g.shape[0]
    f = max((n - 3) // 4, 1)
    out = np.asarray(FILTERS["krum"](jnp.asarray(g), f))
    dists = np.linalg.norm(g - out[None], axis=-1)
    assert dists.min() < 1e-5


@SET
@given(grads_strategy())
def test_cge_norm_bounded_by_kept_set(g):
    n = g.shape[0]
    f = max((n - 3) // 4, 1)
    out = np.asarray(FILTERS["cge"](jnp.asarray(g), f))
    norms = np.sort(np.linalg.norm(g, axis=-1))
    assert np.linalg.norm(out) <= norms[n - f - 1] + 1e-4


@SET
@given(grads_strategy())
def test_scale_equivariance_homogeneous_filters(g):
    n = g.shape[0]
    f = max((n - 3) // 4, 1)
    for name in ["mean", "coordinate_median", "trimmed_mean", "krum",
                 "cge", "cgc", "mda"]:
        a = np.asarray(FILTERS[name](jnp.asarray(2.5 * g), f))
        b = 2.5 * np.asarray(FILTERS[name](jnp.asarray(g), f))
        scale = max(np.abs(g).max(), 1.0)
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3 * scale,
                                   err_msg=name)


@SET
@given(grads_strategy(min_n=8))
def test_identical_inputs_are_fixed_points(g):
    """If every agent sends the same vector v, every filter returns v."""
    n, d = g.shape
    f = max((n - 3) // 4, 1)
    v = g[0]
    tied = np.tile(v, (n, 1))
    for name in ["mean", "coordinate_median", "trimmed_mean", "krum",
                 "geometric_median", "cge", "cgc", "phocas",
                 "mean_around_median", "multi_krum", "mda", "bulyan"]:
        out = np.asarray(FILTERS[name](jnp.asarray(tied), f))
        np.testing.assert_allclose(out, v, rtol=1e-4,
                                   atol=1e-4 * max(np.abs(v).max(), 1.0),
                                   err_msg=name)
