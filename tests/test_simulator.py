"""Fault-injection cluster simulator + staleness-aware async training."""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.p2p.dgd import p2p_dgd_run
from repro.core.p2p.graph import complete_graph, ring_graph
from repro.core.redundancy.coding import tree_draco_aggregate
from repro.data import SyntheticLM
from repro.optim import adamw, constant
from repro.simulator import (Churn, CrashRecover, FaultTrace, Join,
                             MessageDrop, Partition, PermanentCrash, Rejoin,
                             SimConfig, Straggler, async_train_loop,
                             compile_schedule, no_faults, simulate_arrivals)
from repro.training import ByzantineConfig, train_loop

SILENT = {"log_fn": lambda *_: None}
SPECS = (Straggler(dist="lognormal", scale=0.6),
         CrashRecover(rate=0.08, mean_down=2.0),
         MessageDrop(p=0.15),
         PermanentCrash(agents=(5,), at=10),
         Partition(groups=((0, 1, 2), (3, 4, 5)), start=4, end=8))


# ---------------------------------------------------------------------------
# fault schedules


def test_schedule_deterministic_under_seed():
    a = compile_schedule(SPECS, 6, 25, seed=7)
    b = compile_schedule(SPECS, 6, 25, seed=7)
    for x, y in ((a.alive, b.alive), (a.drop, b.drop), (a.delay, b.delay),
                 (a.adj, b.adj)):
        assert np.array_equal(x, y)
    c = compile_schedule(SPECS, 6, 25, seed=8)
    assert not (np.array_equal(a.delay, c.delay)
                and np.array_equal(a.alive, c.alive)
                and np.array_equal(a.drop, c.drop))


def test_schedule_composition_and_shapes():
    tr = compile_schedule(SPECS, 6, 25, seed=0)
    assert tr.alive.shape == tr.drop.shape == tr.delay.shape == (25, 6)
    assert tr.adj.shape == (25, 6, 6)
    assert (tr.delay > 0.0).all()
    assert (tr.delay != 1.0).any()            # stragglers moved latencies
    assert not tr.alive[10:, 5].any()         # permanent crash holds
    assert not tr.adj[5, 0, 4]                # partition severs cross-group
    assert tr.adj[5, 0, 1]
    assert tr.adj[9, 0, 4]                    # heals after `end`
    assert not tr.is_trivial()
    assert no_faults(6, 25).is_trivial()


def test_membership_schedule_composition_and_shapes():
    specs = (Join(agents=(4, 5), at=6),
             Rejoin(agents=(0,), leave_at=3, rejoin_at=9),
             Churn(rate=0.2, mean_out=2.0, agents=(1,)))
    tr = compile_schedule(specs, 6, 25, seed=1)
    assert tr.roster is not None and tr.roster.shape == (25, 6)
    assert not tr.roster[:6, 4].any() and tr.roster[6:, 4].all()   # Join
    assert tr.roster[:3, 0].all() and not tr.roster[3:9, 0].any()
    assert tr.roster[9:, 0].all()                                  # Rejoin
    assert tr.roster[:, (2, 3)].all()           # untouched agents stay in
    assert not tr.is_trivial()
    assert tr.n_live(0) == 4 and tr.n_live(10) >= 5
    # determinism in the seed, like every other spec family
    tr2 = compile_schedule(specs, 6, 25, seed=1)
    assert np.array_equal(tr.roster, tr2.roster)
    assert not np.array_equal(
        tr.roster, compile_schedule(specs, 6, 25, seed=2).roster)
    # no membership specs -> no roster allocated (and member() is True)
    assert compile_schedule(SPECS, 6, 25, seed=0).roster is None
    assert compile_schedule(SPECS, 6, 25, seed=0).member(3, 2)


# ---------------------------------------------------------------------------
# event queue / arrival simulation


def test_no_faults_trace_is_synchronous():
    at = simulate_arrivals(no_faults(8, 21), 20)
    assert at.is_synchronous()
    assert at.quorum_met.all()
    assert (at.vclock == np.arange(1, 21)).all()   # one unit per barrier


def test_crash_removes_agents_from_quorum():
    tr = compile_schedule((PermanentCrash(agents=(2,), at=5),), 4, 31)
    at = simulate_arrivals(tr, 30, quorum=3)
    assert not at.contrib[10:, 2].any()       # gone from every later quorum
    assert at.quorum_met.all()                # 3 survivors still meet q=3
    assert at.contrib[10:, [0, 1, 3]].all()


def test_bounded_staleness_is_bounded():
    tr = compile_schedule(
        (Straggler(dist="pareto", scale=1.2), MessageDrop(p=0.2)),
        8, 41, seed=3)
    at = simulate_arrivals(tr, 40, quorum=5, max_staleness=2)
    assert at.staleness[at.contrib].max(initial=0) <= 2
    assert (at.contrib.sum(1) >= 1).all()


def test_same_instant_ties_join_the_same_update():
    """Deflake regression: arrivals sharing the quorum instant ALL join
    the update (the sweep), so which of them pops first can never change
    the accepted set — with uniform integer delays every step is a full
    barrier even at quorum=2."""
    at = simulate_arrivals(no_faults(6, 21), 20, quorum=2)
    assert at.contrib.all() and at.quorum_met.all()
    assert (at.vclock == np.arange(1, 21)).all()


def test_virtual_clock_is_agent_relabeling_equivariant():
    """Deflake regression for the pinned (vtime, agent) heap tie-break:
    relabeling agents commutes with the simulation.  Integer delays force
    exact same-instant collisions every step; crashes, drops and a
    staleness bound exercise every rejection path — if any tie were
    resolved by internal dispatch order, the permuted run would diverge."""
    n, steps = 5, 24
    rng = np.random.default_rng(0)
    delay = rng.integers(1, 4, size=(steps + 1, n)).astype(float)
    alive = np.ones((steps + 1, n), bool)
    alive[4:9, 2] = False                       # crash/recover window
    drop = rng.random((steps + 1, n)) < 0.2
    base = FaultTrace(alive=alive, drop=drop, delay=delay)
    at = simulate_arrivals(base, steps, quorum=3, max_staleness=2)

    perm = np.asarray([3, 0, 4, 1, 2])
    permuted = FaultTrace(alive=alive[:, perm], drop=drop[:, perm],
                          delay=delay[:, perm])
    atp = simulate_arrivals(permuted, steps, quorum=3, max_staleness=2)
    # column j of the permuted run is original agent perm[j]
    assert np.array_equal(atp.contrib, at.contrib[:, perm])
    assert np.array_equal(atp.staleness, at.staleness[:, perm])
    assert np.array_equal(atp.refresh, at.refresh[:, perm])
    assert np.array_equal(atp.vclock, at.vclock)
    assert np.array_equal(atp.quorum_met, at.quorum_met)


def test_inflight_gradient_dies_with_midflight_departure():
    """A gradient in flight when its sender leaves the roster is discarded
    even if the sender has already REJOINED by the arrival instant — the
    agent's state died with it; it re-dispatches fresh."""
    tr = compile_schedule(
        (Rejoin(agents=(0,), leave_at=5, rejoin_at=6),
         Straggler(dist="constant", scale=3.0, agents=(0,))), 4, 31, seed=0)
    at = simulate_arrivals(tr, 30, quorum=3)
    # every contribution's in-flight window [dispatch, arrival] lies
    # entirely inside the agent's membership
    for t, i in zip(*np.nonzero(at.contrib)):
        v = t - at.staleness[t, i]
        assert tr.roster[v:t + 1, i].all(), (t, i, v)
    # agent 0 still participates after rejoining (fresh dispatch)
    assert at.contrib[10:, 0].any()


def test_p2p_accepts_membership_schedules():
    """Membership schedules run on the p2p path (the PR 8 carried-forward
    NotImplementedError is gone): churned-out agents freeze in place and
    states stay finite.  tests/test_p2p.py holds the full behavioural
    regression (frozen out-rounds, convergence of always-in agents)."""
    adj = complete_graph(4)
    states = p2p_dgd_run(adj, lambda i, x: x, jnp.ones((4, 2)), steps=3,
                         fault_schedule=(Churn(rate=0.3),))
    assert jnp.isfinite(jnp.asarray(states)).all()


def test_roster_aware_quorum_accounting():
    """An agent outside the roster can neither arrive nor count toward
    quorum: the effective quorum is capped at the live roster, so a
    shrunken cluster keeps meeting it."""
    tr = compile_schedule((Rejoin(agents=(0, 1, 2), leave_at=5,
                                  rejoin_at=15),), 6, 31, seed=0)
    at = simulate_arrivals(tr, 30, quorum=5)
    assert not at.contrib[5:15, :3].any()       # gone from every update
    assert at.quorum_met.all()                  # q capped at 3 live agents
    assert at.contrib[6:14, 3:].all()
    assert at.contrib[16:].all()                # whole roster back
    # non-members never dispatch (refresh is roster-gated)
    assert not at.refresh[5:14, :3].any()


def test_straggler_induces_staleness_not_starvation():
    tr = compile_schedule(
        (Straggler(dist="constant", scale=3.0, agents=(0,)),), 6, 61, seed=0)
    at = simulate_arrivals(tr, 60, quorum=5)
    stal0 = at.staleness[at.contrib[:, 0], 0]
    assert at.contrib[:, 0].sum() < 60        # slow agent misses quorums
    assert at.contrib[:, 0].sum() > 5         # ...but keeps participating
    assert stal0.max() >= 1                   # and is stale when it lands


# ---------------------------------------------------------------------------
# async training loop

CFG = get_config("paper-100m-smoke").replace(vocab_size=64, dtype="float32")
DS = SyntheticLM(vocab_size=64, seq_len=16, n_agents=8, per_agent_batch=2)
OPT = lambda: adamw(constant(3e-3))


def losses(hist):
    return [m["loss"] for m in hist]


def test_async_zero_latency_full_quorum_is_bitexact_sync():
    """ISSUE acceptance: latency=0, quorum=n reproduces the synchronous
    train_loop bit-for-bit on the paper_100m config family."""
    bz = ByzantineConfig(n_agents=8, f=2, filter_name="trimmed_mean",
                         attack="sign_flip")
    _, hs = train_loop(CFG, bz, OPT(), DS, steps=8, log_every=2, **SILENT)
    _, ha = async_train_loop(CFG, bz, OPT(), DS, steps=8,
                             sim=SimConfig(), log_every=2, **SILENT)
    assert losses(hs) == losses(ha)           # exact float equality
    assert all(m["staleness_mean"] == 0.0 and m["arrived"] == 8 for m in ha)


def test_general_async_path_reduces_to_sync():
    """The general (buffered, masked-aggregation) path itself collapses to
    the synchronous step on a pure trace."""
    for name in ("trimmed_mean", "krum", "mean"):
        bz = ByzantineConfig(n_agents=8, f=2, filter_name=name,
                             attack="sign_flip")
        _, hs = train_loop(CFG, bz, OPT(), DS, steps=6, log_every=2, **SILENT)
        _, hg = async_train_loop(CFG, bz, OPT(), DS, steps=6,
                                 sim=SimConfig(), log_every=2,
                                 _force_general=True, **SILENT)
        np.testing.assert_allclose(losses(hs), losses(hg), rtol=2e-4,
                                   err_msg=name)


def test_async_under_stragglers_still_converges():
    bz = ByzantineConfig(n_agents=8, f=2, filter_name="trimmed_mean",
                         attack="sign_flip")
    sim = SimConfig(faults=(Straggler(dist="lognormal", scale=0.8),),
                    quorum=6, max_staleness=3, seed=2)
    _, h = async_train_loop(CFG, bz, OPT(), DS, steps=50, log_every=50,
                            sim=sim, **SILENT)
    assert h[-1]["loss"] < 1.2
    assert any(m["staleness_mean"] > 0 or m["arrived"] < 8 for m in h)


def test_zeno_pp_stateful_spec_through_async_loop():
    """ROADMAP follow-up: the delay-adaptive Zeno++-style score filter is
    registered SOLELY through the AggregatorSpec API (one decorator) and
    flows through the async loop with its server-gradient state threaded
    through the jitted step — extensibility proof for the new API."""
    from repro.core.aggregators import make_spec
    spec = make_spec("zeno_pp", f=2, xi=0.5, ema=0.2, n=8)
    assert spec.stateful
    bz = ByzantineConfig(n_agents=8, f=2, aggregator=spec,
                         attack="sign_flip",
                         attack_hyper={"scale": 4.0})
    sim = SimConfig(faults=(Straggler(dist="lognormal", scale=0.8),),
                    quorum=6, max_staleness=3, seed=2)
    _, h = async_train_loop(CFG, bz, OPT(), DS, steps=40, log_every=40,
                            sim=sim, **SILENT)
    assert np.isfinite(h[-1]["loss"])
    assert h[-1]["loss"] < 1.5              # defends where mean diverges
    # same attack through the undefended mean for contrast
    bz_mean = ByzantineConfig(n_agents=8, f=2,
                              aggregator=make_spec("mean", f=2, n=8),
                              attack="sign_flip",
                              attack_hyper={"scale": 4.0})
    _, hm = async_train_loop(CFG, bz_mean, OPT(), DS, steps=40,
                             log_every=40, sim=sim, **SILENT)
    assert h[-1]["loss"] < hm[-1]["loss"] + 0.1


def test_crash_recover_chaos_run_is_finite():
    bz = ByzantineConfig(n_agents=8, f=0, filter_name="coordinate_median")
    sim = SimConfig(faults=(CrashRecover(rate=0.15, mean_down=2.0),
                            MessageDrop(p=0.1),
                            Straggler(dist="exp", scale=0.5)),
                    quorum=4, max_staleness=4, seed=5)
    _, h = async_train_loop(CFG, bz, OPT(), DS, steps=30, log_every=10,
                            sim=sim, **SILENT)
    assert np.isfinite(h[-1]["loss"])
    assert h[-1]["loss"] < 3.0


def test_coded_fallback_on_quorum_miss():
    ds = SyntheticLM(vocab_size=64, seq_len=16, n_agents=8,
                     per_agent_batch=2, regime="parallel")
    bz = ByzantineConfig(n_agents=8, f=0, filter_name="mean")
    sim = SimConfig(faults=(PermanentCrash(agents=(0, 1, 2), at=3),),
                    quorum=7, coded_fallback_r=2,
                    staleness_weighting="none")
    _, h = async_train_loop(CFG, bz, OPT(), ds, steps=30, log_every=10,
                            sim=sim, **SILENT)
    assert h[-1]["arrived"] == 5              # 3 agents gone for good
    assert h[-1]["loss"] < 2.0                # code still recovers signal


# ---------------------------------------------------------------------------
# masked gradient coding


def test_masked_draco_averages_surviving_groups():
    g = {"w": jnp.stack([jnp.full((3,), float(i // 2)) for i in range(8)])}
    full = tree_draco_aggregate(g, 2)
    np.testing.assert_allclose(full["w"], (0 + 1 + 2 + 3) / 4)
    mask = jnp.asarray([True, True, False, False, True, True, True, True])
    part = tree_draco_aggregate(g, 2, mask=mask)
    np.testing.assert_allclose(part["w"], (0 + 2 + 3) / 3, rtol=1e-6)


# ---------------------------------------------------------------------------
# client sampling: the roster as a CHOSEN schedule


def test_sampling_policy_emits_membership_schedule():
    from repro.simulator import SamplingPolicy
    tr = compile_schedule((SamplingPolicy(m=3, round_len=4),), 8, 20, seed=5)
    assert tr.roster is not None
    for t0 in range(0, 20, 4):
        rows = tr.roster[t0:t0 + 4]
        assert (rows == rows[0]).all()          # constant within the round
        assert rows[0].sum() == 3               # exactly m sampled
    # seed-deterministic, and the seed actually matters
    tr2 = compile_schedule((SamplingPolicy(m=3, round_len=4),), 8, 20,
                           seed=5)
    np.testing.assert_array_equal(tr.roster, tr2.roster)
    assert not np.array_equal(
        tr.roster, compile_schedule((SamplingPolicy(m=3, round_len=4),),
                                    8, 20, seed=6).roster)


def test_sampling_policy_composes_by_intersection():
    from repro.simulator import SamplingPolicy
    tr = compile_schedule((Rejoin(agents=(0,), leave_at=4, rejoin_at=16),
                           SamplingPolicy(m=5, round_len=2)), 8, 20, seed=0)
    # an agent a prior membership spec removed is never chosen ...
    assert not tr.roster[4:16, 0].any()
    # ... and each round still samples min(m, available)
    assert (tr.roster.sum(axis=1) == 5).all()


def test_sampling_policy_through_async_loop():
    from repro.core.aggregators import elastic, frac, make_spec
    from repro.simulator import SamplingPolicy
    ds = SyntheticLM(vocab_size=64, seq_len=16, n_agents=8,
                     per_agent_batch=2)
    spec = make_spec("trimmed_mean", f=frac(0.25),
                     n=elastic(8, buckets=(4, 6, 8)))
    bz = ByzantineConfig(n_agents=8, f=2, aggregator=spec)
    sim = SimConfig(faults=(SamplingPolicy(m=4, policy="contribution"),),
                    seed=0)
    _, h = async_train_loop(CFG, bz, OPT(), ds, steps=20, log_every=10,
                            sim=sim, **SILENT)
    assert np.isfinite(h[-1]["loss"])
    assert h[-1]["arrived"] <= 4              # only sampled clients deliver


# ---------------------------------------------------------------------------
# p2p DGD over time-varying (partitioned / crashing) graphs


def test_p2p_fault_schedule_partition_and_freeze():
    n = 8
    adj = complete_graph(n)
    sched = (Partition(groups=((0, 1, 2, 3), (4, 5, 6, 7)), start=3, end=10),
             PermanentCrash(agents=(7,), at=12))
    grad_fn = lambda i, x: x                  # all minimize ||x||^2
    x0 = jnp.arange(n * 2, dtype=jnp.float32).reshape(n, 2)
    traj = p2p_dgd_run(adj, grad_fn, x0, steps=20, f=1, combine="lf",
                       fault_schedule=sched)
    assert bool(jnp.isfinite(traj).all())
    frozen = np.asarray(traj[13:, 7])
    assert (frozen == frozen[0]).all()        # crashed agent frozen
    live = np.asarray(traj[-1, :7])
    assert np.linalg.norm(live) < 0.5 * np.linalg.norm(
        np.asarray(x0[:7]))                   # still descending toward 0


def test_p2p_message_drop_silences_sender_not_receiver():
    """A dropped broadcast must vanish at the RECEIVERS (in-edge semantics);
    the dropping sender still hears its neighbours."""
    adj = complete_graph(3)
    sched = (MessageDrop(p=1.0, agents=(0,)),)
    grad_fn = lambda i, x: jnp.zeros_like(x)
    x0 = jnp.asarray([[100.0], [1.0], [2.0]])
    traj = p2p_dgd_run(adj, grad_fn, x0, steps=1, combine="plain",
                       fault_schedule=sched)
    after = np.asarray(traj[1])
    assert after[1, 0] <= 2.0 + 1e-6          # never saw agent 0's 100.0
    assert after[2, 0] <= 2.0 + 1e-6
    assert after[0, 0] < 100.0                # agent 0 still hears 1 and 2


def test_lf_degraded_degree_keeps_own_estimate():
    """With deg <= 2f the LF trim would eat more values than exist — the
    receiver must fall back to its own estimate, not a zeroed/negated one."""
    adj = ring_graph(4, 1)                    # deg 2 everywhere, f=1
    grad_fn = lambda i, x: jnp.zeros_like(x)
    x0 = jnp.asarray([[4.0], [-3.0], [7.0], [11.0]])
    traj = p2p_dgd_run(adj, grad_fn, x0, steps=1, f=1, combine="lf")
    np.testing.assert_array_equal(np.asarray(traj[1]), np.asarray(x0))


def test_p2p_without_schedule_unchanged():
    n = 6
    adj = complete_graph(n)
    grad_fn = lambda i, x: x
    x0 = jnp.ones((n, 2))
    a = p2p_dgd_run(adj, grad_fn, x0, steps=10, combine="ce")
    b = p2p_dgd_run(adj, grad_fn, x0, steps=10, combine="ce",
                    fault_schedule=None)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
