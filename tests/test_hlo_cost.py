"""Trip-count-aware HLO cost analyzer: validated against analytic FLOP
counts and layer-count scaling (XLA's cost_analysis counts while bodies
once — the analyzer must not)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ArchConfig
from repro.launch.hlo_cost import analyze_hlo_text
from repro.models import init_params, loss_fn


def _an(L, grad=False, family="dense", **kw):
    base = dict(name="t", family=family, num_layers=L, d_model=128,
                num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256,
                head_dim=32, dtype="float32")
    base.update(kw)
    cfg = ArchConfig(**base)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 64), jnp.int32)}
    if grad:
        fn = lambda p, b: jax.grad(lambda q: loss_fn(cfg, q, b))(p)
    else:
        fn = lambda p, b: loss_fn(cfg, p, b)
    compiled = jax.jit(fn).lower(params, batch).compile()
    return analyze_hlo_text(compiled.as_text()), compiled


def test_forward_flops_match_analytic_per_layer():
    a2, _ = _an(2)
    a8, _ = _an(8)
    body = (a8["flops"] - a2["flops"]) / 6
    D, F, T, B, H, hd, K = 128, 256, 64, 2, 4, 32, 2
    proj = 2 * B * T * (D * H * hd + 2 * D * K * hd + H * hd * D)
    mlp = 2 * B * T * (3 * D * F)
    attn = 2 * B * H * T * T * hd * 2
    analytic = proj + mlp + attn
    assert abs(body - analytic) / analytic < 0.05


def test_backward_is_three_x_forward():
    af, _ = _an(2, grad=False)
    ag, _ = _an(2, grad=True)
    assert 2.5 < ag["flops"] / af["flops"] < 3.5


def _xla_flops(compiled):
    ca = compiled.cost_analysis()
    return (ca[0] if isinstance(ca, list) else ca)["flops"]


def test_scales_with_layers_unlike_xla():
    a2, c2 = _an(2, grad=True)
    a8, c8 = _an(8, grad=True)
    # XLA cost_analysis is flat in L (the known limitation)...
    assert _xla_flops(c8) == pytest.approx(_xla_flops(c2), rel=0.01)
    # ...the corrected analyzer is not
    assert a8["flops"] / a2["flops"] > 3.0


def test_nested_scans_hybrid():
    kw = dict(family="hybrid", ssm_state=16, ssm_head_dim=32, ssm_chunk=32,
              hybrid_attn_every=2)
    a4, _ = _an(4, **kw)
    a8, _ = _an(8, **kw)
    assert 1.7 < a8["flops"] / a4["flops"] < 2.3


def test_bytes_and_collectives_present():
    a, _ = _an(2)
    assert a["result_bytes"] > 0
    assert a["collective_bytes_total"] == 0      # single device: none
