import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import generate


def test_greedy_generation_deterministic():
    cfg = get_config("paper-100m-smoke")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 12), 0, cfg.vocab_size)}
    a = generate(cfg, params, batch, 8)
    b = generate(cfg, params, batch, 8)
    assert a.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(jnp.max(a)) < cfg.vocab_size


def test_generation_swa_and_ssm():
    for arch in ("mamba2-130m-smoke", "h2o-danube-3-4b-smoke"):
        cfg = get_config(arch)
        key = jax.random.PRNGKey(1)
        params = init_params(cfg, key)
        batch = {"tokens": jax.random.randint(key, (1, 10), 0,
                                              cfg.vocab_size)}
        out = generate(cfg, params, batch, 5)
        assert out.shape == (1, 5), arch


def test_generation_encdec():
    cfg = get_config("whisper-small-smoke")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    batch = {
        "tokens": jax.random.randint(key, (1, 6), 0, cfg.vocab_size),
        "audio_embeds": 0.05 * jax.random.normal(
            key, (1, cfg.encoder_seq, cfg.d_model)).astype(
                jnp.dtype(cfg.dtype)),
    }
    out = generate(cfg, params, batch, 4)
    assert out.shape == (1, 4)
