import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import generate


def test_greedy_generation_deterministic():
    cfg = get_config("paper-100m-smoke")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 12), 0, cfg.vocab_size)}
    a = generate(cfg, params, batch, 8)
    b = generate(cfg, params, batch, 8)
    assert a.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(jnp.max(a)) < cfg.vocab_size


def test_generation_swa_and_ssm():
    for arch in ("mamba2-130m-smoke", "h2o-danube-3-4b-smoke"):
        cfg = get_config(arch)
        key = jax.random.PRNGKey(1)
        params = init_params(cfg, key)
        batch = {"tokens": jax.random.randint(key, (1, 10), 0,
                                              cfg.vocab_size)}
        out = generate(cfg, params, batch, 5)
        assert out.shape == (1, 5), arch


def test_generation_encdec():
    cfg = get_config("whisper-small-smoke")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    batch = {
        "tokens": jax.random.randint(key, (1, 6), 0, cfg.vocab_size),
        "audio_embeds": 0.05 * jax.random.normal(
            key, (1, cfg.encoder_seq, cfg.d_model)).astype(
                jnp.dtype(cfg.dtype)),
    }
    out = generate(cfg, params, batch, 4)
    assert out.shape == (1, 4)


def test_replicated_decoding_tolerates_corrupt_replica():
    """Fault-tolerant serving through the AggregatorSpec API: with 4
    replicas and f=1, a corrupted replica's logits are filtered out and
    the decoded tokens equal the clean single-model generation."""
    from repro.core.aggregators import make_spec
    from repro.serving import generate_replicated

    cfg = get_config("paper-100m-smoke")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 10), 0, cfg.vocab_size)}
    clean = generate(cfg, params, batch, 5)

    bad = jax.tree.map(lambda l: l + 37.0, params)      # hostile replica
    stack = jax.tree.map(lambda *ls: jnp.stack(ls),
                         params, params, params, bad)
    out = generate_replicated(cfg, stack, batch, 5,
                              make_spec("coordinate_median", f=1, n=4))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(clean))
