"""Prefill + single-token decode must reproduce the full forward exactly
(fp32) for every model family, including ring-buffer SWA caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import (decode_step, forward_train, init_cache, init_params,
                          prefill)

FP32 = dict(dtype="float32")

CASES = {
    "dense": ArchConfig(name="dense", family="dense", num_layers=2,
                        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                        vocab_size=97, head_dim=16, **FP32),
    "swa-ring": ArchConfig(name="swa", family="dense", num_layers=2,
                           d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                           vocab_size=97, head_dim=16, sliding_window=8,
                           **FP32),
    "moe": ArchConfig(name="moe", family="moe", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                      head_dim=16, num_experts=4, experts_per_token=2,
                      shared_expert=True, capacity_factor=8.0, **FP32),
    "ssm": ArchConfig(name="ssm", family="ssm", num_layers=2, d_model=64,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=97,
                      ssm_state=16, ssm_head_dim=32, ssm_chunk=1,
                      tie_embeddings=True, **FP32),
    "hybrid": ArchConfig(name="hyb", family="hybrid", num_layers=3,
                         d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                         vocab_size=97, head_dim=16, ssm_state=16,
                         ssm_head_dim=32, ssm_chunk=1, hybrid_attn_every=2,
                         **FP32),
    "vlm-mrope": ArchConfig(name="vlm", family="vlm", num_layers=2,
                            d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                            vocab_size=97, head_dim=16, positional="mrope",
                            mrope_sections=(4, 2, 2), frontend="vision",
                            frontend_tokens=9, **FP32),
    "encdec": ArchConfig(name="aud", family="audio", num_layers=2, d_model=64,
                         num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                         head_dim=16, encoder_layers=2, encoder_seq=16,
                         frontend="audio", norm="layer", act="gelu",
                         positional="sinusoid", **FP32),
}


def extra_inputs(cfg, B):
    key = jax.random.PRNGKey(9)
    if cfg.frontend == "vision":
        return {"vision_embeds": 0.1 * jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model))}
    if cfg.frontend == "audio":
        return {"audio_embeds": 0.1 * jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))}
    return {}


@pytest.mark.parametrize("case", sorted(CASES))
def test_prefill_decode_match_full_forward(case):
    cfg = CASES[case]
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, T = 2, 16
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size)}
    batch.update(extra_inputs(cfg, B))
    full, _ = forward_train(cfg, params, batch)

    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :T - 1]
    cache = init_cache(cfg, params, B, 64, pb)
    lg_pre, cache = prefill(cfg, params, pb, cache)
    lg_dec, cache = decode_step(cfg, params, batch["tokens"][:, T - 1:T],
                                cache)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(full[:, T - 2]),
                               atol=2e-2, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full[:, T - 1]),
                               atol=2e-2, rtol=1e-3)


def test_multi_step_decode_matches_forward():
    cfg = CASES["swa-ring"]
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    B, T, extra = 2, 12, 6
    toks = jax.random.randint(key, (B, T + extra), 0, cfg.vocab_size)
    full, _ = forward_train(cfg, params, {"tokens": toks})
    cache = init_cache(cfg, params, B, 64, None)
    _, cache = prefill(cfg, params, {"tokens": toks[:, :T]}, cache)
    for i in range(extra):
        lg, cache = decode_step(cfg, params, toks[:, T + i:T + i + 1], cache)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, T + i]),
                                   atol=2e-2, rtol=1e-3)
