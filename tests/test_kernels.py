"""Pallas kernel sweeps (interpret mode) vs the pure-jnp ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.filters.dense import FILTERS, pairwise_sq_dists
from repro.kernels import (kernel_cge, kernel_coordinate_median, kernel_krum,
                           kernel_pairwise_sq_dists, kernel_trimmed_mean)
from repro.kernels import ref
from repro.kernels.coord_stats import coord_sort
from repro.kernels.pairwise import gram
from repro.kernels.wsum import weighted_sum

NS = [8, 16, 32]
DS = [512, 1024, 4096]
DTYPES = [jnp.float32, jnp.bfloat16]


def data(n, d, dtype, seed=0):
    return (jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 2).astype(
        dtype)


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("d", DS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_coord_sort_kernel(n, d, dtype):
    g = data(n, d, dtype)
    out = coord_sort(g)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.coord_sort_ref(g)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("d", DS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_gram_kernel(n, d, dtype):
    g = data(n, d, dtype)
    out = gram(g)
    expect = ref.gram_ref(g)
    scale = float(jnp.max(jnp.abs(expect)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-3, atol=1e-5 * scale)


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("d", DS)
def test_wsum_kernel(n, d):
    g = data(n, d, jnp.float32)
    w = jax.random.uniform(jax.random.PRNGKey(3), (n,))
    out = weighted_sum(w, g)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.weighted_sum_ref(w, g)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("d", [512, 1000, 4097])     # incl. non-tile-aligned
def test_kernel_filters_match_dense(n, d):
    g = data(n, d, jnp.float32, seed=7)
    f = 2
    np.testing.assert_allclose(np.asarray(kernel_coordinate_median(g)),
                               np.asarray(FILTERS["coordinate_median"](g, f)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kernel_trimmed_mean(g, f)),
                               np.asarray(jnp.mean(jnp.sort(g, 0)[f:n - f],
                                                   0)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kernel_krum(g, f)),
                               np.asarray(FILTERS["krum"](g, f)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kernel_cge(g, f)),
                               np.asarray(FILTERS["cge"](g, f)),
                               rtol=1e-4, atol=1e-4)
    scale = float(jnp.max(jnp.sum(g ** 2, -1)))
    np.testing.assert_allclose(np.asarray(kernel_pairwise_sq_dists(g)),
                               np.asarray(pairwise_sq_dists(g)),
                               rtol=1e-4, atol=1e-6 * scale)


def test_padding_is_neutral():
    """Non-aligned d must produce identical results to an aligned copy."""
    g = data(8, 700, jnp.float32)
    out = kernel_coordinate_median(g)
    assert out.shape == (700,)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.median(g, axis=0)),
                               rtol=1e-6, atol=1e-6)
