"""Retrace-count regression: membership churn over a bucketed elastic spec
compiles each loop at most ``len(buckets)`` times — EVER.

The elastic layer's whole point is that joins/leaves/rejoins do not pay
XLA compiles: roster indices/masks are traced operands, and only the
bucket (a static shape + (n, f) plan) can retrigger tracing.  A 200-step
churn run over a 3-bucket spec therefore admits at most 3 traces per loop
— async training, synchronous training, and replicated serving each get a
counter (:mod:`repro.core.tracecount`, incremented by a Python side
effect INSIDE the traced step, so it ticks exactly once per compile).

This is the membership analogue of PR 3's ``test_fault_masks_do_not_
retrace`` and runs in its own CI lane next to the kernels-interpret job.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.aggregators import elastic, frac, make_spec
from repro.core.tracecount import TRACE_COUNTS
from repro.data import SyntheticLM
from repro.optim import adamw, constant
from repro.simulator import (Churn, Join, Rejoin, SimConfig,
                             async_train_loop, compile_schedule)
from repro.training import ByzantineConfig
from repro.training.step import make_train_step

STEPS = 200
BUCKETS = (4, 6, 8)
N = 8

CFG = get_config("paper-100m-smoke").replace(vocab_size=32, dtype="float32")
CHURN = (Join(agents=(7,), at=10),
         Rejoin(agents=(6,), leave_at=40, rejoin_at=60),
         Churn(rate=0.2, mean_out=2.0, agents=(1, 2, 3, 4)))


def elastic_spec(rule="trimmed_mean"):
    return make_spec(rule, f=frac(0.25), n=elastic(N, buckets=BUCKETS))


def churn_roster(steps, seed=0, n=N):
    tr = compile_schedule(CHURN, n, steps + 1, seed=seed)
    assert tr.roster is not None
    # the schedule must actually exercise several buckets
    lives = sorted({int(r.sum()) for r in tr.roster[:steps]})
    assert len(lives) >= 3, f"churn schedule too tame: lives={lives}"
    return tr


def test_async_loop_churn_compiles_at_most_once_per_bucket():
    ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=N,
                     per_agent_batch=1)
    bz = ByzantineConfig(n_agents=N, f=2, aggregator=elastic_spec())
    sim = SimConfig(faults=CHURN, seed=0)
    churn_roster(STEPS)                      # same schedule sanity check
    before_async = TRACE_COUNTS["async_step"]
    before_sync = TRACE_COUNTS["train_step"]
    _, h = async_train_loop(CFG, bz, adamw(constant(1e-3)), ds,
                            steps=STEPS, sim=sim, log_every=STEPS,
                            log_fn=lambda *_: None)
    assert np.isfinite(h[-1]["loss"])
    n_async = TRACE_COUNTS["async_step"] - before_async
    n_sync = TRACE_COUNTS["train_step"] - before_sync
    assert n_async <= len(BUCKETS), (
        f"async loop retraced {n_async} times over {len(BUCKETS)} buckets")
    # full-roster synchronous-timing steps ride the ONE sync fast path
    assert n_sync <= 1, f"sync fast path retraced {n_sync} times"


@pytest.mark.parametrize("mode", ["draco", "fallback"])
def test_coded_async_churn_compiles_at_most_once_per_bucket(mode):
    """Coded aggregation under membership churn: the per-bucket group
    tables (coding_groups — the trim-table trick) are host constants
    folded into each bucket's trace, so a 200-step churn run with
    draco_r > 0 (or the quorum-miss coded fallback) stays within the
    same <= len(buckets) compile budget as the uncoded loops."""
    ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=N,
                     per_agent_batch=1, regime="parallel")
    bz = ByzantineConfig(n_agents=N, f=2, aggregator=elastic_spec(),
                         draco_r=2 if mode == "draco" else 0)
    sim = SimConfig(faults=CHURN, seed=4,
                    quorum=4 if mode == "fallback" else None,
                    coded_fallback_r=2 if mode == "fallback" else 0)
    before = TRACE_COUNTS["async_step"]
    _, h = async_train_loop(CFG, bz, adamw(constant(1e-3)), ds,
                            steps=STEPS, sim=sim, log_every=STEPS,
                            log_fn=lambda *_: None)
    assert np.isfinite(h[-1]["loss"])
    n_async = TRACE_COUNTS["async_step"] - before
    assert n_async <= len(BUCKETS), (
        f"coded ({mode}) async loop retraced {n_async} times over "
        f"{len(BUCKETS)} buckets")


def test_sync_step_churn_compiles_at_most_once_per_bucket():
    """training/step.py threads the roster through the jitted synchronous
    step: 200 churn steps, one compile per bucket."""
    from repro.models import init_params

    ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=N,
                     per_agent_batch=1)
    spec = elastic_spec()
    bz = ByzantineConfig(n_agents=N, f=2, aggregator=spec)
    opt = adamw(constant(1e-3))
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    tr = churn_roster(STEPS, seed=1)
    fns = {}
    before = TRACE_COUNTS["train_step"]
    key = jax.random.PRNGKey(1)
    for t in range(STEPS):
        live = np.flatnonzero(tr.roster[t])
        if len(live) == 0:
            continue
        b, idx, valid = spec.elastic.pack(live)
        if b not in fns:
            fns[b] = jax.jit(make_train_step(CFG, bz, opt, bucket=b))
        key, kd, ks = jax.random.split(key, 3)
        params, opt_state, _, m = fns[b](params, opt_state, None,
                                         ds.batch(kd, t), ks,
                                         jnp.asarray(idx),
                                         jnp.asarray(valid))
    assert np.isfinite(float(m["loss"]))
    n_traces = TRACE_COUNTS["train_step"] - before
    assert n_traces <= len(BUCKETS), (
        f"sync step retraced {n_traces} times over {len(BUCKETS)} buckets")


def test_stateful_adaptive_churn_compiles_at_most_once_per_bucket():
    """PR 10: a STATEFUL rule (centered_clip, center carried across
    rounds) under a DEFENSE-AWARE attack (spec_alie line-searches z
    against each bucket's respecialized spec, inside the trace) through
    200 churn steps — the {agg, atk} state bundle and the per-bucket
    attack rebuild must not cost a single compile beyond the bucket
    budget."""
    ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=N,
                     per_agent_batch=1)
    spec = make_spec("centered_clip", f=frac(0.25), tau=1.0,
                     n=elastic(N, buckets=BUCKETS))
    bz = ByzantineConfig(n_agents=N, f=2, aggregator=spec,
                         attack="spec_alie")
    sim = SimConfig(faults=CHURN, seed=2)
    before = TRACE_COUNTS["async_step"]
    _, h = async_train_loop(CFG, bz, adamw(constant(1e-3)), ds,
                            steps=STEPS, sim=sim, log_every=STEPS,
                            log_fn=lambda *_: None)
    assert np.isfinite(h[-1]["loss"])
    n_async = TRACE_COUNTS["async_step"] - before
    assert n_async <= len(BUCKETS), (
        f"stateful+adaptive loop retraced {n_async} times over "
        f"{len(BUCKETS)} buckets")


def test_serving_churn_compiles_at_most_once_per_bucket():
    """generate_replicated under replica churn: the agreement step
    compiles once per bucket across a 200-token decode."""
    from repro.models import init_params
    from repro.serving import generate_replicated

    r = 5
    params = init_params(CFG, jax.random.PRNGKey(0))
    stack = jax.tree.map(lambda l: jnp.stack([l] * r), params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                          CFG.vocab_size)}
    # replicas 3, 4 pinned live so the roster never empties
    tr = compile_schedule((Churn(rate=0.25, mean_out=2.0, agents=(0, 1, 2)),),
                          r, STEPS, seed=2)
    lives = sorted({int(row.sum()) for row in tr.roster})
    assert len(lives) >= 3, f"churn schedule too tame: lives={lives}"
    spec = make_spec("coordinate_median", f=frac(0.4),
                     n=elastic(r, buckets=(3, 4, 5)))
    before = TRACE_COUNTS["serving_agree"]
    out = generate_replicated(CFG, stack, batch, STEPS, spec,
                              roster=tr.roster)
    assert out.shape == (1, STEPS)
    n_traces = TRACE_COUNTS["serving_agree"] - before
    assert n_traces <= 3, (
        f"serving agreement retraced {n_traces} times over 3 buckets")


def test_mask_only_roster_never_retraces():
    """A non-elastic spec under churn takes the masked path: the roster
    mask is a traced operand, ONE compile total."""
    from repro.models import init_params
    from repro.serving import generate_replicated

    r = 5
    params = init_params(CFG, jax.random.PRNGKey(3))
    stack = jax.tree.map(lambda l: jnp.stack([l] * r), params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0,
                                          CFG.vocab_size)}
    tr = compile_schedule((Churn(rate=0.25, mean_out=2.0, agents=(0, 1)),),
                          r, 50, seed=3)
    before = TRACE_COUNTS["serving_agree"]
    generate_replicated(CFG, stack, batch, 50,
                        make_spec("coordinate_median", f=1, n=r),
                        roster=tr.roster)
    assert TRACE_COUNTS["serving_agree"] - before == 1


def test_within_bucket_churn_reuses_the_compilation():
    """Different rosters with the same live count (same bucket) must hit
    the jit cache — the roster indices are traced, not baked in."""
    spec = elastic_spec()
    ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=N,
                     per_agent_batch=1)
    bz = ByzantineConfig(n_agents=N, f=2, aggregator=spec)
    fn = jax.jit(make_train_step(CFG, bz, adamw(constant(1e-3)),
                                 bucket=6))
    from repro.models import init_params
    params = init_params(CFG, jax.random.PRNGKey(5))
    opt = adamw(constant(1e-3))
    opt_state = opt.init(params)
    before = TRACE_COUNTS["train_step"]
    key = jax.random.PRNGKey(6)
    rng = np.random.default_rng(0)
    for t in range(8):
        live = np.sort(rng.choice(N, 5, replace=False)).astype(np.int32)
        idx = np.concatenate([live, live[:1]]).astype(np.int32)
        valid = np.arange(6) < 5
        key, kd, ks = jax.random.split(key, 3)
        params, opt_state, _, _ = fn(params, opt_state, None,
                                     ds.batch(kd, t), ks,
                                     jnp.asarray(idx), jnp.asarray(valid))
    assert TRACE_COUNTS["train_step"] - before == 1


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))
