"""Membership-conformance property suite: EVERY registered aggregator,
seeded invariants across rosters (the PR-4 elastic-membership gate).

The survey's guarantees are statements about the live (n, f): Table-2
rules tolerate f of n agents, and under elastic membership both numbers
move.  This suite pins the properties the elastic layer rests on, for the
whole registry (a new `register_aggregator` call fails the coverage test
until it declares a conformance row here):

  1. permutation invariance — relabeling live agents cannot change the
     estimate (positional grouping rules are exempt and say so);
  2. departed-content invariance — a masked-out (departed) agent's buffer
     cannot influence the estimate AT ALL, asserted bit-for-bit against
     adversarial garbage in the dead rows (this is what makes ghost-padded
     bucket stacks sound);
  3. full-roster identity — mask=all-live degenerates to the plain path;
  4. documented masked semantics — the masked/weighted path equals the
     impute-then-scale law (or the fused weight-folding law for
     weight-decomposable fused impls), recomputed here from public tree
     helpers, for impl="gather" AND the default impl (pins the fused
     masked kernels to the tree-level reference);
  5. monotone-f breakdown — with <= f adversaries the estimate stays
     within a bounded neighbourhood of the honest mean INDEPENDENT of the
     attack magnitude (and inside the per-coordinate honest hull for the
     selection/order-statistic rules); with a beyond-f majority the
     estimate demonstrably breaks (deviation scales with the attack);
  6. respecialize-vs-fresh-build parity — `spec.respecialize(n)` is
     dataclass-equal AND bit-for-bit equal to `make_spec(..., n=n)` for
     every bucket, wrappers included.

Seeded ``jax.random`` / ``numpy`` fuzz grids only — no ``hypothesis``
(not installed; the importorskip pattern stays out of tier-1).  The
trace-level churn fuzz is cheap host-side numpy; the training-loop churn
fuzz cases are auto-marked ``slow`` by conftest (name contains
``churn_fuzz``) so tier-1 stays fast.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import (elastic, frac, list_aggregators,
                                    make_spec, tree_weighted_sum)

N, F, D = 12, 2, 48

# conformance rows: how to build each registered rule, and which laws it
# is exempt from (with the reason encoded as the flag name)
RULES = {
    "mean": dict(f=0),
    "krum": dict(),
    "multi_krum": dict(hyper={"m": 2}),
    "m_krum": dict(hyper={"m": 2}),
    "mda": dict(),
    "cge": dict(),
    "cgc": dict(),
    "zeno": dict(hyper={"ema": 0.2, "rho": 1e-4}, stateful=True),
    "zeno_pp": dict(stateful=True, own_masked=True),
    "coordinate_median": dict(),
    "trimmed_mean": dict(),
    "phocas": dict(),
    "mean_around_median": dict(),
    "geometric_median": dict(),
    "rfa": dict(),
    "median_of_means": dict(grouping=True),
    "bulyan": dict(f=1),                       # needs n >= 4f + 3
    # compressed-exchange rules (PR 9): sign_sgd's estimate lives on the
    # ±1 hypercube (bounded_output — magnitude can never scale the
    # deviation; a beyond-f majority steers the VOTE direction instead);
    # sparse_mean is an undefended weighted mean over sent coordinates
    # (fragile: one adversary breaks it, exactly like mean)
    "sign_sgd": dict(bounded_output=True),
    "sparse_mean": dict(f=0, own_masked=True, fragile=True),
    # defenses with memory (PR 10): centered_clip iterates a tau-clip
    # around the CARRIED center, so its estimate moves at most
    # iters * tau per round regardless of coalition size — the beyond-f
    # break is a steered (but still magnitude-saturated) center, asserted
    # by the clip_bounded branch; its masked law is the zero-gated clip
    # sum around the carried state, not the impute-then-scale law
    "centered_clip": dict(stateful=True, own_masked=True, clip_bounded=True,
                          hyper={"tau": 1.0}),
    "server_momentum": dict(wrapper=True, stateful=True,
                            hyper={"beta": 0.9}),
    "clipped": dict(wrapper=True, hyper={"tau": 50.0}),
    "bucketed": dict(wrapper=True, grouping=True, hyper={"group_size": 2}),
    "staleness_discounted": dict(wrapper=True, staleness=True),
}

# rules whose estimate must stay inside the per-coordinate honest hull at
# <= f adversaries (selection / order-statistic rules; the clipping and
# fixed-point rules are bounded but legitimately hull-free — cgc averages
# clipped adversarial DIRECTIONS, gm/mom are pulled an epsilon toward them)
HULL_RULES = {"krum", "multi_krum", "m_krum", "mda", "cge", "zeno",
              "zeno_pp", "coordinate_median", "trimmed_mean", "phocas",
              "mean_around_median", "bulyan"}


def build(rule, f=None, n=N, impl="auto"):
    cfg = RULES[rule]
    f = cfg.get("f", F) if f is None else f
    hyper = dict(cfg.get("hyper", {}))
    if cfg.get("wrapper"):
        inner = make_spec("trimmed_mean", f=f, n=n, impl=impl)
        return make_spec(rule, f=f, inner=inner, n=n, **hyper)
    return make_spec(rule, f=f, n=n, impl=impl, **hyper)


def data(n, d, seed, scale=2.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * scale


def state_for(spec, g):
    """Meaningful aggregator state: the honest-mean descent direction (the
    validation gradient Zeno assumes; zeno_pp's EMA warm start)."""
    if not spec.stateful:
        return None
    st = spec.init_state(g[0])
    if "server_grad" in st:
        st = {**st, "server_grad": jnp.mean(g, axis=0)}
    return st


def drop_mask(n, k, seed):
    gone = jax.random.choice(jax.random.PRNGKey(1000 + seed), n, shape=(k,),
                             replace=False)
    return jnp.ones((n,), bool).at[gone].set(False)


# ---------------------------------------------------------------------------
# 0. coverage: the registry and this suite must agree EXACTLY


def test_every_registered_aggregator_is_covered():
    # registrations named test_only_* are throwaway fixtures from other
    # suites (test_aggregator_spec's extensibility contract) — everything
    # else in the registry must declare a conformance row here
    registered = {n for n in list_aggregators()
                  if not n.startswith("test_only")}
    assert set(RULES) == registered, (
        "a rule was (de)registered without a conformance row — every "
        "registered aggregator must declare its membership behaviour here")


# ---------------------------------------------------------------------------
# 1. permutation invariance of live rows


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("rule", sorted(RULES))
def test_permutation_invariance(rule, seed):
    if RULES[rule].get("grouping"):
        pytest.skip(f"{rule} groups rows positionally (documented)")
    spec = build(rule)
    g = data(N, D, seed)
    st = state_for(spec, g)
    perm = jax.random.permutation(jax.random.PRNGKey(77 + seed), N)
    a = np.asarray(spec.aggregate(g, state=st))
    b = np.asarray(spec.aggregate(g[perm], state=st))
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5, err_msg=rule)


# ---------------------------------------------------------------------------
# 2. departed agents cannot influence the estimate — bit for bit


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("rule", sorted(RULES))
def test_departed_row_content_is_irrelevant(rule, seed):
    spec = build(rule)
    if not spec.caps.masked_capable:
        pytest.skip(f"{rule} does not support masked aggregation")
    g = data(N, D, 10 + seed)
    mask = drop_mask(N, 3, seed)
    st = state_for(spec, g)
    # the departed rows turn into adversarial garbage (sign-flipped and
    # blown up; finite so 0 * garbage stays exactly 0 in the weighted sums)
    garbage = jnp.where(mask[:, None], g, -1e6 * (g + 3.0))
    a = np.asarray(spec.aggregate(g, mask=mask, state=st))
    b = np.asarray(spec.aggregate(garbage, mask=mask, state=st))
    np.testing.assert_array_equal(a, b, err_msg=rule)


# ---------------------------------------------------------------------------
# 3. the full roster masked is the plain path


@pytest.mark.parametrize("rule", sorted(RULES))
def test_full_roster_mask_is_identity(rule):
    spec = build(rule)
    if not spec.caps.masked_capable:
        pytest.skip(f"{rule} does not support masked aggregation")
    g = data(N, D, 5)
    st = state_for(spec, g)
    a = np.asarray(spec.aggregate(g, state=st))
    b = np.asarray(spec.aggregate(g, mask=jnp.ones((N,), bool), state=st))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7, err_msg=rule)


# ---------------------------------------------------------------------------
# 4. documented masked semantics, recomputed from public helpers


def expected_masked(spec, g, mask, w, st):
    """The engine's documented masked law, rebuilt outside the engine.
    Coordinate-wise order statistics and the sign vote: the plain rule on
    the GATHERED arrived subset (absent rows are never statistics),
    scaled by tot/cnt.  Everything else: impute departed rows at the
    delivered weighted mean, run the plain rule, scale by tot/cnt —
    except weight-decomposable FUSED impls, which fold the per-agent
    weights into the rule's selection weights."""
    mf = mask.astype(jnp.float32)
    wv = (mf if w is None else w.astype(jnp.float32) * mf)
    cnt = jnp.maximum(mf.sum(), 1.0)
    tot = jnp.maximum(wv.sum(), 1e-30)
    if spec.name in ("phocas", "mean_around_median"):
        # the two-stage trust window over the ARRIVED rows only: center
        # from the arrived-window order statistic, then the cnt - f
        # arrived values closest to it (stable ties), scaled by tot/cnt
        live = np.flatnonzero(np.asarray(mask))
        sub = np.asarray(g, np.float32)[live]
        c, f = len(live), spec.f
        s = np.sort(sub, axis=0)
        if spec.name == "phocas":
            b = min(f, (c - 1) // 2)
            center = s[b:c - b].mean(axis=0)
        else:
            lo = (c - 1) // 2
            center = s[lo:c - lo].mean(axis=0)
        k = max(c - f, 1)
        idx = np.argsort(np.abs(sub - center[None]), axis=0,
                         kind="stable")[:k]
        agg = np.take_along_axis(sub, idx, axis=0).mean(axis=0)
        return agg * float(tot / cnt)
    if spec.name in ("coordinate_median", "trimmed_mean", "sign_sgd"):
        live = np.flatnonzero(np.asarray(mask))
        sub = np.asarray(g, np.float32)[live]
        if spec.name == "sign_sgd":
            agg = np.sign(np.sign(sub).sum(axis=0))
        else:
            s = np.sort(sub, axis=0)
            c = len(live)
            b = 0 if spec.name == "coordinate_median" else min(
                spec.f if spec.hp("beta") is None else
                int(np.ceil(spec.hp("beta") * N)), (N - 1) // 2)
            lo = min(b, (c - 1) // 2) if spec.name == "trimmed_mean" \
                else (c - 1) // 2
            agg = s[lo:c - lo].mean(axis=0)
        return agg * float(tot / cnt)
    mean_w = tree_weighted_sum(g, wv / tot)
    imputed = jnp.where(mask[:, None], g, mean_w[None])
    if spec.caps.weight_decomposable and spec.impl == "fused":
        row_w = jnp.where(mask, wv, tot / cnt)
        rule_w = spec.weights(imputed, state=st)
        fw = rule_w * row_w
        fw = fw * (rule_w.sum() / jnp.maximum(fw.sum(), 1e-30))
        return tree_weighted_sum(imputed, fw)
    agg = spec.aggregate(imputed, state=st)
    return (agg.astype(jnp.float32) * (tot / cnt)).astype(agg.dtype)


@pytest.mark.parametrize("impl", ["gather", "auto"])
@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("rule", sorted(RULES))
def test_masked_semantics_match_documented_law(rule, seed, impl):
    cfg = RULES[rule]
    if cfg.get("wrapper") or cfg.get("own_masked") or rule == "mean":
        pytest.skip(f"{rule} documents its own masked semantics")
    spec = build(rule, impl=impl)
    g = data(N, D, 20 + seed)
    mask = drop_mask(N, 3, seed)
    w = jax.random.uniform(jax.random.PRNGKey(30 + seed), (N,), minval=0.3,
                           maxval=1.0)
    st = state_for(spec, g)
    out = np.asarray(spec.aggregate(g, mask=mask, weights=w, state=st))
    expect = np.asarray(expected_masked(spec, g, mask, w, st))
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6,
                               err_msg=f"{rule}/{spec.impl}")


def test_mean_masked_is_exact_subset_mean():
    """`mean` overrides the impute law: masked aggregation IS the weighted
    mean of the live rows — i.e. aggregating n_live rows plain equals
    aggregating n_max rows under the roster mask (roster-subset
    equivalence proper, the property ghost-free elastic packing relies
    on)."""
    for seed in (0, 1, 2):
        g = data(N, D, 40 + seed)
        mask = drop_mask(N, 4, seed)
        live = np.flatnonzero(np.asarray(mask))
        spec = make_spec("mean", n=N)
        out = np.asarray(spec.aggregate(g, mask=mask))
        sub = np.asarray(make_spec("mean", n=len(live)).aggregate(g[live]))
        np.testing.assert_allclose(out, sub, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("impl", ["pallas", "gather"])
@pytest.mark.parametrize("rule",
                         ["coordinate_median", "trimmed_mean", "sign_sgd"])
def test_attack_does_not_leak_through_absence(rule, impl):
    """THE masked-robustness regression: under the old impute-at-mean law,
    absent rows were imputed at the (attack-contaminated) delivered mean
    and landed INSIDE the trim window — with 2 of 8 rows absent and 2
    Byzantine rows at 1e6, masked trimmed_mean returned ~1e6/6 instead of
    the honest statistic, so a single straggler let a large_value attack
    straight through.  The arrived-window law keeps the f-of-arrived
    breakdown bound: the result must stay at honest magnitude."""
    spec = build(rule, impl=impl)
    g = data(N, D, 77) * 0.1                       # honest rows, O(0.1)
    g = jnp.asarray(g).at[0].set(1e6).at[1].set(1e6)   # 2 Byzantine
    mask = jnp.ones((N,), bool).at[-2:].set(False)     # 2 honest absent
    out = np.asarray(spec.aggregate(g, mask=mask))
    assert np.isfinite(out).all(), rule
    assert float(np.max(np.abs(out))) < 10.0, (rule, impl, out[:4])


@pytest.mark.parametrize("impl", ["gather", "auto"])
@pytest.mark.parametrize("rule", ["phocas", "mean_around_median"])
def test_trust_window_attack_does_not_leak_through_absence(rule, impl):
    """The same regression for the two-stage trust-window rules: under the
    old impute-at-mean law the ghost rows sat at the contaminated mean and
    the closest-to-center stage happily kept them — with 2 of 12 rows
    absent and 2 Byzantine rows at 1e6, masked phocas returned an
    attack-scaled estimate.  The arrived-window law (center AND window
    both over arrived rows only, absent rows at +inf distance) keeps the
    result at honest magnitude."""
    spec = build(rule, impl=impl)
    g = data(N, D, 77) * 0.1                       # honest rows, O(0.1)
    g = jnp.asarray(g).at[0].set(1e6).at[1].set(1e6)   # 2 Byzantine
    mask = jnp.ones((N,), bool).at[-2:].set(False)     # 2 honest absent
    out = np.asarray(spec.aggregate(g, mask=mask))
    assert np.isfinite(out).all(), rule
    assert float(np.max(np.abs(out))) < 10.0, (rule, impl, out[:4])


# ---------------------------------------------------------------------------
# 5. monotone-f breakdown: bounded at f, demonstrably broken beyond f


def attack_stack(n, a, L, seed, d=32):
    """(stack, honest_rows): n - a honest rows clustered at a random
    center, a colluding adversaries at magnitude L opposing it."""
    key = jax.random.PRNGKey(500 + seed)
    k1, k2 = jax.random.split(key)
    center = jax.random.normal(k1, (d,))
    center = center / jnp.linalg.norm(center) * 3.0
    honest = center[None] + 0.1 * jax.random.normal(k2, (n - a, d))
    adv = jnp.broadcast_to(-L * center[None] / 3.0, (a, d))
    return jnp.concatenate([honest, adv], axis=0), honest


def deviation(spec, n, a, L, seed):
    g, honest = attack_stack(n, a, L, seed)
    perm = jax.random.permutation(jax.random.PRNGKey(900 + seed), n)
    g = g[perm]                         # adversary position is arbitrary
    st = state_for(spec, jnp.asarray(honest))
    agg = spec.aggregate(g, state=st)
    hmean = jnp.mean(honest, axis=0)
    dev = float(jnp.linalg.norm(agg.astype(jnp.float32) - hmean))
    spread = float(jnp.max(jnp.linalg.norm(honest - hmean[None], axis=1)))
    lo = np.asarray(honest.min(axis=0))
    hi = np.asarray(honest.max(axis=0))
    in_hull = bool(np.all(np.asarray(agg) >= lo - 1e-3)
                   and np.all(np.asarray(agg) <= hi + 1e-3))
    return dev, spread, in_hull


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("rule", sorted(r for r in RULES
                                        if not RULES[r].get("wrapper")))
def test_breakdown_bounded_at_f(rule, seed):
    """<= f colluding adversaries of UNBOUNDED magnitude: the estimate
    stays within a bounded neighbourhood of the honest mean, and the bound
    does not grow with the attack magnitude."""
    # (grouping rules need no special handling here: a <= f <= group
    # count, and the permutation inside deviation() scatters adversaries)
    spec = build(rule)
    a = spec.f
    dev1, spread, hull1 = deviation(spec, N, a, 1e3, seed)
    dev2, _, _ = deviation(spec, N, a, 1e4, seed)
    bound = 10.0 * max(spread, 1e-3)
    if RULES[rule].get("bounded_output"):
        # the estimate lives on the ±1 hypercube: its distance to the
        # honest mean is bounded by the cube diagonal, not the honest
        # spread — still attack-magnitude-independent (the next assert)
        bound += float(np.sqrt(32))
    assert dev1 <= bound and dev2 <= bound, (
        f"{rule}: deviation {dev1:.3g}/{dev2:.3g} exceeds {bound:.3g} "
        f"with a={a} <= f adversaries")
    assert dev2 <= 2.0 * dev1 + 1e-3, (
        f"{rule}: deviation grows with attack magnitude at a={a} <= f "
        f"({dev1:.3g} -> {dev2:.3g})")
    if rule in HULL_RULES:
        assert hull1, f"{rule}: left the per-coordinate honest hull at f"


@pytest.mark.parametrize("rule", sorted(r for r in RULES
                                        if not RULES[r].get("wrapper")))
def test_breakdown_beyond_f(rule):
    """The tolerance claim is tight: a beyond-f coalition (one adversary
    for the undefended mean, a majority for everything else) steers the
    estimate, with deviation scaling with the attack magnitude."""
    spec = build(rule)
    if RULES[rule].get("bounded_output"):
        # a 1-bit estimate cannot scale with the attack magnitude — the
        # break is a STEERED VOTE: a beyond-f majority flips the estimate's
        # coordinate signs to the adversarial direction, while <= f
        # adversaries leave the honest direction in charge
        g_ok, honest = attack_stack(N, spec.f, 1e3, 0)
        g_bad, _ = attack_stack(N, N // 2 + 1, 1e3, 0)
        direction = jnp.sign(jnp.mean(honest, axis=0))
        aligned = lambda g: float(jnp.mean(
            jnp.sign(spec.aggregate(g)) == direction))
        assert aligned(g_ok) > 0.9, "honest majority lost its own vote"
        assert aligned(g_bad) < 0.1, (
            f"{rule}: a beyond-f majority failed to steer the sign vote")
        return
    if RULES[rule].get("clip_bounded"):
        # tau-clipping saturates: the estimate moves at most iters * tau
        # per round, so deviation CANNOT scale with the attack magnitude
        # even beyond f — the break is a STEERED CENTER instead: a
        # majority drags the carried center measurably farther than <= f
        # adversaries ever can, while staying magnitude-saturated
        dev_f, _, _ = deviation(spec, N, spec.f, 1e3, 0)
        dev_maj1, _, _ = deviation(spec, N, N // 2 + 1, 1e3, 0)
        dev_maj2, _, _ = deviation(spec, N, N // 2 + 1, 1e4, 0)
        assert dev_maj1 >= 3.0 * max(dev_f, 1e-6), (
            f"{rule}: a beyond-f majority failed to steer the clip center "
            f"({dev_f:.3g} -> {dev_maj1:.3g})")
        assert dev_maj2 <= 2.0 * dev_maj1 + 1e-3, (
            f"{rule}: clip saturation broken — deviation scaled with the "
            f"attack magnitude ({dev_maj1:.3g} -> {dev_maj2:.3g})")
        return
    a_bad = (1 if rule == "mean" or RULES[rule].get("fragile")
             else (N // 2 + 1))
    dev1, _, _ = deviation(spec, N, a_bad, 1e3, 0)
    dev2, _, _ = deviation(spec, N, a_bad, 1e4, 0)
    assert dev2 >= 5.0 * max(dev1, 1e-6), (
        f"{rule}: {a_bad} adversaries failed to break the rule "
        f"({dev1:.3g} -> {dev2:.3g}) — the f bound is not tight")


# ---------------------------------------------------------------------------
# 6. respecialize-vs-fresh-build parity (every rule, wrappers included)

BUCKETS = (6, 8, 12)


def build_elastic(rule):
    cfg = RULES[rule]
    el = elastic(N, buckets=BUCKETS)
    fp = frac(1.0 / 6.0)
    hyper = dict(cfg.get("hyper", {}))
    f_static = cfg.get("f")
    if cfg.get("wrapper"):
        inner = make_spec("trimmed_mean", f=fp, n=el)
        return make_spec(rule, f=inner.f, inner=inner, n=N, **hyper)
    if f_static is not None:                 # rules pinning their own f
        return make_spec(rule, f=f_static, n=el, **hyper)
    return make_spec(rule, f=fp, n=el, **hyper)


@pytest.mark.parametrize("rule", sorted(RULES))
def test_respecialize_equals_fresh_build(rule):
    cfg = RULES[rule]
    spec = build_elastic(rule)
    fp = frac(1.0 / 6.0)
    for b in BUCKETS:
        re = spec.respecialize(b)
        assert re is spec.respecialize(b), "bucket specs must be cached"
        if cfg.get("wrapper"):
            fresh = make_spec(rule, f=spec.f,
                              inner=make_spec("trimmed_mean",
                                              f=fp.resolve(b), n=b),
                              n=N, **dict(cfg.get("hyper", {})))
        else:
            f_b = cfg.get("f", fp.resolve(b)) if "f" in cfg \
                else fp.resolve(b)
            fresh = make_spec(rule, f=f_b, n=b,
                              **dict(cfg.get("hyper", {})))
        assert re == fresh, (
            f"{rule}@{b}: respecialize() diverged from a fresh build\n"
            f"  respecialized: {re}\n  fresh:         {fresh}")
        g = data(b, D, b)
        st = state_for(re, g)
        np.testing.assert_array_equal(
            np.asarray(re.aggregate(g, state=st)),
            np.asarray(fresh.aggregate(g, state=st)),
            err_msg=f"{rule}@{b}")
    # live counts between buckets map UP to the next capacity
    assert spec.respecialize(7) is spec.respecialize(8)
    assert spec.respecialize(5) is spec.respecialize(6)
    with pytest.raises(ValueError):
        spec.respecialize(N + 1)


def test_nested_wrappers_delegate_elasticity():
    """Elasticity lives on the inner rule, however deep the wrapper chain:
    elastic_n reads through every level and respecialize() re-specializes
    the rule that actually executes."""
    from repro.core.aggregators import clipped, staleness_discounted
    el = elastic(N, buckets=BUCKETS)
    inner = make_spec("trimmed_mean", f=frac(1.0 / 6.0), n=el)
    nested = clipped(staleness_discounted(inner), tau=50.0)
    assert nested.elastic is None and nested.elastic_n is el
    re6 = nested.respecialize(6)
    assert re6 is nested.respecialize(5), "same bucket -> same object"
    assert re6.inner.inner.n == 6 and re6.inner.inner.f == 1
    assert re6.inner.inner == make_spec("trimmed_mean", f=1, n=6)
    # and the loops detect the elastic chain: a wrapped elastic spec under
    # churn takes the bucketed path (per-bucket compiles, live-n plans)
    from repro.configs import get_config
    from repro.core.tracecount import TRACE_COUNTS
    from repro.data import SyntheticLM
    from repro.optim import adamw, constant
    from repro.simulator import Rejoin, SimConfig, async_train_loop
    from repro.training import ByzantineConfig

    cfg = get_config("paper-100m-smoke").replace(vocab_size=64,
                                                 dtype="float32")
    ds = SyntheticLM(vocab_size=64, seq_len=8, n_agents=N,
                     per_agent_batch=1)
    wrapped = clipped(make_spec("trimmed_mean", f=frac(1.0 / 6.0),
                                n=el), tau=50.0)
    bz = ByzantineConfig(n_agents=N, f=wrapped.f, aggregator=wrapped)
    sim = SimConfig(faults=(Rejoin(agents=(0, 1, 2, 3), leave_at=2,
                                   rejoin_at=8),), seed=0)
    before = TRACE_COUNTS["async_step"]
    _, h = async_train_loop(cfg, bz, adamw(constant(1e-3)), ds, steps=10,
                            sim=sim, log_every=10, log_fn=lambda *_: None)
    assert np.isfinite(h[-1]["loss"])
    used = TRACE_COUNTS["async_step"] - before
    assert 1 <= used <= len(BUCKETS), used


def test_static_spec_respecialize_contract():
    s = make_spec("trimmed_mean", f=2, n=8)
    assert s.respecialize(8) is s
    with pytest.raises(ValueError, match="elastic"):
        s.respecialize(6)
    assert make_spec("trimmed_mean", f=2).respecialize(5).f == 2


def test_frac_policy_tracks_live_roster():
    spec = make_spec("trimmed_mean", f=frac(0.25), n=elastic(12, (4, 8, 12)))
    assert spec.f == 3
    assert spec.respecialize(12).f == 3
    assert spec.respecialize(8).f == 2
    assert spec.respecialize(4).f == 1
    assert spec.respecialize(3).f == 1       # pads up to bucket 4
    # a static int f is carried unchanged
    s2 = make_spec("trimmed_mean", f=1, n=elastic(12, (4, 8, 12)))
    assert {s2.respecialize(b).f for b in (4, 8, 12)} == {1}


# ---------------------------------------------------------------------------
# 7. roster-trace churn fuzz (host-side, cheap) — the simulator keeps the
#    membership accounting honest under composed join/leave/churn faults


@pytest.mark.parametrize("quorum", [None, 4])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_roster_trace_invariants(seed, quorum):
    from repro.simulator import (Churn, Join, MessageDrop, Rejoin,
                                 Straggler, compile_schedule,
                                 simulate_arrivals)
    n, steps = 8, 40
    tr = compile_schedule(
        (Join(agents=(6, 7), at=5),
         Rejoin(agents=(0,), leave_at=8, rejoin_at=14),
         Churn(rate=0.15, mean_out=2.0, agents=(1, 2, 3)),
         Straggler(dist="lognormal", scale=0.5),
         MessageDrop(p=0.1)),
        n, steps + 1, seed=seed)
    at = simulate_arrivals(tr, steps, quorum=quorum, max_staleness=3)
    assert at.roster is not None and at.roster.shape == (steps, n)
    # an agent absent from the roster can neither arrive ...
    assert not at.contrib[~at.roster].any(), "non-member contributed"
    # ... nor dispatch ...
    assert not at.refresh[~(tr.roster[:steps] & tr.alive[:steps])].any()
    # ... nor count toward quorum: met steps delivered >= the live-capped
    # quorum, missed steps genuinely fell short
    q0 = n if quorum is None else quorum
    for t in range(steps):
        live = int(at.roster[t].sum())
        q_t = live if quorum is None else min(q0, live)
        arrived = int(at.contrib[t].sum())
        if at.quorum_met[t]:
            assert arrived >= q_t and live > 0, (t, arrived, q_t)
        else:
            assert arrived < q_t or live == 0, (t, arrived, q_t)
    assert at.staleness[at.contrib].max(initial=0) <= 3
    # every contribution's in-flight [dispatch, arrival] window lies
    # inside the sender's membership (a mid-flight departure kills the
    # delivery even if the agent rejoined before the arrival instant)
    for t, i in zip(*np.nonzero(at.contrib)):
        v = t - at.staleness[t, i]
        assert tr.roster[v:t + 1, i].all(), (t, i, v)
    # determinism: the trace is a pure function of (specs, n, steps, seed)
    at2 = simulate_arrivals(tr, steps, quorum=quorum, max_staleness=3)
    for x, y in ((at.contrib, at2.contrib), (at.staleness, at2.staleness),
                 (at.vclock, at2.vclock), (at.roster, at2.roster)):
        assert np.array_equal(x, y)


# ---------------------------------------------------------------------------
# 8. elastic x coded — the draco repetition decode over bucket-packed
#    rosters obeys the same membership laws as the registered rules: the
#    group tables are re-derived per bucket (coding_groups, ragged trailing
#    group allowed) and the vote runs over DELIVERED rows only

CR = 3                                   # repetition factor under test
CODED_BUCKETS = (5, 9, 12)               # 5 exercises the ragged trailer


def coded_bucket_stack(b, d=32, seed=0):
    """A bucket-packed coded stack: identical honest replicas per group
    under the bucket's own (possibly ragged) group table."""
    from repro.core.redundancy.coding import coding_groups
    groups = coding_groups(b, CR, allow_ragged=True)
    k = int(groups.max()) + 1
    true = jax.random.normal(jax.random.PRNGKey(seed), (k, d))
    return jnp.asarray(true)[np.asarray(groups)], groups, true


@pytest.mark.parametrize("b", CODED_BUCKETS)
def test_coded_vote_exact_under_bucket_byzantine(b):
    """Vote exactness per live group: with <= (s_g - 1) // 2 Byzantine
    rows in a group of size s_g, the decode recovers the honest mean of
    the group values EXACTLY (up to fp32) — for every elastic bucket."""
    from repro.core.redundancy.coding import flat_draco_aggregate
    g, groups, true = coded_bucket_stack(b, seed=b)
    gj = g
    for grp in range(int(groups.max()) + 1):
        slots = np.flatnonzero(np.asarray(groups) == grp)
        for s in slots[: (len(slots) - 1) // 2]:
            gj = gj.at[int(s)].set(1e4 * (grp + 1.0))
    out = np.asarray(flat_draco_aggregate(gj, CR, groups=groups))
    ref = np.asarray(jnp.mean(true, axis=0))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b", (9, 12))
def test_coded_departed_content_invariance(b):
    """A departed (masked-out) agent's buffer cannot influence the coded
    estimate AT ALL — bit-for-bit, adversarial finite garbage in the dead
    rows (what makes ghost-padded coded bucket stacks sound)."""
    from repro.core.redundancy.coding import flat_draco_aggregate
    g, groups, _ = coded_bucket_stack(b, seed=100 + b)
    mask = np.ones(b, bool)
    for grp in range(int(groups.max()) + 1):
        mask[np.flatnonzero(np.asarray(groups) == grp)[0]] = False
    mj = jnp.asarray(mask)
    garbage = jnp.where(mj[:, None], g, 7e5 * (g - 2.0))
    a = np.asarray(flat_draco_aggregate(g, CR, mask=mj, groups=groups))
    bb = np.asarray(flat_draco_aggregate(garbage, CR, mask=mj,
                                         groups=groups))
    np.testing.assert_array_equal(a, bb)


def test_coded_slot_permutation_within_groups_bitwise():
    """Which SLOT inside a group carries the Byzantine row is irrelevant:
    honest replicas are identical, so relabeling agents within their
    groups leaves the decode bit-for-bit unchanged."""
    from repro.core.redundancy.coding import flat_draco_aggregate
    g, groups, _ = coded_bucket_stack(12, seed=7)
    byz_lo = g
    byz_hi = g
    for grp in range(int(groups.max()) + 1):
        slots = np.flatnonzero(np.asarray(groups) == grp)
        byz_lo = byz_lo.at[int(slots[0])].set(-3e4)
        byz_hi = byz_hi.at[int(slots[-1])].set(-3e4)
    np.testing.assert_array_equal(
        np.asarray(flat_draco_aggregate(byz_lo, CR, groups=groups)),
        np.asarray(flat_draco_aggregate(byz_hi, CR, groups=groups)))


def test_coded_full_roster_mask_is_identity():
    from repro.core.redundancy.coding import flat_draco_aggregate
    g, groups, _ = coded_bucket_stack(12, seed=3)
    np.testing.assert_array_equal(
        np.asarray(flat_draco_aggregate(g, CR, groups=groups)),
        np.asarray(flat_draco_aggregate(g, CR, mask=jnp.ones(12, bool),
                                        groups=groups)))


@pytest.mark.parametrize("rule", ["trimmed_mean", "krum"])
@pytest.mark.parametrize("seed", [0, 1])
def test_training_churn_fuzz(rule, seed):
    """Seeded end-to-end churn fuzz (auto-marked slow by conftest): a
    composed join/leave/churn schedule through the elastic async loop
    stays finite, defends against the scheduled attack, and compiles at
    most once per bucket."""
    from repro.configs import get_config
    from repro.core.tracecount import TRACE_COUNTS
    from repro.data import SyntheticLM
    from repro.optim import adamw, constant
    from repro.simulator import (Churn, Join, SimConfig, Straggler,
                                 async_train_loop)
    from repro.training import ByzantineConfig

    cfg = get_config("paper-100m-smoke").replace(vocab_size=64,
                                                 dtype="float32")
    ds = SyntheticLM(vocab_size=64, seq_len=16, n_agents=8,
                     per_agent_batch=2)
    el = elastic(8, buckets=(4, 6, 8))
    spec = make_spec(rule, f=frac(0.25), n=el)
    bz = ByzantineConfig(n_agents=8, f=2, aggregator=spec,
                         attack="sign_flip")
    sim = SimConfig(faults=(Join(agents=(7,), at=4),
                            Churn(rate=0.15, mean_out=2.0,
                                  agents=(0, 1, 2, 3)),
                            Straggler(dist="lognormal", scale=0.5)),
                    quorum=4, max_staleness=3, seed=seed)
    before = TRACE_COUNTS["async_step"]
    _, h = async_train_loop(cfg, bz, adamw(constant(3e-3)), ds, steps=40,
                            sim=sim, log_every=20, log_fn=lambda *_: None)
    assert np.isfinite(h[-1]["loss"]) and h[-1]["loss"] < 2.0
    assert TRACE_COUNTS["async_step"] - before <= len(el.buckets)
