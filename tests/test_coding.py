"""Gradient coding (Draco / DETOX / reactive redundancy) — survey §3.3.3."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.redundancy import (detox_aggregate, draco_aggregate,
                                   init_reactive)
from repro.core.redundancy.coding import majority_vote, tree_draco_aggregate
from repro.core.redundancy.reactive import (check_and_aggregate,
                                            plain_aggregate)

KEY = jax.random.PRNGKey(0)


def coded_stack(n=12, r=3, d=20, corrupt_per_group=1):
    k = n // r
    true = jax.random.normal(KEY, (k, d))
    g = jnp.repeat(true, r, axis=0)
    for grp in range(k):
        for j in range(corrupt_per_group):
            g = g.at[grp * r + j].set(1e5 * (grp + 1))
    return g, jnp.mean(true, axis=0)


def test_majority_vote_recovers_plurality():
    rows = jnp.stack([jnp.ones(8), jnp.ones(8), 5 * jnp.ones(8)])
    np.testing.assert_allclose(np.asarray(majority_vote(rows)), 1.0)


def test_draco_exact_recovery_under_max_faults():
    # r=3 tolerates (r-1)/2 = 1 fault per group
    g, ref = coded_stack(corrupt_per_group=1)
    out = draco_aggregate(g, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


def test_draco_breaks_beyond_threshold():
    g, ref = coded_stack(corrupt_per_group=2)   # 2 > (3-1)/2 — majority lies
    out = draco_aggregate(g, 3)
    assert float(jnp.max(jnp.abs(out - ref))) > 1.0


def test_tree_draco_matches_dense():
    g, ref = coded_stack()
    tree = {"w": g.reshape(12, 4, 5), "b": g[:, :4]}
    out = tree_draco_aggregate(tree, 3)
    np.testing.assert_allclose(np.asarray(out["w"]).reshape(-1),
                               np.asarray(draco_aggregate(g, 3)).reshape(-1),
                               rtol=1e-5)


def test_detox_hierarchical():
    g, ref = coded_stack(n=12, r=3)
    out = detox_aggregate(g, r=3, f=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


def test_reactive_detects_and_removes_fixed_byzantine():
    n, d = 8, 10
    truth = jnp.ones((d,))
    state = init_reactive(n)
    # checking iteration: consecutive pairs computed identical shards
    g = jnp.tile(truth, (n, 1))
    g = g.at[3].set(-50.0)              # agent 3 lies
    agg, state = check_and_aggregate(g, state, lambda i: truth)
    assert not bool(state.active[3])
    assert state.detected == 1
    # subsequent plain iterations exclude it
    g2 = jnp.tile(truth, (n, 1)).at[3].set(99.0)
    out = plain_aggregate(g2, state)
    np.testing.assert_allclose(np.asarray(out), np.asarray(truth), rtol=1e-6)
