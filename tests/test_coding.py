"""Gradient coding (Draco / DETOX / reactive redundancy) — survey §3.3.3."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.redundancy import (detox_aggregate, draco_aggregate,
                                   init_reactive)
from repro.core.redundancy.coding import (coding_groups, draco_assignment,
                                          flat_draco_aggregate, majority_vote,
                                          tree_draco_aggregate)
from repro.core.redundancy.reactive import (check_and_aggregate,
                                            plain_aggregate)

KEY = jax.random.PRNGKey(0)


def coded_stack(n=12, r=3, d=20, corrupt_per_group=1):
    k = n // r
    true = jax.random.normal(KEY, (k, d))
    g = jnp.repeat(true, r, axis=0)
    for grp in range(k):
        for j in range(corrupt_per_group):
            g = g.at[grp * r + j].set(1e5 * (grp + 1))
    return g, jnp.mean(true, axis=0)


def test_majority_vote_recovers_plurality():
    rows = jnp.stack([jnp.ones(8), jnp.ones(8), 5 * jnp.ones(8)])
    np.testing.assert_allclose(np.asarray(majority_vote(rows)), 1.0)


def test_draco_exact_recovery_under_max_faults():
    # r=3 tolerates (r-1)/2 = 1 fault per group
    g, ref = coded_stack(corrupt_per_group=1)
    out = draco_aggregate(g, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


def test_draco_breaks_beyond_threshold():
    g, ref = coded_stack(corrupt_per_group=2)   # 2 > (3-1)/2 — majority lies
    out = draco_aggregate(g, 3)
    assert float(jnp.max(jnp.abs(out - ref))) > 1.0


def test_tree_draco_matches_dense():
    g, ref = coded_stack()
    tree = {"w": g.reshape(12, 4, 5), "b": g[:, :4]}
    out = tree_draco_aggregate(tree, 3)
    np.testing.assert_allclose(np.asarray(out["w"]).reshape(-1),
                               np.asarray(draco_aggregate(g, 3)).reshape(-1),
                               rtol=1e-5)


def test_detox_hierarchical():
    # n=27, r=3 -> k=9 voted gradients -> b=3 buckets: a REAL hierarchy
    # (the historical n=12 shape silently auto-shrank to b=1, i.e. a plain
    # mean with zero breakdown — that shape now raises, see below).
    g, ref = coded_stack(n=27, r=3)
    clean, _ = coded_stack(n=27, r=3, corrupt_per_group=0)
    out = detox_aggregate(g, r=3, f=1)
    # within the vote radius, corruption must not move the output at all
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(detox_aggregate(clean, r=3, f=1)),
                               atol=1e-4)
    # and the robust filter over bucket means stays near the true mean
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=0.5)


def test_detox_rejects_zero_breakdown_bucketing():
    # k=7, f=1: 7 % b forces the auto-shrink down to b=1 < 2f+1 = 3 — a
    # single bucket mean has ZERO breakdown, so this must refuse loudly.
    g, _ = coded_stack(n=21, r=3)
    with pytest.raises(ValueError, match="2f\\+1"):
        detox_aggregate(g, r=3, f=1)


def test_group_size_must_divide_agent_count():
    g = jnp.ones((10, 8))
    with pytest.raises(ValueError, match="n=10.*r=3"):
        draco_aggregate(g, 3)
    with pytest.raises(ValueError, match="n=10"):
        draco_assignment(10, 3)
    with pytest.raises(ValueError, match="group size"):
        coding_groups(10, 4)
    # elastic buckets admit a smaller trailing group instead
    ragged = coding_groups(10, 4, allow_ragged=True)
    np.testing.assert_array_equal(np.asarray(ragged),
                                  [0, 0, 0, 0, 1, 1, 1, 1, 2, 2])


def test_vote_tolerance_not_attacker_inflatable():
    # Regression for the scale = max(sq) vote law: a huge-norm inflater in
    # group 0 used to raise the agreement tolerance GLOBALLY, letting a
    # colluding steerer in group 1 (honest + delta with ||delta||^2 within
    # tol * max_sq) tie the vote and win the slot-order tie-break.  The
    # per-group median-norm scale bounds steering by the honest norms.
    d = 20
    true = jax.random.normal(KEY, (2, d))
    g = jnp.repeat(true, 3, axis=0)            # n=6, r=3
    g = g.at[0].set(1e6)                       # group-0 inflater
    delta = jnp.full((d,), np.sqrt(1e5))       # tiny vs tol * max_sq
    g = g.at[3].set(true[1] + delta)           # group-1 steerer, slot 0
    out = draco_aggregate(g, 3)
    # honest majorities must win both groups: exact recovery of the mean
    # of (true[0], true[1]) up to fp32 — under the old law the steered
    # row wins group 1 and the error is ~ delta/2 per coordinate (~158).
    err = float(jnp.max(jnp.abs(out - jnp.mean(true, axis=0))))
    assert err < 1e-3, err


def test_tree_rides_the_flat_arena_bitwise():
    from repro.core.flat import FlatPlan
    g, _ = coded_stack(n=12, r=3, d=24)
    tree = {"w": g.reshape(12, 6, 4), "b": g[:, :4]}
    plan = FlatPlan.for_tree(tree)
    out = tree_draco_aggregate(tree, 3)
    ref = plan.unravel(flat_draco_aggregate(plan.ravel(tree), 3))
    for key in out:
        np.testing.assert_array_equal(np.asarray(out[key]),
                                      np.asarray(ref[key]))


def test_reactive_detects_and_removes_fixed_byzantine():
    n, d = 8, 10
    truth = jnp.ones((d,))
    state = init_reactive(n)
    # checking iteration: consecutive pairs computed identical shards
    g = jnp.tile(truth, (n, 1))
    g = g.at[3].set(-50.0)              # agent 3 lies
    agg, state = check_and_aggregate(g, state, lambda i: truth)
    assert not bool(state.active[3])
    assert state.detected == 1
    # subsequent plain iterations exclude it
    g2 = jnp.tile(truth, (n, 1)).at[3].set(99.0)
    out = plain_aggregate(g2, state)
    np.testing.assert_allclose(np.asarray(out), np.asarray(truth), rtol=1e-6)
