"""Peer-to-peer architecture (§3.3.5) + graph theory (§2.1) + data-injection
attack and its detection (§4.1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.p2p import (complete_graph, data_injection_attack,
                            detect_injection, erdos_renyi, is_connected,
                            is_f_local, is_r_s_robust, metropolis_weights,
                            p2p_dgd_run, ring_graph, source_component,
                            torus_graph, vertex_connectivity)

KEY = jax.random.PRNGKey(0)


def quad_setup(n=8, d=3, spread=0.2):
    targets = spread * jax.random.normal(KEY, (n, d))
    grad_fn = lambda i, x: x - targets[i]
    x0 = jnp.zeros((n, d)) + 2.0
    return targets, grad_fn, x0


# ---------------- graph theory ----------------

def test_metropolis_doubly_stochastic():
    for adj in (complete_graph(6), ring_graph(8, 2), torus_graph(3, 3)):
        W = metropolis_weights(adj)
        np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-12)
        np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-12)
        assert (W >= 0).all()


def test_connectivity_values():
    assert vertex_connectivity(complete_graph(6)) == 5
    assert vertex_connectivity(ring_graph(8, 1)) == 2
    assert vertex_connectivity(ring_graph(8, 2)) == 4


def test_source_component():
    n = 5
    adj = np.zeros((n, n), bool)
    for i in range(n - 1):           # chain 0 -> 1 -> ... -> 4
        adj[i, i + 1] = True
    comp = source_component(adj)
    assert comp == [0]
    adj[4, 0] = True                  # now a cycle: whole graph is the source
    assert sorted(source_component(adj)) == list(range(n))


def test_f_local():
    adj = complete_graph(6)
    assert is_f_local(adj, byz={0, 1}, f=2)
    assert not is_f_local(adj, byz={0, 1, 2}, f=2)


def test_r_s_robustness_complete_vs_ring():
    assert is_r_s_robust(complete_graph(5), r=2, s=1)
    assert not is_r_s_robust(ring_graph(6, 1), r=2, s=1)


# ---------------- decentralized optimization ----------------

def test_plain_dgd_consensus_no_faults():
    targets, grad_fn, x0 = quad_setup()
    traj = p2p_dgd_run(ring_graph(8, 2), grad_fn, x0, 120)
    final = traj[-1]
    opt = jnp.mean(targets, axis=0)
    assert float(jnp.max(jnp.linalg.norm(final - opt, axis=-1))) < 0.3


def test_ce_and_lf_tolerate_byzantine_broadcast():
    targets, grad_fn, x0 = quad_setup()
    byz = jnp.arange(8) < 2
    byz_fn = lambda k, t, s: jnp.full_like(s, 50.0)
    hm = jnp.mean(targets[2:], axis=0)
    for combine in ("ce", "lf"):
        traj = p2p_dgd_run(complete_graph(8), grad_fn, x0, 80, f=2,
                           combine=combine, byz_mask=byz, byz_fn=byz_fn)
        err = float(jnp.max(jnp.linalg.norm(traj[-1][2:] - hm, axis=-1)))
        assert err < 0.6, (combine, err)
    plain = p2p_dgd_run(complete_graph(8), grad_fn, x0, 80, combine="plain",
                        byz_mask=byz, byz_fn=byz_fn)
    err_plain = float(jnp.max(jnp.linalg.norm(plain[-1][2:] - hm, axis=-1)))
    assert err_plain > 1.0


def test_data_injection_detection():
    """Wu et al. [114]: adversary fakes convergence to a target; the local
    deviation metric flags it."""
    targets, grad_fn, x0 = quad_setup()
    byz = jnp.arange(8) < 1
    target = 10.0 * jnp.ones((3,))
    byz_fn = data_injection_attack(target)
    traj = p2p_dgd_run(complete_graph(8), grad_fn, x0, 60, combine="plain",
                       byz_mask=byz, byz_fn=byz_fn, key=KEY)
    scores = detect_injection(traj, complete_graph(8))
    # every honest agent's most-suspicious neighbour is agent 0
    for i in range(1, 8):
        assert int(np.argmax(scores[i])) == 0


def test_membership_schedule_silences_churned_agents():
    """Membership schedules (Join/Rejoin/Churn rosters) used to raise
    NotImplementedError in the p2p loop; they now fold into the faulted
    adjacency exactly like crashes — churned-out agents freeze (no
    broadcast, no update), the live subgraph keeps mixing, and everyone
    present at the end still converges."""
    from repro.simulator.faults import Churn, Join, Rejoin, compile_schedule

    targets, grad_fn, x0 = quad_setup()
    n, steps = 8, 120
    sched = (Join(agents=(7,), at=10),
             Rejoin(agents=(6,), leave_at=30, rejoin_at=50),
             Churn(rate=0.3, mean_out=3.0, agents=(1, 2, 3)))
    trace = compile_schedule(sched, n, steps + 1, seed=0)
    traj = p2p_dgd_run(ring_graph(8, 2), grad_fn, x0, steps,
                       fault_schedule=trace)
    assert np.isfinite(np.asarray(traj)).all()

    # churned-out members are frozen through their absence: state at the
    # end of an out-round equals state entering it
    roster = np.asarray(trace.roster)
    out_rounds = [(t, i) for t in range(steps) for i in range(n)
                  if not roster[min(t, trace.horizon - 1), i]]
    assert out_rounds, "schedule produced no churned-out rounds"
    for t, i in out_rounds:
        np.testing.assert_array_equal(np.asarray(traj[t + 1][i]),
                                      np.asarray(traj[t][i]))

    # the always-present agents (never scheduled out) still descend to the
    # consensus neighbourhood of the mean target
    always_in = [i for i in range(n) if roster[:, i].all()]
    assert always_in
    opt = jnp.mean(targets, axis=0)
    err = float(jnp.max(jnp.linalg.norm(
        traj[-1][jnp.asarray(always_in)] - opt, axis=-1)))
    assert err < 0.6, err


def test_spec_combine_lifts_table2_into_p2p():
    """Any registered AggregatorSpec works as a p2p combine rule: each
    receiver robustly aggregates its in-neighbourhood through the masked
    engine; honest agents keep descending under a Byzantine broadcaster."""
    from repro.core.aggregators import make_spec

    targets, grad_fn, x0 = quad_setup()
    byz = jnp.arange(8) < 2
    byz_fn = lambda key, t, s: jnp.full_like(s, 40.0)
    hm = jnp.mean(targets[2:], axis=0)
    spec = make_spec("trimmed_mean", f=2, n=8)
    traj = p2p_dgd_run(complete_graph(8), grad_fn, x0, 80, combine=spec,
                       byz_mask=byz, byz_fn=byz_fn)
    err = float(jnp.max(jnp.linalg.norm(traj[-1][2:] - hm, axis=-1)))
    assert np.isfinite(np.asarray(traj)).all()
    assert err < 0.6, err
