"""Chaos test: f-of-r Byzantine-replica-tolerant serving under the
fault-injection schedules (the ROADMAP follow-up from PR 1/2).

``generate_replicated`` decodes with r replicas whose per-step logits are
robustly aggregated; this drives its ``fault_hook`` with a compiled
:class:`~repro.simulator.faults.FaultTrace` (CrashRecover + MessageDrop
over a bounded replica subset) and asserts the decoded stream equals the
clean single-model generation at EVERY step of the trace — greedy decoding
feeds each token forward, so any single-step disagreement diverges the
suffix and fails the array comparison.

Faulty replicas emit adversarial logits (sign-flipped and rescaled — a
strictly harder corruption than the omission faults being scheduled), and
the aggregation rule is the kernel-dispatched ``impl="pallas"``
coordinate median, so the chaos trace also exercises the Pallas path end
to end through the serving engine.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.aggregators import elastic, frac, make_spec
from repro.models import init_params
from repro.serving import generate, generate_replicated
from repro.simulator.faults import (CrashRecover, Join, MessageDrop, Rejoin,
                                    compile_schedule)

R, F_REP = 5, 2                      # replicas / tolerated corruptions
STEPS = 6


def _chaos_trace(steps, seed=3):
    """Faults confined to replicas {3, 4}: at most F_REP corrupted per
    step, as the f-of-r deployment contract requires."""
    return compile_schedule(
        (CrashRecover(rate=0.5, mean_down=2.0, agents=(3,)),
         MessageDrop(p=0.5, agents=(4,))),
        n_agents=R, horizon=steps, seed=seed)


def _faulty_rows(trace, step):
    return (~trace.alive[step]) | trace.drop[step]


def test_replicated_decoding_survives_fault_schedule():
    cfg = get_config("paper-100m-smoke")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 10), 0, cfg.vocab_size)}
    clean = generate(cfg, params, batch, STEPS)

    trace = _chaos_trace(STEPS)
    faulty_steps = [t for t in range(STEPS) if _faulty_rows(trace, t).any()]
    assert faulty_steps, "chaos schedule sampled no faults — raise rates"
    assert all(int(_faulty_rows(trace, t).sum()) <= F_REP
               for t in range(STEPS))

    hits = []

    def fault_hook(step, logits):            # (r, B, V) at the boundary
        rows = _faulty_rows(trace, step)
        if rows.any():
            hits.append(step)
        bad = -7.0 * logits + 3.0            # hostile, confidently wrong
        sel = jnp.asarray(rows)[:, None, None]
        return jnp.where(sel, bad, logits)

    stack = jax.tree.map(lambda l: jnp.stack([l] * R), params)
    spec = make_spec("coordinate_median", f=F_REP, n=R)
    assert spec.impl == "pallas"             # kernel path, end to end
    out = generate_replicated(cfg, stack, batch, STEPS, spec,
                              fault_hook=fault_hook)
    assert hits == faulty_steps              # every scheduled fault fired
    np.testing.assert_array_equal(np.asarray(out), np.asarray(clean))


def _membership_roster(steps):
    """Replica 3 JOINS at step 2; replica 4 crashes out of the roster at
    step 1 and REJOINS at step 3.  Live counts hit 3, 4 and 5 — every
    bucket of the elastic spec, with no ghost padding (live == bucket)."""
    tr = compile_schedule((Join(agents=(3,), at=2),
                           Rejoin(agents=(4,), leave_at=1, rejoin_at=3)),
                          n_agents=R, horizon=steps, seed=0)
    lives = [int(r.sum()) for r in tr.roster]
    assert sorted(set(lives)) == [3, 4, 5], lives
    return tr.roster


def test_join_and_rejoin_mid_decode_fold_into_vote():
    """Elastic membership mid-decode: a replica that joins and one that
    rejoins after a crash are folded into f-of-r decoding the moment they
    enter the roster, while ONE live replica stays Byzantine throughout —
    the output equals the clean stream at every step, and the Byzantine
    budget tracks the LIVE replica count (f = frac(0.4): 1-of-3, 1-of-4,
    2-of-5)."""
    cfg = get_config("paper-100m-smoke")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 10), 0, cfg.vocab_size)}
    clean = generate(cfg, params, batch, STEPS)
    roster = _membership_roster(STEPS)

    hook_steps = []

    def fault_hook(step, logits):
        # non-members emit garbage (they are gone — their output must be
        # bit-irrelevant) and live replica 0 is confidently hostile
        rows = (~roster[step]).copy()
        rows[0] = True
        hook_steps.append(step)
        bad = -7.0 * logits + 3.0
        return jnp.where(jnp.asarray(rows)[:, None, None], bad, logits)

    spec = make_spec("coordinate_median", f=frac(0.4),
                     n=elastic(R, buckets=(3, 4, 5)))
    assert [spec.respecialize(b).f for b in (3, 4, 5)] == [1, 1, 2]
    stack = jax.tree.map(lambda l: jnp.stack([l] * R), params)
    out = generate_replicated(cfg, stack, batch, STEPS, spec,
                              fault_hook=fault_hook, roster=roster)
    assert hook_steps == list(range(STEPS))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(clean))


def test_join_schedule_breaks_beyond_live_f():
    """Tightness under a shrunken roster: 2 corrupted replicas exceed the
    live budget (f=1 when only 3 replicas are members) and CAN steer the
    stream — the same corruption the full 5-replica roster absorbs."""
    cfg = get_config("paper-100m-smoke")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    clean = generate(cfg, params, batch, STEPS)
    roster = _membership_roster(STEPS)
    spec = make_spec("coordinate_median", f=frac(0.4),
                     n=elastic(R, buckets=(3, 4, 5)))
    stack = jax.tree.map(lambda l: jnp.stack([l] * R), params)

    def corrupt2(step, logits):
        rows = np.zeros(R, bool)
        rows[:2] = True                       # 2 corrupted live replicas
        bad = -7.0 * logits + 3.0
        return jnp.where(jnp.asarray(rows)[:, None, None], bad, logits)

    out_churn = generate_replicated(cfg, stack, batch, STEPS, spec,
                                    fault_hook=corrupt2, roster=roster)
    assert not np.array_equal(np.asarray(out_churn), np.asarray(clean))
    # the full static roster tolerates the same corruption (f=2 of 5)
    out_full = generate_replicated(cfg, stack, batch, STEPS, spec,
                                   fault_hook=corrupt2)
    np.testing.assert_array_equal(np.asarray(out_full), np.asarray(clean))


def test_replicated_decoding_breaks_beyond_f():
    """Sanity bound: the same schedule widened to 3 > f corrupted replicas
    must be able to steer the output — the tolerance claim is tight."""
    cfg = get_config("paper-100m-smoke")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    clean = generate(cfg, params, batch, STEPS)

    def fault_hook(step, logits):
        rows = np.zeros(R, bool)
        rows[:3] = True                      # 3 corrupted > F_REP = 2
        bad = -7.0 * logits + 3.0
        return jnp.where(jnp.asarray(rows)[:, None, None], bad, logits)

    stack = jax.tree.map(lambda l: jnp.stack([l] * R), params)
    out = generate_replicated(cfg, stack, batch, STEPS,
                              make_spec("coordinate_median", f=F_REP, n=R),
                              fault_hook=fault_hook)
    assert not np.array_equal(np.asarray(out), np.asarray(clean))


# ---------------------------------------------------------------------------
# the continuous-batching scheduler (repro.serving.sched): every stream's
# tokens must be the EXACT tokens generate_replicated emits for that
# request alone — under clean runs, <= f corruption, early commit AND the
# full-quorum fallback — and request churn must stay inside the batch-
# bucket compile budget.

from repro.core.tracecount import TRACE_COUNTS  # noqa: E402
from repro.serving.sched import (ReplicatedScheduler, Request,  # noqa: E402
                                 SuspicionPolicy, poisson_requests)


def _setup(seed=0, n_reqs=3):
    cfg = get_config("paper-100m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(1))
    stack = jax.tree.map(lambda l: jnp.stack([l] * R), params)
    rng = np.random.default_rng(seed)
    lens = (4, 6)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=int(lens[i % len(lens)])
                                        ).astype(np.int32),
                    max_new_tokens=int(4 + (i % 3)),
                    arrival=float(i))
            for i in range(n_reqs)]
    return cfg, params, stack, reqs


def _solo_refs(cfg, params, reqs):
    """Clean single-model streams — generate_replicated equals these under
    <= f corruption (pinned above), so they are THE reference."""
    return [np.asarray(generate(cfg, params,
                                {"tokens": np.asarray(r.tokens)[None, :]},
                                r.max_new_tokens))[0].tolist()
            for r in reqs]


def _corrupt2_hook(step, logits):
    """Replicas {3, 4} (== F_REP) confidently hostile at every step."""
    sel = jnp.zeros((R,), bool).at[jnp.asarray([3, 4])].set(True)
    return jnp.where(sel[:, None, None], -7.0 * logits + 3.0, logits)


def _run_sched(cfg, stack, spec, reqs, **kw):
    sched = ReplicatedScheduler(cfg, stack, spec, slot_buckets=(2, 4),
                                seq_capacity=16, **kw)
    assert sched.submit_all(reqs) == len(reqs)
    return sched, sched.run()


def test_scheduler_streams_match_solo_decode_clean():
    """Continuous batching is bit-invisible: requests joining/retiring
    mid-decode get exactly their solo token streams, on BOTH commit
    paths — and a clean early-commit run never runs the aggregation."""
    cfg, params, stack, reqs = _setup()
    refs = _solo_refs(cfg, params, reqs)
    spec = make_spec("coordinate_median", f=F_REP, n=R)

    before = TRACE_COUNTS["sched_agree"]
    _, metrics = _run_sched(cfg, stack, spec,
                            [Request(r.rid, r.tokens, r.max_new_tokens,
                                     r.arrival) for r in reqs],
                            early_commit=True)
    assert [r.out for r in reqs] != [refs]  # reqs above were not mutated
    s = metrics.summary()
    assert s["early_commit_fraction"] == 1.0
    assert TRACE_COUNTS["sched_agree"] == before  # vote never compiled

    reqs_e = [Request(r.rid, r.tokens, r.max_new_tokens, r.arrival)
              for r in reqs]
    _run_sched(cfg, stack, spec, reqs_e, early_commit=True)
    assert [r.out for r in reqs_e] == refs

    reqs_f = [Request(r.rid, r.tokens, r.max_new_tokens, r.arrival)
              for r in reqs]
    _, mf = _run_sched(cfg, stack, spec, reqs_f, early_commit=False)
    assert [r.out for r in reqs_f] == refs
    assert mf.summary()["early_commit_fraction"] == 0.0


def test_scheduler_streams_survive_f_corruption_both_paths():
    """<= f hostile replicas: early commit (f+1 bitwise-consistent honest
    replicas outvote them) and the deadline fallback (full masked vote)
    both emit the clean streams."""
    cfg, params, stack, reqs = _setup(seed=1)
    refs = _solo_refs(cfg, params, reqs)
    spec = make_spec("coordinate_median", f=F_REP, n=R)

    for ec in (True, False):
        rs = [Request(r.rid, r.tokens, r.max_new_tokens, r.arrival)
              for r in reqs]
        _run_sched(cfg, stack, spec, rs, early_commit=ec,
                   fault_hook=_corrupt2_hook)
        assert [r.out for r in rs] == refs, f"early_commit={ec}"

    # stragglers + SLO deadline: honest replicas 0/1 arrive late, so some
    # steps fall back to the full vote past the deadline — still clean
    delays = np.ones((1, R))
    delays[0, :2] = 9.0
    rs = [Request(r.rid, r.tokens, r.max_new_tokens, r.arrival)
          for r in reqs]
    _, m = _run_sched(cfg, stack, spec, rs, early_commit=True, deadline=2.0,
                      delays=delays, fault_hook=_corrupt2_hook)
    assert [r.out for r in rs] == refs
    assert m.summary()["token_latency_p95"] >= 9.0  # the SLO miss is real


def test_scheduler_early_commit_breaks_beyond_f():
    """Tightness: f+1 COLLUDING replicas that answer fastest steer an
    early commit before any honest replica arrives — the f-of-r bound,
    now with a timing dimension."""
    cfg, params, stack, reqs = _setup(seed=2, n_reqs=2)
    refs = _solo_refs(cfg, params, reqs)
    spec = make_spec("coordinate_median", f=F_REP, n=R)

    def colluders(step, logits):                  # replicas {2,3,4} = f+1
        sel = jnp.zeros((R,), bool).at[jnp.asarray([2, 3, 4])].set(True)
        return jnp.where(sel[:, None, None], -7.0 * logits + 3.0, logits)

    delays = np.ones((1, R))
    delays[0, 2:] = 0.25                          # colluders answer first
    delays[0, :2] = 5.0                           # honest replicas late
    rs = [Request(r.rid, r.tokens, r.max_new_tokens, r.arrival)
          for r in reqs]
    _, m = _run_sched(cfg, stack, spec, rs, early_commit=True, deadline=1.0,
                      delays=delays, fault_hook=colluders)
    assert [r.out for r in rs] != refs
    assert m.summary()["early_commit_fraction"] == 1.0


def test_scheduler_churn_within_compile_budget():
    """200 scheduler steps of Poisson churn under faults: decode compiles
    at most once per slot bucket, prefill once per distinct prompt
    length, agreement at most once per batch shape — counted by
    obs.counters, the acceptance gate for continuous batching."""
    cfg = get_config("paper-100m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(4))
    stack = jax.tree.map(lambda l: jnp.stack([l] * R), params)
    spec = make_spec("coordinate_median", f=F_REP, n=R)
    reqs = poisson_requests(1.2, 120.0, seed=7, vocab_size=cfg.vocab_size,
                            prompt_lens=(4, 6), new_tokens=(2, 3, 4),
                            max_requests=64)
    assert len(reqs) >= 30
    delays = np.ones((8, R))
    delays[::2, 3] = 3.0                          # a recurring straggler

    before = {k: TRACE_COUNTS[k]
              for k in ("sched_decode", "sched_prefill", "sched_agree")}
    buckets = (1, 2, 4)
    sched = ReplicatedScheduler(
        cfg, stack, spec, slot_buckets=buckets, seq_capacity=16,
        early_commit=True, deadline=2.0, fault_hook=_corrupt2_hook,
        delays=lambda s: delays[s % len(delays)])
    sched.submit_all(reqs)
    metrics = sched.run(max_steps=200)
    assert sched.step_idx == 200 or len(sched.queue) == 0
    assert metrics.summary()["committed_tokens"] >= 100
    n_dec = TRACE_COUNTS["sched_decode"] - before["sched_decode"]
    n_pre = TRACE_COUNTS["sched_prefill"] - before["sched_prefill"]
    n_agr = TRACE_COUNTS["sched_agree"] - before["sched_agree"]
    assert n_dec <= len(buckets), n_dec
    assert n_pre <= 2, n_pre                      # two prompt lengths
    assert n_agr <= len(buckets) + 1, n_agr       # one per batch shape


def test_scheduler_policy_evicts_pinned_replica_and_reinstates():
    """A persistently hostile replica's selection weight pins at zero;
    the live suspicion policy evicts it from the voting roster, folds it
    back after cooloff (it is still hostile, so it is re-evicted), and
    the streams stay clean throughout."""
    cfg, params, stack, reqs = _setup(seed=3, n_reqs=6)
    refs = _solo_refs(cfg, params, reqs)
    spec = make_spec("coordinate_median", f=F_REP, n=R)

    def hostile4(step, logits):
        sel = jnp.zeros((R,), bool).at[4].set(True)
        return jnp.where(sel[:, None, None], -7.0 * logits + 3.0, logits)

    policy = SuspicionPolicy(R, F_REP, window=2, cooloff=3, min_live=3)
    rs = [Request(r.rid, r.tokens, r.max_new_tokens, r.arrival)
          for r in reqs]
    _run_sched(cfg, stack, spec, rs, early_commit=True,
               fault_hook=hostile4, policy=policy)
    assert [r.out for r in rs] == refs
    kinds = [(e["kind"], e["replica"]) for e in policy.events]
    assert ("evict", 4) in kinds
    assert ("reinstate", 4) in kinds
    assert kinds.count(("evict", 4)) >= 2         # re-evicted after return
    honest = [e for e in policy.events
              if e["kind"] == "evict" and e["replica"] != 4]
    assert not honest                             # no honest casualties
