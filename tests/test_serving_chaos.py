"""Chaos test: f-of-r Byzantine-replica-tolerant serving under the
fault-injection schedules (the ROADMAP follow-up from PR 1/2).

``generate_replicated`` decodes with r replicas whose per-step logits are
robustly aggregated; this drives its ``fault_hook`` with a compiled
:class:`~repro.simulator.faults.FaultTrace` (CrashRecover + MessageDrop
over a bounded replica subset) and asserts the decoded stream equals the
clean single-model generation at EVERY step of the trace — greedy decoding
feeds each token forward, so any single-step disagreement diverges the
suffix and fails the array comparison.

Faulty replicas emit adversarial logits (sign-flipped and rescaled — a
strictly harder corruption than the omission faults being scheduled), and
the aggregation rule is the kernel-dispatched ``impl="pallas"``
coordinate median, so the chaos trace also exercises the Pallas path end
to end through the serving engine.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.aggregators import elastic, frac, make_spec
from repro.models import init_params
from repro.serving import generate, generate_replicated
from repro.simulator.faults import (CrashRecover, Join, MessageDrop, Rejoin,
                                    compile_schedule)

R, F_REP = 5, 2                      # replicas / tolerated corruptions
STEPS = 6


def _chaos_trace(steps, seed=3):
    """Faults confined to replicas {3, 4}: at most F_REP corrupted per
    step, as the f-of-r deployment contract requires."""
    return compile_schedule(
        (CrashRecover(rate=0.5, mean_down=2.0, agents=(3,)),
         MessageDrop(p=0.5, agents=(4,))),
        n_agents=R, horizon=steps, seed=seed)


def _faulty_rows(trace, step):
    return (~trace.alive[step]) | trace.drop[step]


def test_replicated_decoding_survives_fault_schedule():
    cfg = get_config("paper-100m-smoke")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 10), 0, cfg.vocab_size)}
    clean = generate(cfg, params, batch, STEPS)

    trace = _chaos_trace(STEPS)
    faulty_steps = [t for t in range(STEPS) if _faulty_rows(trace, t).any()]
    assert faulty_steps, "chaos schedule sampled no faults — raise rates"
    assert all(int(_faulty_rows(trace, t).sum()) <= F_REP
               for t in range(STEPS))

    hits = []

    def fault_hook(step, logits):            # (r, B, V) at the boundary
        rows = _faulty_rows(trace, step)
        if rows.any():
            hits.append(step)
        bad = -7.0 * logits + 3.0            # hostile, confidently wrong
        sel = jnp.asarray(rows)[:, None, None]
        return jnp.where(sel, bad, logits)

    stack = jax.tree.map(lambda l: jnp.stack([l] * R), params)
    spec = make_spec("coordinate_median", f=F_REP, n=R)
    assert spec.impl == "pallas"             # kernel path, end to end
    out = generate_replicated(cfg, stack, batch, STEPS, spec,
                              fault_hook=fault_hook)
    assert hits == faulty_steps              # every scheduled fault fired
    np.testing.assert_array_equal(np.asarray(out), np.asarray(clean))


def _membership_roster(steps):
    """Replica 3 JOINS at step 2; replica 4 crashes out of the roster at
    step 1 and REJOINS at step 3.  Live counts hit 3, 4 and 5 — every
    bucket of the elastic spec, with no ghost padding (live == bucket)."""
    tr = compile_schedule((Join(agents=(3,), at=2),
                           Rejoin(agents=(4,), leave_at=1, rejoin_at=3)),
                          n_agents=R, horizon=steps, seed=0)
    lives = [int(r.sum()) for r in tr.roster]
    assert sorted(set(lives)) == [3, 4, 5], lives
    return tr.roster


def test_join_and_rejoin_mid_decode_fold_into_vote():
    """Elastic membership mid-decode: a replica that joins and one that
    rejoins after a crash are folded into f-of-r decoding the moment they
    enter the roster, while ONE live replica stays Byzantine throughout —
    the output equals the clean stream at every step, and the Byzantine
    budget tracks the LIVE replica count (f = frac(0.4): 1-of-3, 1-of-4,
    2-of-5)."""
    cfg = get_config("paper-100m-smoke")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 10), 0, cfg.vocab_size)}
    clean = generate(cfg, params, batch, STEPS)
    roster = _membership_roster(STEPS)

    hook_steps = []

    def fault_hook(step, logits):
        # non-members emit garbage (they are gone — their output must be
        # bit-irrelevant) and live replica 0 is confidently hostile
        rows = (~roster[step]).copy()
        rows[0] = True
        hook_steps.append(step)
        bad = -7.0 * logits + 3.0
        return jnp.where(jnp.asarray(rows)[:, None, None], bad, logits)

    spec = make_spec("coordinate_median", f=frac(0.4),
                     n=elastic(R, buckets=(3, 4, 5)))
    assert [spec.respecialize(b).f for b in (3, 4, 5)] == [1, 1, 2]
    stack = jax.tree.map(lambda l: jnp.stack([l] * R), params)
    out = generate_replicated(cfg, stack, batch, STEPS, spec,
                              fault_hook=fault_hook, roster=roster)
    assert hook_steps == list(range(STEPS))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(clean))


def test_join_schedule_breaks_beyond_live_f():
    """Tightness under a shrunken roster: 2 corrupted replicas exceed the
    live budget (f=1 when only 3 replicas are members) and CAN steer the
    stream — the same corruption the full 5-replica roster absorbs."""
    cfg = get_config("paper-100m-smoke")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    clean = generate(cfg, params, batch, STEPS)
    roster = _membership_roster(STEPS)
    spec = make_spec("coordinate_median", f=frac(0.4),
                     n=elastic(R, buckets=(3, 4, 5)))
    stack = jax.tree.map(lambda l: jnp.stack([l] * R), params)

    def corrupt2(step, logits):
        rows = np.zeros(R, bool)
        rows[:2] = True                       # 2 corrupted live replicas
        bad = -7.0 * logits + 3.0
        return jnp.where(jnp.asarray(rows)[:, None, None], bad, logits)

    out_churn = generate_replicated(cfg, stack, batch, STEPS, spec,
                                    fault_hook=corrupt2, roster=roster)
    assert not np.array_equal(np.asarray(out_churn), np.asarray(clean))
    # the full static roster tolerates the same corruption (f=2 of 5)
    out_full = generate_replicated(cfg, stack, batch, STEPS, spec,
                                   fault_hook=corrupt2)
    np.testing.assert_array_equal(np.asarray(out_full), np.asarray(clean))


def test_replicated_decoding_breaks_beyond_f():
    """Sanity bound: the same schedule widened to 3 > f corrupted replicas
    must be able to steer the output — the tolerance claim is tight."""
    cfg = get_config("paper-100m-smoke")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    clean = generate(cfg, params, batch, STEPS)

    def fault_hook(step, logits):
        rows = np.zeros(R, bool)
        rows[:3] = True                      # 3 corrupted > F_REP = 2
        bad = -7.0 * logits + 3.0
        return jnp.where(jnp.asarray(rows)[:, None, None], bad, logits)

    stack = jax.tree.map(lambda l: jnp.stack([l] * R), params)
    out = generate_replicated(cfg, stack, batch, STEPS,
                              make_spec("coordinate_median", f=F_REP, n=R),
                              fault_hook=fault_hook)
    assert not np.array_equal(np.asarray(out), np.asarray(clean))
