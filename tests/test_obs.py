"""Flight-recorder guarantees (PR 6, :mod:`repro.obs`).

The observability contract has teeth only if it is pinned:

  * telemetry OFF is the exact historical program — loop outputs are
    BIT-FOR-BIT identical with and without a recorder attached;
  * telemetry ON does not change the aggregation result and does not add
    recompiles — a 200-step churn-and-fault run with a recorder attached
    stays within the elastic-bucket compile budget (``<= len(buckets)``
    async traces, ``<= 1`` sync fast-path trace), proven by the
    :mod:`repro.obs.counters` substrate the recorder itself uses;
  * the (n,) selection weights are FAITHFUL: for weight-decomposable
    rules ``aggregate(grads) == tree_weighted_sum(grads, sel_w)``
    exactly, and the weights agree across the gather/fused/pallas impls
    of the same rule in the plain, masked and weighted regimes;
  * the report CLI renders the suspicion table / recompile ledger from a
    recorded trace, and the Chrome-trace export is structurally valid.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.aggregators import (elastic, frac, make_spec,
                                    tree_weighted_sum)
from repro.data import SyntheticLM
from repro.obs import counters
from repro.obs.recorder import Recorder, chrome_trace, read_trace
from repro.obs.telemetry import (agent_series, dispatch_record,
                                 suspicion_scores)
from repro.optim import adamw, constant
from repro.simulator import (Churn, Join, Rejoin, SimConfig, Straggler,
                             async_train_loop)
from repro.training import ByzantineConfig, train_loop

CFG = get_config("paper-100m-smoke").replace(vocab_size=32, dtype="float32")
N = 8
D = 96


def _stack(key, n=N, d=D, scale=1.0):
    return jax.random.normal(key, (n, d), jnp.float32) * scale


def _tree(key, n=N):
    ka, kb = jax.random.split(key)
    return {"w": jax.random.normal(ka, (n, 4, 6), jnp.float32),
            "b": jax.random.normal(kb, (n, 5), jnp.float32)}


def _leaves_equal(a, b):
    return all(bool((x == y).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------- counters

def test_tracecount_shim_is_the_obs_counter_object():
    """core.tracecount is a view of obs.counters — same live object, so
    historical snapshot-diff tests and the recorder agree on counts."""
    from repro.core import tracecount
    assert tracecount.TRACE_COUNTS is counters.TRACE_COUNTS
    assert tracecount.TRACE_COUNTS is counters.COUNTERS
    assert tracecount.count_trace is counters.count_trace
    assert tracecount.snapshot is counters.snapshot


def test_counter_snapshot_delta():
    before = counters.snapshot()
    counters.inc("obs_test_site")
    counters.inc("obs_test_site")
    counters.set_gauge("obs_test_gauge", 7)
    delta = counters.counter_delta(before)
    assert delta.get("obs_test_site") == 2
    assert counters.gauge("obs_test_gauge") == 7
    after = counters.snapshot()
    assert after["counters"]["obs_test_site"] - \
        before["counters"].get("obs_test_site", 0) == 2
    counters.reset("obs_test_site")
    assert counters.trace_count("obs_test_site") == 0


# ------------------------------------------------- selection-weight truth

WSUM_EXACT = ["mean", "krum", "multi_krum", "m_krum", "mda", "cge", "cgc"]


@pytest.mark.parametrize("rule", WSUM_EXACT)
def test_selection_weights_reconstruct_aggregate(rule):
    """For weight-decomposable rules the telemetry weights ARE the
    aggregation: tree_weighted_sum(grads, sel_w) == aggregate(grads)."""
    grads = _tree(jax.random.PRNGKey(3))
    spec = make_spec(rule, f=2, n=N, impl="gather")
    sel = spec.selection_weights(grads)
    assert sel.shape == (N,) and sel.dtype == jnp.float32
    agg = spec.aggregate(grads)
    rec = tree_weighted_sum(grads, sel)
    for x, y in zip(jax.tree.leaves(agg), jax.tree.leaves(rec)):
        # summation-order float noise only (mean-of-k vs weighted sum)
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


def test_krum_weights_are_one_hot():
    grads = _stack(jax.random.PRNGKey(4))
    spec = make_spec("krum", f=2, n=N)
    sel = np.asarray(spec.selection_weights(grads))
    assert sel.sum() == pytest.approx(1.0)
    assert (sel > 0).sum() == 1
    # the hot index is exactly the krum pick
    agg = np.asarray(spec.aggregate(grads))
    np.testing.assert_array_equal(agg, np.asarray(grads)[sel.argmax()])


@pytest.mark.parametrize("rule", ["krum", "trimmed_mean", "cge",
                                  "coordinate_median"])
@pytest.mark.parametrize("regime", ["plain", "masked", "weighted"])
def test_weights_consistent_across_impls(rule, regime):
    """gather / fused / pallas report consistent selection weights for
    the same rule in every masking regime (CPU: pallas = interpret)."""
    key = jax.random.PRNGKey(5)
    grads = _stack(key, d=128)
    mask = weights = None
    if regime == "masked":
        mask = jnp.asarray([1, 1, 0, 1, 1, 1, 0, 1], bool)
    elif regime == "weighted":
        mask = jnp.asarray([1, 1, 1, 1, 1, 1, 1, 0], bool)
        weights = jnp.asarray([1, .5, 1, .25, 1, 1, .5, 0], jnp.float32)
    impls = ("gather", "fused", "pallas")
    if regime == "weighted" and rule in ("krum", "cge"):
        # selection rules follow a DIFFERENT weighted-masked law on the
        # fused impl (weights enter the rule, not just the imputation) —
        # the aggregates differ, so the weights rightly differ too
        impls = ("gather", "pallas")
    sels = {}
    for impl in impls:
        try:
            spec = make_spec(rule, f=2, n=N, impl=impl)
        except ValueError:
            continue                      # impl not registered for rule
        sels[impl] = np.asarray(
            spec.selection_weights(grads, mask=mask, weights=weights))
    assert len(sels) >= 2, f"{rule}: fewer than two impls resolved"
    ref_impl, ref = next(iter(sels.items()))
    for impl, sel in sels.items():
        np.testing.assert_allclose(
            sel, ref, rtol=0, atol=1e-6,
            err_msg=f"{rule}/{regime}: {impl} disagrees with {ref_impl}")
    # coordwise rules weight by participation: excluded agents carry
    # zero weight.  (Selection rules — krum/cge — run on the imputed
    # stack, so the consensus-filled row of a masked agent CAN win;
    # the weights faithfully report the imputation.)
    if mask is not None and rule in ("trimmed_mean", "coordinate_median"):
        for impl, sel in sels.items():
            assert np.all(sel[~np.asarray(mask)] == 0), (impl, sel)


def test_fused_weighted_masked_law_reconstructs():
    """The fused masked law's exact decomposition: for a selection rule
    under mask+weights, agg == wsum(imputed, fw) with the reported
    fused weights (the tot/cnt scale is folded into fw)."""
    g = _stack(jax.random.PRNGKey(9), d=64)
    mask = jnp.asarray([1, 1, 1, 1, 1, 1, 1, 0], bool)
    w = jnp.asarray([1, .5, 1, .25, 1, 1, .5, 0], jnp.float32)
    spec = make_spec("cge", f=2, n=N, impl="fused")
    agg = np.asarray(spec.aggregate(g, mask=mask, weights=w))
    sel = spec.selection_weights(g, mask=mask, weights=w)
    # impute exactly the way the masked law does: mean of arrived rows
    tot = float(w.sum())
    mean_sel = np.asarray(tree_weighted_sum(g, w / tot))
    gi = np.where(np.asarray(mask)[:, None], np.asarray(g), mean_sel)
    np.testing.assert_allclose(
        (np.asarray(sel)[:, None] * gi).sum(0), agg, rtol=1e-5, atol=1e-6)


def test_wrapper_and_stateful_weights():
    grads = _stack(jax.random.PRNGKey(6))
    clipped = make_spec("clipped", inner=make_spec("krum", f=2, n=N),
                        tau=1.0, f=2, n=N)
    sel = np.asarray(clipped.selection_weights(grads))
    assert sel.shape == (N,) and (sel > 0).sum() == 1
    zpp = make_spec("zeno_pp", xi=0.5, ema=0.2, n=N)
    st = zpp.init_state(jax.tree.map(lambda l: l[0], grads))
    sel = np.asarray(zpp.selection_weights(grads, state=st))
    assert sel.shape == (N,)
    with pytest.raises(ValueError):
        zpp.selection_weights(grads)      # stateful rule needs its state


def test_bulyan_theta_weights():
    grads = _stack(jax.random.PRNGKey(7), n=10, d=64)
    spec = make_spec("bulyan", f=1, n=10)
    sel = np.asarray(spec.selection_weights(grads))
    theta = 10 - 2 * 1                    # n - 2f selected, uniform 1/theta
    assert (sel > 0).sum() == theta
    np.testing.assert_allclose(sel[sel > 0], 1.0 / theta, atol=1e-7)


def test_aggregate_with_telemetry_matches_aggregate():
    grads = _tree(jax.random.PRNGKey(8))
    spec = make_spec("trimmed_mean", f=2, n=N)
    agg, telem = spec.aggregate_with_telemetry(grads)
    assert _leaves_equal(agg, spec.aggregate(grads))
    assert set(telem) == {"sel_w", "mask", "contrib_w"}
    assert telem["sel_w"].shape == (N,)


# ------------------------------------------- bit-for-bit loop equivalence

def _run_async(recorder, steps=12, seed=0):
    ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=N, per_agent_batch=1)
    bz = ByzantineConfig(n_agents=N, f=2,
                         aggregator=make_spec("cge", f=2, n=N),
                         attack="large_value", attack_hyper={})
    sim = SimConfig(faults=(Straggler(dist="pareto", scale=1.0, prob=0.5,
                                      agents=(0, 1)),),
                    quorum=6, max_staleness=3, seed=seed)
    return async_train_loop(CFG, bz, adamw(constant(1e-3)), ds, steps=steps,
                            sim=sim, log_every=steps, log_fn=lambda *_: None,
                            recorder=recorder)


def test_recorder_on_is_bit_identical(tmp_path):
    """The hard contract: attaching a Recorder (telemetry ON) leaves the
    trained parameters bitwise unchanged."""
    p_off, h_off = _run_async(None)
    rec = Recorder(str(tmp_path / "t.jsonl"))
    p_on, h_on = _run_async(rec)
    rec.close()
    assert _leaves_equal(p_off, p_on)
    assert [h["loss"] for h in h_off] == [h["loss"] for h in h_on]
    steps = [e for e in rec.events if e["kind"] == "step"]
    assert len(steps) == 12
    assert all(e.get("telemetry") for e in steps)


def test_stateful_loop_recorder_bit_identical():
    """The PR-10 extension of the contract: a STATEFUL rule (centered_clip
    carries its center across rounds) under a defense-aware attack takes
    the general async path with the {agg, atk} state bundle — attaching a
    Recorder must still leave the trained parameters bitwise unchanged."""
    ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=N, per_agent_batch=1)
    bz = ByzantineConfig(n_agents=N, f=2,
                         aggregator=make_spec("centered_clip", f=2, n=N,
                                              tau=1.0),
                         attack="slow_drift", attack_hyper={})
    sim = SimConfig(faults=(Straggler(dist="pareto", scale=1.0, prob=0.5,
                                      agents=(0, 1)),),
                    quorum=6, max_staleness=3, seed=0)

    def run(recorder):
        return async_train_loop(CFG, bz, adamw(constant(1e-3)), ds, steps=8,
                                sim=sim, log_every=8, log_fn=lambda *_: None,
                                recorder=recorder)
    p_off, h_off = run(None)
    rec = Recorder()
    p_on, h_on = run(rec)
    rec.close()
    assert _leaves_equal(p_off, p_on)
    assert [h["loss"] for h in h_off] == [h["loss"] for h in h_on]
    steps = [e for e in rec.events if e["kind"] == "step"]
    assert len(steps) == 8
    # the telemetry rows carry centered_clip's effective clip weights
    ser = agent_series(rec.events)
    assert ser["sel_w"].shape == (8, N)
    assert np.isfinite(ser["sel_w"][ser["mask"].astype(bool)]).all()


def test_sync_loop_recorder_bit_identical():
    ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=N, per_agent_batch=1)
    bz = ByzantineConfig(n_agents=N, f=2,
                         aggregator=make_spec("trimmed_mean", f=2, n=N))

    def run(recorder):
        return train_loop(CFG, bz, adamw(constant(1e-3)), ds, steps=6,
                          log_every=6, log_fn=lambda *_: None,
                          recorder=recorder)
    p_off, _ = run(None)
    rec = Recorder()
    p_on, _ = run(rec)
    rec.close()
    assert _leaves_equal(p_off, p_on)
    assert sum(1 for e in rec.events if e["kind"] == "step") == 6


# ----------------------------------------- zero-added-recompiles (churn)

def test_churn_run_with_recorder_adds_zero_recompiles():
    """200 churn+straggler steps over a 3-bucket elastic spec WITH a
    recorder attached: still <= 1 compile per bucket (async) and <= 1
    sync fast-path compile — telemetry aux outputs are fixed-shape, so
    observation costs no recompilation."""
    STEPS, BUCKETS = 200, (4, 6, 8)
    ds = SyntheticLM(vocab_size=32, seq_len=8, n_agents=N, per_agent_batch=1)
    spec = make_spec("trimmed_mean", f=frac(0.25),
                     n=elastic(N, buckets=BUCKETS))
    bz = ByzantineConfig(n_agents=N, f=2, aggregator=spec)
    churn = (Join(agents=(7,), at=10),
             Rejoin(agents=(6,), leave_at=40, rejoin_at=60),
             Churn(rate=0.2, mean_out=2.0, agents=(1, 2, 3, 4)),
             Straggler(dist="pareto", scale=1.0, prob=0.3, agents=(2,)))
    sim = SimConfig(faults=churn, seed=0)
    before = counters.snapshot()
    rec = Recorder()
    _, h = async_train_loop(CFG, bz, adamw(constant(1e-3)), ds, steps=STEPS,
                            sim=sim, log_every=STEPS, log_fn=lambda *_: None,
                            recorder=rec)
    rec.close()
    assert np.isfinite(h[-1]["loss"])
    delta = counters.counter_delta(before)
    assert delta.get("async_step", 0) <= len(BUCKETS), delta
    assert delta.get("train_step", 0) <= 1, delta
    # the recorder's own ledger attributes every compile to a step
    ledger = [e for e in rec.events if e["kind"] == "compile"]
    assert sum(e["count"] for e in ledger
               if e["site"] == "async_step") == delta.get("async_step", 0)
    # telemetry rows cover the run with the full fixed shape
    ser = agent_series(rec.events)
    assert ser["sel_w"].shape == (STEPS, N)
    assert ser["mask"].shape == (STEPS, N)


# ------------------------------------------------------ recorder + report

def _recorded_run(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    rec = Recorder(path, meta={"test": "obs"})
    _run_async(rec, steps=10)
    rec.close()
    return path, rec.events


def test_trace_jsonl_roundtrip(tmp_path):
    path, events = _recorded_run(tmp_path)
    loaded = read_trace(path)
    assert [e["kind"] for e in loaded] == [e["kind"] for e in events]
    meta = loaded[0]
    assert meta["kind"] == "meta"
    prov = meta["provenance"]
    for k in ("jax_version", "backend", "device_kind", "interpret",
              "git_sha"):
        assert k in prov, k


def test_chrome_trace_structure(tmp_path):
    _, events = _recorded_run(tmp_path)
    ct = chrome_trace(events)
    assert set(ct) >= {"traceEvents", "displayTimeUnit"}
    phases = {e["ph"] for e in ct["traceEvents"]}
    assert "X" in phases and "M" in phases      # spans + thread names
    spans = [e for e in ct["traceEvents"] if e["ph"] == "X"]
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in spans)
    json.dumps(ct)                              # perfetto-loadable JSON


def test_report_cli_renders(tmp_path, capsys):
    from repro.launch.report import main as report_main
    path, _ = _recorded_run(tmp_path)
    perfetto = str(tmp_path / "trace.json")
    report_main([path, "--perfetto", perfetto])
    out = capsys.readouterr().out
    assert "per-agent suspicion" in out
    assert "recompile ledger" in out
    assert "rule dispatch" in out
    assert "rule=cge" in out
    with open(perfetto) as fh:
        assert "traceEvents" in json.load(fh)


def test_suspicion_ranks_the_excluded_agents(tmp_path):
    """cge + large_value attackers (agents 0..f-1 by convention): the
    filtered-out byzantine agents must top the suspicion ranking."""
    _, events = _recorded_run(tmp_path)
    ser = agent_series(events)
    scores = suspicion_scores(ser["sel_w"], ser["mask"], ser["roster"])
    ranked = [s["agent"] for s in
              sorted(scores, key=lambda s: -s["suspicion"])]
    assert set(ranked[:2]) == {0, 1}, ranked
    by_agent = {s["agent"]: s for s in scores}
    assert all(0.0 <= s["suspicion"] <= 1.0 for s in scores)
    assert by_agent[0]["suspicion"] > by_agent[5]["suspicion"]


def test_dispatch_record_walks_wrapper_chain():
    spec = make_spec("clipped", inner=make_spec("trimmed_mean", f=2, n=N),
                     tau=2.0, f=2, n=N)
    d = dispatch_record(spec)
    assert d["rule"] == "clipped"
    assert d["inner"]["rule"] == "trimmed_mean"
    el = make_spec("trimmed_mean", f=frac(0.25),
                   n=elastic(N, buckets=(4, 6, 8)))
    assert tuple(dispatch_record(el)["elastic_buckets"]) == (4, 6, 8)


# -------------------------------------------------------------- serving

def test_serving_recorder_token_stream_identical(tmp_path):
    from repro.models import init_params
    from repro.serving import generate_replicated

    r, steps = 5, 12
    params = init_params(CFG, jax.random.PRNGKey(0))
    stack = jax.tree.map(lambda l: jnp.stack([l] * r), params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                          CFG.vocab_size)}
    spec = make_spec("coordinate_median", f=1, n=r)

    def corrupt(step, logits):            # replica 0 emits garbage
        return logits.at[0].set(-logits[0] * 50.0)

    out_off = generate_replicated(CFG, stack, batch, steps, spec,
                                  fault_hook=corrupt)
    rec = Recorder(str(tmp_path / "serve.jsonl"))
    out_on = generate_replicated(CFG, stack, batch, steps, spec,
                                 fault_hook=corrupt, recorder=rec)
    rec.close()
    np.testing.assert_array_equal(np.asarray(out_off), np.asarray(out_on))
    step_events = [e for e in rec.events if e["kind"] == "step"]
    assert len(step_events) == steps
    ser = agent_series(rec.events, n=r)
    assert ser["sel_w"].shape == (steps, r)
    # the corrupted replica never carries weight under the median
    scores = {s["agent"]: s for s in
              suspicion_scores(ser["sel_w"], ser["mask"])}
    assert scores[0]["suspicion"] >= max(
        scores[i]["suspicion"] for i in range(1, r))


# ------------------------------------------------- satellites: summaries

def test_async_trace_summary_percentiles():
    churn = (Churn(rate=0.2, mean_out=2.0, agents=(1, 2, 3)),
             Straggler(dist="pareto", scale=1.0, prob=0.3, agents=(0,)))
    from repro.simulator import plan_arrivals
    sim = SimConfig(faults=churn, quorum=6, max_staleness=3, seed=0)
    s = plan_arrivals(sim, N, 50).summary()
    for k in ("staleness_p50", "staleness_p95", "arrived_p50",
              "arrived_p95", "min_arrived", "min_live", "live_p50",
              "live_fraction"):
        assert k in s, k
    assert len(s["live_fraction"]) == N
    assert all(0.0 <= f <= 1.0 for f in s["live_fraction"])
    # pinned agents (not in the churn set) are always live
    assert s["live_fraction"][0] == 1.0
    assert s["staleness_p50"] <= s["staleness_p95"] <= s["max_staleness"]


def test_provenance_keys():
    from repro.obs.provenance import provenance
    p = provenance()
    assert p["jax_version"] == jax.__version__
    assert p["backend"] == jax.default_backend()
    assert isinstance(p["interpret"], bool)
    assert isinstance(p["git_sha"], str) and p["git_sha"]
    json.dumps(p)


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))


# ----------------------------------------- live subscription (serving loop)

def test_subscriber_sees_every_event_in_order(tmp_path):
    """A subscriber receives the same dicts, in the same order, as the
    JSONL file — the live half the scheduler's suspicion policy rides."""
    rec = Recorder(str(tmp_path / "t.jsonl"))
    seen = []
    unsub = rec.subscribe(seen.append)
    rec.emit("note", message="a")
    rec.step(0, metrics={"loss": 1.0})
    rec.emit("note", message="b")
    rec.close()
    # meta predates the subscription; everything after lands live
    assert [e["kind"] for e in seen] == ["note", "step", "note"]
    assert seen == rec.events[1:]
    assert [e["kind"] for e in read_trace(rec.path)[1:]] == \
        [e["kind"] for e in seen]
    unsub()
    assert rec._subscribers == []


def test_unsubscribe_stops_delivery_and_file_unchanged(tmp_path):
    """File emission is byte-identical with or without subscribers, and
    an unsubscribed callback never fires again."""
    def run(path, attach):
        rec = Recorder(str(path))
        seen = []
        unsub = rec.subscribe(seen.append) if attach else None
        rec.emit("note", message="x")
        if unsub is not None:
            unsub()
            unsub()                               # idempotent
        rec.emit("note", message="y")
        rec.close()
        return seen, path.read_text()

    seen, with_sub = run(tmp_path / "a.jsonl", attach=True)
    assert [e["message"] for e in seen] == ["x"]

    _, without = run(tmp_path / "b.jsonl", attach=False)
    strip = lambda s: [json.loads(l) for l in s.splitlines()]  # noqa: E731
    a, b = strip(with_sub), strip(without)
    for ea, eb in zip(a, b):
        ea.pop("t"), eb.pop("t")
        ea.get("provenance", {}).pop("wall_time", None)
        eb.get("provenance", {}).pop("wall_time", None)
    assert a == b
