"""gather (paper-faithful) vs fused (stats->weights) aggregation equality,
exercised through the LEGACY string API on purpose (shim coverage — the
spec-API equivalent lives in test_aggregator_spec.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.aggregation import tree_aggregate

pytestmark = pytest.mark.filterwarnings(
    "ignore::repro.core.aggregators.AggregatorDeprecationWarning")

NAMES = ["mean", "krum", "multi_krum", "m_krum", "cge", "cgc", "mda",
         "coordinate_median", "trimmed_mean", "phocas", "mean_around_median",
         "geometric_median", "rfa", "median_of_means", "bulyan", "zeno"]


@pytest.fixture(scope="module")
def grads():
    key = jax.random.PRNGKey(0)
    n = 12
    return {
        "a": jax.random.normal(key, (n, 5, 7)),
        "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (n, 11)),
              "d": jax.random.normal(jax.random.PRNGKey(2), (n, 3, 2, 2))},
    }


@pytest.mark.parametrize("name", NAMES)
def test_gather_vs_fused(name, grads):
    f = 2
    hyper = {}
    if name == "zeno":
        hyper["server_grad"] = jax.tree.map(lambda l: l[0] * 0.1, grads)
    ga = tree_aggregate(name, grads, f, impl="gather", **hyper)
    fu = tree_aggregate(name, grads, f, impl="fused", **hyper)
    for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(fu)):
        assert float(jnp.max(jnp.abs(x - y))) < 1e-4, name


@pytest.mark.parametrize("name", ["trimmed_mean", "krum", "cge"])
def test_aggregate_under_jit(name, grads):
    out = jax.jit(lambda g: tree_aggregate(name, g, 2))(grads)
    assert jax.tree.structure(out) == jax.tree.structure(
        jax.tree.map(lambda l: l[0], grads))


def test_bf16_stacks_aggregate(grads):
    g16 = jax.tree.map(lambda l: l.astype(jnp.bfloat16), grads)
    out = tree_aggregate("trimmed_mean", g16, 2)
    for l in jax.tree.leaves(out):
        assert l.dtype == jnp.bfloat16
        assert bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
