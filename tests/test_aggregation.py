"""gather (paper-faithful) vs fused (stats->weights) aggregation equality,
exercised through the LEGACY string API on purpose (shim coverage — the
spec-API equivalent lives in test_aggregator_spec.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.aggregation import tree_aggregate

pytestmark = pytest.mark.filterwarnings(
    "ignore::repro.core.aggregators.AggregatorDeprecationWarning")

NAMES = ["mean", "krum", "multi_krum", "m_krum", "cge", "cgc", "mda",
         "coordinate_median", "trimmed_mean", "phocas", "mean_around_median",
         "geometric_median", "rfa", "median_of_means", "bulyan", "zeno"]


@pytest.fixture(scope="module")
def grads():
    key = jax.random.PRNGKey(0)
    n = 12
    return {
        "a": jax.random.normal(key, (n, 5, 7)),
        "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (n, 11)),
              "d": jax.random.normal(jax.random.PRNGKey(2), (n, 3, 2, 2))},
    }


@pytest.mark.parametrize("name", NAMES)
def test_gather_vs_fused(name, grads):
    f = 2
    hyper = {}
    if name == "zeno":
        hyper["server_grad"] = jax.tree.map(lambda l: l[0] * 0.1, grads)
    ga = tree_aggregate(name, grads, f, impl="gather", **hyper)
    fu = tree_aggregate(name, grads, f, impl="fused", **hyper)
    for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(fu)):
        assert float(jnp.max(jnp.abs(x - y))) < 1e-4, name


@pytest.mark.parametrize("name", ["trimmed_mean", "krum", "cge"])
def test_aggregate_under_jit(name, grads):
    out = jax.jit(lambda g: tree_aggregate(name, g, 2))(grads)
    assert jax.tree.structure(out) == jax.tree.structure(
        jax.tree.map(lambda l: l[0], grads))


def test_bf16_stacks_aggregate(grads):
    g16 = jax.tree.map(lambda l: l.astype(jnp.bfloat16), grads)
    out = tree_aggregate("trimmed_mean", g16, 2)
    for l in jax.tree.leaves(out):
        assert l.dtype == jnp.bfloat16
        assert bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# shim regression (PR 3): warning discipline + exact spec equivalence


def test_shims_warn_exactly_once_per_call_site(grads):
    """Under the stdlib "default" action a deprecation must fire once per
    CALL SITE (location-deduped), not once per process and not per call —
    a shim hot loop stays quiet after the first hit, while every distinct
    legacy usage still surfaces in the log."""
    import warnings

    from repro.core import aggregation as legacy
    from repro.core.aggregators import AggregatorDeprecationWarning
    with warnings.catch_warnings(record=True) as rec:
        warnings.resetwarnings()
        warnings.simplefilter("default")
        for _ in range(3):
            legacy.tree_aggregate("mean", grads, 0)      # site A, 3 calls
        legacy.filter_weights("mean", grads, 0)          # site B
    hits = [w for w in rec
            if issubclass(w.category, AggregatorDeprecationWarning)]
    assert len(hits) == 2, [str(w.message)[:40] for w in hits]
    # the warning points at the CALLER (stacklevel), not the shim module
    assert all(w.filename == __file__ for w in hits)


@pytest.mark.parametrize("name", ["trimmed_mean", "krum", "cge"])
def test_shims_stay_bitwise_with_spec_aggregate(name, grads):
    """The shims must keep resolving to impl="fused" even though make_spec
    now defaults to impl="auto" (which upgrades kernelized rules to
    pallas) — legacy callers get the exact historical arrays."""
    from repro.core.aggregation import tree_masked_aggregate
    from repro.core.aggregators import make_spec
    spec = make_spec(name, f=2, impl="fused")
    assert spec.impl == "fused"
    ref = spec.aggregate(grads)
    out = tree_aggregate(name, grads, 2)
    for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        assert x.dtype == y.dtype
        assert bool(jnp.all(x == y)), name
    mask = jnp.asarray([True] * 9 + [False] * 3)
    ref_m = spec.aggregate(grads, mask=mask)
    out_m = tree_masked_aggregate(name, grads, 2, mask)
    for x, y in zip(jax.tree.leaves(out_m), jax.tree.leaves(ref_m)):
        assert bool(jnp.all(x == y)), name
