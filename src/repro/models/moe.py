"""Top-k mixture-of-experts block with capacity-based token dispatch.

TPU-native layout: tokens are scattered into a dense (E, C, D) buffer
(E = experts on the ``model`` mesh axis -> scatter lowers to all-to-all),
experts run as grouped matmuls on the MXU, results gather back weighted by
router probabilities.  Overflow tokens beyond capacity are dropped (their
residual path passes through), standard Switch/GShard semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import activate, dense_init


def init_moe(key, cfg, dtype):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, D, F), dtype),
        "w_up": dense_init(ks[2], (E, D, F), dtype),
        "w_down": dense_init(ks[3], (E, F, D), dtype),
    }
    if cfg.shared_expert:
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(sk[0], (D, F), dtype),
            "w_up": dense_init(sk[1], (D, F), dtype),
            "w_down": dense_init(sk[2], (F, D), dtype),
        }
    return p


def capacity(cfg, num_tokens: int) -> int:
    c = int(num_tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8


def moe_block(cfg, params, x):
    """x: (B, T, D) -> (out, aux_loss)."""
    B, T, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    N = B * T
    C = capacity(cfg, N)
    xf = x.reshape(N, D)

    logits = xf.astype(jnp.float32) @ params["router"]          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)             # (N, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balance aux loss (Switch): E * sum_e fraction_e * prob_e
    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)   # (N, K, E)
    density = jnp.mean(jnp.sum(onehot, axis=1), axis=0)         # (E,)
    router_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * router_prob) * cfg.router_aux_coef

    # position of each (token, k) inside its expert's capacity buffer
    flat_ids = expert_ids.reshape(-1)                           # (N*K,)
    flat_onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
    slot = jnp.cumsum(flat_onehot, axis=0) * flat_onehot        # rank within expert
    slot = jnp.sum(slot, axis=-1) - 1                           # (N*K,)
    keep = slot < C
    slot = jnp.where(keep, slot, 0)
    safe_expert = jnp.where(keep, flat_ids, 0)

    # scatter tokens -> (E, C, D); duplicates (K>1) write the same token twice
    buf = jnp.zeros((E, C, D), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(N), K)
    buf = buf.at[safe_expert, slot].set(
        jnp.where(keep[:, None], xf[tok_idx], 0).astype(x.dtype),
        mode="drop")

    # serving-path sharding hint: without it the partitioner replicates the
    # capacity dim and every device computes ALL experts over the GLOBAL
    # token set (measured 32x redundant compute on mixtral prefill —
    # EXPERIMENTS.md §Perf pair C)
    from repro.distributed.context import get_moe_dispatch
    dp_axes, ep, sizes = get_moe_dispatch()
    cap_spec = None
    if dp_axes is not None:
        sz = 1
        for a in (dp_axes if isinstance(dp_axes, tuple) else (dp_axes,)):
            sz *= sizes.get(a, 1)
        if sz > 1 and C % sz == 0:
            from jax.sharding import PartitionSpec as P
            cap_spec = P("model" if ep else None, dp_axes, None)
            buf = jax.lax.with_sharding_constraint(buf, cap_spec)

    # expert computation: grouped matmuls (E, C, D) @ (E, D, F)
    h = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = activate(cfg, h, u)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])   # (E, C, D)
    if cap_spec is not None:
        out_buf = jax.lax.with_sharding_constraint(out_buf, cap_spec)

    # gather back, weighted by the (renormalized) gate values
    gathered = out_buf[safe_expert, slot]                       # (N*K, D)
    if cap_spec is not None:
        from jax.sharding import PartitionSpec as P
        # pin the combine result back to token sharding so the capacity->
        # token regrouping lowers as an exchange, not a full all-gather
        gathered = jax.lax.with_sharding_constraint(
            gathered, P(dp_axes, None))
    gathered = jnp.where(keep[:, None], gathered, 0)
    gathered = gathered * gate_vals.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.sum(gathered.reshape(N, K, D), axis=1)

    if cfg.shared_expert:
        s = params["shared"]
        sh = activate(cfg, xf @ s["w_gate"], xf @ s["w_up"])
        out = out + sh @ s["w_down"]
    return out.reshape(B, T, D), aux
