"""Mamba2 (SSD — state-space duality) block, chunked matmul form.

Faithful to arXiv:2405.21060's "minimal SSD" reference: intra-chunk terms are
dense (MXU-friendly) attention-like matmuls through the 1-semiseparable decay
mask; inter-chunk terms pass an (h, p, n) recurrent state.  Decode is the O(1)
recurrent update.  The causal depthwise conv (kernel 4) over (x, B, C) is kept,
with a conv ring state for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


# ---------------------------------------------------------------------------
# params


def init_ssm(key, cfg, dtype):
    D = cfg.d_model
    di = cfg.d_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h = cfg.ssm_num_heads
    kq = cfg.ssm_conv
    ks = jax.random.split(key, 3)
    conv_dim = di + 2 * g * n
    return {
        "in_proj": dense_init(ks[0], (D, 2 * di + 2 * g * n + h), dtype),
        "conv_w": dense_init(ks[1], (kq, conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),          # A = -exp(A_log) = -1
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], (di, D), dtype),
    }


# ---------------------------------------------------------------------------
# SSD core


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} x[..., k] (i>=j)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, a, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x: (b, t, h, p)   pre-multiplied by dt
    a: (b, t, h)      log-decay per step (dt * A, negative)
    B, C: (b, t, g, n) with h % g == 0
    Returns y: (b, t, h, p), final_state: (b, h, p, n)
    """
    b, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    t_orig = t
    pad = (-t) % chunk
    if pad:
        # zero-pad to a chunk multiple: zero x/B contribute nothing to the
        # state; a=0 (decay 1) carries the state through the padding
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t = t + pad
    c = t // chunk
    rep = h // g

    xr = x.reshape(b, c, chunk, h, p)
    ar = a.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)       # (b,h,c,l)
    Br = jnp.repeat(B.reshape(b, c, chunk, g, n), rep, axis=3)  # (b,c,l,h,n)
    Cr = jnp.repeat(C.reshape(b, c, chunk, g, n), rep, axis=3)

    a_cs = jnp.cumsum(ar, axis=-1)                              # (b,h,c,l)
    L = jnp.exp(_segsum(ar))                                    # (b,h,c,l,l)

    # intra-chunk (diagonal blocks)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        Cr, Br, L.astype(Cr.dtype), xr,
                        preferred_element_type=jnp.float32)

    # per-chunk final states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)               # (b,h,c,l)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn",
                        Br, decay_states.astype(Br.dtype), xr,
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence over c (small: t/chunk) via segsum matmul
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), states.dtype)
    states = jnp.concatenate(
        [initial_state[:, None].astype(states.dtype), states], axis=1)
    chunk_decay = a_cs[..., -1]                                 # (b,h,c)
    padded = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(_segsum(padded))                      # (b,h,c+1,c+1)
    decay_chunk = jnp.where(jnp.isfinite(decay_chunk), decay_chunk, 0.0)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn",
                            decay_chunk.astype(states.dtype), states)
    carried, final_state = new_states[:, :-1], new_states[:, -1]

    # contribution of carried state to each chunk position
    state_decay = jnp.exp(a_cs)                                 # (b,h,c,l)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       Cr, carried.astype(Cr.dtype),
                       state_decay.astype(Cr.dtype),
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(b, t, h, p)[:, :t_orig]
    return y.astype(x.dtype), final_state


def ssd_decode(x, a, B, C, state):
    """One-step recurrence.  x: (b,h,p); a: (b,h); B,C: (b,g,n);
    state: (b,h,p,n)."""
    b, h, p = x.shape
    g, n = B.shape[1], B.shape[2]
    rep = h // g
    Br = jnp.repeat(B, rep, axis=1)          # (b,h,n)
    Cr = jnp.repeat(C, rep, axis=1)
    da = jnp.exp(a)[..., None, None]         # (b,h,1,1)
    new_state = state * da + jnp.einsum("bhp,bhn->bhpn", x, Br)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Cr)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# causal conv


def causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B, T, C); w: (k, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def conv_decode(x, conv_state, w, b):
    """x: (B, C) one step; conv_state: (B, k-1, C) previous inputs."""
    k = w.shape[0]
    window = jnp.concatenate([conv_state, x[:, None]], axis=1)   # (B,k,C)
    out = jnp.einsum("bkc,kc->bc", window, w)
    new_state = window[:, 1:]
    return jax.nn.silu(out + b), new_state


# ---------------------------------------------------------------------------
# full block


def _split_proj(cfg, zxbcdt):
    di = cfg.d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    h = cfg.ssm_num_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    assert dt.shape[-1] == h
    return z, xBC, dt


def mamba_block(cfg, params, x):
    """Training / prefill forward.  x: (B, T, D) -> (y, final_state)."""
    Bsz, T, D = x.shape
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    h, p = cfg.ssm_num_heads, cfg.ssm_head_dim

    zxbcdt = x @ params["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = causal_conv(xBC, params["conv_w"], params["conv_b"])
    xs = xBC[..., :di].reshape(Bsz, T, h, p)
    Bm = xBC[..., di:di + g * n].reshape(Bsz, T, g, n)
    Cm = xBC[..., di + g * n:].reshape(Bsz, T, g, n)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                       # (h,)
    y, state = ssd_chunked(xs * dt[..., None].astype(xs.dtype),
                           dt * A, Bm, Cm, cfg.ssm_chunk)
    y = y + (params["D_skip"][:, None] * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(Bsz, T, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm_scale"], cfg.norm_eps)
    return y @ params["out_proj"], state


def init_ssm_cache(cfg, batch: int, dtype):
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    h, p = cfg.ssm_num_heads, cfg.ssm_head_dim
    return {
        "state": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * g * n), dtype),
    }


def mamba_decode(cfg, params, x, cache):
    """Single-token decode.  x: (B, 1, D)."""
    Bsz = x.shape[0]
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    h, p = cfg.ssm_num_heads, cfg.ssm_head_dim

    zxbcdt = x[:, 0] @ params["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC, conv_state = conv_decode(xBC, cache["conv"], params["conv_w"],
                                  params["conv_b"])
    xs = xBC[..., :di].reshape(Bsz, h, p)
    Bm = xBC[..., di:di + g * n].reshape(Bsz, g, n)
    Cm = xBC[..., di + g * n:].reshape(Bsz, g, n)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,h)
    A = -jnp.exp(params["A_log"])
    y, state = ssd_decode(xs * dt[..., None].astype(xs.dtype),
                          dt * A, Bm, Cm, cache["state"])
    y = y + (params["D_skip"][:, None] * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(Bsz, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm_scale"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None]
    return out, {"state": state, "conv": conv_state}
