from repro.models.transformer import (decode_step, forward_train, init_cache,
                                      init_params, loss_fn, prefill)

__all__ = ["init_params", "forward_train", "loss_fn", "init_cache",
           "prefill", "decode_step"]
