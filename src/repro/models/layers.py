"""Shared model building blocks: norms, activations, RoPE / M-RoPE, MLPs.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every ``init_*``
has a mirror ``*_specs`` in :mod:`repro.distributed.sharding` mapping the same
tree structure to PartitionSpecs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# init helpers


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    if scale is None:
        scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias=None, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(dt)


def apply_norm(cfg, x, scale, bias=None):
    if cfg.norm == "layer":
        return layer_norm(x, scale, bias, cfg.norm_eps)
    return rms_norm(x, scale, cfg.norm_eps)


# ---------------------------------------------------------------------------
# activations


def activate(cfg, gate, up=None):
    if cfg.act == "gelu":
        h = jax.nn.gelu(gate)
        return h if up is None else h * up
    # SwiGLU default
    h = jax.nn.silu(gate)
    return h if up is None else h * up


# ---------------------------------------------------------------------------
# rotary embeddings


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., T, hd/2)
    angles = angles[..., None, :]                           # (..., T, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Multimodal RoPE (Qwen2-VL).  positions3: (3, ..., T) t/h/w position ids.

    The hd/2 frequency slots are split into ``sections`` (t, h, w); each slice
    rotates by its own position stream.
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_freqs(hd, theta))              # (half,)
    # build per-slot position ids: (..., T, half)
    chunks = []
    start = 0
    for sec, pos in zip(sections, positions3):
        chunks.append(jnp.broadcast_to(
            pos[..., None].astype(jnp.float32),
            pos.shape + (sec,)))
        start += sec
    pos_per_slot = jnp.concatenate(chunks, axis=-1)          # (..., T, half)
    angles = (pos_per_slot * freqs)[..., None, :]            # (..., T, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(max_len: int, d_model: int):
    pos = np.arange(max_len, dtype=np.float32)[:, None]
    dim = np.arange(0, d_model, 2, dtype=np.float32)[None, :]
    angle = pos / np.power(10000.0, dim / d_model)
    table = np.zeros((max_len, d_model), np.float32)
    table[:, 0::2] = np.sin(angle)
    table[:, 1::2] = np.cos(angle)
    return jnp.asarray(table)


# ---------------------------------------------------------------------------
# dense MLP


def init_mlp(key, cfg, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":
        return {
            "w_gate": dense_init(ks[0], (D, F), dtype),
            "w_up": dense_init(ks[1], (D, F), dtype),
            "w_down": dense_init(ks[2], (F, D), dtype),
        }
    return {
        "w_in": dense_init(ks[0], (D, F), dtype),
        "w_out": dense_init(ks[1], (F, D), dtype),
    }


def mlp(cfg, params, x):
    if cfg.act == "silu":
        h = activate(cfg, x @ params["w_gate"], x @ params["w_up"])
        return h @ params["w_down"]
    h = activate(cfg, x @ params["w_in"])
    return h @ params["w_out"]
