"""Family-dispatched model stacks (dense / moe / ssm / hybrid / vlm / audio).

All stacks use STACKED per-layer parameters + ``lax.scan`` so HLO size is
independent of depth — required to compile 48-81 layer configs for 512
placeholder devices on a 1-core host.

Public API
  init_params(cfg, key)                     -> params
  forward_train(cfg, params, batch)         -> (logits, aux_loss)
  init_cache(cfg, batch, seq_len)           -> cache
  prefill(cfg, params, batch, cache)        -> (logits_last, cache)
  decode_step(cfg, params, token, cache)    -> (logits, cache)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (apply_norm, dense_init, embed_init, init_mlp,
                                 mlp, sinusoid_positions)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# per-layer init


def _init_dense_block(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn_lib.init_attention(ks[0], cfg, dtype),
        "mlp_norm": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_mlp(ks[1], cfg, dtype),
    }


def _init_moe_block(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn_lib.init_attention(ks[0], cfg, dtype),
        "mlp_norm": jnp.zeros((cfg.d_model,), dtype),
        "moe": moe_lib.init_moe(ks[1], cfg, dtype),
    }


def _init_ssm_block(key, cfg, dtype):
    return {
        "norm": jnp.zeros((cfg.d_model,), dtype),
        "ssm": ssm_lib.init_ssm(key, cfg, dtype),
    }


def _init_cross_block(key, cfg, dtype):
    """Whisper decoder block: self-attn + cross-attn + mlp."""
    ks = jax.random.split(key, 3)
    return {
        "attn_norm": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn_lib.init_attention(ks[0], cfg, dtype),
        "cross_norm": jnp.zeros((cfg.d_model,), dtype),
        "cross": attn_lib.init_attention(ks[1], cfg, dtype),
        "mlp_norm": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_mlp(ks[2], cfg, dtype),
    }


def _block_init_fn(cfg):
    if cfg.family == "moe":
        return _init_moe_block
    if cfg.family in ("ssm", "hybrid"):
        return _init_ssm_block
    if cfg.is_encdec:
        return _init_cross_block
    return _init_dense_block


def _stack_init(key, cfg, n, fn, dtype):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: fn(k, cfg, dtype))(keys)


def init_params(cfg, key):
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 6)
    params = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "blocks": _stack_init(ks[1], cfg, cfg.num_layers,
                              _block_init_fn(cfg), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab_size),
                                       dtype)
    if cfg.family == "hybrid":
        params["shared_attn"] = _init_dense_block(ks[3], cfg, dtype)
    if cfg.is_encdec:
        params["encoder"] = {
            "blocks": _stack_init(ks[4], cfg, cfg.encoder_layers,
                                  _init_dense_block, dtype),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
    if cfg.frontend != "none":
        params["frontend_proj"] = dense_init(
            ks[5], (cfg.d_model, cfg.d_model), dtype)
    return params


# ---------------------------------------------------------------------------
# forward (training / full sequence)


def _dense_body(cfg, blk, h, positions, mrope_pos, causal=True):
    a = apply_norm(cfg, h, blk["attn_norm"])
    h = h + attn_lib.attention(cfg, blk["attn"], a, positions=positions,
                               mrope_pos=mrope_pos, causal=causal)
    m = apply_norm(cfg, h, blk["mlp_norm"])
    return h + mlp(cfg, blk["mlp"], m)


def _moe_body(cfg, blk, h, positions, mrope_pos):
    a = apply_norm(cfg, h, blk["attn_norm"])
    h = h + attn_lib.attention(cfg, blk["attn"], a, positions=positions,
                               mrope_pos=mrope_pos)
    m = apply_norm(cfg, h, blk["mlp_norm"])
    out, aux = moe_lib.moe_block(cfg, blk["moe"], m)
    return h + out, aux


def _ssm_body(cfg, blk, h):
    a = apply_norm(cfg, h, blk["norm"])
    out, _ = ssm_lib.mamba_block(cfg, blk["ssm"], a)
    return h + out


def _hybrid_groups(cfg):
    k = cfg.hybrid_attn_every
    n_full = (cfg.num_layers // k) * k
    return n_full, n_full // k, cfg.num_layers - n_full


def _split_stacked(blocks, n_full, k):
    main = jax.tree.map(
        lambda a: a[:n_full].reshape((n_full // k, k) + a.shape[1:]), blocks)
    tail = jax.tree.map(lambda a: a[n_full:], blocks)
    return main, tail


def _maybe_remat(body):
    """Per-layer activation checkpointing (see distributed.context)."""
    from repro.distributed.context import layer_remat_on
    if layer_remat_on():
        return jax.checkpoint(body, prevent_cse=False)
    return body


def _backbone(cfg, params, x, positions=None, mrope_pos=None):
    """Run the stacked decoder blocks over (B, T, D).  Returns (h, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        @_maybe_remat
        def body(h, blk):
            h, a = _moe_body(cfg, blk, h, positions, mrope_pos)
            return h, a
        x, auxs = jax.lax.scan(body, x, params["blocks"])
        return x, jnp.sum(auxs)
    if cfg.family == "ssm":
        @_maybe_remat
        def body(h, blk):
            return _ssm_body(cfg, blk, h), None
        x, _ = jax.lax.scan(body, x, params["blocks"])
        return x, aux
    if cfg.family == "hybrid":
        n_full, groups, tail_n = _hybrid_groups(cfg)
        main, tail = _split_stacked(params["blocks"], n_full,
                                    cfg.hybrid_attn_every)
        shared = params["shared_attn"]

        @_maybe_remat
        def ssm_body(h, blk):
            return _ssm_body(cfg, blk, h), None

        @_maybe_remat
        def group_body(h, grp):
            h, _ = jax.lax.scan(ssm_body, h, grp)
            h = _dense_body(cfg, shared, h, positions, mrope_pos)
            return h, None
        x, _ = jax.lax.scan(group_body, x, main)
        if tail_n:
            x, _ = jax.lax.scan(ssm_body, x, tail)
        return x, aux
    # dense / vlm / audio-decoder
    @_maybe_remat
    def body(h, blk):
        return _dense_body(cfg, blk, h, positions, mrope_pos), None
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return x, aux


def _encoder(cfg, params, audio_embeds):
    """Whisper encoder: bidirectional blocks over stub frame embeddings."""
    x = audio_embeds + sinusoid_positions(
        audio_embeds.shape[1], cfg.d_model).astype(audio_embeds.dtype)

    def body(h, blk):
        return _dense_body(cfg, blk, h, None, None, causal=False), None
    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return apply_norm(cfg, x, params["encoder"]["final_norm"])


def _decdec_forward(cfg, params, tokens, enc_out):
    """Whisper decoder full-sequence forward with cross attention."""
    x = params["embed"][tokens]
    T = tokens.shape[1]
    x = x + sinusoid_positions(T, cfg.d_model).astype(x.dtype)

    def body(h, blk):
        a = apply_norm(cfg, h, blk["attn_norm"])
        h = h + attn_lib.attention(cfg, blk["attn"], a)
        c = apply_norm(cfg, h, blk["cross_norm"])
        cc = attn_lib.init_cross_cache(cfg, blk["cross"], enc_out)
        h = h + attn_lib.cross_attention(cfg, blk["cross"], c, cc)
        m = apply_norm(cfg, h, blk["mlp_norm"])
        return h + mlp(cfg, blk["mlp"], m), None
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return x


def _mrope_positions(cfg, batch, n_vis, n_txt):
    """Stub M-RoPE position ids: vision tokens on a sqrt grid at t=0,
    text tokens linear after the grid."""
    side = max(int(n_vis ** 0.5), 1)
    iv = jnp.arange(n_vis)
    vis = jnp.stack([jnp.zeros_like(iv), iv // side, iv % side])   # (3, Tv)
    # text positions continue from the raw token count so that cached decode
    # (which tracks written-token count) stays consistent with prefill
    it = jnp.arange(n_txt) + n_vis
    txt = jnp.stack([it, it, it])
    pos = jnp.concatenate([vis, txt], axis=1)                      # (3, T)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, n_vis + n_txt))


def embed_inputs(cfg, params, batch):
    """tokens (+ frontend embeddings) -> (x, positions, mrope_pos)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = params["embed"][tokens]
    mrope_pos = None
    positions = None
    if cfg.frontend == "vision":
        vis = batch["vision_embeds"].astype(x.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([vis, x], axis=1)
        if cfg.positional == "mrope":
            p = _mrope_positions(cfg, B, vis.shape[1], T)
            mrope_pos = (p, p)
    return x, positions, mrope_pos


def forward_train(cfg, params, batch):
    """Full forward.  Returns (logits over the token positions, aux_loss)."""
    if cfg.is_encdec:
        enc_out = _encoder(cfg, params, batch["audio_embeds"].astype(
            _dtype(cfg)))
        h = _decdec_forward(cfg, params, batch["tokens"], enc_out)
        aux = jnp.zeros((), jnp.float32)
    else:
        x, positions, mrope_pos = embed_inputs(cfg, params, batch)
        h, aux = _backbone(cfg, params, x, positions, mrope_pos)
        if cfg.frontend == "vision":            # only score text positions
            h = h[:, -batch["tokens"].shape[1]:]
    h = apply_norm(cfg, h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    return logits, aux


def loss_fn(cfg, params, batch):
    logits, aux = forward_train(cfg, params, batch)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux


# ---------------------------------------------------------------------------
# caches


def init_cache(cfg, params, batch_size: int, seq_len: int, batch=None):
    """Decode cache for every family.  ``batch`` supplies encoder inputs
    (enc-dec) so cross K/V can be cached."""
    dtype = _dtype(cfg)

    def stacked(n, fn):
        proto = fn()
        return jax.tree.map(
            lambda a: jnp.zeros((n,) + a.shape, a.dtype), proto)

    if cfg.family == "ssm":
        cache = stacked(cfg.num_layers,
                        lambda: ssm_lib.init_ssm_cache(cfg, batch_size, dtype))
        return {"blocks": cache, "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        n_full, groups, tail_n = _hybrid_groups(cfg)
        return {
            "blocks": stacked(cfg.num_layers,
                              lambda: ssm_lib.init_ssm_cache(cfg, batch_size,
                                                             dtype)),
            "attn": stacked(groups,
                            lambda: attn_lib.init_kv_cache(cfg, batch_size,
                                                           seq_len, dtype)),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.is_encdec:
        enc_out = _encoder(cfg, params, batch["audio_embeds"].astype(dtype))

        def cross_for_layer(blk):
            return attn_lib.init_cross_cache(cfg, blk["cross"], enc_out)
        cross = jax.vmap(lambda blk: cross_for_layer(blk))(params["blocks"])
        self_c = stacked(cfg.num_layers,
                         lambda: attn_lib.init_kv_cache(cfg, batch_size,
                                                        seq_len, dtype))
        return {"self": self_c, "cross": cross,
                "pos": jnp.zeros((), jnp.int32)}
    # dense / moe / vlm
    return {
        "blocks": stacked(cfg.num_layers,
                          lambda: attn_lib.init_kv_cache(cfg, batch_size,
                                                         seq_len, dtype)),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# prefill


def prefill(cfg, params, batch, cache):
    """Full-prompt forward that fills the cache.  Returns (last-token logits,
    cache)."""
    dtype = _dtype(cfg)
    if cfg.is_encdec:
        x = params["embed"][batch["tokens"]]
        T = x.shape[1]
        x = x + sinusoid_positions(T, cfg.d_model).astype(x.dtype)

        def body(h, xs):
            blk, self_c, cross_c = xs
            a = apply_norm(cfg, h, blk["attn_norm"])
            out, self_c = attn_lib.prefill_attention(cfg, blk["attn"], a,
                                                     self_c)
            h = h + out
            c = apply_norm(cfg, h, blk["cross_norm"])
            h = h + attn_lib.cross_attention(cfg, blk["cross"], c, cross_c)
            m = apply_norm(cfg, h, blk["mlp_norm"])
            return h + mlp(cfg, blk["mlp"], m), self_c
        h, self_c = jax.lax.scan(body, x,
                                 (params["blocks"], cache["self"],
                                  cache["cross"]))
        cache = {"self": self_c, "cross": cache["cross"],
                 "pos": jnp.asarray(T, jnp.int32)}
    elif cfg.family in ("ssm", "hybrid"):
        x, positions, mrope_pos = embed_inputs(cfg, params, batch)
        T = x.shape[1]
        if cfg.family == "ssm":
            def body(h, xs):
                blk, c = xs
                a = apply_norm(cfg, h, blk["norm"])
                out, state = ssm_lib.mamba_block(cfg, blk["ssm"], a)
                new_c = {"state": state,
                         "conv": _conv_tail(cfg, blk, a, c["conv"])}
                return h + out, new_c
            h, blocks_c = jax.lax.scan(body, x,
                                       (params["blocks"], cache["blocks"]))
            cache = {"blocks": blocks_c, "pos": jnp.asarray(T, jnp.int32)}
        else:
            n_full, groups, tail_n = _hybrid_groups(cfg)
            k = cfg.hybrid_attn_every
            main, tailb = _split_stacked(params["blocks"], n_full, k)
            main_c, tail_c = _split_stacked(cache["blocks"], n_full, k)
            shared = params["shared_attn"]

            def ssm_body(h, xs):
                blk, c = xs
                a = apply_norm(cfg, h, blk["norm"])
                out, state = ssm_lib.mamba_block(cfg, blk["ssm"], a)
                return h + out, {"state": state,
                                 "conv": _conv_tail(cfg, blk, a, c["conv"])}

            def group_body(h, xs):
                grp, grp_c, attn_c = xs
                h, grp_c = jax.lax.scan(ssm_body, h, (grp, grp_c))
                a = apply_norm(cfg, h, shared["attn_norm"])
                out, attn_c = attn_lib.prefill_attention(cfg, shared["attn"],
                                                         a, attn_c)
                h = h + out
                m = apply_norm(cfg, h, shared["mlp_norm"])
                h = h + mlp(cfg, shared["mlp"], m)
                return h, (grp_c, attn_c)
            h, (main_c, attn_c) = jax.lax.scan(
                group_body, x, (main, main_c, cache["attn"]))
            if tail_n:
                h, tail_c = jax.lax.scan(ssm_body, h, (tailb, tail_c))
            blocks_c = jax.tree.map(
                lambda m, t: jnp.concatenate(
                    [m.reshape((n_full,) + m.shape[2:]), t], axis=0),
                main_c, tail_c)
            cache = {"blocks": blocks_c, "attn": attn_c,
                     "pos": jnp.asarray(T, jnp.int32)}
    else:
        x, positions, mrope_pos = embed_inputs(cfg, params, batch)
        T = x.shape[1]

        def body(h, xs):
            blk, c = xs
            a = apply_norm(cfg, h, blk["attn_norm"])
            out, c = attn_lib.prefill_attention(cfg, blk["attn"], a, c,
                                                positions=positions,
                                                mrope_pos=mrope_pos)
            h = h + out
            m = apply_norm(cfg, h, blk["mlp_norm"])
            if cfg.family == "moe":
                o, _ = moe_lib.moe_block(cfg, blk["moe"], m)
            else:
                o = mlp(cfg, blk["mlp"], m)
            return h + o, c
        h, blocks_c = jax.lax.scan(body, x, (params["blocks"],
                                             cache["blocks"]))
        cache = {"blocks": blocks_c, "pos": jnp.asarray(T, jnp.int32)}

    h = apply_norm(cfg, h[:, -1:], params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h @ head)[:, 0], cache


def _conv_tail(cfg, blk, a, conv_prev):
    """Last (k-1) conv inputs after a full-sequence pass (for decode)."""
    k = cfg.ssm_conv
    di = cfg.d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    xBC = (a @ blk["ssm"]["in_proj"])[..., di:di + di + 2 * gn]
    return xBC[:, -(k - 1):]


# ---------------------------------------------------------------------------
# decode


def decode_step(cfg, params, token, cache):
    """token: (B, 1) int32.  Returns (logits (B, V), new cache)."""
    x = params["embed"][token]

    if cfg.is_encdec:
        x = x + _sin_at(cfg, cache["pos"], x.dtype)

        def body(h, xs):
            blk, self_c, cross_c = xs
            a = apply_norm(cfg, h, blk["attn_norm"])
            out, self_c = attn_lib.decode_attention(cfg, blk["attn"], a,
                                                    self_c)
            h = h + out
            c = apply_norm(cfg, h, blk["cross_norm"])
            h = h + attn_lib.cross_attention(cfg, blk["cross"], c, cross_c)
            m = apply_norm(cfg, h, blk["mlp_norm"])
            return h + mlp(cfg, blk["mlp"], m), self_c
        h, self_c = jax.lax.scan(body, x, (params["blocks"], cache["self"],
                                           cache["cross"]))
        new_cache = {"self": self_c, "cross": cache["cross"],
                     "pos": cache["pos"] + 1}
    elif cfg.family == "ssm":
        def body(h, xs):
            blk, c = xs
            a = apply_norm(cfg, h, blk["norm"])
            out, c = ssm_lib.mamba_decode(cfg, blk["ssm"], a, c)
            return h + out, c
        h, blocks_c = jax.lax.scan(body, x, (params["blocks"],
                                             cache["blocks"]))
        new_cache = {"blocks": blocks_c, "pos": cache["pos"] + 1}
    elif cfg.family == "hybrid":
        n_full, groups, tail_n = _hybrid_groups(cfg)
        k = cfg.hybrid_attn_every
        main, tailb = _split_stacked(params["blocks"], n_full, k)
        main_c, tail_c = _split_stacked(cache["blocks"], n_full, k)
        shared = params["shared_attn"]

        def ssm_body(h, xs):
            blk, c = xs
            a = apply_norm(cfg, h, blk["norm"])
            out, c = ssm_lib.mamba_decode(cfg, blk["ssm"], a, c)
            return h + out, c

        def group_body(h, xs):
            grp, grp_c, attn_c = xs
            h, grp_c = jax.lax.scan(ssm_body, h, (grp, grp_c))
            a = apply_norm(cfg, h, shared["attn_norm"])
            out, attn_c = attn_lib.decode_attention(cfg, shared["attn"], a,
                                                    attn_c)
            h = h + out
            m = apply_norm(cfg, h, shared["mlp_norm"])
            h = h + mlp(cfg, shared["mlp"], m)
            return h, (grp_c, attn_c)
        h, (main_c, attn_c) = jax.lax.scan(group_body, x,
                                           (main, main_c, cache["attn"]))
        if tail_n:
            h, tail_c = jax.lax.scan(ssm_body, h, (tailb, tail_c))
        blocks_c = jax.tree.map(
            lambda m, t: jnp.concatenate(
                [m.reshape((n_full,) + m.shape[2:]), t], axis=0),
            main_c, tail_c)
        new_cache = {"blocks": blocks_c, "attn": attn_c,
                     "pos": cache["pos"] + 1}
    else:
        def body(h, xs):
            blk, c = xs
            a = apply_norm(cfg, h, blk["attn_norm"])
            out, c = attn_lib.decode_attention(cfg, blk["attn"], a, c)
            h = h + out
            m = apply_norm(cfg, h, blk["mlp_norm"])
            if cfg.family == "moe":
                o, _ = moe_lib.moe_block(cfg, blk["moe"], m)
            else:
                o = mlp(cfg, blk["mlp"], m)
            return h + o, c
        h, blocks_c = jax.lax.scan(body, x, (params["blocks"],
                                             cache["blocks"]))
        new_cache = {"blocks": blocks_c, "pos": cache["pos"] + 1}

    h = apply_norm(cfg, h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h @ head)[:, 0], new_cache


def _sin_at(cfg, pos, dtype):
    """Sinusoid position row at a dynamic position (decode)."""
    dim = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)
    inv = jnp.power(10000.0, dim / cfg.d_model)
    angle = pos.astype(jnp.float32) / inv
    row = jnp.zeros((cfg.d_model,), jnp.float32)
    row = row.at[0::2].set(jnp.sin(angle)).at[1::2].set(jnp.cos(angle))
    return row[None, :].astype(dtype)
