"""GQA attention with full / sliding-window masking and KV (ring) caches.

Layouts
  q:      (B, T, H, hd)
  k, v:   (B, S, K, hd)          K = kv heads, H % K == 0
  cache:  {"k": (B, C, K, hd), "v": ..., "pos": ()}   C = cache capacity
          For sliding-window archs at long context the cache is a ring
          buffer of capacity ``min(seq_len, window)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_mrope, apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params


def init_attention(key, cfg, dtype):
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, cfg.num_heads * hd), dtype),
        "wk": dense_init(ks[1], (D, cfg.num_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (D, cfg.num_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, D), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


def _project_qkv(cfg, params, x, x_kv=None):
    B, T, D = x.shape
    hd = cfg.resolved_head_dim
    x_kv = x if x_kv is None else x_kv
    S = x_kv.shape[1]
    q = x @ params["wq"]
    k = x_kv @ params["wk"]
    v = x_kv @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, T, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    return q, k, v


def _rotary(cfg, q, k, q_pos, k_pos, mrope_pos=None):
    if cfg.positional == "rope":
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, k_pos, cfg.rope_theta)
    elif cfg.positional == "mrope":
        # mrope_pos: (3, B, T) for q and (3, B, S) for k
        qp, kp = mrope_pos
        q = apply_mrope(q, qp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, kp, cfg.rope_theta, cfg.mrope_sections)
    return q, k


def gqa_scores(cfg, q, k):
    """(B,T,H,hd)x(B,S,K,hd) -> (B,K,H/K,T,S) grouped attention logits."""
    B, T, H, hd = q.shape
    K = k.shape[2]
    q = q.reshape(B, T, K, H // K, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", q, k,
                        preferred_element_type=jnp.float32)
    return scores / jnp.sqrt(hd).astype(jnp.float32)


def gqa_out(cfg, probs, v, params):
    B, K, G, T, S = probs.shape
    out = jnp.einsum("bkgts,bskh->btkgh", probs.astype(v.dtype), v)
    out = out.reshape(B, T, K * G * v.shape[-1])
    return out @ params["wo"]


def _causal_window_mask(T, S, q_offset, window: int):
    """Mask (T, S): query i (abs pos q_offset+i) attends key j iff
    j <= pos and pos - j < window (window=0 -> unlimited)."""
    q_pos = q_offset + jnp.arange(T)[:, None]
    k_pos = jnp.arange(S)[None, :]
    m = k_pos <= q_pos
    if window:
        m &= (q_pos - k_pos) < window
    return m


# ---------------------------------------------------------------------------
# full-sequence (training / prefill) attention


def attention(cfg, params, x, *, positions=None, mrope_pos=None,
              causal: bool = True, x_kv=None, k_pos=None):
    """Full-sequence attention.  Returns (B, T, D)."""
    B, T, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x, x_kv)
    S = k.shape[1]
    if positions is None:
        positions = jnp.arange(T)[None, :]
    if k_pos is None:
        k_pos = positions if x_kv is None else jnp.arange(S)[None, :]
    q, k = _rotary(cfg, q, k, positions, k_pos, mrope_pos)
    scores = gqa_scores(cfg, q, k)
    if causal:
        mask = _causal_window_mask(T, S, 0, cfg.sliding_window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return gqa_out(cfg, probs, v, params)


# ---------------------------------------------------------------------------
# KV cache


def init_kv_cache(cfg, batch: int, seq_len: int, dtype):
    """Cache capacity: full seq, or ring of ``window`` for SWA archs."""
    hd = cfg.resolved_head_dim
    cap = seq_len
    if cfg.sliding_window and seq_len > cfg.sliding_window:
        cap = cfg.sliding_window
    return {
        "k": jnp.zeros((batch, cap, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, cap, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),     # number of tokens written
    }


def prefill_attention(cfg, params, x, cache, *, positions=None,
                      mrope_pos=None):
    """Run full attention over a prompt AND build the cache."""
    out = attention(cfg, params, x, positions=positions, mrope_pos=mrope_pos)
    B, T, _ = x.shape
    _, k, v = _project_qkv(cfg, params, x)
    if positions is None:
        positions = jnp.arange(T)[None, :]
    if cfg.positional == "rope":
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.positional == "mrope":
        k = apply_mrope(k, mrope_pos[1], cfg.rope_theta, cfg.mrope_sections)
    cap = cache["k"].shape[1]
    if T <= cap:
        cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
            "pos": jnp.asarray(T, jnp.int32),
        }
    else:  # keep last ``cap`` tokens, rolled so token p sits at slot p % cap
        shift = T % cap
        cache = {
            "k": jnp.roll(k[:, -cap:], shift, axis=1),
            "v": jnp.roll(v[:, -cap:], shift, axis=1),
            "pos": jnp.asarray(T, jnp.int32),
        }
    return out, cache


def decode_attention(cfg, params, x, cache, *, mrope_pos=None):
    """Single-token decode: x (B, 1, D) against the cache (ring-aware).

    ``cache["pos"]`` may be a scalar (all rows decode in lock-step — the
    historical path, jaxpr unchanged) or a (B,) vector of PER-ROW decode
    positions: each row rotates at its own position, writes its own ring
    slot and masks its own written prefix.  Per-row positions are what
    lets the continuous-batching scheduler (:mod:`repro.serving.sched`)
    hold requests at different depths in one batch; row values are
    bit-identical to the same row decoded alone at the scalar position.
    """
    B, T, _ = x.shape
    assert T == 1
    q, k, v = _project_qkv(cfg, params, x)
    pos = cache["pos"]
    per_row = jnp.ndim(pos) == 1
    positions = (pos[:, None].astype(jnp.int32) if per_row
                 else jnp.full((B, 1), pos, jnp.int32))
    if cfg.positional == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.positional == "mrope":
        qp = jnp.broadcast_to(positions, (3,) + positions.shape)
        q = apply_mrope(q, qp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, qp, cfg.rope_theta, cfg.mrope_sections)
    cap = cache["k"].shape[1]
    slot = jnp.mod(pos, cap)
    if per_row:
        ck = cache["k"].at[jnp.arange(B), slot].set(k[:, 0])
        cv = cache["v"].at[jnp.arange(B), slot].set(v[:, 0])
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    scores = gqa_scores(cfg, q, ck)                  # (B,K,G,1,cap)
    # valid = slots already written (ring: window constraint is implied by
    # the capacity — old slots get overwritten)
    idx = jnp.arange(cap)
    written = jnp.where(pos >= cap, cap, pos + 1)
    if per_row:
        valid = idx[None, :] < written[:, None]      # (B, cap)
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    else:
        valid = idx < written
        scores = jnp.where(valid[None, None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = gqa_out(cfg, probs, cv, params)
    new_cache = {"k": ck, "v": cv, "pos": pos + 1}
    return out, new_cache


# ---------------------------------------------------------------------------
# cross-attention cache (enc-dec)


def init_cross_cache(cfg, params, enc_out):
    """Precompute K/V over encoder output once per request."""
    B, S, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ params["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = (enc_out @ params["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    return {"k": k, "v": v}


def cross_attention(cfg, params, x, cross_cache):
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, T, cfg.num_heads, hd)
    scores = gqa_scores(cfg, q, cross_cache["k"])
    probs = jax.nn.softmax(scores, axis=-1)
    return gqa_out(cfg, probs, cross_cache["v"], params)
