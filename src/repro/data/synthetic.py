"""Synthetic data pipeline with per-agent distributions.

Covers the survey's three data-distribution regimes (§3.3.1):
  (1) iid        — every agent samples the same process D;
  (2) non-iid    — agent i samples its own D_i (federated setting, §3.4);
  (3) parallel   — all agents receive identical batches (the gradient-coding
                   setting of Draco/DETOX, §3.3.3).

The process is a learnable modular-arithmetic LM: within a sequence,
token_{k+1} = (token_k + step) mod V where ``step`` is fixed (iid/parallel) or
agent-specific (non-iid).  A model can drive the loss well below log V by
learning the transition — giving convergence signal for end-to-end tests.

Data poisoning (label-flip attack, §3.4) is a data-level Byzantine behaviour:
the f Byzantine agents train on labels rotated by V/2.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    n_agents: int
    per_agent_batch: int
    regime: str = "iid"              # iid | noniid | parallel
    base_step: int = 7

    def _steps(self):
        if self.regime == "noniid":
            # distinct residues -> distinct agent distributions
            return (self.base_step
                    + 2 * jnp.arange(self.n_agents)) % self.vocab_size
        return jnp.full((self.n_agents,), self.base_step)

    def batch(self, key, step_idx: int = 0):
        """Returns {"tokens": (n, b, T), "labels": (n, b, T)} int32."""
        n, b, T, V = (self.n_agents, self.per_agent_batch, self.seq_len,
                      self.vocab_size)
        k_start = jax.random.fold_in(key, step_idx)
        if self.regime == "parallel":
            starts = jax.random.randint(k_start, (1, b), 0, V)
            starts = jnp.broadcast_to(starts, (n, b))
        else:
            starts = jax.random.randint(k_start, (n, b), 0, V)
        steps = self._steps()[:, None]                        # (n, 1)
        ks = jnp.arange(T + 1)[None, None, :]                 # (1, 1, T+1)
        seq = (starts[..., None] + ks * steps[..., None]) % V  # (n, b, T+1)
        return {"tokens": seq[..., :-1].astype(jnp.int32),
                "labels": seq[..., 1:].astype(jnp.int32)}


def label_flip(batch, byz_mask, vocab_size: int):
    """Rotate the labels of Byzantine agents by V/2 (poisoning attack)."""
    flipped = (batch["labels"] + vocab_size // 2) % vocab_size
    mask = byz_mask.reshape((-1,) + (1,) * (batch["labels"].ndim - 1))
    return dict(batch, labels=jnp.where(mask, flipped, batch["labels"]))
