from repro.data.synthetic import SyntheticLM, label_flip

__all__ = ["SyntheticLM", "label_flip"]
