"""Process-global counters and gauges — the compile-accounting substrate.

Promoted from ``repro.core.tracecount`` (which remains as a
backward-compat shim): ``count_trace(site)`` is called from INSIDE
jit-traced step functions (the async/sync training steps, the serving
agreement step).  Python side effects run once per TRACE, never per
execution, so the counter increments exactly when XLA (re)compiles that
site — zero runtime cost on the compiled path.  The membership-retrace
suite asserts compile bounds on the real loops with it, and the flight
recorder (:mod:`repro.obs.recorder`) diffs :func:`snapshot` around every
step to emit its recompile ledger.

Counters are monotonic; consumers snapshot before/after rather than
resetting blindly (tests sharing the process must not clobber each
other).  Gauges are last-write-wins host-side values (live roster size,
arrived count, staleness) the loops publish for scrapers that want the
current state without parsing a trace.
"""
from __future__ import annotations

from collections import Counter

# the ONE counter store — repro.core.tracecount aliases this same object,
# so legacy TRACE_COUNTS reads see every inc() and vice versa
COUNTERS: Counter = Counter()
GAUGES: dict = {}

# legacy alias (same object, not a copy)
TRACE_COUNTS: Counter = COUNTERS


def inc(name: str, by: int = 1) -> None:
    """Increment a counter (monotonic)."""
    COUNTERS[name] += by


def count_trace(site: str) -> None:
    """Record one tracing of ``site`` (call from INSIDE the traced fn)."""
    inc(site)


def trace_count(site: str) -> int:
    return COUNTERS[site]


def set_gauge(name: str, value) -> None:
    """Publish a last-write-wins host-side gauge value."""
    GAUGES[name] = value


def gauge(name: str, default=None):
    return GAUGES.get(name, default)


def snapshot() -> dict:
    """Point-in-time copy: ``{"counters": {...}, "gauges": {...}}``.

    Plain dicts (detached from the live stores), so two snapshots diff
    safely across any amount of intervening work."""
    return {"counters": dict(COUNTERS), "gauges": dict(GAUGES)}


def counter_delta(before: dict, after: dict | None = None) -> dict:
    """Per-site counter increments between two :func:`snapshot` calls
    (``after=None`` means "now").  Sites with zero delta are omitted —
    the recorder emits one compile event per nonzero entry."""
    after = after if after is not None else snapshot()
    b = before.get("counters", {})
    out = {}
    for site, n in after.get("counters", {}).items():
        d = n - b.get(site, 0)
        if d:
            out[site] = d
    return out


def reset(name: str | None = None) -> None:
    """Clear counters and gauges (one name, or everything).  Prefer
    snapshot-diffing in tests — reset is for interactive sessions."""
    if name is None:
        COUNTERS.clear()
        GAUGES.clear()
    else:
        COUNTERS.pop(name, None)
        GAUGES.pop(name, None)


def reset_traces(site: str | None = None) -> None:
    """Legacy alias of :func:`reset` restricted to counters."""
    if site is None:
        COUNTERS.clear()
    else:
        COUNTERS.pop(site, None)


__all__ = [
    "COUNTERS", "GAUGES", "TRACE_COUNTS", "inc", "count_trace",
    "trace_count", "set_gauge", "gauge", "snapshot", "counter_delta",
    "reset", "reset_traces",
]
