"""Run provenance: the environment fingerprint stamped into every bench
JSON and recorded trace.

ROADMAP item 1 moves the bench trajectories from CPU-interpret Pallas to
real TPU cores; numbers from the two regimes are not comparable, and a
``BENCH_*.json`` without a fingerprint cannot be told apart after the
fact.  One dict, cheap to compute, safe everywhere (every lookup is
individually guarded — a missing git binary or a non-repo checkout
degrades to ``"unknown"``, never an exception)."""
from __future__ import annotations

import os
import subprocess
import time


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5)
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:
        pass
    return "unknown"


def provenance() -> dict:
    """Environment fingerprint: jax version, backend, device kind,
    Pallas interpret-mode default, git SHA, wall-clock timestamp."""
    rec = {
        "jax_version": "unknown",
        "backend": "unknown",
        "device_kind": "unknown",
        "device_count": 0,
        "interpret": None,
        "git_sha": _git_sha(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }
    try:
        import jax
        rec["jax_version"] = jax.__version__
        rec["backend"] = jax.default_backend()
        devs = jax.devices()
        rec["device_count"] = len(devs)
        if devs:
            rec["device_kind"] = devs[0].device_kind
    except Exception:
        pass
    try:
        from repro.kernels.dispatch import default_interpret
        rec["interpret"] = bool(default_interpret())
    except Exception:
        pass
    return rec


__all__ = ["provenance"]
