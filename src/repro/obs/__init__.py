"""Flight-recorder observability substrate (PR 6).

Three layers, consumed by the training loops, the async simulator and the
replicated serving engine:

:mod:`repro.obs.counters`
    Process-global compile counters (the promoted ``core/tracecount``) and
    host-side gauges, with a public ``snapshot()``/``reset()`` API — the
    substrate every compile-regression test and the recorder's recompile
    ledger read from.

:mod:`repro.obs.telemetry`
    Per-step aggregation telemetry: fixed-shape per-agent selection
    weights emitted as aux outputs of the jitted steps
    (``spec.aggregate_with_telemetry``), host-side accumulation into
    per-agent time series, and derived *suspicion scores*
    (selection-rate vs the uniform baseline — the signal every
    detection-based defense in the survey starts from).

:mod:`repro.obs.recorder`
    The :class:`Recorder`: a JSONL event log (run metadata, step spans,
    telemetry rows, compile events, membership/fault annotations) plus a
    Chrome-trace/Perfetto export so a churn+crash run is visually
    inspectable in ``chrome://tracing`` / ui.perfetto.dev.

:mod:`repro.obs.report`
    Renders a recorded trace into the per-agent suspicion table,
    staleness/quorum percentiles, recompile ledger and rule-dispatch
    breakdown (``python -m repro.launch.report trace.jsonl``).

Hard contract: telemetry OFF is bit-identical to the pre-observability
code path (the telemetry branch is a static Python flag — same jaxpr, no
added recompiles); telemetry ON adds only fixed-shape aux outputs, so the
elastic-bucket compile budget is unchanged and the aggregation output
stays bit-for-bit (tests/test_obs.py pins both).
"""
from repro.obs import counters
from repro.obs.provenance import provenance
from repro.obs.recorder import Recorder, chrome_trace, read_trace
from repro.obs.telemetry import (agent_series, dispatch_record,
                                 suspicion_scores)

__all__ = [
    "counters", "provenance", "Recorder", "chrome_trace", "read_trace",
    "agent_series", "dispatch_record", "suspicion_scores",
]
