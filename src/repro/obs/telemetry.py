"""Aggregation telemetry: dispatch records, per-agent series, suspicion.

The in-trace half lives on the spec itself
(:meth:`repro.core.aggregators.AggregatorSpec.selection_weights` /
``aggregate_with_telemetry`` — fixed-shape aux outputs threaded through
the jitted steps).  This module is the HOST side: the static dispatch
record stamped into a run's metadata, the accumulation of per-step
telemetry rows into per-agent time series, and the derived *suspicion
scores* — the signal the survey's detection-based defenses (Bouhata et
al. §detection taxonomy) start from, which the repo used to throw away.

Suspicion definition: a robust rule that keeps excluding an agent's rows
is evidence against that agent.  Per delivered row we convert the rule's
application weights into *selection shares* (normalized to sum 1 over
the delivered set) and compare each agent's share against the uniform
baseline ``1/arrived``:

    rate_i      = mean_t[ share_i(t) * arrived(t) | delivered_i(t) ]
    suspicion_i = clip(1 - rate_i, 0, 1)

Under plain averaging every delivered agent has rate 1 (suspicion 0); an
agent Krum never selects has rate 0 (suspicion 1).  Rates ABOVE uniform
(an agent the rule over-selects) clamp to suspicion 0 — over-selection
is consensus, not evidence of attack.
"""
from __future__ import annotations

import numpy as np


# ---------------------------------------------------------------------------
# static dispatch record — the per-rule decision gather|fused|pallas, the
# elastic bucket table and the static plan sizes, stamped once per run (and
# once per bucket specialization) into the recorder's metadata


def dispatch_record(spec, bucket: int | None = None) -> dict:
    """Host-side static description of how ``spec`` will dispatch.

    Everything here is known at spec-build time — rule, impl
    (``gather|fused|pallas``), (n, f), the elastic bucket table, whether
    the zero-copy flat path applies, and the coordwise trim count —
    so the record costs nothing per step and never touches a trace."""
    from repro.core.aggregators import trim_count
    rec = {
        "rule": spec.name,
        "impl": spec.impl,
        "f": int(spec.f) if isinstance(spec.f, int) else str(spec.f),
        "n": None if spec.n is None else int(spec.n),
        "flat": bool(spec.flat_capable),
        "stateful": bool(spec.stateful),
    }
    if bucket is not None:
        rec["bucket"] = int(bucket)
    if spec.name == "trimmed_mean" and spec.n is not None:
        rec["trim_b"] = int(trim_count(spec.n, spec.f, spec.hp("beta")))
    el = spec.elastic_n
    if el is not None:
        rec["elastic_buckets"] = [int(b) for b in el.buckets]
    if spec.inner is not None:
        rec["inner"] = dispatch_record(spec.inner)
    return rec


# ---------------------------------------------------------------------------
# host-side accumulation: recorder events -> per-agent time series


def agent_series(events, n: int | None = None) -> dict:
    """Stack the per-step telemetry rows of a recorded run.

    ``events``: the event list of a :class:`repro.obs.recorder.Recorder`
    (or :func:`repro.obs.recorder.read_trace`).  Returns fixed-shape
    arrays over the T steps that carried telemetry:

      ``sel_w``     (T, n) — the rule's application weights;
      ``mask``      (T, n) bool — delivered rows;
      ``contrib_w`` (T, n) — staleness-discounted delivery weights
                    (all-ones when the run never set them);
      ``roster``    (T, n) bool — live membership (all-True when static);
      ``step``      (T,) int — source step indices.
    """
    rows = [e for e in events
            if e.get("kind") == "step" and e.get("telemetry")]
    if not rows:
        z = np.zeros((0, n or 0))
        return {"sel_w": z, "mask": z.astype(bool), "contrib_w": z,
                "roster": z.astype(bool), "step": np.zeros(0, int)}
    first = rows[0]["telemetry"]
    n = n if n is not None else len(first["sel_w"])

    def col(key, default):
        return np.asarray([r["telemetry"].get(key, default)
                           for r in rows])
    sel = col("sel_w", [0.0] * n).astype(np.float64)
    mask = col("mask", [True] * n).astype(bool)
    contrib = col("contrib_w", [1.0] * n).astype(np.float64)
    roster = np.asarray([r.get("roster", [True] * n) for r in rows],
                        bool)
    step = np.asarray([r.get("step", i) for i, r in enumerate(rows)], int)
    return {"sel_w": sel, "mask": mask, "contrib_w": contrib,
            "roster": roster, "step": step}


def suspicion_scores(sel_w, mask, roster=None) -> list[dict]:
    """Per-agent selection statistics and suspicion scores.

    ``sel_w`` (T, n) application weights, ``mask`` (T, n) delivered,
    ``roster`` (T, n) live membership (None = always live).  Returns one
    dict per agent: live/delivered fractions, mean selection share
    relative to uniform (``sel_rate``, 1.0 = uniform), and
    ``suspicion`` in [0, 1] (see module docstring).  Agents that never
    delivered report ``sel_rate=None`` and inherit suspicion 0 — no
    evidence is not evidence of attack (crashed != Byzantine)."""
    sel_w = np.asarray(sel_w, np.float64)
    mask = np.asarray(mask, bool)
    T, n = sel_w.shape if sel_w.ndim == 2 else (0, 0)
    roster = (np.ones((T, n), bool) if roster is None
              else np.asarray(roster, bool))
    out = []
    # selection shares: normalize each step's weights over the delivered
    # set so rules whose weights sum below 1 (cgc attenuation) and
    # discount-scaled rows compare on the same uniform baseline
    tot = np.sum(np.where(mask, sel_w, 0.0), axis=1, keepdims=True)
    share = np.where(mask, sel_w, 0.0) / np.maximum(tot, 1e-30)
    arrived = mask.sum(1)
    for i in range(n):
        live_frac = float(roster[:, i].mean()) if T else 0.0
        live_steps = max(int(roster[:, i].sum()), 1)
        del_frac = float(mask[:, i].sum() / live_steps) if T else 0.0
        d = mask[:, i]
        if d.any():
            rate = float(np.mean(share[d, i] * arrived[d]))
            susp = float(np.clip(1.0 - rate, 0.0, 1.0))
        else:
            rate, susp = None, 0.0
        out.append({
            "agent": i,
            "live_frac": live_frac,
            "delivered_frac": del_frac,
            "sel_rate": rate,
            "suspicion": susp,
        })
    return out


__all__ = ["dispatch_record", "agent_series", "suspicion_scores"]
