"""Render a recorded flight-recorder trace into human-readable tables.

Consumed by ``python -m repro.launch.report <trace.jsonl>``: per-agent
suspicion table (selection-rate vs uniform baseline), staleness/quorum
percentiles, the recompile ledger (which step paid for which jit trace),
and the rule-dispatch breakdown stamped at run start.  Pure functions
from an event list (as produced by :class:`repro.obs.recorder.Recorder`
or :func:`repro.obs.recorder.read_trace`) to strings — no jax imports,
so the CLI starts instantly on a laptop reading a TPU run's trace."""
from __future__ import annotations

import numpy as np

from repro.obs.telemetry import agent_series, suspicion_scores


def _fmt_table(headers, rows) -> str:
    cols = [len(h) for h in headers]
    srows = [[str(c) for c in row] for row in rows]
    for row in srows:
        cols = [max(w, len(c)) for w, c in zip(cols, row)]
    fmt = "  ".join(f"{{:>{w}}}" for w in cols)
    lines = [fmt.format(*headers), fmt.format(*("-" * w for w in cols))]
    lines += [fmt.format(*row) for row in srows]
    return "\n".join(lines)


def _steps(events):
    return [e for e in events if e.get("kind") == "step"]


def render_dispatch(events) -> str:
    """Rule-dispatch breakdown from the run metadata event(s)."""
    runs = [e for e in events if e.get("kind") == "run"]
    if not runs:
        return "dispatch: no run metadata recorded"
    lines = ["rule dispatch"]
    for run in runs:
        d = run.get("dispatch") or {}
        while d:
            bits = [f"rule={d.get('rule')}", f"impl={d.get('impl')}",
                    f"f={d.get('f')}", f"n={d.get('n')}"]
            if d.get("elastic_buckets"):
                bits.append(f"buckets={d['elastic_buckets']}")
            if d.get("trim_b") is not None:
                bits.append(f"trim_b={d['trim_b']}")
            if d.get("flat"):
                bits.append("flat-arena")
            if d.get("stateful"):
                bits.append("stateful")
            lines.append("  " + "  ".join(bits))
            d = d.get("inner") or {}
    return "\n".join(lines)


def render_suspicion(events, top: int | None = None) -> str:
    """Per-agent suspicion table (most suspicious first)."""
    ser = agent_series(events)
    if ser["sel_w"].shape[0] == 0:
        return ("suspicion: no telemetry rows in trace "
                "(record with telemetry enabled)")
    scores = suspicion_scores(ser["sel_w"], ser["mask"], ser["roster"])
    scores = sorted(scores, key=lambda s: -s["suspicion"])
    if top:
        scores = scores[:top]
    rows = [[s["agent"], f"{s['live_frac']:.2f}",
             f"{s['delivered_frac']:.2f}",
             "--" if s["sel_rate"] is None else f"{s['sel_rate']:.3f}",
             f"{s['suspicion']:.3f}",
             "#" * int(round(10 * s["suspicion"]))] for s in scores]
    hdr = ["agent", "live", "delivered", "sel_rate", "suspicion", ""]
    return (f"per-agent suspicion ({ser['sel_w'].shape[0]} telemetry "
            "steps; sel_rate 1.0 = uniform)\n" + _fmt_table(hdr, rows))


def _pcts(values) -> dict:
    v = np.asarray(values, np.float64)
    if v.size == 0:
        return {"p50": 0.0, "p95": 0.0, "max": 0.0}
    return {"p50": float(np.percentile(v, 50)),
            "p95": float(np.percentile(v, 95)),
            "max": float(v.max())}


def render_percentiles(events) -> str:
    """Staleness / arrival / quorum statistics over the recorded steps."""
    steps = _steps(events)
    metrics = [e.get("metrics") or {} for e in steps]
    if not metrics:
        return "percentiles: no step events in trace"
    rows = []
    for key, label in (("staleness_mean", "staleness(mean/step)"),
                       ("staleness_max", "staleness(max/step)"),
                       ("arrived", "arrived"),
                       ("n_live", "n_live")):
        vals = [m[key] for m in metrics if key in m]
        if vals:
            p = _pcts(vals)
            rows.append([label, f"{p['p50']:.2f}", f"{p['p95']:.2f}",
                         f"{p['max']:.2f}"])
    out = [f"step statistics over {len(steps)} recorded steps"]
    if rows:
        out.append(_fmt_table(["metric", "p50", "p95", "max"], rows))
    quorum = [m.get("quorum_ok") for m in metrics
              if m.get("quorum_ok") is not None]
    if quorum:
        misses = sum(1 for q in quorum if not q)
        out.append(f"quorum: {len(quorum) - misses}/{len(quorum)} steps met"
                   f" ({misses} missed)")
    return "\n".join(out)


def render_compile_ledger(events) -> str:
    """Which step paid for which jit trace — the recompile ledger."""
    compiles = [e for e in events if e.get("kind") == "compile"]
    n_steps = len(_steps(events))
    if not compiles:
        return f"recompile ledger: 0 traces over {n_steps} steps"
    per_site: dict = {}
    for e in compiles:
        site = e.get("site", "?")
        per_site.setdefault(site, []).append(
            (e.get("step", -1), e.get("count", 1)))
    rows = []
    for site, hits in sorted(per_site.items()):
        total = sum(c for _, c in hits)
        at = ", ".join(f"step {s}" + (f" (x{c})" if c > 1 else "")
                       for s, c in hits)
        rows.append([site, total, at])
    head = (f"recompile ledger: {sum(r[1] for r in rows)} traces over "
            f"{n_steps} steps")
    return head + "\n" + _fmt_table(["site", "traces", "paid at"], rows)


def render_membership(events) -> str:
    rows = [[e.get("step"), f"+{e.get('joined')}", f"-{e.get('left')}",
             e.get("n_live")] for e in events
            if e.get("kind") == "membership"]
    if not rows:
        return ""
    return ("membership changes\n"
            + _fmt_table(["step", "joined", "left", "n_live"], rows))


def render_report(events, top: int | None = None) -> str:
    """The full report ``python -m repro.launch.report`` prints."""
    meta = next((e for e in events if e.get("kind") == "meta"), {})
    prov = meta.get("provenance") or {}
    head = ("flight-recorder report"
            f"  [jax {prov.get('jax_version', '?')}"
            f" | {prov.get('backend', '?')}/{prov.get('device_kind', '?')}"
            f" | interpret={prov.get('interpret')}"
            f" | git {str(prov.get('git_sha', '?'))[:12]}]")
    sections = [head, render_dispatch(events), render_suspicion(events, top),
                render_percentiles(events), render_compile_ledger(events),
                render_membership(events)]
    return "\n\n".join(s for s in sections if s)


__all__ = ["render_report", "render_dispatch", "render_suspicion",
           "render_percentiles", "render_compile_ledger",
           "render_membership"]
