"""The run recorder: structured JSONL event logs + Chrome-trace export.

A :class:`Recorder` is a cheap host-side event sink the training loop,
the async simulator and the replicated serving engine all feed.  Events
are plain dicts, appended in memory and (when a path is given) written
one JSON line at a time, so a crashed run keeps everything up to the
last completed step.  Event kinds:

  ``meta``        run metadata: provenance fingerprint, dispatch record,
                  config echo — emitted once at recorder creation;
  ``step``        one optimizer/agreement step: span timing, scalar
                  metrics, the fixed-shape telemetry row (sel_w / mask /
                  contrib_w), roster and gauge values;
  ``compile``     one jit (re)trace of a counted site — the recorder
                  diffs :func:`repro.obs.counters.snapshot` around every
                  step, so recompiles land exactly on the step that paid
                  for them (the recompile ledger);
  ``membership``  roster delta annotations (joined/left agent ids);
  ``fault``       fault-schedule annotations (attack flips, crashes);
  ``note``        anything else.

:func:`chrome_trace` converts the event list into the Chrome trace-event
JSON (``{"traceEvents": [...]}``) that ``chrome://tracing`` and
ui.perfetto.dev load: step spans as "X" duration events, compiles and
faults as "i" instants on their own rows, live/arrived/staleness as "C"
counter tracks.

The recorder NEVER touches a jit trace: every hook runs on host between
steps, on concrete outputs the loop already fetched.  Recorder-on adds
zero recompiles by construction (pinned by tests/test_obs.py).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.obs import counters
from repro.obs.provenance import provenance


def _jsonable(x):
    """Recursively convert numpy/jax scalars and arrays for json.dump."""
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (bool, int, float, str)) or x is None:
        return x
    if isinstance(x, (np.bool_, np.integer)):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    if hasattr(x, "tolist"):          # np.ndarray and jax.Array alike
        return _jsonable(np.asarray(x).tolist())
    return str(x)


class Recorder:
    """Append-only event sink with optional JSONL persistence.

    ``path=None`` keeps events in memory only (tests, examples);
    otherwise every event is written as one JSON line immediately.
    ``meta`` extra fields for the opening metadata event (config echo,
    dispatch record, ...).
    """

    def __init__(self, path=None, meta: dict | None = None):
        self.events: list[dict] = []
        self.path = None if path is None else str(path)
        self._fh = open(self.path, "w") if self.path else None
        self._t0 = time.perf_counter()
        self._snap = counters.snapshot()
        self._roster = None
        self._subscribers: list = []
        self.emit("meta", provenance=provenance(), **(meta or {}))

    # -- core -----------------------------------------------------------
    def now(self) -> float:
        """Seconds since recorder creation (use for step t0/t1 spans)."""
        return time.perf_counter() - self._t0

    def subscribe(self, callback):
        """Stream events to ``callback(event_dict)`` as they are emitted.

        The live half of the recorder: a subscriber sees every event the
        JSONL file gets (same dicts, same order, including any emitted
        before it unsubscribes) WITHOUT re-parsing the file — this is how
        the scheduler's suspicion policy (:mod:`repro.serving.sched`)
        consumes selection-weight telemetry inside the serving loop.
        Subscription is purely additive: file emission stays byte
        identical whether zero or many subscribers are attached, and a
        subscriber registered mid-run simply starts at the next event
        (replay ``recorder.events`` yourself if you need history).
        Returns a zero-argument unsubscribe callable."""
        self._subscribers.append(callback)

        def unsubscribe():
            if callback in self._subscribers:
                self._subscribers.remove(callback)
        return unsubscribe

    def emit(self, kind: str, **fields) -> dict:
        ev = {"kind": kind, "t": round(self.now(), 6)}
        ev.update(_jsonable(fields))
        self.events.append(ev)
        if self._fh is not None:
            self._fh.write(json.dumps(ev) + "\n")
            self._fh.flush()
        for cb in tuple(self._subscribers):
            cb(ev)
        return ev

    # -- convenience hooks the loops call -------------------------------
    def step(self, step: int, t0: float | None = None,
             t1: float | None = None, metrics: dict | None = None,
             telemetry: dict | None = None, roster=None, **fields):
        """Record one completed step.

        Diffs the compile counters first so recompile events precede (and
        are attributable to) the step that triggered them, then emits any
        roster-delta annotation, then the step event itself."""
        delta = counters.counter_delta(self._snap)
        if delta:
            self._snap = counters.snapshot()
            for site, k in delta.items():
                self.emit("compile", step=step, site=site, count=k)
        if roster is not None:
            r = np.asarray(roster, bool)
            if self._roster is not None and not np.array_equal(r, self._roster):
                joined = np.flatnonzero(r & ~self._roster)
                left = np.flatnonzero(~r & self._roster)
                self.emit("membership", step=step,
                          joined=joined.tolist(), left=left.tolist(),
                          n_live=int(r.sum()))
            self._roster = r
        ev = {"step": int(step)}
        if t0 is not None:
            ev["t0"] = round(float(t0), 6)
            ev["t1"] = round(float(t1 if t1 is not None else self.now()), 6)
        if metrics:
            ev["metrics"] = metrics
        if telemetry:
            ev["telemetry"] = telemetry
        if roster is not None:
            ev["roster"] = np.asarray(roster, bool).tolist()
        ev.update(fields)
        return self.emit("step", **ev)

    def fault(self, step: int, fault: str, agents=(), **fields):
        return self.emit("fault", step=int(step), fault=str(fault),
                         agents=list(agents), **fields)

    def note(self, message: str, **fields):
        return self.emit("note", message=str(message), **fields)

    def close(self):
        # flush any compiles since the last step so the ledger is complete
        delta = counters.counter_delta(self._snap)
        if delta:
            self._snap = counters.snapshot()
            for site, k in delta.items():
                self.emit("compile", step=-1, site=site, count=k)
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- exports --------------------------------------------------------
    def chrome_trace(self) -> dict:
        return chrome_trace(self.events)

    def dump_chrome_trace(self, path) -> str:
        path = str(path)
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh)
        return path


def read_trace(path) -> list[dict]:
    """Load a JSONL trace back into the recorder's event-list form."""
    events = []
    with open(str(path)) as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def chrome_trace(events) -> dict:
    """Convert recorder events to Chrome trace-event JSON.

    Rows (tids) under one process: 0 = step spans, 1 = compile instants,
    2 = fault/membership annotations; counter tracks for live/arrived/
    staleness ride as "C" events.  Timestamps are µs; steps without
    explicit t0/t1 spans fall back to 1 ms synthetic slots so the track
    still renders in order."""
    out = []
    pid = 0
    for tid, label in ((0, "steps"), (1, "compiles"), (2, "faults")):
        out.append({"ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_name", "args": {"name": label}})
    for i, ev in enumerate(events):
        kind = ev.get("kind")
        ts = ev.get("t", i * 1e-3) * 1e6
        if kind == "step":
            if "t0" in ev:
                ts = ev["t0"] * 1e6
                dur = max((ev.get("t1", ev["t0"]) - ev["t0"]) * 1e6, 1.0)
            else:
                ts, dur = ev.get("step", i) * 1e3, 1e3
            args = {"step": ev.get("step")}
            args.update(ev.get("metrics") or {})
            out.append({"ph": "X", "pid": pid, "tid": 0,
                        "name": f"step {ev.get('step')}",
                        "ts": ts, "dur": dur, "cat": "step", "args": args})
            m = ev.get("metrics") or {}
            for key in ("n_live", "arrived", "staleness_mean", "quorum_ok"):
                if key in m:
                    out.append({"ph": "C", "pid": pid, "tid": 0, "ts": ts,
                                "name": key, "args": {key: m[key]}})
            if ev.get("roster") is not None:
                out.append({"ph": "C", "pid": pid, "tid": 0, "ts": ts,
                            "name": "roster_live",
                            "args": {"live": int(sum(ev["roster"]))}})
        elif kind == "compile":
            out.append({"ph": "i", "pid": pid, "tid": 1, "ts": ts, "s": "t",
                        "cat": "compile",
                        "name": f"compile:{ev.get('site')}",
                        "args": {"site": ev.get("site"),
                                 "count": ev.get("count"),
                                 "step": ev.get("step")}})
        elif kind in ("fault", "membership"):
            name = (ev.get("fault") if kind == "fault" else
                    f"roster Δ +{ev.get('joined')} -{ev.get('left')}")
            out.append({"ph": "i", "pid": pid, "tid": 2, "ts": ts, "s": "t",
                        "cat": kind, "name": str(name),
                        "args": {k: v for k, v in ev.items()
                                 if k not in ("kind", "t")}})
    return {"traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"source": "repro.obs.recorder"}}


__all__ = ["Recorder", "read_trace", "chrome_trace"]
