"""Whisper-small  [arXiv:2212.04356]

Encoder-decoder, 12+12L, d_model=768, 12H (MHA), d_ff=3072, vocab=51865.
The mel-spectrogram + conv frontend is the allowed STUB: input_specs()
provides (B, 1500, 768) frame embeddings.  LayerNorm + GELU + sinusoid
positions (decoder learned positions replaced by sinusoid — DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    encoder_layers=12,
    encoder_seq=1500,
    frontend="audio",
    norm="layer",
    act="gelu",
    positional="sinusoid",
    qkv_bias=True,
    source="arXiv:2212.04356",
)
