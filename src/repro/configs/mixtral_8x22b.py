"""Mixtral-8x22B  [arXiv:2401.04088]

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768 — 8 experts top-2,
sliding-window attention (4096), so long_500k decode runs.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    source="arXiv:2401.04088",
)
