"""Mamba2-130M  [arXiv:2405.21060]

24L d_model=768 attention-free SSD (state-space duality), ssm_state=128,
d_inner=1536, head_dim=64 (24 SSM heads), vocab=50280, tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
