"""The survey's own experimental scale: a ~100M-parameter dense LM used by
the end-to-end examples (the surveyed papers evaluate on small models —
MNIST/CIFAR MLPs & CNNs; we use a modern equivalent decoder LM)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paper-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32000,
    source="survey experimental scale",
)
