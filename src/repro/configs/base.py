"""Architecture configuration schema.

Every assigned architecture gets one ``ArchConfig`` in ``repro/configs/<id>.py``
with the exact dimensions from the assignment table (source cited in
``source``).  ``reduced()`` derives the CPU smoke-test variant of the same
family (2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # --- hybrid (Zamba2-style: shared attention every k SSM blocks) ---
    hybrid_attn_every: int = 0     # 0 -> not hybrid
    # --- attention flavour ---
    sliding_window: int = 0        # 0 -> full causal attention
    rope_theta: float = 10000.0
    positional: str = "rope"       # rope | mrope | sinusoid | none
    mrope_sections: tuple = (16, 24, 24)   # t/h/w splits of head_dim//2
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0           # frames after the (stubbed) conv frontend
    # --- modality frontend stub ---
    frontend: str = "none"         # none | audio | vision
    frontend_tokens: int = 0       # patches/frames prepended for vlm
    # --- misc ---
    norm: str = "rms"              # rms | layer
    act: str = "silu"              # silu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context (O(window) or O(state))?"""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window > 0
        )

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant of the same family: tiny but structurally equal."""
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, max(1, heads // 2)) if heads else 0
        kw = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=(64 if heads else 0),
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )
        if self.num_experts:
            kw.update(
                num_experts=min(self.num_experts, 4),
                experts_per_token=min(self.experts_per_token, 2),
            )
        if self.ssm_state:
            kw.update(ssm_state=min(self.ssm_state, 16), ssm_head_dim=32,
                      ssm_chunk=32)
        if self.hybrid_attn_every:
            kw.update(hybrid_attn_every=1, num_layers=2)
        if self.positional == "mrope":
            # sections must sum to head_dim/2 of the reduced head size
            kw.update(mrope_sections=(8, 12, 12))
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=min(self.encoder_seq, 64))
        if self.frontend_tokens:
            kw.update(frontend_tokens=min(self.frontend_tokens, 16))
        return self.replace(**kw)


def num_params(cfg: ArchConfig) -> int:
    """Closed-form parameter count (embedding + blocks + head)."""
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.num_layers
    hd = cfg.resolved_head_dim
    total = V * D                                  # embed
    if not cfg.tie_embeddings:
        total += V * D                             # lm head
    total += D                                     # final norm

    def attn_params() -> int:
        q = D * cfg.num_heads * hd
        kv = 2 * D * cfg.num_kv_heads * hd
        o = cfg.num_heads * hd * D
        return q + kv + o

    def mlp_params() -> int:
        return 3 * D * F if cfg.act == "silu" else 2 * D * F

    def moe_params() -> int:
        p = D * cfg.num_experts + cfg.num_experts * 3 * D * F
        if cfg.shared_expert:
            p += 3 * D * F
        return p

    def ssm_params() -> int:
        di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
        h = cfg.ssm_num_heads
        in_proj = D * (2 * di + 2 * g * n + h)
        conv = cfg.ssm_conv * (di + 2 * g * n)
        extra = 3 * h          # A_log, dt_bias, D skip (per head)
        out = di * D
        return in_proj + conv + extra + out + di   # + gated norm scale

    if cfg.family == "ssm":
        total += L * (ssm_params() + D)
    elif cfg.family == "hybrid":
        total += L * (ssm_params() + D)
        total += attn_params() + mlp_params() + 2 * D   # one shared block
    elif cfg.family == "moe":
        total += L * (attn_params() + moe_params() + 2 * D)
    else:
        total += L * (attn_params() + mlp_params() + 2 * D)
        if cfg.is_encdec:
            E = cfg.encoder_layers
            total += E * (attn_params() + mlp_params() + 2 * D)
            # decoder cross-attention
            total += L * (attn_params() + D)
    return total


def active_params(cfg: ArchConfig) -> int:
    """Active parameters per token (MoE: only routed experts count)."""
    if not cfg.num_experts:
        return num_params(cfg)
    D, F, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    dense_experts = cfg.num_experts - cfg.experts_per_token
    inactive = L * dense_experts * 3 * D * F
    return num_params(cfg) - inactive
