"""Architecture registry: ``get_config("<id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, active_params, num_params

_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "zamba2-7b": "zamba2_7b",
    "whisper-small": "whisper_small",
    "mamba2-130m": "mamba2_130m",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "llama3-8b": "llama3_8b",
    "internlm2-20b": "internlm2_20b",
    "mixtral-8x22b": "mixtral_8x22b",
    "paper-100m": "paper_100m",
}

ASSIGNED_ARCHS = [k for k in _MODULES if k != "paper-100m"]


def get_config(name: str) -> ArchConfig:
    smoke = name.endswith("-smoke")
    base = name[:-len("-smoke")] if smoke else name
    if base not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[base]}")
    cfg = mod.CONFIG
    return cfg.reduced() if smoke else cfg


def list_archs():
    return sorted(_MODULES)


__all__ = ["ArchConfig", "get_config", "list_archs", "ASSIGNED_ARCHS",
           "num_params", "active_params"]
