"""Qwen2-VL-72B  [arXiv:2409.12191]

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — M-RoPE
(t/h/w sections 16/24/24 of head_dim/2=64), dynamic-resolution ViT stubbed:
input_specs() provides 256 patch embeddings per image.  Full attention:
long_500k decode skipped (DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    positional="mrope",
    mrope_sections=(16, 24, 24),
    frontend="vision",
    frontend_tokens=256,
    qkv_bias=True,
    rope_theta=1000000.0,
    source="arXiv:2409.12191",
)
