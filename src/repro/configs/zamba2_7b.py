"""Zamba2-7B  [arXiv:2411.15242]

81 Mamba2 blocks (d_model=3584, ssm_state=64) + a SHARED full transformer
block (32H MHA kv=32, d_ff=14336) applied every 6 SSM blocks.  vocab=32000.
The shared attention uses a 4096 sliding window here so long_500k decode is
O(window) — deviation noted in DESIGN.md.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    hybrid_attn_every=6,
    sliding_window=4096,
    source="arXiv:2411.15242",
)
