"""Llama-4 Scout 17B-active / 16 experts  [hf:meta-llama/Llama-4-Scout-17B-16E]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1
with shared expert, early-fusion multimodal (text backbone here).  Chunked
local attention (8192) modeled as sliding-window — see DESIGN.md deviations.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    experts_per_token=1,
    shared_expert=True,
    sliding_window=8192,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
