"""Step-size schedules.

``diminishing`` implements the survey's Appendix A.2 condition
(sum eta_t = inf, sum eta_t^2 < inf): eta_t = eta0 / (1 + decay * t) —
required by the DGD/BGD convergence analyses the survey cites."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def diminishing(eta0: float, decay: float = 1.0):
    return lambda step: eta0 / (1.0 + decay * step.astype(jnp.float32))


def inverse_sqrt(eta0: float, warmup: int = 100):
    def fn(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        return eta0 * jnp.minimum(s / warmup, jnp.sqrt(warmup / s))
    return fn


def cosine_warmup(base: float, warmup: int, total: int, floor: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = base * s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + 0.5 * (base - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return fn
