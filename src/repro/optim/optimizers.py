"""Minimal production optimizers (pytree-native, jit/SPMD friendly).

Optimizer state lives in fp32 regardless of parameter dtype (mixed-precision
training); updates are cast back to the parameter dtype on apply."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable       # (grads, state, params, step) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(
            p.dtype), params, updates)


def sgd(lr, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0):
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, _step=None):
        step = state["step"]
        eta = lr(step)
        g = jax.tree.map(lambda gr, p: gr.astype(jnp.float32)
                         + weight_decay * p.astype(jnp.float32),
                         grads, params)
        if momentum == 0.0:
            upd = jax.tree.map(lambda gr: -eta * gr, g)
            return upd, {"step": step + 1}
        mu = jax.tree.map(lambda m, gr: momentum * m + gr, state["mu"], g)
        if nesterov:
            upd = jax.tree.map(lambda m, gr: -eta * (momentum * m + gr),
                               mu, g)
        else:
            upd = jax.tree.map(lambda m: -eta * m, mu)
        return upd, {"step": step + 1, "mu": mu}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, _step=None):
        step = state["step"] + 1
        eta = lr(state["step"])
        t = step.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1)
                         * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(m_, v_, p):
            mhat = m_ / bc1
            vhat = v_ / bc2
            return -eta * (mhat / (jnp.sqrt(vhat) + eps)
                           + weight_decay * p.astype(jnp.float32))
        updates = jax.tree.map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)
