from repro.optim.optimizers import Optimizer, adamw, apply_updates, sgd
from repro.optim.schedules import (constant, cosine_warmup, diminishing,
                                   inverse_sqrt)

__all__ = ["Optimizer", "sgd", "adamw", "apply_updates", "constant",
           "diminishing", "cosine_warmup", "inverse_sqrt"]
