"""Byzantine attack models (survey §3.1 behaviours + §4.1 adversarial models).

An attack rewrites the update vectors of the f Byzantine agents.  Attacks see
everything (omniscient adversary): the honest gradients, the mask, and shared
randomness — the strongest standard threat model.

Signature: ``attack(key, g, byz_mask, **hyper) -> g_attacked`` with
``g: (n, d)`` and ``byz_mask: (n,) bool`` (True = Byzantine).  SPMD-uniform:
implemented as a dense ``where`` so the same program runs on every shard.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

ATTACKS: dict = {}


def register(name):
    def deco(fn):
        ATTACKS[name] = fn
        return fn
    return deco


def get_attack(name: str, **hyper):
    fn = ATTACKS[name]
    return functools.partial(fn, **hyper) if hyper else fn


def make_byzantine_mask(n: int, f: int, fixed: bool = True, key=None):
    """First f agents are Byzantine (fixed); or a random subset (mobile —
    the survey notes most algorithms tolerate changing Byzantine identity)."""
    if fixed or key is None:
        return jnp.arange(n) < f
    perm = jax.random.permutation(key, n)
    return jnp.isin(jnp.arange(n), perm[:f])


def honest_moments(g, byz_mask):
    """Per-coordinate mean and std of the honest rows only.

    Shared by the static zoo (``alie``, ``ipm``, ...) and the defense-aware
    attacks in :mod:`repro.core.attacks.adaptive` — the omniscient adversary's
    view of the honest population.
    """
    w = (~byz_mask).astype(g.dtype)[:, None]
    cnt = jnp.maximum(jnp.sum(w), 1.0)
    mu = jnp.sum(g * w, axis=0) / cnt
    var = jnp.sum(jnp.square(g - mu[None]) * w, axis=0) / cnt
    return mu, jnp.sqrt(var + 1e-12)


_honest_stats = honest_moments


def _replace(g, byz_mask, bad):
    return jnp.where(byz_mask[:, None], bad, g)


@register("none")
def none(key, g, byz_mask):
    return g


@register("sign_flip")
def sign_flip(key, g, byz_mask, scale: float = 1.0):
    """Send -scale * (honest mean): classic reversal attack."""
    mu, _ = _honest_stats(g, byz_mask)
    return _replace(g, byz_mask, -scale * mu[None, :])


@register("gaussian")
def gaussian(key, g, byz_mask, sigma: float = 10.0):
    noise = sigma * jax.random.normal(key, g.shape, g.dtype)
    return _replace(g, byz_mask, noise)


@register("large_value")
def large_value(key, g, byz_mask, magnitude: float = 1e6):
    bad = jnp.full_like(g, magnitude)
    return _replace(g, byz_mask, bad)


@register("constant_drift")
def constant_drift(key, g, byz_mask, target=None, scale: float = 1.0):
    """Push the aggregate toward a fixed direction (data-injection flavour,
    Wu et al. [114])."""
    d = g.shape[-1]
    if target is None:
        target = jnp.ones((d,), g.dtype) / jnp.sqrt(d)
    return _replace(g, byz_mask, scale * target[None, :])


@register("alie")
def alie(key, g, byz_mask, z: float = 1.5):
    """"A little is enough": mean - z * std per coordinate — stays inside the
    honest spread so distance/median filters keep it."""
    mu, sd = _honest_stats(g, byz_mask)
    return _replace(g, byz_mask, (mu - z * sd)[None, :])


@register("ipm")
def ipm(key, g, byz_mask, epsilon: float = 0.5):
    """Inner-product manipulation: -epsilon * honest mean.  epsilon < 1
    keeps norms small (defeats naive norm filters); makes <agg, true> <= 0
    when it succeeds."""
    mu, _ = _honest_stats(g, byz_mask)
    return _replace(g, byz_mask, -epsilon * mu[None, :])


@register("mimic")
def mimic(key, g, byz_mask, victim: int = -1):
    """All Byzantine agents copy one honest agent — breaks iid-variance
    assumptions of (alpha, f)-resilience-style analyses."""
    n = g.shape[0]
    if victim < 0:
        victim = n - 1          # last agent is honest under the fixed mask
    return _replace(g, byz_mask, g[victim][None, :])


@register("zero")
def zero(key, g, byz_mask):
    """Stalling attack: contribute nothing (models crash faults too)."""
    return _replace(g, byz_mask, jnp.zeros_like(g[0])[None, :])


@register("saddle_push")
def saddle_push(key, g, byz_mask, saddle_dir=None, scale: float = 1.0):
    """Saddle-point attack (Yin et al. [122]): cancel the honest mean and add
    a push along the saddle's unstable direction's *opposite*, trying to pin
    iterates near a first-order stationary point."""
    mu, _ = _honest_stats(g, byz_mask)
    n_byz = jnp.maximum(jnp.sum(byz_mask.astype(g.dtype)), 1.0)
    n_hon = jnp.sum((~byz_mask).astype(g.dtype))
    cancel = -(n_hon / n_byz) * mu
    if saddle_dir is not None:
        cancel = cancel + scale * saddle_dir
    return _replace(g, byz_mask, cancel[None, :])


def apply_attack(attack, key, g, byz_mask):
    """Uniform entry point used by the training step."""
    if isinstance(attack, str):
        attack = get_attack(attack)
    return attack(key, g, byz_mask)
