from repro.core.attacks.adaptive import (ADAPTIVE_ATTACKS,
                                         DefenseAwareAttack,
                                         calibrate_alie_z,
                                         is_adaptive_attack,
                                         make_adaptive_attack)
from repro.core.attacks.gradient import (ATTACKS, apply_attack, get_attack,
                                         honest_moments, make_byzantine_mask)

__all__ = ["ATTACKS", "get_attack", "apply_attack", "make_byzantine_mask",
           "honest_moments", "ADAPTIVE_ATTACKS", "DefenseAwareAttack",
           "make_adaptive_attack", "is_adaptive_attack", "calibrate_alie_z"]
