from repro.core.attacks.gradient import (ATTACKS, apply_attack, get_attack,
                                         make_byzantine_mask)

__all__ = ["ATTACKS", "get_attack", "apply_attack", "make_byzantine_mask"]
