"""Defense-aware (adaptive) Byzantine attacks — the survey's hardest regime.

A static attack (``core.attacks.gradient``) perturbs blindly; an *adaptive*
attack is compiled against the specific defense it faces.  Here the adversary
receives the typed :class:`~repro.core.aggregators.AggregatorSpec` — a frozen,
array-free object carrying the rule name, f, trim/selection hyperparameters
and the wrapper chain — plus the honest-gradient moments, and optimizes its
perturbation against exactly that rule:

``spec_alie``
    ALIE ("a little is enough") with the z-score *calibrated from the spec's
    trim window* at build time: large enough to bias, small enough that the
    f Byzantine rows stay strictly inside the rule's selection set.  The
    static ALIE's fixed z lands outside trimmed_mean's kept window and gets
    discarded; the calibrated one survives it.

``min_max``
    Line-searches (bisection under ``jax.lax.fori_loop``, so it jits) the
    largest deviation along the reversed honest mean that still *survives*
    ``spec.aggregate`` — the attack literally runs the defense inside its own
    forward pass and backs off until the rule accepts the poison.

``slow_drift``
    Stateful: a direction-locked bias ramped slowly across rounds, each round
    individually below per-round detection thresholds, so history-free
    defenses pass it while the accumulated drift diverges training.  Attack
    state threads through the jitted step exactly like aggregator state.

Protocol: ``attack(key, g, byz_mask, state, defense_vec=None) -> (g', state')``
with ``g`` an (n, d) stack (the flat arena's per-leaf or raveled view),
``byz_mask`` (n,) bool (True = Byzantine), ``state`` the pytree returned by
``attack.init_state()``, and ``defense_vec`` the defense's carried center
(raveled ``server_grad``) when the defense is stateful — the omniscient,
state-aware threat model.  Honest rows are bitwise untouched.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri

from repro.core.attacks.gradient import honest_moments

ADAPTIVE_ATTACKS: dict = {}


def register_adaptive(name):
    def deco(factory):
        ADAPTIVE_ATTACKS[name] = factory
        return factory
    return deco


def is_adaptive_attack(attack) -> bool:
    """True iff ``attack`` names (or is) a defense-aware attack."""
    if isinstance(attack, DefenseAwareAttack):
        return True
    return isinstance(attack, str) and attack in ADAPTIVE_ATTACKS


@dataclasses.dataclass(frozen=True)
class DefenseAwareAttack:
    """An attack instance compiled against one :class:`AggregatorSpec`.

    Frozen and array-free (closures capture only python scalars and the
    spec), so instances pass through jit boundaries like specs do.  Under
    elastic membership the per-bucket step rebuilds the attack against the
    respecialized bucket spec — calibration tracks the defense's actual
    (n, f) window, which is the point.
    """
    name: str
    spec: object                       # the AggregatorSpec being attacked
    apply_fn: Callable = dataclasses.field(repr=False, compare=False,
                                           default=None)
    init_state_fn: Callable = dataclasses.field(repr=False, compare=False,
                                                default=None)
    stateful: bool = False

    def init_state(self):
        """Initial attack state ({} for stateless attacks)."""
        return self.init_state_fn() if self.init_state_fn else {}

    def __call__(self, key, g, byz_mask, state, defense_vec=None):
        return self.apply_fn(key, g, byz_mask, state, defense_vec)


def make_adaptive_attack(name: str, spec, **hyper) -> DefenseAwareAttack:
    """Build the named defense-aware attack against ``spec``."""
    try:
        factory = ADAPTIVE_ATTACKS[name]
    except KeyError:
        raise KeyError(
            f"unknown adaptive attack {name!r}; registered: "
            f"{sorted(ADAPTIVE_ATTACKS)}") from None
    return factory(spec, **hyper)


# ---------------------------------------------------------------------------
# spec introspection helpers (host-side, build time)


def _executing_rule(spec):
    """Unwrap the composition chain to the rule whose selection window the
    row-level perturbation must survive."""
    while getattr(spec, "inner", None) is not None:
        spec = spec.inner
    return spec


def _probe_spec(spec):
    """The spec used to *simulate* the defense inside the attack: same math,
    dense ``gather`` impl (probe the rule's semantics, not its kernels)."""
    inner = _probe_spec(spec.inner) if spec.inner is not None else None
    probe = dataclasses.replace(spec, inner=inner)
    if probe.impl == "pallas":
        probe = probe.with_impl("gather")
    return probe


def _probe_state(spec, center):
    """Best-effort defense state for probing a stateful spec: every stateful
    rule in the registry carries its memory under ``server_grad``."""
    if not spec.stateful:
        return None
    st = {"server_grad": center}
    if spec.inner is not None and spec.inner.stateful:
        st["inner"] = _probe_state(spec.inner, center)
    return st


def _trim_count(rule) -> Optional[int]:
    """Per-side trim count of a trimmed_mean spec (None for other rules)."""
    if rule.name != "trimmed_mean":
        return None
    n, f = rule.n, rule.f
    if not n:
        return None
    beta = rule.hp("beta", None)
    b = int(math.ceil((beta if beta is not None else f / n) * n))
    return min(b, (n - 1) // 2)


def calibrate_alie_z(spec, margin: float = 0.25) -> float:
    """z-score tailored to ``spec``'s selection window.

    trimmed_mean trims b rows per side: place the Byzantine rows at
    ``mu - z sd`` with z such that the *expected* number of honest rows
    below them exceeds b (+ margin) — just inside the kept window, where
    they are averaged at full weight.  Majority-selection rules (median,
    krum, ...) get the classical ALIE supporter-count calibration.
    """
    rule = _executing_rule(spec)
    n = rule.n or spec.n
    if not n:
        raise ValueError(
            "spec_alie needs a spec with static n (make_spec(..., n=...)) "
            "to calibrate its z-score")
    f = rule.f
    n_h = max(n - f, 1)
    b = _trim_count(rule)
    if b is not None:
        # survive the lower trim: > b honest rows expected below the poison
        phi = min(max((b + margin) / n_h, 1e-3), 0.5)
        z = float(-ndtri(phi))
    else:
        # classical ALIE: enough honest "supporters" further from the mean
        s = n // 2 + 1 - f
        phi = max((n_h - s) / n_h, 0.5 + 1e-3)
        z = float(ndtri(min(phi, 1.0 - 1e-6)))
    return max(z, 0.1)


def _moments32(g, byz_mask):
    g32 = g.astype(jnp.float32)
    mu, sd = honest_moments(g32, byz_mask)
    return g32, mu, sd


def _plant(g, byz_mask, bad_row):
    """Replace Byzantine rows with ``bad_row``; honest rows bitwise kept."""
    return jnp.where(byz_mask[:, None], bad_row[None, :].astype(g.dtype), g)


# ---------------------------------------------------------------------------
# the attacks


@register_adaptive("spec_alie")
def spec_alie(spec, margin: float = 0.25, z: Optional[float] = None,
              z_max: float = 4.0, iters: int = 14, rho: float = 0.5):
    """ALIE with z calibrated from the defense's trim/selection window.

    trimmed_mean exposes its window analytically, so z comes from
    :func:`calibrate_alie_z` at build time.  Selection rules (krum family,
    bulyan, cge, ...) hide theirs, so the attack bisects the largest z
    whose variance-aligned poison ``mu - z sd`` still *survives* the
    defense (the induced aggregate shift along ``-sd`` retains at least
    ``rho`` of a plain mean's) — the same in-jit line-search machinery as
    :func:`min_max`, but along ALIE's within-distribution direction
    instead of the reversed mean.  Static ALIE's fixed z lands outside the
    selection set and gets discarded; the calibrated one rides just inside
    it, at full weight, every round.
    """
    rule = _executing_rule(spec)
    z_static = (float(z) if z is not None
                else calibrate_alie_z(spec, margin)
                if _trim_count(rule) is not None else None)
    probe = _probe_spec(spec) if z_static is None else None

    def apply(key, g, byz_mask, state, defense_vec=None):
        g32, mu, sd = _moments32(g, byz_mask)
        if z_static is not None:
            return _plant(g, byz_mask, mu - z_static * sd), state
        sn = jnp.maximum(jnp.linalg.norm(sd), 1e-12)
        p = -sd / sn
        n = g.shape[0]
        fb = jnp.sum(byz_mask.astype(jnp.float32))
        center = (defense_vec.astype(jnp.float32) if defense_vec is not None
                  else jnp.zeros_like(mu))
        pst = _probe_state(probe, center)

        def survives(zc):
            att = _plant(g32, byz_mask, mu - zc * sd)
            agg = probe.aggregate(att, state=pst).astype(jnp.float32)
            return jnp.dot(agg - mu, p) >= rho * zc * sn * fb / n

        def body(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            ok = survives(mid)
            return (jnp.where(ok, mid, lo), jnp.where(ok, hi, mid))

        lo, _ = jax.lax.fori_loop(
            0, iters, body, (jnp.float32(0.0), jnp.float32(z_max)))
        return _plant(g, byz_mask, mu - lo * sd), state

    return DefenseAwareAttack(name="spec_alie", spec=spec, apply_fn=apply,
                              init_state_fn=None, stateful=False)


@register_adaptive("min_max")
def min_max(spec, lam_max: float = 10.0, iters: int = 14, rho: float = 0.5):
    """Largest reversed-mean deviation surviving ``spec.aggregate``.

    Candidate Byzantine row: ``mu + lam * |mu| * p`` with ``p`` the unit
    reversed honest mean.  A candidate *survives* when the induced aggregate
    shift along p retains at least ``rho`` of the shift a plain mean would
    grant the f rows — i.e. the rule accepted rather than filtered them.
    Bisection over lam runs a fixed ``iters`` rounds under
    ``jax.lax.fori_loop`` with the defense itself evaluated in the body, so
    the whole search stays inside the jitted step.
    """
    probe = _probe_spec(spec)

    def apply(key, g, byz_mask, state, defense_vec=None):
        g32, mu, sd = _moments32(g, byz_mask)
        norm = jnp.maximum(jnp.linalg.norm(mu), 1e-12)
        p = -mu / norm
        n = g.shape[0]
        fb = jnp.sum(byz_mask.astype(jnp.float32))
        center = (defense_vec.astype(jnp.float32) if defense_vec is not None
                  else jnp.zeros_like(mu))
        pst = _probe_state(probe, center)

        def survives(lam):
            att = _plant(g32, byz_mask, mu + lam * norm * p)
            agg = probe.aggregate(att, state=pst).astype(jnp.float32)
            shift = jnp.dot(agg - mu, p)
            return shift >= rho * lam * norm * fb / n

        def body(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            ok = survives(mid)
            return (jnp.where(ok, mid, lo), jnp.where(ok, hi, mid))

        lo, _ = jax.lax.fori_loop(
            0, iters, body,
            (jnp.float32(0.0), jnp.float32(lam_max)))
        return _plant(g, byz_mask, mu + lo * norm * p), state

    return DefenseAwareAttack(name="min_max", spec=spec, apply_fn=apply,
                              init_state_fn=None, stateful=False)


@register_adaptive("slow_drift")
def slow_drift(spec, z0: float = 0.3, rate: float = 0.02,
               z_cap: float = 1.5, seed: int = 7):
    """Direction-locked bias ramped below per-round detection thresholds.

    Round t plants ``mu + z_t * sd * signs`` with ``z_t = min(z0 + rate*t,
    z_cap)`` and a fixed Rademacher sign pattern (seeded, shape-derived —
    constant across rounds, so the per-round bias accumulates instead of
    averaging out).  Every single round sits inside the honest spread;
    only a defense with memory sees the drift.
    """
    def init_state():
        return {"t": jnp.zeros((), jnp.float32)}

    def apply(key, g, byz_mask, state, defense_vec=None):
        g32, mu, sd = _moments32(g, byz_mask)
        signs = jax.random.rademacher(
            jax.random.PRNGKey(seed), (g.shape[1],), jnp.float32)
        z_t = jnp.minimum(z0 + rate * state["t"], z_cap)
        out = _plant(g, byz_mask, mu + z_t * sd * signs)
        return out, {"t": state["t"] + 1.0}

    return DefenseAwareAttack(name="slow_drift", spec=spec, apply_fn=apply,
                              init_state_fn=init_state, stateful=True)


__all__ = [
    "ADAPTIVE_ATTACKS", "DefenseAwareAttack", "make_adaptive_attack",
    "is_adaptive_attack", "calibrate_alie_z", "register_adaptive",
]
