from repro.core.p2p.dgd import (COMBINE, data_injection_attack,
                                detect_injection, p2p_dgd_run)
from repro.core.p2p.graph import (complete_graph, erdos_renyi, is_connected,
                                  is_f_local, is_r_s_robust,
                                  metropolis_weights, ring_graph,
                                  source_component, torus_graph,
                                  vertex_connectivity)

__all__ = [
    "COMBINE", "p2p_dgd_run", "data_injection_attack", "detect_injection",
    "complete_graph", "ring_graph", "torus_graph", "erdos_renyi",
    "is_connected", "vertex_connectivity", "source_component", "is_f_local",
    "is_r_s_robust", "metropolis_weights",
]
