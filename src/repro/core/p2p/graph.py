"""Graph-theory utilities for the peer-to-peer architecture (survey §2.1,
§3.3.5): topology constructors, connectivity, source components, f-local
property, and (r, s)-robustness (Sundaram–Gharesifard / LeBlanc et al.)."""
from __future__ import annotations

import itertools

import numpy as np


# ---------------------------------------------------------------------------
# topologies (adjacency as (n, n) bool, no self loops)


def complete_graph(n: int):
    a = np.ones((n, n), bool)
    np.fill_diagonal(a, False)
    return a


def ring_graph(n: int, k: int = 1):
    """Each node connected to k neighbours on each side."""
    a = np.zeros((n, n), bool)
    for i in range(n):
        for j in range(1, k + 1):
            a[i, (i + j) % n] = a[i, (i - j) % n] = True
    return a


def torus_graph(rows: int, cols: int):
    n = rows * cols
    a = np.zeros((n, n), bool)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                j = ((r + dr) % rows) * cols + (c + dc) % cols
                a[i, j] = True
    np.fill_diagonal(a, False)
    return a


def erdos_renyi(n: int, p: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    a = rng.random((n, n)) < p
    a = a | a.T
    np.fill_diagonal(a, False)
    return a


# ---------------------------------------------------------------------------
# structural properties


def is_connected(adj) -> bool:
    adj = np.asarray(adj, bool)
    n = len(adj)
    seen = np.zeros(n, bool)
    stack = [0]
    seen[0] = True
    while stack:
        i = stack.pop()
        for j in np.flatnonzero(adj[i] | adj[:, i]):
            if not seen[j]:
                seen[j] = True
                stack.append(j)
    return bool(seen.all())


def remove_nodes(adj, nodes):
    keep = np.setdiff1d(np.arange(len(adj)), np.asarray(list(nodes)))
    return np.asarray(adj, bool)[np.ix_(keep, keep)], keep


def vertex_connectivity(adj, max_check: int = 200000) -> int:
    """Brute-force minimum vertex cut (small graphs: tests / examples)."""
    adj = np.asarray(adj, bool)
    n = len(adj)
    if not is_connected(adj):
        return 0
    for k in range(1, n - 1):
        combos = itertools.islice(
            itertools.combinations(range(n), k), max_check)
        for cut in combos:
            sub, _ = remove_nodes(adj, cut)
            if len(sub) and not is_connected(sub):
                return k
    return n - 1


def strongly_connected_components(adj):
    """Tarjan SCCs for directed adjacency."""
    adj = np.asarray(adj, bool)
    n = len(adj)
    index = [None] * n
    low = [0] * n
    onstack = [False] * n
    stack, out = [], []
    counter = [0]

    def strong(v):
        work = [(v, 0)]
        while work:
            node, pi = work[-1]
            if pi == 0:
                index[node] = low[node] = counter[0]
                counter[0] += 1
                stack.append(node)
                onstack[node] = True
            recurse = False
            nbrs = np.flatnonzero(adj[node])
            for i in range(pi, len(nbrs)):
                w = nbrs[i]
                if index[w] is None:
                    work[-1] = (node, i + 1)
                    work.append((int(w), 0))
                    recurse = True
                    break
                elif onstack[w]:
                    low[node] = min(low[node], index[w])
            if recurse:
                continue
            if low[node] == index[node]:
                comp = []
                while True:
                    w = stack.pop()
                    onstack[w] = False
                    comp.append(w)
                    if w == node:
                        break
                out.append(sorted(comp))
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])

    for v in range(n):
        if index[v] is None:
            strong(v)
    return out


def source_component(adj):
    """The SCC with no incoming edges from outside, if it can reach all
    others (survey: non-empty source component condition, Su–Vaidya [103]).
    Returns the node list or None."""
    adj = np.asarray(adj, bool)
    sccs = strongly_connected_components(adj)
    for comp in sccs:
        comp_set = set(comp)
        incoming = any(adj[j, i] for i in comp for j in range(len(adj))
                       if j not in comp_set)
        if incoming:
            continue
        # must reach every node
        seen = set(comp)
        stack = list(comp)
        while stack:
            i = stack.pop()
            for j in np.flatnonzero(adj[i]):
                if j not in seen:
                    seen.add(int(j))
                    stack.append(int(j))
        if len(seen) == len(adj):
            return comp
    return None


def is_f_local(adj, byz, f: int) -> bool:
    """Each non-faulty node has at most f Byzantine in-neighbours."""
    adj = np.asarray(adj, bool)
    byz = set(int(b) for b in byz)
    for i in range(len(adj)):
        if i in byz:
            continue
        if sum(1 for j in np.flatnonzero(adj[:, i]) if int(j) in byz) > f:
            return False
    return True


def is_r_s_robust(adj, r: int, s: int, max_check: int = 100000) -> bool:
    """(r, s)-robustness (LeBlanc et al. [63]): for every pair of disjoint
    nonempty subsets, at least one of: |X_A^r| = |A|, |X_B^r| = |B|, or
    |X_A^r| + |X_B^r| >= s — where X_S^r are nodes in S with >= r
    in-neighbours outside S.  Exponential brute force: small graphs only."""
    adj = np.asarray(adj, bool)
    n = len(adj)
    nodes = range(n)
    checked = 0
    for size_a in range(1, n):
        for A in itertools.combinations(nodes, size_a):
            rest = [v for v in nodes if v not in A]
            for size_b in range(1, len(rest) + 1):
                for B in itertools.combinations(rest, size_b):
                    checked += 1
                    if checked > max_check:
                        raise ValueError("graph too large for brute force")
                    xa = sum(1 for i in A
                             if np.sum(adj[:, i]) - sum(adj[j, i] for j in A)
                             >= r)
                    xb = sum(1 for i in B
                             if np.sum(adj[:, i]) - sum(adj[j, i] for j in B)
                             >= r)
                    if not (xa == len(A) or xb == len(B) or xa + xb >= s):
                        return False
    return True


def metropolis_weights(adj):
    """Doubly-stochastic weight matrix W for DGD (eq. 14)."""
    adj = np.asarray(adj, bool)
    n = len(adj)
    deg = adj.sum(1)
    W = np.zeros((n, n))
    for i in range(n):
        for j in np.flatnonzero(adj[i]):
            W[i, j] = 1.0 / (1 + max(deg[i], deg[j]))
        W[i, i] = 1.0 - W[i].sum()
    return W
