"""Peer-to-peer (decentralized) fault-tolerant DGD — survey §3.3.5.

In the p2p architecture agents broadcast their local ESTIMATES x_i (not
gradients, eq. 14).  Byzantine agents broadcast arbitrary vectors.  Honest
agent i combines the received values with a local rule, then takes a local
(sub)gradient step with a diminishing step size:

  x_i^{t+1} = Combine_i({x_j : j in N_i^in} ∪ {x_i}) - eta_t * grad Q_i(x_i)

Combine rules implemented:
  * plain    — Metropolis-weighted average (non-robust DGD baseline)
  * lf       — Local Filtering dynamics (Sundaram–Gharesifard [105]):
               coordinate-wise remove the f largest and f smallest neighbour
               values (relative to own), average the rest; sound on
               (2f+1)-robust graphs.
  * ce       — Comparative Elimination (Gupta–Doan–Vaidya [48]): drop the f
               neighbour estimates FARTHEST (euclidean) from own, average the
               rest; designed for fully-connected networks with
               2f-redundancy.
  * any stateless :class:`~repro.core.aggregators.AggregatorSpec` — every
    receiver robustly aggregates its in-neighbourhood (self included) with
    the spec's rule via the masked engine (non-neighbours are masked out),
    then mixes the result with its own estimate.  This lifts the stateless
    Table-2 catalogue into the p2p architecture through the one aggregator
    API (stateful rules have no server to hold their state here).

The data-injection attack of Wu et al. [114] and its detect/localize metric
are provided for the adversarial-models section (§4.1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.p2p.graph import metropolis_weights

BIG = 1e30


def _neighbor_tensor(adj, states):
    """states: (n, d) -> received: (n, n, d) with non-neighbors masked later.
    (Dense n^2 d tensor: p2p simulations are small-n by design.)"""
    n = states.shape[0]
    return jnp.broadcast_to(states[None, :, :], (n, n, states.shape[1]))


def combine_plain(adj, W, states, f):
    return jnp.asarray(W, states.dtype) @ states


def combine_lf(adj, W, states, f):
    """Trimmed-mean local filtering, coordinate-wise per receiver."""
    n, d = states.shape
    inc = jnp.asarray(np.asarray(adj, bool).T)        # inc[i, j]: j -> i
    recv = _neighbor_tensor(adj, states)              # (n, n, d)
    hi = jnp.where(inc[:, :, None], recv, BIG)
    lo = jnp.where(inc[:, :, None], recv, -BIG)
    s_hi = jnp.sort(hi, axis=1)                       # masked -> top
    s_lo = jnp.sort(lo, axis=1)
    deg = jnp.sum(inc, axis=1)                        # (n,)
    # per receiver: sum of neighbour values minus f largest & f smallest
    total = jnp.sum(jnp.where(inc[:, :, None], recv, 0.0), axis=1)
    if f:
        # ascending sort of `hi` puts masked (+BIG) entries last — the f
        # largest real values sit at positions [deg - f, deg)
        idx_hi = (deg - f)[:, None] + jnp.arange(f)[None, :]     # (n, f)
        top_f = jnp.take_along_axis(
            s_hi, jnp.broadcast_to(idx_hi[:, :, None], (n, f, d)).astype(
                jnp.int32), axis=1)
        # ascending sort of `lo` puts masked (-BIG) entries first — the f
        # smallest real values start at offset n - deg per row
        idx_lo = (n - deg)[:, None] + jnp.arange(f)[None, :]     # (n, f)
        bot_f = jnp.take_along_axis(
            s_lo, jnp.broadcast_to(idx_lo[:, :, None], (n, f, d)).astype(
                jnp.int32), axis=1)
        trimmed = total - jnp.sum(top_f, axis=1) - jnp.sum(bot_f, axis=1)
        cnt = jnp.maximum(deg - 2 * f, 1)[:, None]
    else:
        trimmed = total
        cnt = jnp.maximum(deg, 1)[:, None]
    nbr_avg = trimmed / cnt
    # degraded receivers keep their own estimate: with deg <= 2f the trim
    # would remove more values than exist (producing zeroed or sign-flipped
    # averages), and with deg == 0 the neighbourhood is empty — both are
    # reachable under time-varying (partitioned / crashing) graphs
    enough = (deg > 2 * f) if f else (deg > 0)
    nbr_avg = jnp.where(enough[:, None], nbr_avg, states)
    return 0.5 * states + 0.5 * nbr_avg               # keep own estimate


def combine_ce(adj, W, states, f):
    """Comparative elimination: drop f farthest-from-own, average rest+own."""
    n, d = states.shape
    inc = jnp.asarray(np.asarray(adj, bool).T)
    recv = _neighbor_tensor(adj, states)
    d2 = jnp.sum(jnp.square(recv - states[:, None, :]), axis=-1)   # (n, n)
    d2 = jnp.where(inc, d2, jnp.inf)
    deg = jnp.sum(inc, axis=1)
    keep_k = jnp.maximum(deg - f, 0)                               # (n,)
    order = jnp.argsort(d2, axis=1)                                # nearest..
    rank = jnp.argsort(order, axis=1)
    keep = (rank < keep_k[:, None]) & inc
    total = jnp.sum(jnp.where(keep[:, :, None], recv, 0.0), axis=1)
    cnt = jnp.sum(keep, axis=1)[:, None] + 1                       # + self
    return (total + states) / cnt


COMBINE = {"plain": combine_plain, "lf": combine_lf, "ce": combine_ce}


def make_combine_spec(spec):
    """Wrap a STATELESS :class:`~repro.core.aggregators.AggregatorSpec` as
    a p2p combine rule: receiver i aggregates the broadcast estimates over
    the mask {j : j -> i} ∪ {i} with ``spec`` (absent rows are imputed by
    the masked engine), then keeps half its own estimate — the conservative
    mixing the lf/ce dynamics use.  ``spec.f`` is the per-neighbourhood
    Byzantine budget (the run-level ``f`` argument is ignored).

    Stateful rules (zeno, zeno_pp) are rejected: there is no server in the
    decentralized architecture to hold their validation state, and per-
    receiver state threading is not implemented."""
    if spec.stateful:
        raise ValueError(
            f"{spec.name} is stateful and cannot be a p2p combine rule "
            "(no server-side state in the decentralized architecture); "
            "use a stateless spec")

    def comb(adj, W, states, f):
        n = states.shape[0]
        inc = jnp.asarray(np.asarray(adj, bool).T)        # inc[i, j]: j -> i
        masks = inc | jnp.eye(n, dtype=bool)              # self included
        agg = jax.vmap(lambda m: spec.aggregate(states, mask=m))(masks)
        return 0.5 * states + 0.5 * agg.astype(states.dtype)
    return comb


def _faulted_adj(adj, trace, t):
    """Effective directed adjacency at round t under a FaultTrace: partition
    severs cross-group links, crashed agents neither send nor receive, a
    dropped broadcast removes all of the sender's outgoing edges (adj[a, b]
    is the edge a -> b), and a churned-out roster member is silenced exactly
    like a crashed agent — no broadcast, no reception, Metropolis weights
    rebuilt over the live subgraph (decentralized membership IS the crash
    handling: there is no server to repack a roster)."""
    h = trace.horizon
    v = min(t, h - 1)
    a = adj.copy()
    if trace.adj is not None:
        a &= trace.adj[v]
    alive = trace.alive[v]
    if trace.roster is not None:
        alive = alive & trace.roster[v]
    a &= alive[:, None] & alive[None, :]
    a[trace.drop[v]] = False
    return a, alive


def p2p_dgd_run(adj, grad_fn, x0, steps: int, f: int = 0,
                combine: str = "plain", byz_mask=None, byz_fn=None,
                eta0: float = 0.5, eta_decay: float = 1.0, key=None,
                fault_schedule=None, fault_seed: int = 0):
    """Simulate T rounds of p2p DGD.

    grad_fn(i, x) -> gradient of Q_i at x (vmapped over agents).
    byz_fn(key, t, states) -> broadcast values of Byzantine agents.
    combine -> "plain" | "lf" | "ce" or a stateless AggregatorSpec (a
    registered robust rule applied per in-neighbourhood; spec.f governs).
    fault_schedule -> a compiled :class:`repro.simulator.faults.FaultTrace`
    or an iterable of fault specs (compiled here with ``fault_seed``): the
    graph becomes time-varying — partitions cut links, crash/recover faults
    freeze agents (no broadcast, no update), message drops silence a
    sender's round, and membership schedules (Join/Rejoin/Churn) silence
    churned-out agents the same way crashes do (the live subgraph keeps
    mixing; departed agents freeze and re-enter where they left off).
    Metropolis weights are rebuilt per round.
    Returns trajectory (steps+1, n, d)."""
    from repro.simulator.faults import FaultTrace, compile_schedule
    adj = np.asarray(adj, bool)
    n, d = x0.shape
    trace = None
    if fault_schedule is not None:
        trace = (fault_schedule if isinstance(fault_schedule, FaultTrace)
                 else compile_schedule(tuple(fault_schedule), n, steps + 1,
                                       seed=fault_seed))
        assert trace.n_agents == n, (trace.n_agents, n)
    W = metropolis_weights(adj)
    if isinstance(combine, str):
        comb = COMBINE[combine]
    else:                                  # an AggregatorSpec
        comb = make_combine_spec(combine)
    if byz_mask is None:
        byz_mask = jnp.zeros((n,), bool)
    key = key if key is not None else jax.random.PRNGKey(0)

    states = jnp.asarray(x0)
    traj = [states]
    for t in range(steps):
        key, sub = jax.random.split(key)
        adj_t, W_t, alive = adj, W, None
        if trace is not None:
            adj_t, alive = _faulted_adj(adj, trace, t)
            # receivers mix over IN-neighbours: message drops make the
            # faulted graph asymmetric, and metropolis rows weight the
            # passed matrix's out-edges — hand it the transpose (no-op for
            # the symmetric un-faulted topologies)
            W_t = metropolis_weights(adj_t.T)
        sent = states
        if byz_fn is not None:
            bad = byz_fn(sub, t, states)
            sent = jnp.where(byz_mask[:, None], bad, states)
        mixed = comb(adj_t, W_t, sent, f)
        eta = eta0 / (1.0 + eta_decay * t)     # diminishing (appendix A.2)
        grads = jax.vmap(grad_fn, in_axes=(0, 0))(jnp.arange(n), mixed)
        new = jnp.where(byz_mask[:, None], sent, mixed - eta * grads)
        if alive is not None:                  # crashed agents are frozen
            new = jnp.where(jnp.asarray(alive)[:, None], new, states)
        states = new
        traj.append(states)
    return jnp.stack(traj)


# ---------------------------------------------------------------------------
# data-injection attack + detection metric (Wu et al. [114], §4.1)


def data_injection_attack(target, sigma0: float = 1.0, decay: float = 0.05):
    """Adversary broadcasts  target + z_t  with ||z_t|| -> 0 a.s. — it fakes
    convergence toward its target point."""
    def byz_fn(key, t, states):
        n, d = states.shape
        z = sigma0 * jnp.exp(-decay * t) * jax.random.normal(key, (n, d))
        return target[None, :] + z
    return byz_fn


def detect_injection(traj, adj, window: int = 10):
    """Local detect metric (simplified from [114]): for receiver i and
    in-neighbour j, the accumulated deviation of j's broadcast from the
    neighbourhood consensus.  Large score -> flag j as adversarial.
    Returns (n, n) scores (i's suspicion of j)."""
    adj = np.asarray(adj, bool)
    x = np.asarray(traj[-window:])                  # (w, n, d)
    mean_nbhd = []
    n = adj.shape[0]
    scores = np.zeros((n, n))
    for i in range(n):
        nbrs = np.flatnonzero(adj[:, i])
        if len(nbrs) == 0:
            continue
        center = x[:, nbrs].mean(axis=1)            # (w, d)
        for j in nbrs:
            scores[i, j] = np.mean(
                np.linalg.norm(x[:, j] - center, axis=-1))
    return scores
