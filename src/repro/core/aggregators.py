"""Unified robust-aggregation API: typed, stateful, composable.

The survey's core object — the robust aggregation rule — used to be
dispatched through four stringly-typed surfaces (``FILTERS[name]``,
``tree_aggregate(name, ...)``, ``filter_weights(name, ...)``,
``tree_masked_aggregate(name, ...)``) with capability sets duplicated in
ad-hoc constants and stateful rules (Zeno's ``server_grad``) smuggled
through ``**hyper``.  This module replaces all of that with one object:

:class:`AggregatorSpec`
    A frozen dataclass naming a registered rule plus its static
    configuration (``f``, hyper-parameters, ``impl``).  Hyper-parameters
    are validated against the rule's declared keys at *build* time, so a
    typo raises immediately instead of deep inside jit; impl-only keys
    (``native_dtype``) are split off once into ``impl_hyper``.

``spec.aggregate(grads, mask=None, weights=None, state=None)``
    One entry point subsuming the legacy ``tree_aggregate`` (mask/weights
    None), ``tree_masked_aggregate`` (mask given) and ``filter_weights``
    (via :meth:`AggregatorSpec.weights`).  ``impl="gather"`` is the
    paper-faithful dense path, ``impl="fused"`` the sharding-aware
    stats->weights / leaf-wise decomposition — bit-for-bit identical to
    the historical functions (tests/test_aggregator_spec.py) —
    ``impl="pallas"`` the tiled TPU-kernel path (:mod:`repro.kernels`),
    auto-selected by ``make_spec`` where the rule's caps match an
    available kernel and proven against the gather path by
    tests/test_kernels_parity.py.

Registered rules — caps, impls, masked kernels, elastic, telemetry, compression
    ==================  =========================  ==================  ======  ==================  =========  =========
    rule                caps                       impls               m-pls   elastic             telemetry  compress
    ==================  =========================  ==================  ======  ==================  =========  =========
    mean                weight_decomposable        fused, gather       --      yes                 exact w    q (deq)
    krum                weight_decomp, pairwise    fused, gather, pls  yes     yes (nbr counts)    exact w    q (deq)
    multi_krum          weight_decomp, pairwise    fused, gather, pls  yes     yes (nbr counts)    exact w    q (deq)
    m_krum              weight_decomp, pairwise    fused, gather, pls  yes     yes (nbr counts)    exact w    q (deq)
    mda                 weight_decomp, pairwise    fused, gather, pls  yes     yes (subset tables) exact w    q (deq)
    cge                 weight_decomp, pairwise    fused, gather, pls  yes     yes (keep counts)   exact w    q (deq)
    cgc                 weight_decomposable        fused, gather       --      yes                 exact w    q (deq)
    zeno                weight_decomp, stateful    fused, gather       --      yes (state n-free)  exact w    --
    zeno_pp             weight_decomp, stateful    custom (fused)      --      yes (state n-free)  exact w    --
    coordinate_median   coordwise                  fused, gather, pls  yes     yes                 particip.  q in-tile
    trimmed_mean        coordwise                  fused, gather, pls  yes     yes (trim counts)   particip.  q in-tile
    phocas              coordwise                  fused, gather       --      yes                 particip.  q (deq)
    mean_around_median  coordwise                  fused, gather       --      yes                 particip.  q (deq)
    geometric_median    iterative                  fused, gather       --      yes                 particip.  q (deq)
    rfa                 iterative                  fused, gather       --      yes                 particip.  q (deq)
    median_of_means     iterative                  fused, gather       --      yes                 particip.  q (deq)
    bulyan              iterative, pairwise        fused, gather, pls  yes     yes (theta/beta)    theta sel  q (deq)
    sign_sgd            coordwise                  fused, gather, pls  yes     yes                 particip.  1-bit vote
    sparse_mean         coordwise (custom+flat)    flat, gather law    yes     yes                 particip.  sparse
    centered_clip       iterative, STATEFUL        flat, gather, pls*  own     yes (state n-free)  clip w     --
    clipped             wrapper                    delegates to inner  --      via inner           via inner  --
    bucketed            wrapper                    delegates to inner  --      via inner           particip.  --
    staleness_disc.     wrapper                    delegates to inner  --      via inner           via inner  --
    server_momentum     wrapper, STATEFUL          delegates to inner  --      via inner           via inner  --
    ==================  =========================  ==================  ======  ==================  =========  =========

    Defenses with MEMORY (the PR-10 history filters — the survey's answer
    to adaptive, defense-aware attackers): ``centered_clip`` iteratively
    re-clips every row to radius ``tau`` around the CARRIED server center
    (state key ``server_grad``, EMA of past aggregates via ``ema``), so a
    poison small enough to survive one round still cannot move the
    estimate more than ``iters * tau`` per step; its telemetry (*clip w*)
    exposes the effective per-row clip weights ``lam_i``, and ``pls*``
    marks the explicit-opt-in fused MAC (``impl="pallas"`` routes the
    per-iteration multiply-accumulate through
    ``kernels.wsum.clipped_weighted_sum``; ``auto`` keeps the dense body
    — different reduce association).  ``server_momentum`` wraps ANY inner
    rule and EMAs its outputs (``beta``), de-correlating round-to-round
    adaptive bias; both thread state through the ordinary
    ``init_state``/``update_state`` protocol the async loop already
    carries for zeno (``state["inner"]`` nests wrapper chains).  The
    defense-aware attack side lives in :mod:`repro.core.attacks.adaptive`
    (``spec_alie`` / ``min_max`` line-search their poison against the
    executing spec itself; ``slow_drift`` accumulates bias below
    per-round thresholds) and the two sides meet in
    ``benchmarks/bench_convergence.py``'s leaderboard.

    ``compress`` (the compressed robust exchange layer, ROADMAP item 3):
    *1-bit vote* — ``sign_sgd`` exchanges sign(g) (1 bit/coordinate) and
    aggregates by per-coordinate majority vote; *sparse* —
    ``sparse_mean`` treats a zero coordinate as NOT SENT and averages
    each coordinate over ``(coord_sent) * weight`` with explicit-zero
    guards (the fed_dropout_avg shape); *q in-tile* — int8 / fp8 arena
    codes (``repro.core.flat.quantize_rows`` per-row scale sidecar,
    ``aggregate_flat(..., scale=)``) are dequantized INSIDE the Pallas
    tile — no dequantized (n, P) copy is ever materialized (jaxpr-gated
    by tests/test_kernels_parity.py); *q (deq)* — quantized arenas are
    accepted but dequantized at engine level before the rule runs (a
    one-time ``warn_once`` names the rule); stateful rules reject
    quantized arenas by construction (no flat path).

    ``telemetry`` (:meth:`AggregatorSpec.selection_weights`, consumed by
    :mod:`repro.obs`): *exact w* — the rule's own (n,) application
    weights (synchronous fused path reconstructs the aggregate exactly
    via ``tree_weighted_sum``); *theta sel* — bulyan's krum-stage
    selection, 1/theta on chosen rows; *particip.* — normalized delivery
    weights (every arrived row enters the order statistics); *via
    inner* — the wrapper applies its row transform, then reads the inner
    rule's telemetry.  ``spec.aggregate_with_telemetry`` /
    ``aggregate_flat_with_telemetry`` bundle the aggregate with the
    fixed-shape ``{sel_w, mask, contrib_w}`` struct the flight recorder
    accumulates into per-agent suspicion scores.

    ``elastic``: every rule supports elastic-n specs — build with
    ``make_spec(name, n=elastic(n_max, buckets=...), f=frac(0.2))`` and
    the per-bucket static plans named in parentheses are precomputed at
    BUILD time; ``spec.respecialize(n_live)`` then selects the bucket's
    concrete spec (dataclass-equal to a fresh ``make_spec(..., n=b)``, so
    jit caches hit and membership churn over the bucketed range costs at
    most ``len(buckets)`` compilations).  ``f = frac(ratio)`` re-derives
    the Byzantine budget per bucket so breakdown bounds track the live
    roster; a static int ``f`` is carried unchanged across buckets.

    Coding x elastic: the draco/detox repetition decoders
    (:mod:`repro.core.redundancy.coding`) sit UPSTREAM of this registry —
    they vote over coded groups, then (detox) feed bucket means into a
    registered rule above.  Their group tables are the same trim-table
    trick as the per-bucket plans: ``coding_groups(n, r)`` is an
    lru-cached read-only host array re-derived per elastic bucket at
    respecialize time (``allow_ragged=True`` admits a smaller trailing
    group when ``r`` does not divide the bucket), so coded aggregation
    under membership churn stays within the same ``len(buckets)``
    compile budget and rides the flat arena
    (:func:`~repro.core.redundancy.coding.flat_draco_aggregate`)
    bit-for-bit with the tree entry point.

    ``m-pls`` (masked-selection column): the rule's masked/weighted
    pallas path is a FUSED imputation-free kernel — mean-imputation
    happens inside the sort tile (repro.kernels.masked) for the
    coordinate rules and inside the Gram / application tiles
    (repro.kernels.pairwise.masked_gram + repro.kernels.wsum) for the
    selection family, so the imputed (n, d) stack is NEVER materialized
    and quorum masks / staleness weights stay traced operands (fault
    schedules and rosters never recompile, never allocate).  Rules
    without a masked kernel impute at tree level (a one-time warning
    fires if a pallas spec falls back there on mixed-dtype leaves).
    All pallas entries run in interpret mode off-TPU (same code path);
    ``impl="auto"`` (the ``make_spec`` default) picks pallas exactly for
    the rules marked above (bulyan: only for its classic ``base="krum"``)
    and :func:`pallas_available` is the predicate.

Zero-copy flat pipeline
    Dense-stack impls (gather / pallas, stateless non-wrapper rules —
    ``spec.flat_capable``) also expose ``spec.aggregate_flat(arena,
    mask=..., weights=...)`` over a pre-raveled (n, P) gradient arena
    (:class:`repro.core.flat.FlatPlan`): the training loops ravel ONCE
    per step at gradient production, the serving engine reshapes the
    logits stack for free, and the single unravel happens at
    optimizer-apply — the aggregation dispatch itself never touches a
    pytree and never re-concatenates the model-sized stack.  Bit-for-bit
    with the tree engine for uniform-dtype trees.

Capability flags (:class:`AggregatorCaps`)
    coordwise / weight-decomposable / iterative / masked-capable /
    sharding-aware / stateful — engine dispatch is driven purely by these
    flags and the per-rule callables, so registering a new rule is ONE
    :func:`register_aggregator` call: no dispatch chains, no constants.

State protocol
    Stateful rules (Zeno's server gradient, the delay-adaptive
    ``zeno_pp``) declare ``init_state`` / ``update_state`` hooks; callers
    thread the returned pytree explicitly instead of hiding arrays in
    ``**hyper``:

        state = spec.init_state(proto)
        agg   = spec.aggregate(grads, mask=m, weights=w, state=state)
        state = spec.update_state(state, agg)

Composition wrappers (specs themselves)
    :func:`clipped` (pre-aggregation norm clipping), :func:`bucketed`
    (median-of-means style pre-bucketing) and :func:`staleness_discounted`
    (Kardam/Zeno++-line delay discounting) wrap an inner spec and are
    ordinary registry entries, so they nest:  ``clipped(bucketed(spec))``.

Static work (MDA subset enumeration, trim counts) is precomputed once per
(n, f) via caches at spec-build time (``make_spec(..., n=...)``) or on
first trace, instead of on every call.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import warnings
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filters import dense as D
from repro.core.flat import FlatPlan


class AggregatorDeprecationWarning(DeprecationWarning):
    """Raised (as a warning) by the legacy string-dispatch shims in
    :mod:`repro.core.aggregation` — internal code must use specs."""


_WARNED_ONCE: set = set()


def warn_once(key, message, category=UserWarning, stacklevel=3):
    """Warn exactly once per ``key`` across the process.

    stdlib location-dedup ("default" action) is version-gated on the
    global warning filters, which jax mutates on ordinary dispatches —
    without manual dedup a warning inside a training loop would re-fire
    every single step.  THE one dedup mechanism: the deprecation shims
    (:mod:`repro.core.aggregation`) and the kernel-fallback notices below
    both key into this set."""
    if key in _WARNED_ONCE:
        return
    _WARNED_ONCE.add(key)
    warnings.warn(message, category, stacklevel=stacklevel)


# ---------------------------------------------------------------------------
# tree helpers (agent axis = leading axis of every leaf)


def tree_stack_ravel(grads):
    """(pytree with leading n) -> (n, P) dense stack (one concatenate;
    leaf dtypes preserved — mixed-dtype trees promote like concatenate)."""
    leaves = jax.tree.leaves(grads)
    n = leaves[0].shape[0]
    return jnp.concatenate([l.reshape(n, -1) for l in leaves], axis=1)


def tree_unravel_like(vec, proto):
    """(P,) -> pytree shaped like one agent's grads (proto has leading n).

    Offsets/sizes come from the proto's cached :class:`FlatPlan` — computed
    once per tree structure, never per call (the legacy version re-derived
    ``np.prod`` sizes inside every trace)."""
    return FlatPlan.for_tree(proto).unravel(vec)


def tree_sqnorms(grads):
    """Per-agent squared norms, accumulated leaf-wise: (n,) fp32.

    NO reshapes: flattening (n, d1, d2, ...) -> (n, -1) merges sharded and
    unsharded dims, which forces the SPMD partitioner to regroup (gather)
    the whole stack.  Axis-tuple reductions keep the contraction local +
    one tiny psum."""
    def leaf(l):
        axes = tuple(range(1, l.ndim))
        return jnp.sum(jnp.square(l.astype(jnp.float32)), axis=axes)
    return functools.reduce(jnp.add, [leaf(l) for l in jax.tree.leaves(grads)])


def tree_gram(grads):
    """Pairwise inner products, accumulated leaf-wise: (n, n) fp32
    (multi-dim tensordot — sharding-preserving, no reshape)."""
    def leaf(l):
        axes = tuple(range(1, l.ndim))
        return jnp.tensordot(l.astype(jnp.float32), l.astype(jnp.float32),
                             axes=(axes, axes))
    return functools.reduce(jnp.add, [leaf(l) for l in jax.tree.leaves(grads)])


def tree_dot(grads, vec_tree):
    """<g_i, v> per agent: (n,) fp32 (sharding-preserving)."""
    def leaf(l, v):
        axes = tuple(range(1, l.ndim))
        return jnp.tensordot(l.astype(jnp.float32), v.astype(jnp.float32),
                             axes=(axes, tuple(range(v.ndim))))
    return functools.reduce(
        jnp.add, jax.tree.leaves(jax.tree.map(leaf, grads, vec_tree)))


def tree_weighted_sum(grads, w):
    """sum_i w_i * g_i per leaf."""
    def leaf(l):
        wl = w.astype(jnp.float32).reshape((-1,) + (1,) * (l.ndim - 1))
        return jnp.sum(l.astype(jnp.float32) * wl, axis=0).astype(l.dtype)
    return jax.tree.map(leaf, grads)


def tree_where_agents(mask, a, b):
    """Per-agent select on n-leading pytrees (keeps b's leaf dtypes)."""
    def leaf(x, y):
        m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x.astype(y.dtype), y)
    return jax.tree.map(leaf, a, b)


def _gram_to_d2(gram):
    sq = jnp.diag(gram)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)


def _n_agents(grads) -> int:
    return jax.tree.leaves(grads)[0].shape[0]


# ---------------------------------------------------------------------------
# static plans — combinatorial / count work shared across traces


@functools.lru_cache(maxsize=None)
def mda_combos(n: int, f: int) -> np.ndarray:
    """All (n-f)-subsets for minimum-diameter averaging, enumerated ONCE
    per (n, f) (the legacy path re-enumerated per trace)."""
    combos = np.asarray(list(itertools.combinations(range(n), n - f)))
    if len(combos) > 200_000:
        raise ValueError(f"MDA infeasible for n={n}, f={f}")
    return combos


@functools.lru_cache(maxsize=None)
def trim_count(n: int, f: int, beta: float | None) -> int:
    """Per-side trim count of the coordinate-wise trimmed mean."""
    b = int(np.ceil((beta if beta is not None else f / n) * n)) if n else 0
    return min(b, (n - 1) // 2)


# ---------------------------------------------------------------------------
# elastic membership: n as a bucketed range, f as a live-roster policy


@dataclass(frozen=True)
class ElasticN:
    """A bucketed range of live agent counts for elastic-n specs.

    ``buckets`` are ascending capacities ending at ``n_max``; a live roster
    of ``n_live`` agents is served by the smallest bucket >= n_live (live
    rows are packed into the bucket's stack, surplus slots are ghost rows
    masked out under the engine's documented masked semantics).  Build via
    :func:`elastic`."""
    n_max: int
    buckets: Tuple[int, ...]

    def __post_init__(self):
        if not self.buckets:
            raise ValueError("elastic: need at least one bucket")
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(
                f"elastic: buckets must be strictly ascending, got "
                f"{self.buckets}")
        if self.buckets[-1] != self.n_max or self.buckets[0] < 1:
            raise ValueError(
                f"elastic: buckets must lie in [1, n_max={self.n_max}] and "
                f"end at n_max, got {self.buckets}")

    def bucket_for(self, n_live: int) -> int:
        """Smallest bucket capacity serving ``n_live`` live agents."""
        if n_live > self.n_max:
            raise ValueError(
                f"n_live={n_live} exceeds the elastic n_max={self.n_max}")
        if n_live < 1:
            raise ValueError(f"n_live must be >= 1, got {n_live}")
        for b in self.buckets:
            if b >= n_live:
                return b
        raise AssertionError("unreachable: last bucket == n_max")

    def pack(self, live):
        """Pack live agent indices into their bucket's fixed shape.

        ``live``: 1-d array of live agent slots (ascending, >= 1 entry —
        raises on an empty roster; the loops only reach the elastic path
        when something was delivered, which implies a live member).
        Returns ``(bucket, idx, valid)``: ``idx`` (bucket,) int32 — the
        live slots padded by REPEATING the first live slot — and ``valid``
        (bucket,) bool marking the real ones.  The one shared packing
        idiom of the async loop, the sync step driver and the serving
        engine, so pad strategy and error behaviour can never diverge."""
        live = np.asarray(live, np.int32)
        b = self.bucket_for(len(live))       # raises on an empty roster
        idx = np.concatenate([live, np.full(b - len(live), live[0],
                                            np.int32)])
        valid = np.arange(b) < len(live)
        return b, idx, valid


def elastic(n_max: int, buckets: int | Tuple[int, ...] = 3,
            n_min: int | None = None) -> ElasticN:
    """Elastic agent count for ``make_spec(..., n=elastic(n_max, ...))``.

    ``buckets`` is either an explicit ascending tuple of capacities (the
    last must equal ``n_max``) or a bucket COUNT: capacities are then
    spread evenly over [n_min (default ~n_max/2), n_max].  More buckets =
    tighter plans under churn but more (bounded, build-time-planned)
    compilations; ``buckets=tuple(range(n_min, n_max + 1))`` degenerates
    to one plan per live count — the naive re-jit baseline the benchmarks
    compare against."""
    if isinstance(buckets, int):
        lo = n_min if n_min is not None else max(1, (n_max + 1) // 2)
        if not 1 <= lo <= n_max:
            raise ValueError(f"n_min={lo} outside [1, n_max={n_max}]")
        k = max(1, int(buckets))
        if k == 1:
            return ElasticN(n_max=n_max, buckets=(n_max,))
        pts = np.unique(np.linspace(lo, n_max, k).round().astype(int))
        return ElasticN(n_max=n_max, buckets=tuple(int(b) for b in pts))
    return ElasticN(n_max=n_max, buckets=tuple(int(b) for b in buckets))


@dataclass(frozen=True)
class FracF:
    """A Byzantine-budget POLICY: ``f = max(min_f, floor(ratio * n))``,
    re-derived per elastic bucket so the breakdown bound tracks the live
    roster.  Build via :func:`frac`."""
    ratio: float
    min_f: int = 0

    def __post_init__(self):
        if not 0.0 <= self.ratio < 1.0:
            raise ValueError(f"frac ratio must be in [0, 1), got "
                             f"{self.ratio}")

    def resolve(self, n: int) -> int:
        # epsilon guards fp products landing just below an integer
        # (0.29 * 100 == 28.999999999999996): the budget must not silently
        # tolerate one fewer adversary than the stated ratio
        return max(self.min_f, int(np.floor(self.ratio * n + 1e-9)))


def frac(ratio: float, min_f: int = 0) -> FracF:
    """``f=frac(0.2)``: tolerate 20% of the LIVE roster per bucket."""
    return FracF(ratio=ratio, min_f=min_f)


# ---------------------------------------------------------------------------
# capability flags + registry


@dataclass(frozen=True)
class AggregatorCaps:
    """What an aggregation rule can do — drives engine dispatch."""
    coordwise: bool = False           # leaf-wise per-coordinate rule
    weight_decomposable: bool = False  # filter(g) == sum_i w_i g_i exactly
    iterative: bool = False           # fixed-point / multi-round tree rule
    masked_capable: bool = True       # supports mask/weights aggregation
    sharding_aware: bool = False      # fused impl avoids full-stack gather
    stateful: bool = False            # carries init_state/update_state
    staleness_aware: bool = False     # `weights` = raw staleness ROUNDS,
    #                                   not discount multipliers
    pairwise: bool = False            # selection statistics derivable from
    #                                   the (n, n) Gram of the stack
    #                                   (pairwise distances / norm diagonal)


@dataclass(frozen=True)
class AggregatorDef:
    """Registry record: capabilities + the callables the engine dispatches
    to.  All callables take the spec first, so hyper/state plumbing is
    uniform and new rules never touch the engine."""
    name: str
    caps: AggregatorCaps
    hyper_keys: frozenset          # allowed hyper-parameter names
    impl_keys: frozenset           # impl-only keys (split into impl_hyper)
    state_keys: frozenset          # keys that must arrive via state=, not hyper
    gather_keys: frozenset         # hyper forwarded to the dense gather fn
    dense_fn: Optional[Callable] = None    # (stack, f, **hyper) -> (P,)
    weights_fn: Optional[Callable] = None  # (spec, grads, state) -> (n,)
    tree_fn: Optional[Callable] = None     # (spec, grads, state) -> tree
    custom_fn: Optional[Callable] = None   # (spec, grads, mask, w, state)
    masked_fn: Optional[Callable] = None   # masked-path override
    flat_fn: Optional[Callable] = None     # (spec, stack, mask, w, state,
    #                                        qscale) -> (P,) — rules whose
    #                                        flat law is NOT impute-then-
    #                                        scale (per-coordinate weights)
    gather_state_fn: Optional[Callable] = None  # (spec, state) -> extra hyper
    init_state_fn: Optional[Callable] = None    # (spec, proto) -> state
    update_state_fn: Optional[Callable] = None  # (spec, state, agg) -> state
    is_wrapper: bool = False       # requires inner spec
    tags: tuple = ()               # e.g. ("table2",)


REGISTRY: dict[str, AggregatorDef] = {}


def register_aggregator(name: str, *, caps: AggregatorCaps,
                        hyper: tuple = (), impl_keys: tuple = (),
                        state_keys: tuple = (), gather: tuple = (),
                        dense_fn=None, weights_fn=None, tree_fn=None,
                        masked_fn=None, flat_fn=None, gather_state_fn=None,
                        init_state=None, update_state=None,
                        is_wrapper: bool = False, tags: tuple = ()):
    """Register an aggregation rule.  Returns a DECORATOR — apply it to
    the rule's custom aggregate function

        @register_aggregator("my_rule", caps=AggregatorCaps(...))
        def my_rule(spec, grads, mask, weights, state): ...

    or, when the rule is fully described by the keyword callables
    (dense_fn/weights_fn/tree_fn), apply it to None:

        register_aggregator("my_rule", caps=..., weights_fn=...)(None)

    This is the single extension point: no capability constants, no
    dispatch chains, no edits anywhere else."""
    def _add(custom_fn):
        if name in REGISTRY:
            raise ValueError(f"aggregator {name!r} already registered")
        REGISTRY[name] = AggregatorDef(
            name=name, caps=caps, hyper_keys=frozenset(hyper),
            impl_keys=frozenset(impl_keys), state_keys=frozenset(state_keys),
            gather_keys=frozenset(gather), dense_fn=dense_fn,
            weights_fn=weights_fn, tree_fn=tree_fn, custom_fn=custom_fn,
            masked_fn=masked_fn, flat_fn=flat_fn,
            gather_state_fn=gather_state_fn,
            init_state_fn=init_state, update_state_fn=update_state,
            is_wrapper=is_wrapper, tags=tags)
        return custom_fn

    return _add


def _register_plain(name, **kw):
    register_aggregator(name, **kw)(None)


def get_aggregator_def(name: str) -> AggregatorDef:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown aggregator {name!r}; registered: "
            f"{sorted(REGISTRY)}") from None


def list_aggregators(tag: str | None = None) -> list[str]:
    return sorted(n for n, d in REGISTRY.items()
                  if tag is None or tag in d.tags)


# ---------------------------------------------------------------------------
# the spec


@dataclass(frozen=True)
class AggregatorSpec:
    """Typed handle to a registered aggregation rule.

    Build with :func:`make_spec` (validates hyper keys, splits impl-only
    keys, precomputes static plans when ``n`` is known).  Frozen and
    array-free, so specs pass freely through jit closures and configs.
    """
    name: str
    f: int = 0
    hyper: tuple = ()                 # sorted ((key, value), ...) — static
    impl: str = "fused"               # fused | gather
    impl_hyper: tuple = ()            # impl-only keys, e.g. native_dtype
    inner: Optional["AggregatorSpec"] = None   # wrapper composition
    n: Optional[int] = None           # static agent count (plan precompute)
    # elastic-n: the bucketed live-count range this spec was built for
    # (``n`` then holds n_max and ``f`` the budget resolved at n_max);
    # ``respecialize(n_live)`` selects the per-bucket concrete spec
    elastic: Optional[ElasticN] = None
    f_policy: Optional[FracF] = None  # f re-derived per bucket when set

    # -- introspection ----------------------------------------------------
    @property
    def caps(self) -> AggregatorCaps:
        return get_aggregator_def(self.name).caps

    @property
    def stateful(self) -> bool:
        d = get_aggregator_def(self.name)
        return d.caps.stateful or (self.inner is not None
                                   and self.inner.stateful)

    @property
    def staleness_aware(self) -> bool:
        """True if this spec (or any nested inner) interprets ``weights``
        as raw staleness round counts rather than discount multipliers."""
        d = get_aggregator_def(self.name)
        return d.caps.staleness_aware or (self.inner is not None
                                          and self.inner.staleness_aware)

    @property
    def elastic_n(self) -> Optional[ElasticN]:
        """The ElasticN governing this spec — its own, or the wrapped
        chain's (composition wrappers delegate elasticity to their inner
        rule, however deeply nested).  This is what the training/serving
        loops consult: reading ``.elastic`` alone would silently miss
        wrapper(elastic-inner) specs."""
        if self.elastic is not None:
            return self.elastic
        return self.inner.elastic_n if self.inner is not None else None

    @property
    def hyper_dict(self) -> dict:
        return dict(self.hyper)

    @property
    def impl_hyper_dict(self) -> dict:
        return dict(self.impl_hyper)

    def hp(self, key: str, default=None):
        for k, v in self.hyper:
            if k == key:
                return v
        return default

    def describe(self) -> str:
        h = ", ".join(f"{k}={v}" for k, v in self.hyper)
        el = (f", elastic[{'/'.join(map(str, self.elastic.buckets))}]"
              if self.elastic else "")
        inner = f" -> {self.inner.describe()}" if self.inner else ""
        return (f"{self.name}(f={self.f}{', ' + h if h else ''}{el})"
                + inner)

    # -- evolution --------------------------------------------------------
    def with_f(self, f: int) -> "AggregatorSpec":
        return dataclasses.replace(self, f=f)

    def with_f_capped(self, f_max: int) -> "AggregatorSpec":
        """Cap f on this spec AND every nested inner spec — the rule that
        actually executes inside composition wrappers must respect the
        reduced budget (e.g. after pre-aggregation grouping shrinks n)."""
        inner = self.inner.with_f_capped(f_max) if self.inner else None
        return dataclasses.replace(self, f=min(self.f, f_max), inner=inner)

    def with_impl(self, impl: str) -> "AggregatorSpec":
        return dataclasses.replace(
            self, impl=_resolve_impl(self.name, impl, self.hyper_dict))

    def respecialize(self, n_live: int) -> "AggregatorSpec":
        """The concrete spec serving a live roster of ``n_live`` agents.

        Elastic specs select the smallest bucket >= n_live: the returned
        spec is dataclass-EQUAL (hence hash-equal, hence jit-cache-equal)
        to a fresh ``make_spec(name, f=f_b, impl=..., n=b, **hyper)`` with
        ``f_b`` re-derived by the ``frac`` policy when one was given —
        bit-for-bit parity is pinned by
        tests/test_membership_conformance.py.  All bucket specs are
        prebuilt at ``make_spec`` time, so this never retraces and never
        enumerates plans on the hot path; churn over the bucketed range
        costs at most ``len(buckets)`` step compilations.

        Non-elastic specs return themselves when ``n_live`` matches (or
        ``n`` was never pinned); a mismatched static n raises — silently
        serving a different roster than the spec was planned for would
        void the (n, f) guarantee."""
        return _respecialize(self, n_live)

    def with_impl_hyper(self, **kw) -> "AggregatorSpec":
        d = get_aggregator_def(self.name)
        merged = dict(self.impl_hyper)
        for k, v in kw.items():
            if k not in d.impl_keys:
                raise ValueError(
                    f"{self.name}: {k!r} is not an impl key "
                    f"(allowed: {sorted(d.impl_keys)})")
            merged[k] = v
        return dataclasses.replace(self,
                                   impl_hyper=tuple(sorted(merged.items())))

    def with_impl_hyper_if_supported(self, **kw) -> "AggregatorSpec":
        """Set impl-only keys on this spec AND every nested inner spec,
        wherever the rule declares them — a no-op elsewhere.  This is how
        loop-level knobs (``agg_dtype`` -> ``native_dtype``) reach the rule
        that actually executes inside composition wrappers."""
        d = get_aggregator_def(self.name)
        inner = (self.inner.with_impl_hyper_if_supported(**kw)
                 if self.inner else None)
        spec = dataclasses.replace(self, inner=inner)
        supported = {k: v for k, v in kw.items() if k in d.impl_keys}
        return spec.with_impl_hyper(**supported) if supported else spec

    # -- state protocol ---------------------------------------------------
    def init_state(self, proto):
        """Initial aggregator state for a single-agent gradient prototype
        (pytree without the agent axis).  {} for stateless rules."""
        d = get_aggregator_def(self.name)
        state = d.init_state_fn(self, proto) if d.init_state_fn else {}
        if self.inner is not None and self.inner.stateful:
            state = dict(state)
            state["inner"] = self.inner.init_state(proto)
        return state

    def update_state(self, state, agg):
        """Post-step state transition given the aggregate just produced."""
        d = get_aggregator_def(self.name)
        inner_state = None
        if self.inner is not None and self.inner.stateful:
            inner_state = self.inner.update_state(state["inner"], agg)
        new = (d.update_state_fn(self, state, agg)
               if d.update_state_fn else dict(state))
        if inner_state is not None:
            new = dict(new)
            new["inner"] = inner_state
        return new

    # -- the one entry point ----------------------------------------------
    def aggregate(self, grads, mask=None, weights=None, state=None):
        """Aggregate per-agent gradients (leading axis = agent).

        ``mask``    (n,) bool — rows that actually arrived (None = all);
        ``weights`` (n,) float — per-agent multipliers (staleness
                    discounts); zeroed where ``mask`` is False;
        ``state``   pytree from :meth:`init_state` for stateful rules.

        mask=None and weights=None is the synchronous case (legacy
        ``tree_aggregate``); otherwise the masked/weighted semantics of
        the legacy ``tree_masked_aggregate`` apply, bit-for-bit."""
        d = get_aggregator_def(self.name)
        if self.stateful and state is None:
            raise ValueError(
                f"{self.describe()} is stateful: pass "
                "state=spec.init_state(proto) (called on THIS spec — for "
                "composed specs it nests the inner state correctly)")
        if d.custom_fn is not None:
            return d.custom_fn(self, grads, mask, weights, state)
        if mask is None and weights is None:
            return _sync_aggregate(self, d, grads, state)
        if not d.caps.masked_capable:
            raise ValueError(f"{self.name} does not support masked "
                             f"aggregation")
        if mask is None:
            mask = jnp.ones((_n_agents(grads),), bool)
        if d.masked_fn is not None:
            return d.masked_fn(self, grads, mask, weights, state)
        return _masked_aggregate(self, d, grads, mask, weights, state)

    def weights(self, grads, state=None):
        """Per-agent weights w with filter(g) == sum_i w_i g_i (exact) —
        only for weight-decomposable rules (legacy ``filter_weights``)."""
        d = get_aggregator_def(self.name)
        if d.weights_fn is None:
            raise ValueError(f"{self.name} is not weight-decomposable")
        if d.caps.stateful and state is None:
            raise ValueError(
                f"{self.name} is stateful: pass state=spec.init_state(...)")
        return d.weights_fn(self, grads, state)

    # -- the zero-copy flat path ------------------------------------------
    @property
    def flat_capable(self) -> bool:
        """True iff this spec can aggregate a pre-raveled (n, P) arena via
        :meth:`aggregate_flat` — the dense-stack impls (gather / pallas)
        of plain stateless rules, plus stateful rules that registered an
        explicit flat law.  Composition wrappers, other custom-path
        rules and the fused (leaf-wise, sharding-aware) impl keep the
        tree engine: their arithmetic is defined on leaves, and flattening
        would silently change reduce orders."""
        d = get_aggregator_def(self.name)
        if d.is_wrapper:
            return False
        if self.stateful:
            # stateful rules ride the arena only through an explicit flat
            # law (state raveling is rule-specific — see centered_clip);
            # the caller then passes state= to aggregate_flat
            return d.flat_fn is not None
        if d.flat_fn is not None:
            return True
        return (d.custom_fn is None and d.masked_fn is None
                and self.impl in ("gather", "pallas"))

    def aggregate_flat(self, stack, mask=None, weights=None, state=None,
                       scale=None):
        """Aggregate a pre-raveled (n, P) gradient arena -> (P,) fp32.

        The flat-pipeline twin of :meth:`aggregate`: the caller raveled
        the per-agent gradients ONCE at production time
        (:meth:`repro.core.flat.FlatPlan.ravel`) and unravels the result
        once at optimizer-apply, so the aggregation dispatch itself moves
        no model-sized memory.  Masked/weighted semantics are the gather
        path's impute-then-scale law, bit-for-bit with the tree engine
        for uniform-dtype trees; ``impl="pallas"`` runs the fused masked
        kernels (imputation inside the tile — the (n, P) imputed copy is
        never materialized).

        ``scale``: per-row (n,) fp32 dequantization sidecar for a
        QUANTIZED arena (``stack`` then holds int8 / fp8 exchange codes
        from :func:`repro.core.flat.quantize_rows`; row i decodes as
        ``stack[i].astype(f32) * scale[i]``).  Kernelized coordinate
        rules dequantize INSIDE the tile (no dequantized (n, P) copy is
        materialized — jaxpr-gated by tests/test_kernels_parity.py);
        other rules dequantize at engine level with a one-time
        warning."""
        d = get_aggregator_def(self.name)
        if not self.flat_capable:
            raise ValueError(
                f"{self.describe()} (impl={self.impl}) has no flat path — "
                "check spec.flat_capable before routing the arena")
        if d.flat_fn is not None:
            return d.flat_fn(self, stack, mask, weights, state, scale)
        if mask is None and weights is None:
            return _flat_sync_vec(self, d, stack, state, scale)
        if not d.caps.masked_capable:
            raise ValueError(f"{self.name} does not support masked "
                             f"aggregation")
        if mask is None:
            mask = jnp.ones((stack.shape[0],), bool)
        return _flat_masked_vec(self, d, stack, mask, weights, state, scale)

    # -- aggregation telemetry (repro.obs) --------------------------------
    def selection_weights(self, grads, mask=None, weights=None, state=None):
        """(n,) fp32 per-agent selection/application weights — the
        telemetry signal every detection-based defense starts from.

        For weight-decomposable rules these are the rule's OWN application
        weights (synchronous fused path: ``aggregate(grads) ==
        tree_weighted_sum(grads, selection_weights(grads))`` exactly;
        masked paths: the weights over the imputed stack, matching the
        engine's masked law for the spec's impl).  Bulyan reports its
        theta-selection (1/theta on chosen rows); coordinate-wise and
        iterative rules report *participation* weights (the normalized
        delivery weights — every arrived row enters the order statistics);
        wrappers transform and recurse.  ``grads`` may be a pytree or a
        bare (n, P) arena stack (the flat pipeline's view).

        Fixed shape, no data-dependent control flow: safe to emit as an
        aux output of a jitted step without changing the compile budget.
        """
        d = get_aggregator_def(self.name)
        if self.stateful and state is None:
            raise ValueError(
                f"{self.describe()} is stateful: pass "
                "state=spec.init_state(proto), as for aggregate()")
        return _selection_weights(self, d, grads, mask, weights, state)

    def aggregate_with_telemetry(self, grads, mask=None, weights=None,
                                 state=None):
        """:meth:`aggregate` plus the fixed-shape telemetry struct:
        ``(agg, {"sel_w": (n,) f32, "mask": (n,) bool, "contrib_w":
        (n,) f32})``.  The aggregate is computed by the SAME engine call
        as :meth:`aggregate` — bit-for-bit identical output; the aux
        struct adds only (n,)-sized work, so emitting it from a jitted
        step changes neither results nor the compile budget."""
        agg = self.aggregate(grads, mask=mask, weights=weights, state=state)
        return agg, self._telemetry(grads, mask, weights, state)

    def aggregate_flat_with_telemetry(self, stack, mask=None, weights=None,
                                      state=None, scale=None):
        """:meth:`aggregate_flat` plus the telemetry struct (see
        :meth:`aggregate_with_telemetry`)."""
        vec = self.aggregate_flat(stack, mask=mask, weights=weights,
                                  state=state, scale=scale)
        return vec, self._telemetry(stack, mask, weights, state)

    def _telemetry(self, grads, mask, weights, state):
        n = _n_agents(grads)
        m = jnp.ones((n,), bool) if mask is None else mask.astype(bool)
        cw = (m.astype(jnp.float32) if weights is None
              else weights.astype(jnp.float32) * m.astype(jnp.float32))
        sel = self.selection_weights(grads, mask=mask, weights=weights,
                                     state=state)
        return {"sel_w": sel.astype(jnp.float32), "mask": m,
                "contrib_w": cw}


@functools.lru_cache(maxsize=None)
def _respecialize(spec: AggregatorSpec, n_live: int) -> AggregatorSpec:
    """Cached respecialization: repeat calls for the same (spec, n_live)
    return the SAME object, so hot loops pay one dict probe and jit
    closures see a stable static."""
    if spec.elastic is None:
        if spec.inner is not None and spec.inner.elastic_n is not None:
            # key the wrapper on the RESOLVED inner (recursing through
            # however many wrapper levels sit above the elastic rule), so
            # every n_live that maps to the same bucket yields the same
            # wrapper object
            return _with_inner(spec, _respecialize(spec.inner, n_live))
        if spec.n is None or spec.n == n_live:
            return spec
        raise ValueError(
            f"{spec.describe()} was built for static n={spec.n}, not "
            f"n_live={n_live} — build it with n=elastic(...) to allow "
            "membership changes")
    return _bucket_spec(spec, spec.elastic.bucket_for(n_live))


@functools.lru_cache(maxsize=None)
def _with_inner(spec: AggregatorSpec, inner: AggregatorSpec):
    return dataclasses.replace(spec, inner=inner)


@functools.lru_cache(maxsize=None)
def _bucket_spec(spec: AggregatorSpec, b: int) -> AggregatorSpec:
    """The concrete per-bucket spec of an elastic spec — cached, so every
    respecialize() call for the same bucket returns the SAME object."""
    f_b = spec.f_policy.resolve(b) if spec.f_policy is not None else spec.f
    inner = spec.inner
    if inner is not None and inner.elastic_n is not None:
        inner = inner.respecialize(b)
    out = dataclasses.replace(spec, n=b, f=f_b, elastic=None,
                              f_policy=None, inner=inner)
    _warm_plan(out, b)
    return out


def pallas_available(name: str) -> bool:
    """True iff ``name`` has a registered Pallas kernel path AND its caps
    declare the matching structure (coordinate-wise order statistics or
    Gram-derivable selection) — the condition ``impl="auto"`` checks."""
    d = get_aggregator_def(name)
    if d.is_wrapper or not (d.caps.coordwise or d.caps.pairwise):
        return False
    from repro.kernels import pallas_supported
    return pallas_supported(name)


def _pallas_supports_hyper(name: str, hyper: dict | None) -> bool:
    """Hyper-level kernel gate: bulyan's kernels implement only the
    classic krum base (the generic-base path calls an arbitrary inner
    filter per selection round — not Gram-derivable)."""
    if name == "bulyan":
        return (hyper or {}).get("base", "krum") == "krum"
    return True


def _resolve_impl(name: str, impl: str, hyper: dict | None = None) -> str:
    """``auto`` -> ``pallas`` where caps + kernel availability (and the
    rule's hyper, e.g. bulyan's base) allow, else ``fused``; explicit
    ``pallas`` on an unsupported rule raises HERE (at build time), not
    deep inside jit."""
    if impl not in ("auto", "fused", "gather", "pallas"):
        raise ValueError(
            f"impl must be auto|fused|gather|pallas, got {impl!r}")
    supported = pallas_available(name) and _pallas_supports_hyper(name,
                                                                 hyper)
    if impl == "auto":
        return "pallas" if supported else "fused"
    if impl == "pallas" and not supported:
        from repro.kernels.dispatch import FLAT_SELF_KERNELED
        if name in FLAT_SELF_KERNELED:
            # the rule's flat_fn dispatches its own fused kernel stages
            # (centered_clip's clipped-weighted-sum MAC); the tree path
            # stays dense.  ``auto`` deliberately does NOT select this —
            # the kernel's reduce association differs from the dense body.
            return impl
        from repro.kernels import pallas_supported
        if not pallas_supported(name):
            reason = "no Pallas kernel registered for it"
        elif not _pallas_supports_hyper(name, hyper):
            reason = "its hyper-parameters select a non-kernelized variant"
        else:
            reason = "its caps are neither coordwise nor pairwise"
        raise ValueError(
            f"{name}: impl='pallas' requested but {reason} "
            "(see repro.kernels.dispatch.PALLAS_RULES)")
    return impl


def make_spec(name: str, f: "int | FracF" = 0, impl: str = "auto",
              inner: AggregatorSpec | None = None,
              n: "int | ElasticN | None" = None,
              **hyper) -> AggregatorSpec:
    """Build a validated :class:`AggregatorSpec`.

    Unknown hyper keys raise HERE (not deep inside jit); impl-only keys
    (``native_dtype``) are split off once into ``impl_hyper``; state-like
    keys (``server_grad``) must be threaded via ``state=`` instead.  When
    ``n`` is given, static plans (MDA subset tables, trim counts) are
    precomputed at build time.

    ``n=elastic(n_max, buckets=...)`` builds an ELASTIC spec: static plans
    are precomputed per bucket at build time and
    :meth:`AggregatorSpec.respecialize` selects the bucket's concrete spec
    without retracing when membership changes.  ``f`` may then be a
    :func:`frac` policy, re-derived per bucket so breakdown bounds track
    the live roster (a plain int f is carried unchanged).

    ``impl="auto"`` (the default) resolves to ``"pallas"`` when the rule's
    :class:`AggregatorCaps` (coordwise / pairwise) match a registered
    kernel in :mod:`repro.kernels.dispatch`, else ``"fused"`` — pass
    ``impl=`` explicitly to override.

    NOTE — masked semantics of the new default: ``pallas`` follows the
    GATHER path's masked/weighted semantics (impute-then-scale).  For
    coordinate-wise rules fused is numerically identical, but for the
    weight-decomposable kernelized rules (krum, cge) the fused path folds
    the per-agent weights into the selection weights instead — a
    different (also valid) estimator.  Default-built krum/cge specs
    therefore changed masked behavior when the default moved from
    ``"fused"`` to ``"auto"``: pass ``impl="fused"`` to keep the
    historical masked semantics (``ByzantineConfig.impl`` still defaults
    to it).  tests/test_kernels_parity.py pins all three."""
    d = get_aggregator_def(name)
    el = n if isinstance(n, ElasticN) else None
    n_int = el.n_max if el is not None else n
    f_policy = f if isinstance(f, FracF) else None
    if f_policy is not None:
        if n_int is None:
            raise ValueError(
                f"{name}: f=frac(...) needs n= to resolve the budget — "
                "pass n=<int> or n=elastic(...)")
        f = f_policy.resolve(n_int)
        if el is None:
            f_policy = None           # static n: nothing to re-derive
    if f < 0:
        raise ValueError(f"f must be >= 0, got {f}")
    if d.is_wrapper and inner is None:
        raise ValueError(f"{name} is a composition wrapper: pass inner=")
    if not d.is_wrapper and inner is not None:
        raise ValueError(f"{name} takes no inner spec")
    plain, impl_only = {}, {}
    for k, v in hyper.items():
        if k in d.state_keys:
            raise ValueError(
                f"{name}: {k!r} is aggregator STATE, not a hyper-parameter "
                f"— pass it via state= (see AggregatorSpec.init_state)")
        if k in d.impl_keys:
            impl_only[k] = v
        elif k in d.hyper_keys:
            plain[k] = v
        else:
            raise ValueError(
                f"{name}: unknown hyper-parameter {k!r} "
                f"(allowed: {sorted(d.hyper_keys | d.impl_keys)})")
    impl = _resolve_impl(name, impl, plain)
    spec = AggregatorSpec(name=name, f=f,
                          hyper=tuple(sorted(plain.items())), impl=impl,
                          impl_hyper=tuple(sorted(impl_only.items())),
                          inner=inner, n=n_int, elastic=el,
                          f_policy=f_policy)
    if el is not None:
        for b in el.buckets:          # prebuild every bucket's plans NOW
            _bucket_spec(spec, b)
    elif n_int is not None:
        _warm_plan(spec, n_int)
    return spec


def _warm_plan(spec: AggregatorSpec, n: int):
    """Precompute per-(n, f) static work at spec-build time."""
    if spec.name == "mda":
        mda_combos(n, spec.f)
    if spec.name == "trimmed_mean":
        trim_count(n, spec.f, spec.hp("beta"))
    if spec.inner is not None:
        _warm_plan(spec.inner, n)


# ---------------------------------------------------------------------------
# engine: synchronous path (legacy tree_aggregate, bit-for-bit)


def _sync_aggregate(spec, d, grads, state):
    if spec.impl == "pallas":
        # kernel path: same dense (n, P) fp32 contract as the gather path,
        # with the sort / Gram / selection / application stages running as
        # tiled Pallas kernels (interpret mode off-TPU — same code path)
        from repro.kernels import pallas_aggregate
        stack = tree_stack_ravel(
            jax.tree.map(lambda l: l.astype(jnp.float32), grads))
        return tree_unravel_like(
            pallas_aggregate(spec.name, stack, spec.f, spec.hyper), grads)
    if spec.impl == "gather":
        stack = tree_stack_ravel(
            jax.tree.map(lambda l: l.astype(jnp.float32), grads))
        hyper = {k: v for k, v in spec.hyper if k in d.gather_keys}
        if d.gather_state_fn is not None:
            hyper.update(d.gather_state_fn(spec, state))
        return tree_unravel_like(d.dense_fn(stack, spec.f, **hyper), grads)
    if d.caps.coordwise:
        return d.tree_fn(spec, grads, state)
    if d.caps.weight_decomposable:
        return tree_weighted_sum(grads, d.weights_fn(spec, grads, state))
    if d.caps.iterative:
        return d.tree_fn(spec, grads, state)
    raise ValueError(f"{spec.name}: no fused path registered")


# ---------------------------------------------------------------------------
# engine: masked / staleness-weighted path (legacy tree_masked_aggregate)


def _masked_prelude(grads, mask, weights):
    mask = mask.astype(bool)
    mf = mask.astype(jnp.float32)
    w = mf if weights is None else weights.astype(jnp.float32) * mf
    cnt = jnp.maximum(jnp.sum(mf), 1.0)
    tot = jnp.maximum(jnp.sum(w), 1e-30)
    return mask, w, cnt, tot


def _masked_aggregate(spec, d, grads, mask, weights, state):
    """Robust aggregation over a *varying subset* of agents with per-agent
    weights.  The rules are fixed-n (one jit shape across rounds); the
    masked law differs by rule class:

      * coordinate-wise order statistics and the sign vote
        (_ARRIVED_STAT_RULES) — the statistic over the ARRIVED rows only:
        absent rows enter the sort as +inf sentinels and the kept rank
        window follows the traced arrived count, then the result is
        scaled by the mean weight of arrived rows (a staleness-adaptive
        step size).  Imputing the absent rows at the delivered mean is
        NOT robust — the mean is attack-contaminated, so the ghost rows
        land inside the trim window and one straggler lets the attack
        through;
      * weight-decomposable — rule weights on the mean-imputed stack,
        times the per-agent weights, renormalized (imputed rows carry the
        average arrived weight so a selection landing on them is
        neutral); the imputed ghosts are outliers to the selection
        distances, not candidates inside a trust window;
      * remaining coordinate-wise / iterative — rule on the mean-imputed
        stack, scaled by the mean arrived weight (a known robustness gap
        under attack + absence — see ROADMAP).

    With mask all-True and weights all-one this reduces to the synchronous
    path up to exact-arithmetic no-ops.

    ``impl="pallas"`` + a registered masked kernel (every coordinate-wise
    AND pairwise kernelized rule — see kernels.dispatch.PALLAS_MASKED_
    RULES) takes the FUSED imputation-free path: imputation happens
    inside the sort / Gram / application tiles, so no imputed (n, d)
    copy is ever materialized and the mask/weights stay traced operands
    (fault schedules never recompile).  Arithmetic is identical to the
    imputation below, bit-for-bit in fp32 — the gather path's masked
    semantics exactly.  A pallas spec over MIXED-dtype leaves cannot take
    the fused kernel (one exchange dtype per stack) and falls back to the
    imputed path below with a one-time warning."""
    mask, w, cnt, tot = _masked_prelude(grads, mask, weights)
    # tot is eps-clamped: with EVERY delivered weight zero (possible under
    # sparse/dropout weighting) tot/cnt would be eps-garbage — the update
    # must be an explicit zero instead (tot == sum(w) whenever sum(w) > 0,
    # so the guard is bit-free on every live path)
    scale = jnp.where(jnp.sum(w) > 0, tot / cnt, 0.0)
    if spec.impl == "pallas":
        from repro.kernels import (pallas_masked_aggregate,
                                   pallas_masked_supported)
        leaves = jax.tree.leaves(grads)
        uniform = all(l.dtype == leaves[0].dtype for l in leaves)
        if pallas_masked_supported(spec.name) and uniform:
            stack = tree_stack_ravel(grads)        # native dtype, no cast
            vec = pallas_masked_aggregate(
                spec.name, stack, mask.astype(jnp.float32), w / tot,
                spec.f, spec.hyper)
            agg = tree_unravel_like(vec, grads)
            return jax.tree.map(
                lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype),
                agg)
        if pallas_masked_supported(spec.name) and d.caps.coordwise:
            # mixed-dtype tree, coordinate-wise rule: per-coordinate
            # statistics never mix columns, so per-DTYPE sub-arenas still
            # get the fused kernel — group leaves by dtype, launch one
            # kernel per uniform segment, slice back per leaf.  Bitwise
            # the uniform path per segment (columns are independent), so
            # this equals the gather reference leaf for leaf.
            flat_leaves, treedef = jax.tree.flatten(grads)
            n = flat_leaves[0].shape[0]
            by_dt: dict = {}
            for i, l in enumerate(flat_leaves):
                by_dt.setdefault(jnp.dtype(l.dtype), []).append(i)
            outs: list = [None] * len(flat_leaves)
            for dt, idxs in by_dt.items():
                seg = jnp.concatenate(
                    [flat_leaves[i].reshape(n, -1) for i in idxs], axis=1)
                vec = pallas_masked_aggregate(
                    spec.name, seg, mask.astype(jnp.float32), w / tot,
                    spec.f, spec.hyper)
                off = 0
                for i in idxs:
                    sz = flat_leaves[i][0].size
                    outs[i] = (vec[off:off + sz].astype(dt)
                               .astype(jnp.float32)
                               * scale).astype(dt).reshape(
                                   flat_leaves[i].shape[1:])
                    off += sz
            return jax.tree.unflatten(treedef, outs)
        if pallas_masked_supported(spec.name):
            # pairwise kernels need one exchange dtype for the WHOLE row
            # (the Gram couples every column); a mixed tree falls back to
            # the imputed tree path — same estimator, just the slow path
            dts = tuple(sorted({jnp.dtype(l.dtype).name for l in leaves}))
            warn_once(
                ("masked-pallas-mixed-dtype", spec.name, dts),
                f"{spec.name}: masked pallas kernel skipped — gradient "
                f"leaves carry mixed dtypes {dts}; falling back to the "
                "tree-level imputed path (materializes the imputed "
                "(n, d) stack).  Cast the leaves to one exchange dtype "
                "to restore the fused kernel.")
    if d.caps.coordwise and spec.name in _ARRIVED_STAT_RULES:
        # arrived-window law (see _ARRIVED_STAT_RULES), leaf-wise:
        # coordinate statistics never couple columns, so per-leaf equals
        # the arena path column for column — and the same double rounding
        # through the leaf dtype keeps it bit-for-bit with the kernels
        def _leaf(l):
            vec = _arrived_coord_vec(
                spec, l.reshape(l.shape[0], -1).astype(jnp.float32), mask)
            out = vec.astype(l.dtype)
            return (out.astype(jnp.float32) * scale).astype(
                l.dtype).reshape(l.shape[1:])
        return jax.tree.map(_leaf, grads)
    wn = w / tot
    mean_sel = tree_weighted_sum(grads, wn)
    imputed = tree_where_agents(
        mask, grads,
        jax.tree.map(lambda m, l: jnp.broadcast_to(
            m.astype(l.dtype)[None], l.shape), mean_sel, grads))
    if d.caps.weight_decomposable and spec.impl == "fused":
        # imputed rows carry the average arrived weight: a rule selecting
        # one (it equals the weighted consensus) stays a valid update.
        # Normalize to the RULE's own total weight, not to 1: selection
        # rules sum to 1 so nothing changes, but cgc's clip attenuation
        # (sum < 1) must survive masking — and with mask all-True and
        # weights all-one, fw == rule_w bit-for-bit (the documented
        # full-roster identity the conformance suite pins)
        row_w = jnp.where(mask, w, tot / cnt)
        rule_w = d.weights_fn(spec, imputed, state)
        fw = rule_w * row_w
        fw = fw * (jnp.sum(rule_w) / jnp.maximum(jnp.sum(fw), 1e-30))
        return tree_weighted_sum(imputed, fw)
    agg = _sync_aggregate(spec, d, imputed, state)
    # scale <= 1, == 1 when all fresh; exact 0 when no weight was delivered
    return jax.tree.map(
        lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), agg)


# ---------------------------------------------------------------------------
# engine: flat-arena path (zero-copy pipeline — the loops ravel once at
# gradient production, this engine never touches a pytree, and the caller
# unravels exactly once at optimizer-apply)


def _flat_f32(stack):
    return stack if stack.dtype == jnp.float32 else stack.astype(jnp.float32)


def _flat_dequant(spec, stack, qscale):
    """Engine-level dequantization fallback for rules without an in-tile
    scaled kernel: materializes the (n, P) f32 copy, with a one-time
    notice (the kernelized coordinate rules never come here — their
    dequant happens inside the tile)."""
    from repro.core.flat import dequantize_rows
    if spec.impl == "pallas":
        warn_once(
            ("flat-scaled-dequant", spec.name),
            f"{spec.name}: no scaled (quantized-arena) kernel — "
            "dequantizing the (n, P) arena at engine level before "
            "aggregation.  Only the kernelized coordinate rules "
            "(coordinate_median, trimmed_mean, sign_sgd, sparse_mean) "
            "dequantize inside the tile.")
    return dequantize_rows(stack, qscale)


# the coordinate-wise rules whose masked law is the order statistic (or
# sign vote) over the ARRIVED rows only — absent rows are +inf sort
# sentinels, never statistics.  Mean-imputing them (the pairwise family's
# law) is not robust: the delivered mean is attack-contaminated, so the
# ghost rows land inside the trim window and one straggler lets the attack
# through.  sparse_mean is arrived-only by construction (absent rows carry
# zero weight).  phocas/mean_around_median ride the count-windowed
# closest-to-center law (kernels/ref.arrived_mean_closest_ref): center
# from the arrived-window statistic, then the cnt-f arrived rows closest
# to it per coordinate.
_ARRIVED_STAT_RULES = ("coordinate_median", "trimmed_mean", "sign_sgd",
                       "phocas", "mean_around_median")


def _arrived_coord_vec(spec, xf, mask):
    """(n, P) fp32 stack -> (P,) fp32 masked coordinate-wise law: the
    statistic over arrived rows, one arithmetic copy shared with the
    fused kernels (kernels/ref.py) so every impl is bit-for-bit."""
    from repro.kernels import ref
    if spec.name == "sign_sgd":
        return ref.masked_sign_vote_ref(xf, mask)
    if spec.name == "coordinate_median":
        return ref.masked_stat_ref(xf, mask, None, "median")
    if spec.name == "phocas":
        return ref.arrived_mean_closest_ref(xf, mask, "trimmed_mean",
                                            spec.f)
    if spec.name == "mean_around_median":
        return ref.arrived_mean_closest_ref(xf, mask, "median", spec.f)
    b = trim_count(xf.shape[0], spec.f, spec.hp("beta"))
    return ref.masked_stat_ref(xf, mask, None, "trimmed_mean", b=b)


def _flat_sync_vec(spec, d, stack, state, qscale=None):
    """(n, P) arena -> (P,) fp32: the dense sync engine without the
    per-call ravel/unravel (bit-for-bit with `_sync_aggregate` on the
    equivalent tree — the cast-then-concat and concat-then-cast orders
    produce identical fp32 bits).  ``qscale``: per-row dequant sidecar
    of a quantized arena (kernelized coordinate rules dequantize inside
    the tile; everything else pays an engine-level dequant copy)."""
    if qscale is not None and spec.impl == "pallas":
        from repro.kernels import (pallas_scaled_aggregate,
                                   pallas_scaled_supported)
        if pallas_scaled_supported(spec.name):
            return pallas_scaled_aggregate(spec.name, stack, qscale,
                                           spec.f, spec.hyper)
    if qscale is not None:
        stack = _flat_dequant(spec, stack, qscale)
    if spec.impl == "pallas":
        from repro.kernels import pallas_aggregate
        return pallas_aggregate(spec.name, _flat_f32(stack), spec.f,
                                spec.hyper)
    hyper = {k: v for k, v in spec.hyper if k in d.gather_keys}
    return d.dense_fn(_flat_f32(stack), spec.f, **hyper)


def _flat_masked_vec(spec, d, stack, mask, weights, state, qscale=None):
    """Masked/weighted flat path on the arena: the arrived-window law for
    the coordinate-wise rules (_ARRIVED_STAT_RULES — absent rows are +inf
    sort sentinels, never statistics), the impute-at-delivered-mean law
    for everything else, each scaled by tot/cnt.  ``impl="pallas"`` + a
    registered masked kernel fuses the whole law into the kernel tiles —
    no masked (n, P) copy is ever materialized and mask/weights stay
    traced operands.  With ``qscale`` (quantized arena) dequantization
    happens in-tile for the scaled kernels and the law runs in the
    dequantized fp32 domain."""
    mask, w, cnt, tot = _masked_prelude(stack, mask, weights)
    # all-zero delivered weights must yield an explicit zero update, not
    # an eps-scaled garbage row (tot is clamped at 1e-30); tot == sum(w)
    # whenever sum(w) > 0, so the guard changes no live-path bits
    scale = jnp.where(jnp.sum(w) > 0, tot / cnt, 0.0)
    out_dtype = jnp.float32 if qscale is not None else stack.dtype

    def scaled(vec):
        # the tree engine rounds the fp32 aggregate to the LEAF dtype
        # before applying the scale (unravel, then per-leaf
        # (l.astype(f32) * scale).astype(l.dtype)); replicate that
        # double rounding through the arena dtype so non-f32 uniform
        # trees stay bit-for-bit (a no-op round trip for f32 arenas).
        # Quantized arenas skip the round trip: their virtual dtype is
        # fp32 (rounding the f32 aggregate to int8 would destroy it)
        return vec.astype(out_dtype).astype(jnp.float32) * scale

    if qscale is not None and spec.impl == "pallas":
        from repro.kernels import (pallas_scaled_masked_aggregate,
                                   pallas_scaled_supported)
        if pallas_scaled_supported(spec.name):
            vec = pallas_scaled_masked_aggregate(
                spec.name, stack, qscale, mask.astype(jnp.float32),
                w / tot, spec.f, spec.hyper)
            return scaled(vec)
    if qscale is not None:
        stack = _flat_dequant(spec, stack, qscale)
    if spec.impl == "pallas":
        from repro.kernels import (pallas_masked_aggregate,
                                   pallas_masked_supported)
        if pallas_masked_supported(spec.name):
            vec = pallas_masked_aggregate(
                spec.name, stack, mask.astype(jnp.float32), w / tot,
                spec.f, spec.hyper)
            return scaled(vec)
    if d.caps.coordwise and spec.name in _ARRIVED_STAT_RULES:
        # arrived-window law (see _ARRIVED_STAT_RULES): shared arithmetic
        # with the fused kernels, so gather/pallas stay bit-for-bit
        return scaled(_arrived_coord_vec(spec, _flat_f32(stack), mask))
    wn = w / tot
    xf = _flat_f32(stack)
    mean_sel = jnp.sum(xf * wn[:, None], axis=0).astype(stack.dtype)
    imputed = jnp.where(mask[:, None], stack, mean_sel[None])
    return scaled(_flat_sync_vec(spec, d, imputed, state))


# ---------------------------------------------------------------------------
# engine: selection-weight telemetry (repro.obs) — one (n,) read-out per
# rule class, mirroring the aggregate laws above.  Everything is fixed
# shape with no data-dependent control flow, so the loops can emit it as an
# aux output of a jitted step without touching the compile budget; the
# aggregate itself is NEVER computed through this path, so telemetry can't
# perturb results.


def _participation(grads, mask, weights):
    """Normalized delivery weights — the telemetry read-out for rules
    without a per-row application decomposition (coordinate-wise and
    iterative rules: every arrived row enters the order statistics)."""
    n = _n_agents(grads)
    if mask is None and weights is None:
        return jnp.full((n,), 1.0 / n, jnp.float32)
    m = jnp.ones((n,), bool) if mask is None else mask
    _, w, _, tot = _masked_prelude(grads, m, weights)
    return w / tot


def _selection_weights(spec, d, grads, mask, weights, state):
    name = spec.name
    # wrappers: apply the same row transform the aggregate path applies,
    # then read the inner rule's selection
    if d.is_wrapper:
        inner_state = _inner_state(spec, state)
        if name == "clipped":
            tau = spec.hp("tau", 1.0)
            norms = jnp.sqrt(jnp.maximum(tree_sqnorms(grads), 1e-30))
            scale = jnp.minimum(1.0, tau / norms)
            clipped_g = jax.tree.map(
                lambda l: (l.astype(jnp.float32)
                           * scale.reshape((-1,) + (1,) * (l.ndim - 1))
                           ).astype(l.dtype), grads)
            return spec.inner.selection_weights(
                clipped_g, mask=mask, weights=weights, state=inner_state)
        if name == "staleness_discounted":
            s = (jnp.zeros((_n_agents(grads),), jnp.float32)
                 if weights is None else weights.astype(jnp.float32))
            w = staleness_discount_table(s, spec.hp("weighting", "poly"),
                                         spec.hp("power", 1.0),
                                         spec.hp("gamma", 0.7))
            return spec.inner.selection_weights(
                grads, mask=mask, weights=w, state=inner_state)
        if name == "server_momentum":
            # momentum mixes on the OUTPUT; the per-row transform is the
            # identity, so attribution is the inner rule's selection
            return spec.inner.selection_weights(
                grads, mask=mask, weights=weights, state=inner_state)
        # bucketed (and any future group-transform wrapper): rows enter
        # through their group means — per-agent attribution is uniform
        return _participation(grads, mask, weights)
    if name == "zeno_pp":
        # the custom path's own weights (normalized over accepted rows)
        return _zeno_pp_weights(spec, grads, mask, weights, state)
    if name == "centered_clip":
        # effective clip weights of the final iteration, normalized — a
        # row the carried center distrusts (large ||g_i - v||) reports a
        # proportionally smaller share
        _, lam = _cclip_iterate(spec, grads, mask, weights, state)
        tot = jnp.sum(lam)
        return jnp.where(tot > 0, lam / jnp.maximum(tot, 1e-30), lam)
    if name == "bulyan":
        if spec.hp("base", "krum") != "krum":
            return _participation(grads, mask, weights)
        n, f = _n_agents(grads), spec.f
        theta = n - 2 * f
        if mask is None and weights is None:
            d2 = _gram_to_d2(tree_gram(grads))
        else:
            m = (jnp.ones((n,), bool) if mask is None
                 else mask.astype(bool))
            m, w, _, tot = _masked_prelude(grads, m, weights)
            mean_sel = tree_weighted_sum(grads, w / tot)
            imputed = tree_where_agents(
                m, grads,
                jax.tree.map(lambda mn, l: jnp.broadcast_to(
                    mn.astype(l.dtype)[None], l.shape), mean_sel, grads))
            d2 = _gram_to_d2(tree_gram(imputed))
        sel = _bulyan_theta_select(d2, n, f, theta)
        return sel.astype(jnp.float32) / theta
    if d.weights_fn is None:
        return _participation(grads, mask, weights)
    # weight-decomposable rules: the rule's own application weights
    if mask is None and weights is None:
        return d.weights_fn(spec, grads, state)
    n = _n_agents(grads)
    m = jnp.ones((n,), bool) if mask is None else mask.astype(bool)
    if name == "mean":
        # exact: the masked mean applies w/tot directly (no imputation)
        _, w, _, tot = _masked_prelude(grads, m, weights)
        return w / tot
    m, w, cnt, tot = _masked_prelude(grads, m, weights)
    mean_sel = tree_weighted_sum(grads, w / tot)
    imputed = tree_where_agents(
        m, grads,
        jax.tree.map(lambda mn, l: jnp.broadcast_to(
            mn.astype(l.dtype)[None], l.shape), mean_sel, grads))
    rule_w = d.weights_fn(spec, imputed, state)
    if spec.impl == "fused":
        # the fused masked law's exact decomposition (see
        # _masked_aggregate): agg == wsum(imputed, fw) bit-for-bit
        row_w = jnp.where(m, w, tot / cnt)
        fw = rule_w * row_w
        return fw * (jnp.sum(rule_w) / jnp.maximum(jnp.sum(fw), 1e-30))
    # gather / pallas masked law: rule weights over the imputed stack
    # (the aggregate additionally scales by tot/cnt — a global factor
    # that does not change per-agent shares)
    return rule_w


# ---------------------------------------------------------------------------
# fused per-rule implementations (ported verbatim from the legacy module)


def _w_mean(spec, grads, state):
    n = _n_agents(grads)
    return jnp.full((n,), 1.0 / n)


def _mean_masked(spec, grads, mask, weights, state):
    """Exact weighted mean of the arrived rows (no imputation needed)."""
    _, w, _, tot = _masked_prelude(grads, mask, weights)
    return tree_weighted_sum(grads, w / tot)


def _w_cge(spec, grads, state):
    n, f = _n_agents(grads), spec.f
    norms = jnp.sqrt(tree_sqnorms(grads))
    _, idx = jax.lax.top_k(-norms, n - f)
    w = jnp.zeros((n,)).at[idx].set(1.0)
    return w / (n - f) if spec.hp("normalize", True) else w


def _w_cgc(spec, grads, state):
    n, f = _n_agents(grads), spec.f
    norms = jnp.sqrt(tree_sqnorms(grads))
    tau = jnp.sort(norms)[n - f - 1]
    w = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
    return w / n if spec.hp("normalize", True) else w


def _w_zeno(spec, grads, state):
    n, f = _n_agents(grads), spec.f
    v = state["server_grad"]
    rho = spec.hp("rho", 1e-3)
    lr = spec.hp("lr", 1.0)
    score = lr * tree_dot(grads, v) - rho * tree_sqnorms(grads)
    _, idx = jax.lax.top_k(score, n - f)
    return jnp.zeros((n,)).at[idx].set(1.0 / (n - f))


def _zeno_gather_state(spec, state):
    return {"server_grad": tree_stack_ravel(
        jax.tree.map(lambda l: l.astype(jnp.float32)[None],
                     state["server_grad"]))[0],
        **{k: v for k, v in spec.hyper if k in ("rho", "lr")}}


def _server_grad_zeros(proto):
    return {"server_grad": jax.tree.map(
        lambda l: jnp.zeros(l.shape, jnp.float32), proto)}


def _server_grad_ema(state, agg, ema):
    if not ema:
        return dict(state)             # externally-maintained v
    v = jax.tree.map(
        lambda s, a: (1.0 - ema) * s + ema * a.astype(jnp.float32),
        state["server_grad"], agg)
    return {**state, "server_grad": v}


def _zeno_init_state(spec, proto):
    if not spec.hp("ema", 0.0):
        # classic Zeno has no self-maintained state: with ema=0 the zeros
        # this returns would FREEZE and the defense silently degrades to
        # norm filtering.  Either set ema>0 (EMA of own aggregates) or
        # build the state dict yourself with a real validation gradient:
        # state = {"server_grad": v}.
        raise ValueError(
            "zeno with ema=0 needs an externally maintained validation "
            "gradient: pass state={'server_grad': v} yourself, or set "
            "ema>0 to self-maintain it from past aggregates")
    return _server_grad_zeros(proto)


def _zeno_update_state(spec, state, agg):
    return _server_grad_ema(state, agg, spec.hp("ema", 0.0))


def _w_krum(spec, grads, state):
    n = _n_agents(grads)
    d2 = _gram_to_d2(tree_gram(grads))
    s = D.krum_scores(d2, spec.f)
    return jax.nn.one_hot(jnp.argmin(s), n)


def _w_multi_krum(spec, grads, state):
    n = _n_agents(grads)
    m = spec.hp("m", 2)
    d2 = _gram_to_d2(tree_gram(grads))
    s = D.krum_scores(d2, spec.f)
    _, idx = jax.lax.top_k(-s, m)
    return jnp.zeros((n,)).at[idx].set(1.0 / m)


def _w_m_krum(spec, grads, state):
    n, f = _n_agents(grads), spec.f
    m = spec.hp("m", 2)
    d2 = _gram_to_d2(tree_gram(grads))
    # unrolled with a shrinking neighbour count (see D.krum_scores): the
    # fused path must select exactly the rows the dense reference selects
    mask = jnp.ones((n,), bool)
    w = jnp.zeros((n,))
    for it in range(m):
        s = D.krum_scores(d2, f, mask=mask, k=max(n - it - f - 2, 1))
        i = D.argmin_tiebreak(s, D.masked_row_sums(d2, mask))
        mask = mask.at[i].set(False)
        w = w.at[i].set(1.0 / m)
    return w


def _w_mda(spec, grads, state):
    n, f = _n_agents(grads), spec.f
    combos = mda_combos(n, f)
    d2 = _gram_to_d2(tree_gram(grads))
    sub = d2[combos[:, :, None], combos[:, None, :]]
    # equal-diameter ties broken by subset perimeter (permutation
    # invariance under elastic re-packing — see D.argmin_tiebreak)
    best = jnp.asarray(combos)[
        D.argmin_tiebreak(jnp.max(sub, axis=(1, 2)),
                          jnp.sum(sub, axis=(1, 2)))]
    return jnp.zeros((n,)).at[best].set(1.0 / (n - f))


# -- leaf-wise coordinate rules (fused path — exactly shardable) ------------
#
# Implemented natively on the N-d leaves (agent axis 0).  NO reshape to
# (n, -1): flattening merges sharded/unsharded dims and forces the SPMD
# partitioner to re-gather the whole gradient stack.  The sort itself still
# needs the agent axis local (one all-gather along the agent mesh axes) —
# that is the survey's inherent aggregation cost; everything else stays
# sharded.


def _mean_closest_nd(l, center, k):
    """Per-coordinate mean of the k values closest to ``center``."""
    dist = jnp.abs(l.astype(jnp.float32) - center[None].astype(jnp.float32))
    idx = jnp.argsort(dist, axis=0)[:k]
    vals = jnp.take_along_axis(l.astype(jnp.float32), idx, axis=0)
    return jnp.mean(vals, axis=0)


def _leafwise(spec, grads, state):
    name = spec.name
    native = spec.impl_hyper_dict.get("native_dtype")

    def leaf(l):
        n = l.shape[0]
        f = spec.f
        x = l if native else l.astype(jnp.float32)
        if name == "coordinate_median":
            out = jnp.median(x, axis=0)
        elif name == "trimmed_mean":
            b = trim_count(n, f, spec.hp("beta"))
            s = jnp.sort(x, axis=0)
            kept = s[b:n - b] if b else s
            # native_dtype: keep the mean in the exchange dtype too, else the
            # partitioner hoists the fp32 convert BEFORE the agent gather and
            # the halved-bytes exchange never materializes
            out = jnp.mean(kept if native else kept.astype(jnp.float32),
                           axis=0)
        elif name == "phocas":
            s = jnp.sort(x, axis=0)
            b = min(f, (n - 1) // 2)
            tm = jnp.mean((s[b:n - b] if b else s).astype(jnp.float32),
                          axis=0)
            out = _mean_closest_nd(x, tm, n - f)
        elif name == "mean_around_median":
            med = jnp.median(x.astype(jnp.float32), axis=0)
            out = _mean_closest_nd(x, med, n - f)
        elif name == "sign_sgd":
            # majority vote: the ±1/0 votes sum EXACTLY in fp32 for
            # n < 2^24, so this equals the dense/pallas paths bitwise
            out = jnp.sign(jnp.sum(jnp.sign(x).astype(jnp.float32),
                                   axis=0))
        else:
            raise KeyError(name)
        return out.astype(l.dtype)
    return jax.tree.map(leaf, grads)


# -- iterative rules on trees ----------------------------------------------


def tree_geometric_median(grads, iters: int = 32, eps: float = 1e-8):
    y = jax.tree.map(lambda l: jnp.mean(l.astype(jnp.float32), axis=0), grads)

    def body(y, _):
        diff_sq = tree_sqnorms(
            jax.tree.map(lambda l, c: l.astype(jnp.float32) - c[None], grads,
                         y))
        w = 1.0 / jnp.maximum(jnp.sqrt(diff_sq), eps)
        w = w / jnp.sum(w)
        y = jax.tree.map(
            lambda l: jnp.sum(
                l.astype(jnp.float32)
                * w.reshape((-1,) + (1,) * (l.ndim - 1)), axis=0),
            grads)
        return y, None
    y, _ = jax.lax.scan(body, y, None, length=iters)
    return jax.tree.map(lambda c, l: c.astype(l.dtype), y, grads)


def _t_geometric_median(spec, grads, state):
    return tree_geometric_median(
        grads, iters=spec.hp("iters", 32),
        eps=spec.hp("eps", spec.hp("nu", 1e-8)))


def tree_median_of_means(grads, f, num_groups=None, **gm_kw):
    n = _n_agents(grads)
    k = num_groups if num_groups else (min(n, 2 * f + 1) if f else n)
    while n % k:
        k += 1
    means = jax.tree.map(
        lambda l: jnp.mean(
            l.astype(jnp.float32).reshape((k, n // k) + l.shape[1:]), axis=1),
        grads)
    return tree_geometric_median(means, **gm_kw)


def _t_median_of_means(spec, grads, state):
    return tree_median_of_means(grads, spec.f,
                                num_groups=spec.hp("num_groups"))


def _bulyan_theta_select(d2, n, f, theta):
    """Bulyan's krum-based selection stage: (n,) bool mask of the theta
    rows picked.  Unrolled with a shrinking neighbour count (see
    D.krum_scores) so all theta selections are genuine — the scan version
    collapsed to index order after f + 2 picks.  Shared by the aggregate
    path and :meth:`AggregatorSpec.selection_weights` telemetry."""
    mask = jnp.ones((n,), bool)
    sel = jnp.zeros((n,), bool)
    for it in range(theta):
        s = D.krum_scores(d2, f, mask=mask, k=max(n - it - f - 2, 1))
        i = D.argmin_tiebreak(s, D.masked_row_sums(d2, mask))
        mask = mask.at[i].set(False)
        sel = sel.at[i].set(True)
    return sel


def tree_bulyan(grads, f):
    """Bulyan on trees: krum-based selection from the Gram matrix, then
    leaf-wise coordinate stage with a global selection mask."""
    n = _n_agents(grads)
    theta = n - 2 * f
    d2 = _gram_to_d2(tree_gram(grads))
    sel = _bulyan_theta_select(d2, n, f, theta)

    beta = max(theta - 2 * f, 1)

    def leaf(l):
        flat = l.astype(jnp.float32).reshape(n, -1)
        med = D._masked_median(flat, sel)
        big = jnp.asarray(jnp.inf, flat.dtype)
        dist = jnp.where(sel[:, None], jnp.abs(flat - med[None]), big)
        _, idx = jax.lax.top_k(-dist.T, beta)
        vals = jnp.take_along_axis(flat.T, idx, axis=1)
        return jnp.mean(vals, axis=1).reshape(l.shape[1:]).astype(l.dtype)
    return jax.tree.map(leaf, grads)


def _t_bulyan(spec, grads, state):
    return tree_bulyan(grads, spec.f)


# ---------------------------------------------------------------------------
# built-in registrations — survey Table 2 + Zeno (the registry IS the
# capability table; the legacy COORDWISE/WEIGHTED/ITERATIVE constants are
# derived views over these caps)

_T2 = ("table2",)

_register_plain(
    "mean",
    caps=AggregatorCaps(weight_decomposable=True, sharding_aware=True),
    dense_fn=D.mean, weights_fn=_w_mean, masked_fn=_mean_masked, tags=_T2)
_register_plain(
    "krum",
    caps=AggregatorCaps(weight_decomposable=True, sharding_aware=True,
                        pairwise=True),
    dense_fn=D.krum, weights_fn=_w_krum, tags=_T2)
_register_plain(
    "multi_krum",
    caps=AggregatorCaps(weight_decomposable=True, sharding_aware=True,
                        pairwise=True),
    hyper=("m",), gather=("m",),
    dense_fn=D.multi_krum, weights_fn=_w_multi_krum, tags=_T2)
_register_plain(
    "m_krum",
    caps=AggregatorCaps(weight_decomposable=True, sharding_aware=True,
                        pairwise=True),
    hyper=("m",), gather=("m",),
    dense_fn=D.m_krum, weights_fn=_w_m_krum, tags=_T2)
_register_plain(
    "mda",
    caps=AggregatorCaps(weight_decomposable=True, sharding_aware=True,
                        pairwise=True),
    dense_fn=D.mda, weights_fn=_w_mda, tags=_T2)
_register_plain(
    "cge",
    caps=AggregatorCaps(weight_decomposable=True, sharding_aware=True,
                        pairwise=True),
    hyper=("normalize",), gather=("normalize",),
    dense_fn=D.cge, weights_fn=_w_cge, tags=_T2)
_register_plain(
    "cgc",
    caps=AggregatorCaps(weight_decomposable=True, sharding_aware=True),
    hyper=("normalize",), gather=("normalize",),
    dense_fn=D.cgc, weights_fn=_w_cgc, tags=_T2)
_register_plain(
    "zeno",
    caps=AggregatorCaps(weight_decomposable=True, sharding_aware=True,
                        stateful=True),
    hyper=("rho", "lr", "ema"), state_keys=("server_grad",),
    dense_fn=D.zeno, weights_fn=_w_zeno, gather_state_fn=_zeno_gather_state,
    init_state=_zeno_init_state, update_state=_zeno_update_state, tags=_T2)
_register_plain(
    "coordinate_median",
    caps=AggregatorCaps(coordwise=True, sharding_aware=True),
    impl_keys=("native_dtype",),
    dense_fn=D.coordinate_median, tree_fn=_leafwise, tags=_T2)
_register_plain(
    "trimmed_mean",
    caps=AggregatorCaps(coordwise=True, sharding_aware=True),
    hyper=("beta",), gather=("beta",), impl_keys=("native_dtype",),
    dense_fn=D.trimmed_mean, tree_fn=_leafwise, tags=_T2)
_register_plain(
    "phocas",
    caps=AggregatorCaps(coordwise=True, sharding_aware=True),
    impl_keys=("native_dtype",),
    dense_fn=D.phocas, tree_fn=_leafwise, tags=_T2)
_register_plain(
    "mean_around_median",
    caps=AggregatorCaps(coordwise=True, sharding_aware=True),
    impl_keys=("native_dtype",),
    dense_fn=D.mean_around_median, tree_fn=_leafwise, tags=_T2)
_register_plain(
    "sign_sgd",
    caps=AggregatorCaps(coordwise=True, sharding_aware=True),
    impl_keys=("native_dtype",),
    dense_fn=D.sign_sgd, tree_fn=_leafwise, tags=("compressed",))
_register_plain(
    "geometric_median",
    caps=AggregatorCaps(iterative=True, sharding_aware=True),
    # "nu" kept as a legacy eps alias (the historical fused path accepted
    # it); the gather path forwards only the dense fn's real kwargs
    hyper=("iters", "eps", "nu"), gather=("iters", "eps"),
    dense_fn=D.geometric_median, tree_fn=_t_geometric_median, tags=_T2)
_register_plain(
    "rfa",
    caps=AggregatorCaps(iterative=True, sharding_aware=True),
    hyper=("iters", "nu", "eps"), gather=("iters", "nu"),
    dense_fn=D.rfa, tree_fn=_t_geometric_median, tags=_T2)
_register_plain(
    "median_of_means",
    caps=AggregatorCaps(iterative=True, sharding_aware=True),
    hyper=("num_groups",), gather=("num_groups",),
    dense_fn=D.median_of_means, tree_fn=_t_median_of_means, tags=_T2)
_register_plain(
    "bulyan",
    caps=AggregatorCaps(iterative=True, sharding_aware=True, pairwise=True),
    hyper=("base",), gather=("base",),
    # "meta" keeps bulyan out of the derived legacy ITERATIVE constant
    # (historically it was name-dispatched, not a member of that set)
    dense_fn=D.bulyan, tree_fn=_t_bulyan, tags=_T2 + ("meta",))


# ---------------------------------------------------------------------------
# delay-adaptive score filter (Zeno++ line) — registered SOLELY through the
# new API: one decorator, no capability constants, no dispatch chains.


def _zeno_pp_init_state(spec, proto):
    return _server_grad_zeros(proto)


def _zeno_pp_update_state(spec, state, agg):
    return _server_grad_ema(state, agg, spec.hp("ema", 0.2))


def _zeno_pp_weights(spec, grads, mask, weights, state):
    """The (n,) aggregation weights of the delay-adaptive score filter —
    shared by the custom aggregate path and ``spec.weights``."""
    n = _n_agents(grads)
    eps = spec.hp("eps", 1e-12)
    xi = spec.hp("xi", 0.5)
    if mask is None:
        mask = jnp.ones((n,), bool)
    mask, base_w, _, base_tot = _masked_prelude(grads, mask, weights)
    v = state["server_grad"]
    v_sq = jnp.maximum(tree_sqnorms(jax.tree.map(lambda l: l[None], v))[0],
                       0.0)
    g_norm = jnp.sqrt(jnp.maximum(tree_sqnorms(grads), eps))
    cos_v = tree_dot(grads, v) / (g_norm * jnp.sqrt(jnp.maximum(v_sq, eps)))
    # primary reference: the coordinate-wise median over ONLY the
    # delivered rows (order statistics with +/-inf padding — NO mean
    # imputation: the delivered mean is attacker-controlled, and imputing
    # with it would hand the adversary extra rows and flip the median) —
    # robust at EVERY step, including step 0 when v is still ~0.  The EMA
    # must never be the sole gatekeeper: it lags the true descent
    # direction (rejecting honest rows near convergence) and anything
    # that reaches the aggregate feeds back into it (self-poisoning).
    cnt_i = jnp.sum(mask).astype(jnp.int32)
    lo_i = jnp.maximum(cnt_i - 1, 0) // 2
    hi_i = cnt_i // 2

    def leaf_masked_median(l):
        m = mask.reshape((-1,) + (1,) * (l.ndim - 1))
        s = jnp.sort(jnp.where(m, l.astype(jnp.float32), jnp.inf), axis=0)
        return 0.5 * (jnp.take(s, lo_i, axis=0) + jnp.take(s, hi_i, axis=0))

    ref = jax.tree.map(leaf_masked_median, grads)
    ref_sq = tree_sqnorms(jax.tree.map(lambda l: l[None], ref))[0]
    cos_ref = tree_dot(grads, ref) / (
        g_norm * jnp.sqrt(jnp.maximum(ref_sq, eps)))
    disc = jnp.where(mask, base_w / jnp.maximum(jnp.max(base_w), eps), 0.0)
    thresh = xi * (1.0 - jnp.clip(disc, 0.0, 1.0))
    # norm-sanity gate (Zeno's rho||g||^2 penalty, made scale-free): near
    # convergence gradients are noise-dominated and alignment alone stops
    # discriminating — but a scaled attack still stands out by norm, so
    # rows farther than c_norm x the delivered rows' median norm are
    # rejected regardless of their cosine
    c_norm = spec.hp("c_norm", 2.5)
    s_norm = jnp.sort(jnp.where(mask, g_norm, jnp.inf))
    med_norm = 0.5 * (s_norm[lo_i] + s_norm[hi_i])
    sane = g_norm <= c_norm * med_norm
    # accept: delay-adaptive alignment with the instantaneous robust
    # reference, OR strong alignment (>= xi, the strictest threshold) with
    # the historically-honest EMA — the rescue path for stale rows whose
    # instantaneous alignment has rotated away
    rescue = (v_sq >= eps) & (cos_v >= xi)
    w = jnp.where(((cos_ref >= thresh) | rescue) & sane & mask,
                  base_w, 0.0)
    tot = jnp.sum(w)
    # fallback: discounted mean of the norm-sane delivered rows
    w_sane = jnp.where(sane & mask, base_w, 0.0)
    t_sane = jnp.sum(w_sane)
    fallback = jnp.where(t_sane > eps, w_sane / jnp.maximum(t_sane, eps),
                         base_w / base_tot)
    return jnp.where(tot > eps, w / jnp.maximum(tot, eps), fallback)


@register_aggregator(
    "zeno_pp",
    caps=AggregatorCaps(weight_decomposable=True, sharding_aware=True,
                        masked_capable=True, stateful=True),
    hyper=("xi", "ema", "eps", "c_norm"), state_keys=("server_grad",),
    weights_fn=lambda spec, grads, state: _zeno_pp_weights(
        spec, grads, None, None, state),
    init_state=_zeno_pp_init_state, update_state=_zeno_pp_update_state)
def zeno_pp(spec, grads, mask, weights, state):
    """Delay-adaptive Zeno++-style score filter.

    The PRIMARY acceptance test scores every delivered gradient against
    the coordinate-wise median of the delivered rows (a reference that is
    robust at every step, including step 0):

        accept_i  iff  cos(g_i, median) >= xi * (1 - w_i)

    where w_i in (0, 1] is the caller's staleness discount (1 = fresh):
    fresh gradients only need to be non-adversarial (threshold ~0), while
    very stale ones must align strongly with the current consensus
    direction — the Zeno++/Kardam insight that staleness and Byzantine
    corruption are the same hazard and the acceptance test must tighten
    with delay.

    The server additionally keeps a descent-direction estimate v (an EMA
    of its own past aggregates — the asynchronous analogue of Zeno's
    validation gradient) as a RESCUE path only: a row rejected by the
    instantaneous median test is still accepted if it aligns strongly
    (cos >= xi, the strictest threshold) with v.  The EMA is never the
    sole gatekeeper — it lags the true descent direction, and anything
    reaching the aggregate feeds back into it (self-poisoning).

    A norm-sanity gate (rows with ||g_i|| > c_norm x the delivered median
    norm are rejected regardless of cosine — Zeno's rho||g||^2 penalty
    made scale-free) covers the near-convergence regime where alignment
    stops discriminating.  Accepted gradients are averaged with their
    discounts; if nothing passes, the rule falls back to the discounted
    mean of the norm-sane rows (a pure-staleness step, never a frozen
    server)."""
    wn = _zeno_pp_weights(spec, grads, mask, weights, state)
    return tree_weighted_sum(grads, wn)


# ---------------------------------------------------------------------------
# defenses with memory (Karimireddy et al. line): iterative clipping around
# the carried server estimate, and the server-momentum composition wrapper.
# Both live on the same init_state/update_state protocol as zeno/zeno_pp —
# elastic respecialization and conformance coverage come free from the
# registry.


def _cclip_center(state, grads):
    """``state["server_grad"]`` shaped like one row of ``grads``: when the
    caller works on a bare (n, d)/(n, P) stack (conformance probes, the
    flat arena) but the carried center is a pytree, ravel it once (the
    ``_zeno_gather_state`` pattern)."""
    v = state["server_grad"]
    if hasattr(grads, "ndim"):
        leaves = jax.tree.leaves(v)
        if len(leaves) == 1 and leaves[0].ndim == grads.ndim - 1:
            return leaves[0].astype(jnp.float32)
        return tree_stack_ravel(jax.tree.map(
            lambda l: l.astype(jnp.float32)[None], v))[0]
    return jax.tree.map(lambda c: c.astype(jnp.float32), v)


def _cclip_iterate(spec, grads, mask, weights, state):
    """The centered-clipping fixed point on a gradient pytree (or bare
    stack): ``iters`` rounds of

        v <- v + sum_i w_i min(1, tau/||g_i - v||) (g_i - v) / sum_i w_i

    starting from the CARRIED center.  Absent rows are where-gated to an
    exact 0 before the norm (departed-content invariance: inf/NaN garbage
    in a dead row cannot reach the distance, the clip or the sum).
    Returns ``(v_final fp32 tree, lam_last (n,))`` — lam_last are the
    final iteration's effective clip weights, the telemetry signal."""
    n = _n_agents(grads)
    tau = spec.hp("tau", 1.0)
    iters = spec.hp("iters", 5)
    m = jnp.ones((n,), bool) if mask is None else mask.astype(bool)
    m, w, _, tot = _masked_prelude(grads, m, weights)
    wn = w / tot
    v0 = _cclip_center(state, grads)

    def lam_of(v):
        diff = jax.tree.map(
            lambda l, c: jnp.where(
                m.reshape((-1,) + (1,) * (l.ndim - 1)),
                l.astype(jnp.float32) - c.astype(jnp.float32)[None], 0.0),
            grads, v)
        dist = jnp.sqrt(jnp.maximum(tree_sqnorms(diff), 1e-30))
        return wn * jnp.minimum(1.0, tau / dist), diff

    def body(_, v):
        lam, diff = lam_of(v)
        return jax.tree.map(
            lambda vv, dd: vv + jnp.sum(
                dd * lam.reshape((-1,) + (1,) * (dd.ndim - 1)), axis=0),
            v, diff)

    v = jax.lax.fori_loop(0, iters, body, v0)
    lam, _ = lam_of(v)
    return v, lam


def _cclip_flat(spec, stack, mask, weights, state, qscale=None):
    """centered_clip on the (n, P) arena.  The per-iteration clip radius
    needs full-row norms (a cross-tile reduction), so the scalar stage is
    jnp; the model-sized multiply-accumulate rides the fused
    clipped-weighted-sum kernel (repro.kernels.wsum) under
    ``impl="pallas"``."""
    n, P = stack.shape
    if qscale is not None:
        from repro.core.flat import dequantize_rows
        xf = dequantize_rows(stack, qscale)
    else:
        xf = _flat_f32(stack)
    m = jnp.ones((n,), bool) if mask is None else mask.astype(bool)
    m, w, _, tot = _masked_prelude(stack, m, weights)
    wn = w / tot
    tau = spec.hp("tau", 1.0)
    iters = spec.hp("iters", 5)
    v0 = _cclip_center(state, xf)

    def lam_of(v):
        diff = jnp.where(m[:, None], xf - v[None], 0.0)
        dist = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=1), 1e-30))
        return wn * jnp.minimum(1.0, tau / dist), diff

    use_kernel = spec.impl == "pallas"
    if use_kernel:
        from repro.kernels import clipped_weighted_sum, default_interpret
        from repro.kernels.tiling import TILE_D
        use_kernel = P % TILE_D == 0

    def body(_, v):
        lam, diff = lam_of(v)
        if use_kernel:
            return clipped_weighted_sum(lam, xf, v,
                                        interpret=default_interpret())
        return v + jnp.sum(diff * lam[:, None], axis=0)

    return jax.lax.fori_loop(0, iters, body, v0)


def _cclip_init_state(spec, proto):
    return _server_grad_zeros(proto)


def _cclip_update_state(spec, state, agg):
    # ema=1 (default): the center IS the last aggregate — Karimireddy et
    # al.'s v_{t} = agg_t; smaller ema trails it
    return _server_grad_ema(state, agg, spec.hp("ema", 1.0))


@register_aggregator(
    "centered_clip",
    caps=AggregatorCaps(iterative=True, sharding_aware=True,
                        masked_capable=True, stateful=True),
    hyper=("tau", "iters", "ema"), state_keys=("server_grad",),
    flat_fn=_cclip_flat,
    init_state=_cclip_init_state, update_state=_cclip_update_state,
    tags=("memory",))
def centered_clip(spec, grads, mask, weights, state):
    """Centered clipping (Karimireddy et al.): iteratively re-clip every
    row around the CARRIED server estimate v (an EMA of past aggregates),
    so a perturbation small enough to pass one round still cannot bias
    the aggregate by more than tau per step — the history-aware answer to
    ALIE/IPM-style inside-the-spread attacks.  The clip saturates: beyond
    f the adversary gains rows, never magnitude.  Masked rows contribute
    exact zeros (own masked law — no mean imputation: an imputed row
    would drag v toward the attacker-controlled delivered mean)."""
    v, _ = _cclip_iterate(spec, grads, mask, weights, state)
    return v


# ---------------------------------------------------------------------------
# compressed robust exchange: sparse/dropout per-coordinate weighting.  A
# zero coordinate means NOT SENT (the fed_dropout_avg convention), so the
# aggregate averages each coordinate over (coord_sent) * weight — per-
# coordinate weights, which the impute-then-scale masked law cannot
# express; hence custom_fn (tree) + flat_fn (arena) instead of the
# generic engine paths.


def _sparse_row_weights(n, mask, weights):
    """(n,) fp32 row weights with the mask folded in (dead rows -> 0)."""
    m = (jnp.ones((n,), bool) if mask is None
         else mask.astype(bool)).astype(jnp.float32)
    return m if weights is None else weights.astype(jnp.float32) * m


def _sparse_mean_law(xf, cw):
    """agg_c = sum_i cw_ic x_ic / sum_i cw_ic, explicit 0 where the
    denominator is 0 (nobody sent the coordinate — never an eps-scaled
    garbage row).  The where-gate keeps 0 * non-finite == 0 exactly, so
    dead-row garbage cannot leak through a zero weight."""
    num = jnp.sum(jnp.where(cw > 0, xf, 0.0) * cw, axis=0)
    den = jnp.sum(cw, axis=0)
    return jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)


def _sparse_mean_flat(spec, stack, mask, weights, state, qscale=None):
    """sparse_mean on the (n, P) arena.  ``impl="pallas"`` runs the
    sparse kernel (repro.kernels.wsum) with sent-detection on the native
    codes and, for quantized arenas, in-tile dequantization — no
    dequantized (n, P) copy; other impls apply the jnp law."""
    n = stack.shape[0]
    w = _sparse_row_weights(n, mask, weights)
    if spec.impl == "pallas":
        from repro.kernels import (pallas_masked_aggregate,
                                   pallas_scaled_masked_aggregate)
        m = (jnp.ones((n,), jnp.float32) if mask is None
             else mask.astype(jnp.float32))
        if qscale is not None:
            return pallas_scaled_masked_aggregate(
                "sparse_mean", stack, qscale, m, w, spec.f, spec.hyper)
        return pallas_masked_aggregate(
            "sparse_mean", stack, m, w, spec.f, spec.hyper)
    if qscale is not None:
        from repro.core.flat import dequantize_rows
        xf = dequantize_rows(stack, qscale)
    else:
        xf = _flat_f32(stack)
    cw = (xf != 0).astype(jnp.float32) * w[:, None]
    return _sparse_mean_law(xf, cw)


@register_aggregator(
    "sparse_mean",
    caps=AggregatorCaps(coordwise=True, sharding_aware=True),
    flat_fn=_sparse_mean_flat, tags=("compressed",))
def sparse_mean(spec, grads, mask, weights, state):
    """Sparse/dropout-aware weighted mean (tree path; see
    :func:`repro.core.filters.dense.sparse_mean` for the unit-weight
    dense oracle).  Per-coordinate weights are ``(coord_sent) * w_i``
    with ``w_i`` the caller's per-agent weight (dataset size, staleness
    discount) zeroed on masked-out rows; coordinates nobody sent yield
    an explicit zero update."""
    n = _n_agents(grads)
    w = _sparse_row_weights(n, mask, weights)
    if spec.impl == "pallas":
        # the law is per-coordinate, so the tree splits EXACTLY into
        # per-dtype (n, -1) segments riding the fused sparse kernel —
        # sent-detection and weighting stay inside the tile (no (n, d)
        # where/select materialized; jaxpr-gated by the parity suite)
        from repro.kernels import pallas_masked_aggregate
        m = (jnp.ones((n,), jnp.float32) if mask is None
             else mask.astype(jnp.float32))
        flat_leaves, treedef = jax.tree.flatten(grads)
        by_dtype = {}
        for i, l in enumerate(flat_leaves):
            by_dtype.setdefault(jnp.dtype(l.dtype), []).append(i)
        outs = [None] * len(flat_leaves)
        for dt, idxs in by_dtype.items():
            seg = jnp.concatenate(
                [flat_leaves[i].reshape(n, -1) for i in idxs], axis=1)
            vec = pallas_masked_aggregate("sparse_mean", seg, m, w,
                                          spec.f, spec.hyper)
            off = 0
            for i in idxs:
                sz = flat_leaves[i][0].size
                outs[i] = (vec[off:off + sz].astype(dt)
                           .reshape(flat_leaves[i].shape[1:]))
                off += sz
        return jax.tree.unflatten(treedef, outs)

    def leaf(l):
        xf = l.astype(jnp.float32)
        wl = w.reshape((-1,) + (1,) * (l.ndim - 1))
        cw = (xf != 0).astype(jnp.float32) * wl
        return _sparse_mean_law(xf, cw).astype(l.dtype)
    return jax.tree.map(leaf, grads)


# ---------------------------------------------------------------------------
# composition wrappers — specs that transform, then delegate to spec.inner


def _clip_fn(spec, grads, mask, weights, state):
    """Pre-aggregation norm clipping (static-radius centered clipping):
    every row is scaled to ||g_i|| <= tau before the inner rule runs."""
    tau = spec.hp("tau", 1.0)
    norms = jnp.sqrt(jnp.maximum(tree_sqnorms(grads), 1e-30))
    scale = jnp.minimum(1.0, tau / norms)
    clipped_g = jax.tree.map(
        lambda l: (l.astype(jnp.float32)
                   * scale.reshape((-1,) + (1,) * (l.ndim - 1))
                   ).astype(l.dtype), grads)
    return spec.inner.aggregate(clipped_g, mask=mask, weights=weights,
                                state=_inner_state(spec, state))


def _bucket_fn(spec, grads, mask, weights, state):
    """Pre-aggregation bucketing (median-of-means stage 1): group-mean the
    rows in consecutive buckets of ``group_size`` before the inner rule —
    synchronous delivery only (bucket membership is static)."""
    if mask is not None or weights is not None:
        raise ValueError("bucketed: masked aggregation not supported "
                         "(bucket membership is static)")
    gs = spec.hp("group_size", 2)
    n = _n_agents(grads)
    if n % gs:
        raise ValueError(f"bucketed: n={n} not divisible by "
                         f"group_size={gs}")
    k = n // gs

    def leaf(l):
        return jnp.mean(
            l.astype(jnp.float32).reshape((k, gs) + l.shape[1:]),
            axis=1).astype(l.dtype)
    means = jax.tree.map(leaf, grads)
    f_eff = min(spec.inner.f, max((k - 1) // 2, 0))
    return spec.inner.with_f(f_eff).aggregate(
        means, state=_inner_state(spec, state))


def staleness_discount_table(s, weighting: str = "poly",
                             power: float = 1.0, gamma: float = 0.7):
    """Staleness rounds -> discount multipliers (Kardam/Zeno++ line):
    ``none`` -> 1, ``poly`` -> (1+s)^-power, ``exp`` -> gamma^s.  Plain
    operators, so it works on NumPy float64 (host-side trace planning)
    and jnp float32 (in-trace) alike — THE one copy of the table."""
    if weighting == "none":
        return s * 0.0 + 1.0
    if weighting == "poly":
        return (1.0 + s) ** (-power)
    if weighting == "exp":
        return gamma ** s
    raise KeyError(weighting)


def _staleness_fn(spec, grads, mask, weights, state):
    """Staleness discounting as a spec: ``weights`` here are raw staleness
    ROUND COUNTS s_i >= 0 (not multipliers); the wrapper converts them to
    the Kardam/Zeno++-line discounts and delegates."""
    s = (jnp.zeros((_n_agents(grads),), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    w = staleness_discount_table(s, spec.hp("weighting", "poly"),
                                 spec.hp("power", 1.0),
                                 spec.hp("gamma", 0.7))
    return spec.inner.aggregate(grads, mask=mask, weights=w,
                                state=_inner_state(spec, state))


def _inner_state(spec, state):
    if spec.inner is not None and spec.inner.stateful:
        return (state or {}).get("inner")
    return None


def _server_momentum_fn(spec, grads, mask, weights, state):
    """Server momentum as a composition wrapper (the survey's history
    filter): the emitted update is an EMA of the inner rule's aggregates,

        out_t = beta * m_{t-1} + (1 - beta) * inner(g_t),   m_t = out_t

    so a single poisoned round moves the served direction by at most
    (1 - beta) of the inner rule's error, and round-to-round sign flips
    (the classic way adaptive attacks whipsaw one-shot rules) average
    out.  Wraps ANY registered rule; state nests the inner rule's own
    memory under ``state["inner"]`` like every other wrapper."""
    beta = spec.hp("beta", 0.9)
    inner = spec.inner.aggregate(grads, mask=mask, weights=weights,
                                 state=_inner_state(spec, state))
    m = state["server_grad"]
    return jax.tree.map(
        lambda mm, a: (beta * mm.astype(jnp.float32)
                       + (1.0 - beta) * a.astype(jnp.float32)), m, inner)


def _server_momentum_init(spec, proto):
    return _server_grad_zeros(proto)


def _server_momentum_update(spec, state, agg):
    # the momentum buffer IS the emitted update (out_t above)
    return _server_grad_ema(state, agg, 1.0)


register_aggregator(
    "clipped",
    caps=AggregatorCaps(masked_capable=True, sharding_aware=True),
    hyper=("tau",), is_wrapper=True)(_clip_fn)
register_aggregator(
    "bucketed",
    caps=AggregatorCaps(masked_capable=False, sharding_aware=True),
    hyper=("group_size",), is_wrapper=True)(_bucket_fn)
register_aggregator(
    "staleness_discounted",
    caps=AggregatorCaps(masked_capable=True, sharding_aware=True,
                        staleness_aware=True),
    hyper=("weighting", "power", "gamma"), is_wrapper=True)(_staleness_fn)
register_aggregator(
    "server_momentum",
    caps=AggregatorCaps(masked_capable=True, sharding_aware=True,
                        stateful=True),
    hyper=("beta",), state_keys=("server_grad",),
    init_state=_server_momentum_init,
    update_state=_server_momentum_update,
    is_wrapper=True)(_server_momentum_fn)


def clipped(inner: AggregatorSpec, tau: float = 1.0) -> AggregatorSpec:
    return make_spec("clipped", f=inner.f, inner=inner, tau=tau)


def bucketed(inner: AggregatorSpec, group_size: int = 2) -> AggregatorSpec:
    return make_spec("bucketed", f=inner.f, inner=inner,
                     group_size=group_size)


def staleness_discounted(inner: AggregatorSpec, weighting: str = "poly",
                         power: float = 1.0,
                         gamma: float = 0.7) -> AggregatorSpec:
    return make_spec("staleness_discounted", f=inner.f, inner=inner,
                     weighting=weighting, power=power, gamma=gamma)


def server_momentum(inner: AggregatorSpec,
                    beta: float = 0.9) -> AggregatorSpec:
    return make_spec("server_momentum", f=inner.f, inner=inner, beta=beta)


__all__ = [
    "AggregatorCaps", "AggregatorDef", "AggregatorSpec",
    "AggregatorDeprecationWarning", "REGISTRY", "register_aggregator",
    "get_aggregator_def", "list_aggregators", "make_spec", "warn_once",
    "pallas_available", "ElasticN", "FlatPlan", "FracF", "elastic", "frac",
    "clipped", "bucketed", "staleness_discounted", "server_momentum",
    "tree_stack_ravel", "tree_unravel_like", "tree_sqnorms", "tree_gram",
    "tree_dot", "tree_weighted_sum", "tree_where_agents",
    "tree_geometric_median", "tree_median_of_means", "tree_bulyan",
    "mda_combos", "trim_count",
]
