"""FlatPlan: the precomputed ravel/unravel plan of the zero-copy flat
aggregation pipeline.

Every dense aggregation path (``impl="gather"``, ``impl="pallas"``) works on
the raveled (n, P) gradient stack, but the legacy engine rebuilt the
flattening *inside every aggregation call*: ``tree_stack_ravel`` re-derived
each leaf's size and re-concatenated the model-sized stack per call, and
``tree_unravel_like`` recomputed ``np.prod`` offsets per call inside traced
code.  At model scale that is pure memory traffic and trace-time overhead on
the hottest path in the system (the survey's per-step aggregation tax).

A :class:`FlatPlan` hoists all of that to plan time:

* leaf offsets / trailing shapes / dtypes are computed ONCE per tree
  structure (cached on ``(treedef, shapes, dtypes)`` — a dict probe on
  every later call, including calls inside jit traces);
* :meth:`FlatPlan.ravel` builds the (n, P) arena with one concatenate —
  the training loops call it once per step at gradient-production time and
  thread the arena through the jitted step (donated on TPU backends);
* :meth:`FlatPlan.unravel` splits the aggregate back into the parameter
  tree exactly once, at optimizer-apply — never inside the aggregation
  dispatch.

The arena dtype is the tree's uniform leaf dtype when one exists (so
``agg_dtype`` exchange-compression survives the flattening) and fp32
otherwise; per-coordinate arithmetic is unchanged either way, so the flat
pipeline is bit-for-bit with the per-call ravel it replaces.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class FlatPlan:
    """Ravel/unravel plan for a pytree with a leading agent axis.

    ``shapes``/``dtypes`` describe the per-leaf TRAILING dims (agent axis
    stripped); ``offsets[i]:offsets[i] + sizes[i]`` is leaf i's slice of
    the (n, P) arena; ``total`` is P.  Frozen and hashable, so plans pass
    freely through jit closures as statics."""
    treedef: Any
    shapes: tuple
    dtypes: tuple
    offsets: tuple
    sizes: tuple
    total: int
    uniform_dtype: Optional[Any]

    @staticmethod
    def for_tree(tree) -> "FlatPlan":
        """The (cached) plan of ``tree``, whose leaves carry a leading
        agent axis.  Works on tracers — only shapes/dtypes are read."""
        leaves, treedef = jax.tree.flatten(tree)
        return _plan(treedef,
                     tuple(tuple(l.shape[1:]) for l in leaves),
                     tuple(jnp.dtype(l.dtype).name for l in leaves))

    @staticmethod
    def for_proto(proto) -> "FlatPlan":
        """The plan of a SINGLE-AGENT prototype (no leading agent axis)."""
        leaves, treedef = jax.tree.flatten(proto)
        return _plan(treedef,
                     tuple(tuple(l.shape) for l in leaves),
                     tuple(jnp.dtype(l.dtype).name for l in leaves))

    @property
    def arena_dtype(self):
        """Dtype of the (n, P) arena :meth:`ravel` builds: the uniform
        leaf dtype when there is one (exchange compression survives),
        fp32 otherwise (the dense engine contract)."""
        return (jnp.dtype(self.uniform_dtype) if self.uniform_dtype
                else jnp.float32)

    def ravel(self, tree, dtype=None):
        """(pytree with leading n) -> one (n, P) arena (ONE concatenate)."""
        leaves = jax.tree.leaves(tree)
        n = leaves[0].shape[0]
        dt = jnp.dtype(dtype) if dtype is not None else self.arena_dtype
        return jnp.concatenate(
            [l.reshape(n, -1).astype(dt) for l in leaves], axis=1)

    def unravel(self, vec):
        """(P,) -> single-agent pytree (leaf dtypes restored)."""
        out = [jax.lax.slice(vec, (o,), (o + s,)).reshape(shp).astype(dt)
               for o, s, shp, dt in zip(self.offsets, self.sizes,
                                        self.shapes, self.dtypes)]
        return jax.tree.unflatten(self.treedef, out)

    def unravel_stack(self, arena):
        """(n, P) -> pytree with leading n (leaf dtypes restored)."""
        n = arena.shape[0]
        out = [jax.lax.slice(arena, (0, o), (n, o + s))
               .reshape((n,) + shp).astype(dt)
               for o, s, shp, dt in zip(self.offsets, self.sizes,
                                        self.shapes, self.dtypes)]
        return jax.tree.unflatten(self.treedef, out)


# ---------------------------------------------------------------------------
# wire quantization: per-row symmetric codes + fp32 scale sidecar
#
# The compressed-exchange path (ByzantineConfig.agg_dtype in QUANT_DTYPES)
# quantizes the fp32 arena right after ravel — each agent row gets its own
# scale (rows are per-agent messages; one outlier agent must not crush
# everyone else's resolution) — and the kernels dequantize INSIDE the tile,
# so the (n, P) dequantized copy is never materialized (jaxpr-gated in
# tests/test_kernels_parity.py).  qmax is the symmetric code range: 127 for
# int8, 448 for float8_e4m3fn (its largest finite value).

QUANT_DTYPES = {"int8": 127.0}
if hasattr(jnp, "float8_e4m3fn"):
    QUANT_DTYPES["float8_e4m3fn"] = 448.0


def quantize_rows(x, dtype):
    """fp32 (n, P) -> (codes (n, P) ``dtype``, scale (n,) fp32), per-row
    symmetric: ``scale_i = amax_i / qmax`` (1.0 for an all-zero row, so
    dequantization never divides by zero), ``codes = x / scale`` rounded
    (integer dtypes) or cast (fp8).  ``dequantize_rows(codes, scale)``
    reconstructs within one code step."""
    name = jnp.dtype(dtype).name
    qmax = QUANT_DTYPES[name]
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=1)
    scale = jnp.where(amax > 0.0, amax / qmax, 1.0)
    codes = xf / scale[:, None]
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        codes = jnp.clip(jnp.round(codes), -qmax, qmax)
    return codes.astype(dtype), scale


def dequantize_rows(codes, scale):
    """(codes (n, P), scale (n,) fp32) -> fp32 (n, P).  The reference
    arithmetic for the in-tile dequantization — the scaled kernels compute
    exactly ``codes.astype(f32) * scale[:, None]`` per VMEM block, so this
    host-visible version is the bit-for-bit parity oracle."""
    return codes.astype(jnp.float32) * scale[:, None]


def fake_quantize(x, dtype):
    """Quantize-dequantize round trip: the fp32 stack every NON-flat
    consumer (tree fallbacks, telemetry weights on gather) sees, so the
    compressed run's semantics do not depend on which path executed."""
    codes, scale = quantize_rows(x, dtype)
    return dequantize_rows(codes, scale)


@functools.lru_cache(maxsize=None)
def _plan(treedef, shapes, dtypes) -> FlatPlan:
    sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    uniform = dtypes[0] if len(set(dtypes)) == 1 else None
    return FlatPlan(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    offsets=offsets, sizes=sizes,
                    total=int(sum(sizes)), uniform_dtype=uniform)


__all__ = ["FlatPlan", "QUANT_DTYPES", "quantize_rows", "dequantize_rows",
           "fake_quantize"]
