"""Robust aggregation over *pytree* gradients — the bridge between the dense
filter catalogue (survey Table 2) and real model training.

Two implementations, both exact w.r.t. :mod:`repro.core.filters.dense`:

``impl="gather"`` — paper-faithful: ravel every agent's gradient pytree into
one (n, P) stack and run the dense filter.  This is what the surveyed systems
do (the server holds n full update vectors); under SPMD it forces an
all-gather of the full gradient stack along the agent axis.

``impl="fused"`` — beyond-paper decomposition: every non-coordinate-wise
filter in the survey factors into  (global scalar statistics) -> (per-agent
weights w in R^n) -> (weighted sum per leaf).  The statistics (sq-norms,
Gram matrix) are tree-sums of per-leaf contractions, so under SPMD only n or
n^2 *scalars* cross the machine instead of n full gradients; coordinate-wise
filters apply leaf-wise (they are exactly shardable).  See EXPERIMENTS.md
§Perf for the measured collective-byte impact.
"""
from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filters import dense as D

COORDWISE = {"coordinate_median", "trimmed_mean", "phocas",
             "mean_around_median"}
WEIGHTED = {"mean", "krum", "multi_krum", "m_krum", "cge", "cgc", "mda",
            "zeno"}
ITERATIVE = {"geometric_median", "rfa", "median_of_means"}


# ---------------------------------------------------------------------------
# tree helpers (agent axis = leading axis of every leaf)


def tree_stack_ravel(grads):
    """(pytree with leading n) -> (n, P) dense stack."""
    leaves = jax.tree.leaves(grads)
    n = leaves[0].shape[0]
    return jnp.concatenate([l.reshape(n, -1) for l in leaves], axis=1)


def tree_unravel_like(vec, proto):
    """(P,) -> pytree shaped like one agent's grads (proto has leading n)."""
    leaves, treedef = jax.tree.flatten(proto)
    out, off = [], 0
    for l in leaves:
        size = int(np.prod(l.shape[1:], dtype=np.int64))
        out.append(vec[off:off + size].reshape(l.shape[1:]).astype(l.dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


def tree_sqnorms(grads):
    """Per-agent squared norms, accumulated leaf-wise: (n,) fp32.

    NO reshapes: flattening (n, d1, d2, ...) -> (n, -1) merges sharded and
    unsharded dims, which forces the SPMD partitioner to regroup (gather)
    the whole stack.  Axis-tuple reductions keep the contraction local +
    one tiny psum."""
    def leaf(l):
        axes = tuple(range(1, l.ndim))
        return jnp.sum(jnp.square(l.astype(jnp.float32)), axis=axes)
    return functools.reduce(jnp.add, [leaf(l) for l in jax.tree.leaves(grads)])


def tree_gram(grads):
    """Pairwise inner products, accumulated leaf-wise: (n, n) fp32
    (multi-dim tensordot — sharding-preserving, no reshape)."""
    def leaf(l):
        axes = tuple(range(1, l.ndim))
        return jnp.tensordot(l.astype(jnp.float32), l.astype(jnp.float32),
                             axes=(axes, axes))
    return functools.reduce(jnp.add, [leaf(l) for l in jax.tree.leaves(grads)])


def tree_dot(grads, vec_tree):
    """<g_i, v> per agent: (n,) fp32 (sharding-preserving)."""
    def leaf(l, v):
        axes = tuple(range(1, l.ndim))
        return jnp.tensordot(l.astype(jnp.float32), v.astype(jnp.float32),
                             axes=(axes, tuple(range(v.ndim))))
    return functools.reduce(
        jnp.add, jax.tree.leaves(jax.tree.map(leaf, grads, vec_tree)))


def tree_weighted_sum(grads, w):
    """sum_i w_i * g_i per leaf."""
    def leaf(l):
        wl = w.astype(jnp.float32).reshape((-1,) + (1,) * (l.ndim - 1))
        return jnp.sum(l.astype(jnp.float32) * wl, axis=0).astype(l.dtype)
    return jax.tree.map(leaf, grads)


def _gram_to_d2(gram):
    sq = jnp.diag(gram)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)


# ---------------------------------------------------------------------------
# per-agent weight computation (fused path)


def filter_weights(name, grads, f, **hyper):
    """Return w: (n,) such that filter(g) == sum_i w_i g_i (exactly)."""
    n = jax.tree.leaves(grads)[0].shape[0]
    if name == "mean":
        return jnp.full((n,), 1.0 / n)
    if name in ("cge", "cgc"):
        norms = jnp.sqrt(tree_sqnorms(grads))
        if name == "cge":
            _, idx = jax.lax.top_k(-norms, n - f)
            w = jnp.zeros((n,)).at[idx].set(1.0)
            return w / (n - f) if hyper.get("normalize", True) else w
        tau = jnp.sort(norms)[n - f - 1]
        w = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
        return w / n if hyper.get("normalize", True) else w
    if name == "zeno":
        v = hyper["server_grad"]
        rho = hyper.get("rho", 1e-3)
        lr = hyper.get("lr", 1.0)
        score = lr * tree_dot(grads, v) - rho * tree_sqnorms(grads)
        _, idx = jax.lax.top_k(score, n - f)
        return jnp.zeros((n,)).at[idx].set(1.0 / (n - f))
    # distance-based: need the Gram matrix (n^2 scalars)
    d2 = _gram_to_d2(tree_gram(grads))
    if name == "krum":
        s = D.krum_scores(d2, f)
        return jax.nn.one_hot(jnp.argmin(s), n)
    if name == "multi_krum":
        m = hyper.get("m", 2)
        s = D.krum_scores(d2, f)
        _, idx = jax.lax.top_k(-s, m)
        return jnp.zeros((n,)).at[idx].set(1.0 / m)
    if name == "m_krum":
        m = hyper.get("m", 2)

        def body(carry, _):
            mask, w = carry
            s = D.krum_scores(d2, f, mask=mask)
            i = jnp.argmin(s)
            return (mask.at[i].set(False), w.at[i].set(1.0 / m)), None
        (_, w), _ = jax.lax.scan(
            body, (jnp.ones((n,), bool), jnp.zeros((n,))), None, length=m)
        return w
    if name == "mda":
        combos = np.asarray(list(itertools.combinations(range(n), n - f)))
        sub = d2[combos[:, :, None], combos[:, None, :]]
        best = jnp.asarray(combos)[jnp.argmin(jnp.max(sub, axis=(1, 2)))]
        return jnp.zeros((n,)).at[best].set(1.0 / (n - f))
    raise KeyError(name)


# ---------------------------------------------------------------------------
# leaf-wise coordinate filters (fused path — exactly shardable)
#
# Implemented natively on the N-d leaves (agent axis 0).  NO reshape to
# (n, -1): flattening merges sharded/unsharded dims and forces the SPMD
# partitioner to re-gather the whole gradient stack.  The sort itself still
# needs the agent axis local (one all-gather along the agent mesh axes) —
# that is the survey's inherent aggregation cost; everything else stays
# sharded.


def _mean_closest_nd(l, center, k):
    """Per-coordinate mean of the k values closest to ``center``."""
    dist = jnp.abs(l.astype(jnp.float32) - center[None].astype(jnp.float32))
    idx = jnp.argsort(dist, axis=0)[:k]
    vals = jnp.take_along_axis(l.astype(jnp.float32), idx, axis=0)
    return jnp.mean(vals, axis=0)


def _leafwise(name, grads, f, **hyper):
    def leaf(l):
        n = l.shape[0]
        x = l if hyper.get("native_dtype") else l.astype(jnp.float32)
        if name == "coordinate_median":
            out = jnp.median(x, axis=0)
        elif name == "trimmed_mean":
            import numpy as _np
            beta = hyper.get("beta")
            b = int(_np.ceil((beta if beta is not None else f / n) * n))
            b = min(b, (n - 1) // 2)
            s = jnp.sort(x, axis=0)
            kept = s[b:n - b] if b else s
            # native_dtype: keep the mean in the exchange dtype too, else the
            # partitioner hoists the fp32 convert BEFORE the agent gather and
            # the halved-bytes exchange never materializes
            out = jnp.mean(
                kept if hyper.get("native_dtype")
                else kept.astype(jnp.float32), axis=0)
        elif name == "phocas":
            s = jnp.sort(x, axis=0)
            b = min(f, (n - 1) // 2)
            tm = jnp.mean((s[b:n - b] if b else s).astype(jnp.float32),
                          axis=0)
            out = _mean_closest_nd(x, tm, n - f)
        elif name == "mean_around_median":
            med = jnp.median(x.astype(jnp.float32), axis=0)
            out = _mean_closest_nd(x, med, n - f)
        else:
            raise KeyError(name)
        return out.astype(l.dtype)
    return jax.tree.map(leaf, grads)


# ---------------------------------------------------------------------------
# iterative filters on trees


def tree_geometric_median(grads, iters: int = 32, eps: float = 1e-8):
    y = jax.tree.map(lambda l: jnp.mean(l.astype(jnp.float32), axis=0), grads)

    def body(y, _):
        diff_sq = tree_sqnorms(
            jax.tree.map(lambda l, c: l.astype(jnp.float32) - c[None], grads,
                         y))
        w = 1.0 / jnp.maximum(jnp.sqrt(diff_sq), eps)
        w = w / jnp.sum(w)
        y = jax.tree.map(
            lambda l: jnp.sum(
                l.astype(jnp.float32)
                * w.reshape((-1,) + (1,) * (l.ndim - 1)), axis=0),
            grads)
        return y, None
    y, _ = jax.lax.scan(body, y, None, length=iters)
    return jax.tree.map(lambda c, l: c.astype(l.dtype), y, grads)


def tree_median_of_means(grads, f, num_groups=None, **gm_kw):
    n = jax.tree.leaves(grads)[0].shape[0]
    k = num_groups if num_groups else (min(n, 2 * f + 1) if f else n)
    while n % k:
        k += 1
    means = jax.tree.map(
        lambda l: jnp.mean(
            l.astype(jnp.float32).reshape((k, n // k) + l.shape[1:]), axis=1),
        grads)
    return tree_geometric_median(means, **gm_kw)


def tree_bulyan(grads, f, **hyper):
    """Bulyan on trees: krum-based selection from the Gram matrix, then
    leaf-wise coordinate stage with a global selection mask."""
    n = jax.tree.leaves(grads)[0].shape[0]
    theta = n - 2 * f
    d2 = _gram_to_d2(tree_gram(grads))

    def body(carry, _):
        mask, sel = carry
        s = D.krum_scores(d2, f, mask=mask)
        i = jnp.argmin(s)
        return (mask.at[i].set(False), sel.at[i].set(True)), None
    (_, sel), _ = jax.lax.scan(
        body, (jnp.ones((n,), bool), jnp.zeros((n,), bool)), None,
        length=theta)

    beta = max(theta - 2 * f, 1)

    def leaf(l):
        flat = l.astype(jnp.float32).reshape(n, -1)
        med = D._masked_median(flat, sel)
        big = jnp.asarray(jnp.inf, flat.dtype)
        dist = jnp.where(sel[:, None], jnp.abs(flat - med[None]), big)
        _, idx = jax.lax.top_k(-dist.T, beta)
        vals = jnp.take_along_axis(flat.T, idx, axis=1)
        return jnp.mean(vals, axis=1).reshape(l.shape[1:]).astype(l.dtype)
    return jax.tree.map(leaf, grads)


# ---------------------------------------------------------------------------
# masked / staleness-weighted aggregation (async simulator entry point)


def tree_where_agents(mask, a, b):
    """Per-agent select on n-leading pytrees (keeps b's leaf dtypes)."""
    def leaf(x, y):
        m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x.astype(y.dtype), y)
    return jax.tree.map(leaf, a, b)


def tree_masked_aggregate(name, grads, f, mask, weights=None,
                          impl: str = "fused", **hyper):
    """Robust aggregation over a *varying subset* of agents with per-agent
    weights — the bridge between the filter catalogue and the asynchronous
    simulator (:mod:`repro.simulator`).

    ``mask``    (n,) bool — which rows actually arrived this round.
    ``weights`` (n,) float — optional multipliers (e.g. staleness discounts
                gamma^s of the Zeno++/Kardam line); zeroed where ``mask`` is
                False.

    The filters in :mod:`repro.core.filters.dense` are fixed-n: absent rows
    are *imputed* with the weighted mean of the arrived rows, so they sit at
    the current consensus and cannot shift any order statistic outward, and
    the stack keeps one jit shape across rounds.  Weights fold in exactly
    where each filter class admits them:

      * mean                — the weighted mean of arrived rows (exact);
      * weight-decomposable — filter weights on the imputed stack, times the
        per-agent weights, renormalized (imputed rows carry the average
        arrived weight so a selection landing on them is neutral);
      * coordinate-wise / iterative — filter on the imputed stack, scaled by
        the mean weight of arrived rows (a staleness-adaptive step size).

    With mask all-True and weights all-one this reduces to
    :func:`tree_aggregate` up to exact-arithmetic no-ops (the synchronous
    degenerate case)."""
    n = jax.tree.leaves(grads)[0].shape[0]
    mask = mask.astype(bool)
    mf = mask.astype(jnp.float32)
    w = mf if weights is None else weights.astype(jnp.float32) * mf
    cnt = jnp.maximum(jnp.sum(mf), 1.0)
    tot = jnp.maximum(jnp.sum(w), 1e-30)
    wn = w / tot
    mean_sel = tree_weighted_sum(grads, wn)
    if name == "mean":
        return mean_sel
    imputed = tree_where_agents(
        mask, grads,
        jax.tree.map(lambda m, l: jnp.broadcast_to(
            m.astype(l.dtype)[None], l.shape), mean_sel, grads))
    if name in WEIGHTED and impl == "fused":
        # imputed rows carry the average arrived weight: a filter selecting
        # one (it equals the weighted consensus) stays a valid update
        row_w = jnp.where(mask, w, tot / cnt)
        fw = filter_weights(name, imputed, f, **hyper) * row_w
        fw = fw / jnp.maximum(jnp.sum(fw), 1e-30)
        return tree_weighted_sum(imputed, fw)
    agg = tree_aggregate(name, imputed, f, impl=impl, **hyper)
    scale = tot / cnt                      # <= 1, == 1 when all fresh
    return jax.tree.map(
        lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), agg)


# ---------------------------------------------------------------------------
# public entry point


def tree_aggregate(name, grads, f, impl: str = "fused", **hyper):
    """Aggregate per-agent gradient pytrees (leading axis = agent).

    impl="gather": ravel to (n, P), dense filter, unravel (paper-faithful).
    impl="fused":  stats->weights / leaf-wise decomposition (same output).
    """
    if impl == "gather":
        hyper = {k: v for k, v in hyper.items() if k != "native_dtype"}
        stack = tree_stack_ravel(
            jax.tree.map(lambda l: l.astype(jnp.float32), grads))
        if name == "zeno":
            hyper = dict(hyper)
            hyper["server_grad"] = tree_stack_ravel(
                jax.tree.map(lambda l: l.astype(jnp.float32)[None],
                             hyper["server_grad"]))[0]
        out = D.get_filter(name, **hyper)(stack, f)
        return tree_unravel_like(out, grads)

    if name in COORDWISE:
        return _leafwise(name, grads, f, **hyper)
    if name in WEIGHTED:
        w = filter_weights(name, grads, f, **hyper)
        return tree_weighted_sum(grads, w)
    if name in ("geometric_median", "rfa"):
        kw = {"iters": hyper.get("iters", 32),
              "eps": hyper.get("eps", hyper.get("nu", 1e-8))}
        return tree_geometric_median(grads, **kw)
    if name == "median_of_means":
        return tree_median_of_means(grads, f,
                                    num_groups=hyper.get("num_groups"))
    if name == "bulyan":
        return tree_bulyan(grads, f)
    raise KeyError(name)
