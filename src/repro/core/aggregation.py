"""DEPRECATED string-dispatch aggregation API — thin shims over
:mod:`repro.core.aggregators`.

The engine, the per-rule implementations and the tree helpers now live in
:mod:`repro.core.aggregators` behind the typed :class:`AggregatorSpec` API:

    from repro.core.aggregators import make_spec
    spec = make_spec("trimmed_mean", f=3, impl="fused", beta=0.25)
    agg  = spec.aggregate(grads)                       # == tree_aggregate
    agg  = spec.aggregate(grads, mask=m, weights=w)    # == tree_masked_...
    w    = spec.weights(grads)                         # == filter_weights

The functions below keep the historical signatures working bit-for-bit
(tests/test_aggregator_spec.py asserts the parity) but emit
:class:`AggregatorDeprecationWarning` — repo-internal code must pass specs.
Stateful rules: the legacy calls accept ``server_grad=...`` in ``**hyper``
and translate it to the explicit ``state=`` protocol.

The capability constants are derived views over the registry's
:class:`~repro.core.aggregators.AggregatorCaps` — they are no longer edited
when a rule is added."""
from __future__ import annotations

import sys

from repro.core.aggregators import (                       # noqa: F401
    AggregatorDeprecationWarning, REGISTRY, get_aggregator_def, make_spec,
    tree_bulyan, tree_dot, tree_geometric_median, tree_gram,
    tree_median_of_means, tree_sqnorms, tree_stack_ravel,
    tree_unravel_like, tree_weighted_sum, tree_where_agents, warn_once)

# legacy capability sets — now derived, kept only for external importers
COORDWISE = {n for n, d in REGISTRY.items()
             if d.caps.coordwise and "table2" in d.tags}
WEIGHTED = {n for n, d in REGISTRY.items()
            if d.caps.weight_decomposable and "table2" in d.tags}
ITERATIVE = {n for n, d in REGISTRY.items()
             if d.caps.iterative and "table2" in d.tags
             and "meta" not in d.tags}


def _shim_spec(fn_name, name, f, impl, hyper):
    # one warning per CALLER call site (filename, lineno) — the dedup set
    # lives in aggregators.warn_once, shared with the kernel-fallback
    # notices (stdlib location-dedup breaks under jax's filter churn)
    caller = sys._getframe(2)
    warn_once(
        ("shim", caller.f_code.co_filename, caller.f_lineno),
        f"{fn_name}(name, ...) is deprecated: build an AggregatorSpec "
        f"with repro.core.aggregators.make_spec({name!r}, f={f}, ...) "
        f"and call spec.aggregate(...)",
        AggregatorDeprecationWarning, stacklevel=4)
    hyper = dict(hyper)
    state = None
    if "server_grad" in hyper:
        state = {"server_grad": hyper.pop("server_grad")}
    # the legacy gather path stripped native_dtype for EVERY rule; keep
    # that tolerance here (the spec API proper rejects it at build time)
    d = get_aggregator_def(name)
    if "native_dtype" in hyper and "native_dtype" not in (d.impl_keys
                                                          | d.hyper_keys):
        hyper.pop("native_dtype")
    return make_spec(name, f=f, impl=impl, **hyper), state


def tree_aggregate(name, grads, f, impl: str = "fused", **hyper):
    """DEPRECATED — ``make_spec(name, f=f, impl=impl, **hyper)
    .aggregate(grads)``."""
    spec, state = _shim_spec("tree_aggregate", name, f, impl, hyper)
    return spec.aggregate(grads, state=state)


def tree_masked_aggregate(name, grads, f, mask, weights=None,
                          impl: str = "fused", **hyper):
    """DEPRECATED — ``make_spec(...).aggregate(grads, mask=mask,
    weights=weights)``."""
    spec, state = _shim_spec("tree_masked_aggregate", name, f, impl, hyper)
    return spec.aggregate(grads, mask=mask, weights=weights, state=state)


def filter_weights(name, grads, f, **hyper):
    """DEPRECATED — ``make_spec(...).weights(grads)``."""
    spec, state = _shim_spec("filter_weights", name, f, "fused", hyper)
    return spec.weights(grads, state=state)
