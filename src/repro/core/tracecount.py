"""Trace-count bookkeeping for compile-regression tests.

``count_trace(site)`` is called from inside jit-traced step functions (the
async/sync training steps, the serving agreement step).  Python side
effects run once per TRACE, never per execution, so the counter increments
exactly when XLA (re)compiles that site — the same trick the kernel-parity
suite uses locally, promoted to a library hook so the membership-retrace
suite can assert compile bounds on the REAL loops: membership churn over a
bucketed elastic spec must cost at most ``len(buckets)`` compilations per
loop, ever (tests/test_membership_retrace.py).

Zero runtime cost on the compiled path; counters are process-global and
monotonic — tests snapshot before/after rather than resetting blindly.
"""
from __future__ import annotations

from collections import Counter

TRACE_COUNTS: Counter = Counter()


def count_trace(site: str) -> None:
    """Record one tracing of ``site`` (call from INSIDE the traced fn)."""
    TRACE_COUNTS[site] += 1


def trace_count(site: str) -> int:
    return TRACE_COUNTS[site]


def reset_traces(site: str | None = None) -> None:
    if site is None:
        TRACE_COUNTS.clear()
    else:
        TRACE_COUNTS.pop(site, None)


__all__ = ["TRACE_COUNTS", "count_trace", "trace_count", "reset_traces"]
