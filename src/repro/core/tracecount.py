"""Backward-compat shim — the trace-count bookkeeping moved to
:mod:`repro.obs.counters` (PR 6), which adds the public
``snapshot()``/``reset()``/gauge API the flight recorder builds its
recompile ledger on.  ``TRACE_COUNTS`` here IS the same Counter object as
``repro.obs.counters.COUNTERS``, so existing snapshot-diff tests keep
working unchanged.  New code should import from ``repro.obs.counters``.
"""
from __future__ import annotations

from repro.obs.counters import (TRACE_COUNTS, count_trace, reset,
                                reset_traces, snapshot, trace_count)

__all__ = ["TRACE_COUNTS", "count_trace", "trace_count", "reset_traces",
           "snapshot", "reset"]
