"""Worker-side momentum — survey §3.3.4 "variance reducing techniques".

Karimireddy et al. [60]: agents send exponentially-averaged updates
m_i^t = (1-alpha) m_i^{t-1} + alpha g_i^t instead of raw stochastic gradients;
combined with any (delta_max, c)-robust aggregator this provably fixes
convergence for non-convex smooth losses.  El-Mhamdi et al. [33]: the same
mechanism computed at agents boosts robustness of existing filters.

Implemented as a transform on the per-agent gradient stack so it composes
with every filter and with the attack-injection point (Byzantine agents
corrupt the *sent* momentum, mirroring the real protocol).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_momentum(grads_proto):
    """Zero momentum buffers shaped like the per-agent gradient stack."""
    return jax.tree.map(jnp.zeros_like, grads_proto)


def worker_momentum(momentum, grads, alpha: float = 0.1):
    """Returns (sent_updates, new_momentum).  alpha is the survey's
    'averaging historical gradients' knob ([49] empirically, [60] provably):
    smaller alpha -> stronger variance reduction."""
    new_m = jax.tree.map(
        lambda m, g: ((1.0 - alpha) * m.astype(jnp.float32)
                      + alpha * g.astype(jnp.float32)).astype(m.dtype),
        momentum, grads)
    return new_m, new_m
