"""Executable redundancy theory — survey §3.2 (solvability).

2f-redundancy (Gupta & Vaidya [45], Def. 1) and (2f, eps)-redundancy
(Liu et al. [68], Def. 2) are *properties of the agents' cost functions*.
We make them checkable for the closed-form family used throughout the
fault-tolerance literature's analyses: quadratic costs
Q_i(x) = 1/2 (x - x_i*)^T H_i (x - x_i*) with H_i PSD, whose subset-aggregate
argmin is (sum_S H_i)^{-1} (sum_S H_i x_i*) — a single point, so Hausdorff
distance reduces to the euclidean metric (general finite-set Hausdorff is
also provided, appendix A.1)."""
from __future__ import annotations

import itertools

import jax.numpy as jnp
import numpy as np


def hausdorff_distance(X, Y):
    """Finite point sets X: (a, d), Y: (b, d) — survey appendix A.1."""
    X, Y = jnp.atleast_2d(X), jnp.atleast_2d(Y)
    d = jnp.sqrt(jnp.maximum(
        jnp.sum(jnp.square(X[:, None] - Y[None]), axis=-1), 0.0))
    return jnp.maximum(jnp.max(jnp.min(d, axis=1)), jnp.max(jnp.min(d, axis=0)))


def quadratic_argmin(Hs, xstars, subset=None):
    """argmin_x sum_{i in subset} 1/2 (x-x_i*)^T H_i (x-x_i*)."""
    Hs, xstars = np.asarray(Hs), np.asarray(xstars)
    idx = np.asarray(subset) if subset is not None else np.arange(len(Hs))
    H = Hs[idx].sum(0)
    rhs = np.einsum("ijk,ik->j", Hs[idx], xstars[idx])
    return np.linalg.solve(H, rhs)


def _subsets(n, size, limit):
    combos = itertools.combinations(range(n), size)
    out = list(itertools.islice(combos, limit + 1))
    if len(out) > limit:
        # deterministic subsample to keep the check tractable
        rng = np.random.default_rng(0)
        all_combos = list(itertools.combinations(range(n), size))
        pick = rng.choice(len(all_combos), size=limit, replace=False)
        out = [all_combos[i] for i in sorted(pick)]
    return out


def check_2f_redundancy(Hs, xstars, f: int, tol: float = 1e-6,
                        max_subsets: int = 2000):
    """Def. 1: every subset of size >= n-2f has the same argmin as the full
    set.  Returns (holds, worst_violation)."""
    n = len(Hs)
    full = quadratic_argmin(Hs, xstars)
    worst = 0.0
    for size in range(n - 2 * f, n + 1):
        for S in _subsets(n, size, max_subsets):
            x = quadratic_argmin(Hs, xstars, S)
            worst = max(worst, float(np.linalg.norm(x - full)))
    return worst <= tol, worst


def check_2f_eps_redundancy(Hs, xstars, f: int, max_subsets: int = 2000):
    """Def. 2: returns the smallest eps for which (2f, eps)-redundancy holds
    (max over pairs S (|S| = n-f) superset-of Shat (|Shat| >= n-2f) of the
    argmin distance)."""
    n = len(Hs)
    eps = 0.0
    for S in _subsets(n, n - f, max_subsets):
        xS = quadratic_argmin(Hs, xstars, S)
        inner_budget = max(max_subsets // max(len(S), 1), 50)
        for size in range(n - 2 * f, n - f + 1):
            if size > len(S):
                continue
            for Shat in _subsets(len(S), size, inner_budget):
                sub = [S[j] for j in Shat]
                xh = quadratic_argmin(Hs, xstars, sub)
                eps = max(eps, float(np.linalg.norm(xS - xh)))
    return eps


def make_redundant_quadratics(n: int, d: int, eps: float = 0.0, seed: int = 0):
    """Construct n quadratic agents sharing a common minimizer (exact
    2f-redundancy) perturbed by radius eps (giving (2f, O(eps))-redundancy)."""
    rng = np.random.default_rng(seed)
    common = rng.normal(size=(d,))
    Hs, xs = [], []
    for _ in range(n):
        A = rng.normal(size=(d, d))
        Hs.append(A @ A.T + np.eye(d))
        delta = rng.normal(size=(d,))
        delta = eps * delta / max(np.linalg.norm(delta), 1e-12)
        xs.append(common + delta)
    return np.stack(Hs), np.stack(xs), common
