from repro.core.redundancy.coding import (detox_aggregate, draco_aggregate,
                                          draco_assignment)
from repro.core.redundancy.properties import (check_2f_eps_redundancy,
                                              check_2f_redundancy,
                                              hausdorff_distance,
                                              quadratic_argmin)
from repro.core.redundancy.reactive import (ReactiveState, init_reactive,
                                            reactive_step)

__all__ = [
    "draco_assignment", "draco_aggregate", "detox_aggregate",
    "check_2f_redundancy", "check_2f_eps_redundancy", "hausdorff_distance",
    "quadratic_argmin", "ReactiveState", "init_reactive", "reactive_step",
]
