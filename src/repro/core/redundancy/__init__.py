from repro.core.redundancy.coding import (coded_vote_weights, coding_groups,
                                          detox_aggregate, draco_aggregate,
                                          draco_assignment,
                                          flat_draco_aggregate,
                                          tree_draco_aggregate)
from repro.core.redundancy.properties import (check_2f_eps_redundancy,
                                              check_2f_redundancy,
                                              hausdorff_distance,
                                              quadratic_argmin)
from repro.core.redundancy.reactive import (ReactiveState, init_reactive,
                                            reactive_step)

__all__ = [
    "coding_groups", "coded_vote_weights", "draco_assignment",
    "draco_aggregate", "detox_aggregate", "flat_draco_aggregate",
    "tree_draco_aggregate",
    "check_2f_redundancy", "check_2f_eps_redundancy", "hausdorff_distance",
    "quadratic_argmin", "ReactiveState", "init_reactive", "reactive_step",
]
