"""Randomized reactive redundancy — Gupta & Vaidya [44] (survey §3.3.3).

Instead of paying the coding overhead every iteration, the server invokes the
redundancy check only with probability q; otherwise it runs plain DGD
(mean aggregation over still-active agents).  When the Byzantine set is
FIXED (the paper's assumption for removal), a detected faulty agent is
removed forever, so the amortized overhead is O(q) — arbitrarily small.

Protocol (paper's scheme specialized to the parallel setting):
 1. The server samples check-vs-plain *before* assigning work
    (``should_check``); in a checking iteration, consecutive active agents
    are paired on identical data shards.
 2. A mismatching pair is resolved by the server recomputing that shard
    itself ("heuristic checking by server" [44]), exposing the liar(s).

Detection mutates the active set — inherently sequential, rare, host-side;
the hot path (plain iterations) stays pure-jnp.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ReactiveState:
    active: jnp.ndarray          # (n,) bool — agents not yet exposed
    checks_run: int = 0
    detected: int = 0


def init_reactive(n: int) -> ReactiveState:
    return ReactiveState(active=jnp.ones((n,), bool))


def should_check(key, q: float) -> bool:
    return bool(jax.random.uniform(key) < q)


def check_pairs(state: ReactiveState):
    """Consecutive pairing of active agents (the announced assignment)."""
    idx = [int(i) for i in np.flatnonzero(np.asarray(state.active))]
    return list(zip(idx[0::2], idx[1::2]))


def plain_aggregate(g, state: ReactiveState):
    w = state.active.astype(g.dtype)
    return jnp.sum(g * w[:, None], axis=0) / jnp.maximum(jnp.sum(w), 1.0)


def check_and_aggregate(g, state: ReactiveState, server_recompute,
                        tol: float = 1e-6):
    """Checking iteration: agents in each pair computed the SAME shard, so
    honest pairs agree exactly; disagreement triggers server recompute and
    removal of whoever differs from the truth."""
    gn = np.asarray(g, np.float64)
    active = np.asarray(state.active).copy()
    detected = state.detected
    scale = max(float(np.max(np.sum(gn ** 2, axis=-1))), 1e-30)
    for a, b in check_pairs(state):
        if np.sum((gn[a] - gn[b]) ** 2) > tol * scale:
            truth = np.asarray(server_recompute(int(a)), np.float64)
            for c in (a, b):
                if np.sum((gn[c] - truth) ** 2) > tol * scale:
                    active[c] = False
                    detected += 1
    new_state = ReactiveState(active=jnp.asarray(active),
                              checks_run=state.checks_run + 1,
                              detected=detected)
    return plain_aggregate(g, new_state), new_state


def reactive_step(key, g, state: ReactiveState, q: float,
                  server_recompute=None, tol: float = 1e-6):
    """Convenience wrapper: sample, then check or run plain."""
    if server_recompute is not None and should_check(key, q):
        return check_and_aggregate(g, state, server_recompute, tol)
    return plain_aggregate(g, state), state
