"""Gradient coding / algorithmic redundancy — survey §3.3.3.

Draco [18]: the parallel setting — the server assigns the SAME data shard to
r agents (repetition / fractional-repetition code).  With <= (r-1)/2 Byzantine
agents per group, a majority vote over each group recovers the exact gradient
(linear-time decode).  We implement the repetition code with a distance-based
majority (floating-point-safe plurality).

DETOX [86]: hierarchical — (1) Draco-style majority vote inside groups of r,
(2) partition the n/r voted gradients into buckets and average, (3) a robust
aggregation (any gradient filter) over bucket means.  Trades redundancy for
both speed and robustness.

Decode paths.  There is ONE copy of the vote law,
:func:`coded_vote_weights`: (n, n) Gram -> (n,) one-hot-per-group decode
weights.  :func:`flat_draco_aggregate` runs it over the zero-copy (n, P)
arena on the Pallas primitives (``kernels.pairwise.gram`` for the vote,
``kernels.wsum.masked_weighted_sum`` for the application — which also
where-zeroes non-winning rows, so a rejected Byzantine row carrying
±inf/NaN cannot leak 0*inf = NaN into the decode).
:func:`tree_draco_aggregate` ravels uniform-dtype pytrees through their
cached :class:`~repro.core.flat.FlatPlan` into that same arena path
(bit-for-bit: the tree entry point IS the arena path), and keeps a
leaf-wise Gram fallback only for mixed-dtype trees.

Roster-aware grouping.  :func:`coding_groups` is the lru-cached per-(n, r)
group table — the same build-time-cache trick as the trim tables
(``aggregators.trim_count``).  Under elastic membership the training loops
re-derive it per bucket capacity when the bucket's step function is built
(respecialize time), grouping the packed LIVE rows positionally.  In the
parallel regime every agent computes the same full-shard gradient, so
regrouping live agents per bucket preserves exact recovery.  A bucket
capacity not divisible by r carries a smaller trailing group (with a
proportionally lower per-group vote tolerance); the *static* entry points
require ``n % r == 0`` and raise :class:`ValueError` otherwise.

Vote tolerance.  Agreement is ``d2 <= tol * scale_g`` where ``scale_g`` is
the per-group MEDIAN delivered row sq-norm.  The historical global
``max(sq)`` scale was attacker-inflatable: one large-value Byzantine row
anywhere in the stack raised every group's tolerance until genuinely
disagreeing rows counted as "agreeing" and the argmax tie-break became
steerable (tests/test_coding.py pins the exploit).  With a delivered
majority of honest rows per group, the median norm is an honest row's
norm, so the steering budget collapses from sqrt(tol)·max-norm to
sqrt(tol)·honest-norm.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filters import dense as D


@functools.lru_cache(maxsize=None)
def coding_groups(n: int, r: int, allow_ragged: bool = False):
    """The static per-(n, r) group-id table: slot i belongs to group
    ``i // r``.  Cached (lru) and returned read-only — the elastic loops
    call this once per bucket capacity at step-build time and bake the
    table into the bucket's traced step, exactly the trick the trim
    tables use, so churn costs at most one compile per bucket.

    ``allow_ragged`` (elastic buckets only): a capacity not divisible by
    r keeps a smaller trailing group instead of raising."""
    if r <= 0:
        raise ValueError(
            f"gradient coding needs a positive repetition group size: "
            f"got r={r} (n={n})")
    if not allow_ragged and n % r:
        raise ValueError(
            f"draco repetition code needs the group size to divide the "
            f"agent count: got n={n}, r={r} (n % r == {n % r})")
    groups = (np.arange(n, dtype=np.int64) // r)
    groups.setflags(write=False)
    return groups


def draco_assignment(n: int, r: int):
    """Fractional repetition assignment: group g = agents [g*r, (g+1)*r).
    Returns (num_groups, group_of_agent index array).  Raises
    :class:`ValueError` (with the shapes) unless ``r`` divides ``n``."""
    return n // r, jnp.asarray(coding_groups(n, r))


def majority_vote(g, tol: float = 1e-6):
    """Plurality vector among rows of g: (r, d) -> (d,).

    Counts, for each row, how many rows lie within ``tol`` relative to the
    MEDIAN row sq-norm — returns the row with the highest count.  Exact-
    agreement majority in fp arithmetic; the median scale keeps a single
    large-value Byzantine row from inflating the tolerance."""
    d2 = D.pairwise_sq_dists(g)
    sq = jnp.sum(jnp.square(g), axis=-1)
    scale = jnp.maximum(jnp.median(sq), 1e-30)
    votes = jnp.sum(d2 <= tol * scale, axis=-1)
    return g[jnp.argmax(votes)]


def coded_vote_weights(gram, r: int, tol: float = 1e-6, mask=None,
                       groups=None):
    """THE vote law: (n, n) fp32 Gram -> (n,) decode weights (one-hot per
    surviving group, normalized over surviving groups).

    ``mask`` (n,) bool restricts the vote to *delivered* rows: absent rows
    neither vote nor win, groups with no delivery get zero weight, and the
    average renormalizes over the surviving groups.  ``groups`` is a HOST
    (numpy) group-id table from :func:`coding_groups` — static, so the
    group one-hots fold into the trace as constants.

    Agreement tolerance is per group: ``d2 <= tol * median(sq_delivered)``
    of that group — see the module docstring for why not ``max(sq)``."""
    n = gram.shape[0]
    if groups is None:
        groups = coding_groups(n, r)
    groups = np.asarray(groups)
    k = int(groups.max()) + 1
    onehot = groups[None, :] == np.arange(k)[:, None]         # (k, n) static
    same = groups[:, None] == groups[None, :]                 # (n, n) static

    sq = jnp.diag(gram)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
    m = jnp.ones((n,), bool) if mask is None else mask.astype(bool)
    in_group = onehot & m[None, :]                            # (k, n)
    cnt = jnp.sum(in_group, axis=-1)                          # (k,) delivered
    # per-group lower-median delivered sq-norm: with <= (cnt-1)//2
    # Byzantine rows delivered per group, the element at sorted index
    # (cnt-1)//2 is an honest row's norm whatever the attacker sends
    sq_rows = jnp.where(in_group, sq[None, :], jnp.inf)
    mid = jnp.clip((cnt - 1) // 2, 0, n - 1)
    med = jnp.take_along_axis(jnp.sort(sq_rows, axis=-1),
                              mid[:, None], axis=-1)[:, 0]
    scale = jnp.maximum(jnp.where(cnt > 0, med, 0.0), 1e-30)  # (k,)

    agree = (d2 <= tol * scale[jnp.asarray(groups)][:, None]) & same
    votes = jnp.where(m, jnp.sum(agree & m[None, :], axis=-1), -1)
    # winner per group: argmax over the group's slots (-2 outside keeps
    # the historical first-max-in-slot-order tie-break; a delivered row
    # always self-agrees, so it outranks the -1 absent rows)
    win = jnp.argmax(jnp.where(onehot, votes[None, :], -2), axis=-1)
    group_ok = cnt > 0
    group_w = jnp.where(group_ok, 1.0, 0.0) / jnp.maximum(
        jnp.sum(group_ok), 1)
    return jnp.zeros((n,)).at[win].set(group_w)


def flat_draco_aggregate(x, r: int, tol: float = 1e-6, mask=None,
                         groups=None, interpret: bool | None = None):
    """Draco decode over the (n, P) arena: (n, P) -> (P,) fp32.

    The vote rides ``kernels.pairwise.gram`` (one MXU matmul per tile) and
    the application ``kernels.wsum.masked_weighted_sum`` (one-hot winner
    weights are non-negative, satisfying its precondition; non-winning
    rows are where-zeroed, so Byzantine ±inf never leaks).  Columns are
    zero-padded to the kernels' TILE_D multiple — zero columns change
    neither the Gram nor the weighted sum — and the pad is sliced off."""
    from repro.kernels.dispatch import default_interpret
    from repro.kernels.ops import _pad_d
    from repro.kernels.pairwise import gram
    from repro.kernels.wsum import masked_weighted_sum
    if interpret is None:
        interpret = default_interpret()
    n, p = x.shape
    if groups is None:
        groups = coding_groups(n, r)
    xp, _ = _pad_d(x)
    w = coded_vote_weights(gram(xp, interpret=interpret), r, tol=tol,
                           mask=mask, groups=groups)
    m = jnp.ones((n,), bool) if mask is None else mask.astype(bool)
    out = masked_weighted_sum(w, xp, m,
                              jnp.zeros((xp.shape[1],), jnp.float32),
                              interpret=interpret)
    return out[:p]


def draco_aggregate(g, r: int, tol: float = 1e-6):
    """g: (n, d) with groups of r computing identical tasks.
    Returns the mean (over groups) majority gradient — exact when each
    group has at most (r-1)//2 Byzantine members."""
    draco_assignment(g.shape[0], r)               # validates n % r == 0
    return flat_draco_aggregate(g, r, tol=tol).astype(g.dtype)


def detox_aggregate(g, r: int, f: int = 0, buckets: int = 0,
                    filter_name: str = "geometric_median",
                    tol: float = 1e-6):
    """DETOX: vote -> bucket-average -> robust aggregate.

    The bucket stage tolerates ``f`` vote-overwhelmed groups only if the
    robust filter sees a strict honest majority of bucket means, i.e.
    ``b >= 2f + 1`` buckets survive the divisibility shrink; otherwise
    the filter silently degrades (at ``b = 1`` it collapses to a plain
    average — zero breakdown), so we raise instead."""
    n, d = g.shape
    k, _ = draco_assignment(n, r)
    voted = jax.vmap(lambda grp: majority_vote(grp, tol))(
        g.reshape(k, r, d))
    b = buckets if buckets else max(1, k // max(2 * f + 1, 1))
    while k % b:
        b -= 1
    if b < 2 * f + 1:
        raise ValueError(
            f"detox: k={k} voted gradients (n={n}, r={r}) admit only "
            f"b={b} equal buckets — cannot hold 2f+1={2 * f + 1} bucket "
            f"means for f={f}; pick n/r with more groups or a lower f")
    means = jnp.mean(voted.reshape(b, k // b, d), axis=1)
    return D.FILTERS[filter_name](means, min(f, max((b - 1) // 2, 0)))


def tree_draco_aggregate(grads, r: int, tol: float = 1e-6, mask=None,
                         groups=None):
    """Draco on pytree gradient stacks.

    Uniform-dtype trees ravel through their cached
    :class:`~repro.core.flat.FlatPlan` into the (n, P) arena and decode
    with :func:`flat_draco_aggregate` — the tree entry point IS the arena
    path, bit-for-bit.  Mixed-dtype trees split into per-dtype sub-arenas:
    the full-row Gram is additive over column segments, so the segment
    Grams (each on the arena ``kernels.pairwise.gram`` primitive) sum into
    ONE Gram feeding ONE vote, and the winner weights apply per segment
    through ``kernels.wsum.masked_weighted_sum`` — same vote law, same
    where-zeroed Byzantine-row hygiene as the uniform path.  The split is
    announced once via :func:`~repro.core.aggregators.warn_once` with the
    offending dtypes (a uniform exchange dtype restores the single arena).

    ``mask`` (n,) bool restricts the vote to *delivered* gradients (the
    async simulator's straggler fallback): absent agents neither vote nor
    win, groups with no delivery are excluded, and the average
    renormalizes over the surviving groups.  ``groups`` (host array from
    :func:`coding_groups`) overrides the static ``i // r`` table — the
    elastic loops pass their bucket's (possibly ragged) table here."""
    from repro.core.aggregators import warn_once
    from repro.core.flat import FlatPlan
    from repro.kernels.dispatch import default_interpret
    from repro.kernels.ops import _pad_d
    from repro.kernels.pairwise import gram
    from repro.kernels.wsum import masked_weighted_sum
    n = jax.tree.leaves(grads)[0].shape[0]
    if groups is None:
        groups = coding_groups(n, r)
    plan = FlatPlan.for_tree(grads)
    if plan.uniform_dtype is not None:
        vec = flat_draco_aggregate(plan.ravel(grads), r, tol=tol,
                                   mask=mask, groups=groups)
        return plan.unravel(vec)
    # mixed-dtype tree: per-dtype sub-arenas.  Gram(full row) is the sum of
    # the segment Grams (column blocks are disjoint), so the vote sees the
    # SAME (n, n) Gram the single-arena path would — one vote, applied per
    # segment with the arena weighted-sum kernel (winner one-hots are
    # non-negative; losing rows are where-zeroed, so Byzantine ±inf in a
    # rejected row never leaks into the decode).
    leaves, treedef = jax.tree.flatten(grads)
    by_dtype = {}
    for i, leaf in enumerate(leaves):
        by_dtype.setdefault(jnp.dtype(leaf.dtype), []).append(i)
    warn_once(
        ("draco-mixed-dtype", tuple(sorted(str(d) for d in by_dtype))),
        "tree_draco_aggregate: mixed-dtype gradient tree "
        f"({', '.join(sorted(str(d) for d in by_dtype))}) decodes through "
        "per-dtype sub-arenas instead of one flat arena; set a uniform "
        "exchange dtype (e.g. agg_dtype) to restore the single-ravel path")
    interpret = default_interpret()
    m = jnp.ones((n,), bool) if mask is None else mask.astype(bool)
    segs = {}
    total_gram = None
    for dt, idxs in by_dtype.items():
        seg = jnp.concatenate(
            [leaves[i].reshape(n, -1) for i in idxs], axis=1)
        segp, _ = _pad_d(seg)
        segs[dt] = (idxs, seg.shape[1], segp)
        g = gram(segp, interpret=interpret)
        total_gram = g if total_gram is None else total_gram + g
    w = coded_vote_weights(total_gram, r, tol=tol, mask=mask, groups=groups)
    out = [None] * len(leaves)
    for dt, (idxs, p, segp) in segs.items():
        vec = masked_weighted_sum(
            w, segp, m, jnp.zeros((segp.shape[1],), jnp.float32),
            interpret=interpret)[:p]
        off = 0
        for i in idxs:
            size = leaves[i][0].size
            out[i] = vec[off:off + size].reshape(
                leaves[i].shape[1:]).astype(leaves[i].dtype)
            off += size
    return jax.tree.unflatten(treedef, out)
