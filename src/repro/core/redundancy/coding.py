"""Gradient coding / algorithmic redundancy — survey §3.3.3.

Draco [18]: the parallel setting — the server assigns the SAME data shard to
r agents (repetition / fractional-repetition code).  With <= (r-1)/2 Byzantine
agents per group, a majority vote over each group recovers the exact gradient
(linear-time decode).  We implement the repetition code with a distance-based
majority (floating-point-safe plurality).

DETOX [86]: hierarchical — (1) Draco-style majority vote inside groups of r,
(2) partition the n/r voted gradients into buckets and average, (3) a robust
aggregation (any gradient filter) over bucket means.  Trades redundancy for
both speed and robustness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.filters import dense as D


def draco_assignment(n: int, r: int):
    """Fractional repetition assignment: group g = agents [g*r, (g+1)*r).
    Returns (num_groups, group_of_agent index array)."""
    assert n % r == 0, (n, r)
    return n // r, jnp.arange(n) // r


def majority_vote(g, tol: float = 1e-6):
    """Plurality vector among rows of g: (r, d) -> (d,).

    Counts, for each row, how many rows lie within ``tol`` (relative) —
    returns the row with the highest count.  Exact-agreement majority in
    fp arithmetic."""
    d2 = D.pairwise_sq_dists(g)
    scale = jnp.maximum(jnp.max(jnp.sum(jnp.square(g), axis=-1)), 1e-30)
    votes = jnp.sum(d2 <= tol * scale, axis=-1)
    return g[jnp.argmax(votes)]


def draco_aggregate(g, r: int, tol: float = 1e-6):
    """g: (n, d) with groups of r computing identical tasks.
    Returns the summed (over groups) majority gradient — exact when each
    group has at most (r-1)//2 Byzantine members."""
    n, d = g.shape
    k, _ = draco_assignment(n, r)
    grouped = g.reshape(k, r, d)
    voted = jax.vmap(lambda grp: majority_vote(grp, tol))(grouped)
    return jnp.mean(voted, axis=0)


def detox_aggregate(g, r: int, f: int = 0, buckets: int = 0,
                    filter_name: str = "geometric_median",
                    tol: float = 1e-6):
    """DETOX: vote -> bucket-average -> robust aggregate."""
    n, d = g.shape
    k, _ = draco_assignment(n, r)
    voted = jax.vmap(lambda grp: majority_vote(grp, tol))(
        g.reshape(k, r, d))
    b = buckets if buckets else max(1, k // max(2 * f + 1, 1))
    while k % b:
        b -= 1
    means = jnp.mean(voted.reshape(b, k // b, d), axis=1)
    return D.FILTERS[filter_name](means, min(f, max((b - 1) // 2, 0)))


def tree_draco_aggregate(grads, r: int, tol: float = 1e-6, mask=None):
    """Draco on pytree gradient stacks: vote weights are global (from the
    pairwise Gram of each group), applied per leaf — exact and sharded.

    ``mask`` (n,) bool restricts the vote to *delivered* gradients (the
    async simulator's straggler fallback): absent agents neither vote nor
    win, groups with no delivery are excluded, and the average renormalizes
    over the surviving groups.  mask=None (or all-True) is the classic
    synchronous code."""
    from repro.core.aggregators import tree_gram, tree_weighted_sum
    n = jax.tree.leaves(grads)[0].shape[0]
    assert n % r == 0
    k = n // r
    gram = tree_gram(grads)
    sq = jnp.diag(gram)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
    scale = jnp.maximum(jnp.max(sq), 1e-30)
    same_group = (jnp.arange(n)[:, None] // r) == (jnp.arange(n)[None, :] // r)
    agree = (d2 <= tol * scale) & same_group
    if mask is None:
        votes = jnp.sum(agree, axis=-1)                             # (n,)
        group_w = jnp.full((k,), 1.0 / k)
    else:
        m = mask.astype(bool)
        votes = jnp.where(m, jnp.sum(agree & m[None, :], axis=-1), -1)
        group_ok = jnp.any(m.reshape(k, r), axis=-1)                # (k,)
        group_w = jnp.where(group_ok, 1.0, 0.0) / jnp.maximum(
            jnp.sum(group_ok), 1)
    # winner per group -> weighted one-hot over surviving groups
    votes_g = votes.reshape(k, r)
    win = jnp.argmax(votes_g, axis=-1) + jnp.arange(k) * r          # (k,)
    w = jnp.zeros((n,)).at[win].set(group_w)
    return tree_weighted_sum(grads, w)
