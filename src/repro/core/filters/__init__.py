from repro.core.filters.dense import (FILTERS, compose, get_filter,
                                      krum_scores, pairwise_sq_dists)

__all__ = ["FILTERS", "get_filter", "compose", "pairwise_sq_dists",
           "krum_scores"]
