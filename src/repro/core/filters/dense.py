"""Gradient filters (robust aggregation rules) — survey §3.3.2 / Table 2.

Reference implementations on dense stacks ``g: (n, d)`` (n agents, d params).
Uniform signature ``filter(g, f, **hyper) -> (d,)``.  All are pure jnp and
jit-able with static ``n``/``f``.  The sharded pytree variants live in
:mod:`repro.core.aggregation`; Pallas kernels for the hot coordinate-wise and
pairwise paths live in :mod:`repro.kernels` — this module is their oracle.

Survey Table 2 coverage: Krum, m-Krum, multi-Krum, coordinate-wise median,
coordinate-wise trimmed mean, Phocas, mean-around-median, geometric median,
median-of-means, MDA, CGC, CGE, Bulyan.  Plus: mean (the provably non-robust
baseline, Blanchard et al.), Zeno (§3.3.4), RFA (smoothed geometric median,
§3.4).
"""
from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np

FILTERS: dict = {}


def register(name):
    def deco(fn):
        FILTERS[name] = fn
        return fn
    return deco


def get_filter(name: str, **hyper):
    fn = FILTERS[name]
    return functools.partial(fn, **hyper) if hyper else fn


# ---------------------------------------------------------------------------
# helpers


def pairwise_sq_dists(g):
    """(n, d) -> (n, n) squared euclidean distances (MXU-friendly form).
    The diagonal is exactly zero (fp cancellation there is masked)."""
    n = g.shape[0]
    sq = jnp.sum(jnp.square(g), axis=-1)
    gram = g @ g.T
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
    return jnp.where(jnp.eye(n, dtype=bool), 0.0, d2)


def krum_scores(d2, f, mask=None, k=None):
    """Krum score s(i) = sum of distances to the k closest others
    (k defaults to the classic n - f - 2).

    ``mask``: bool (n,) — unavailable agents get +inf distance & +inf score
    (used by iterative m-Krum / Bulyan selection).  Iterative callers MUST
    shrink ``k`` with the remaining candidate count (k = remaining - f - 2):
    once k exceeds candidates - 1 every sum picks up +inf pads, every score
    collapses to inf, and argmin degrades to index order — the selection
    then silently depends on agent NUMBERING, which the membership
    conformance suite (permutation invariance) rejects.
    """
    n = d2.shape[0]
    big = jnp.asarray(jnp.inf, d2.dtype)
    d2 = d2 + jnp.where(jnp.eye(n, dtype=bool), big, 0.0)   # exclude self
    if mask is not None:
        d2 = jnp.where(mask[None, :], d2, big)
    k = (n - f - 2) if k is None else int(k)
    k = max(min(k, n - 1), 1)
    neg_top, _ = jax.lax.top_k(-d2, k)                      # k smallest
    scores = -jnp.sum(neg_top, axis=-1)
    if mask is not None:
        scores = jnp.where(mask, scores, big)
    return scores


def masked_row_sums(d2, mask):
    """Full-degree score: sum of a candidate's distances to ALL remaining
    candidates (masked rows get +inf).  The cheap O(n^2) tie-break
    secondary for the iterative selection loops — equal to
    ``krum_scores(..., k=candidates - 1)`` without its top_k sort."""
    n = d2.shape[0]
    off = ~jnp.eye(n, dtype=bool)
    s = jnp.sum(jnp.where(mask[None, :] & off, d2, 0.0), axis=-1)
    return jnp.where(mask, s, jnp.inf)


def argmin_tiebreak(primary, secondary):
    """Index of the minimum of ``primary``, with EXACT fp ties broken by
    ``secondary`` (and only then by index).  Iterative krum selection ties
    structurally — with one neighbour left, the closest PAIR shares one
    symmetric distance, so both rows carry bitwise-equal scores — and a
    bare argmin would resolve by agent NUMBERING, which elastic membership
    makes arbitrary (rows are re-packed per roster bucket).  Secondary =
    the full-degree score keeps the pick a function of the geometry
    alone."""
    tied = primary == jnp.min(primary)
    return jnp.argmin(jnp.where(tied, secondary, jnp.inf))


# ---------------------------------------------------------------------------
# baseline


@register("mean")
def mean(g, f=0):
    """No defence.  Blanchard et al. [6]: cannot tolerate a single Byzantine
    agent — reproduced in tests/benchmarks."""
    return jnp.mean(g, axis=0)


# ---------------------------------------------------------------------------
# compressed exchange (survey §5.2 scaling / Bernstein et al. signSGD)


@register("sign_sgd")
def sign_sgd(g, f=0):
    """signSGD with majority vote: agents send sign(g_i) (1 bit/coord),
    the server returns the per-coordinate sign of the vote.  The ±1/0
    votes sum EXACTLY in fp32 for n < 2^24, so every impl (gather, fused
    leaf-wise, pallas tile) is bitwise identical.  Output is magnitude-
    bounded (per-coordinate in [-1, 1]) — robust to <= f sign-flippers by
    majority, broken only by a vote majority (the conformance suite's
    bounded-output breakdown law)."""
    return jnp.sign(jnp.sum(jnp.sign(g).astype(jnp.float32), axis=0))


@register("sparse_mean")
def sparse_mean(g, f=0):
    """Sparse/dropout-aware mean: a zero coordinate means NOT SENT (the
    fed_dropout_avg convention), so each coordinate averages only the
    rows that carry it — agg_c = sum_i [g_ic != 0] g_ic / sum_i
    [g_ic != 0], with an explicit 0 where nobody sent the coordinate
    (never an eps-scaled garbage row).  Per-agent weights (dataset
    sizes, staleness discounts) enter via the spec engine's weighted
    path; this dense oracle is the unit-weight case."""
    sent = (g != 0).astype(jnp.float32)
    den = jnp.sum(sent, axis=0)
    num = jnp.sum(g.astype(jnp.float32) * sent, axis=0)
    return jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)


# ---------------------------------------------------------------------------
# angle / distance based


@register("krum")
def krum(g, f):
    d2 = pairwise_sq_dists(g)
    s = krum_scores(d2, f)
    return g[jnp.argmin(s)]


@register("multi_krum")
def multi_krum(g, f, m: int = 2):
    """Second variant of [6, 7]: average of the m smallest-score vectors."""
    d2 = pairwise_sq_dists(g)
    s = krum_scores(d2, f)
    _, idx = jax.lax.top_k(-s, m)
    return jnp.mean(g[idx], axis=0)


@register("m_krum")
def m_krum(g, f, m: int = 2):
    """First (iterative) variant: recompute scores after each removal.
    Unrolled (m is static) so the neighbour count shrinks with the
    remaining candidate set — see :func:`krum_scores`."""
    n = g.shape[0]
    d2 = pairwise_sq_dists(g)
    mask = jnp.ones((n,), bool)
    acc = jnp.zeros_like(g[0])
    for it in range(m):
        s = krum_scores(d2, f, mask=mask, k=max(n - it - f - 2, 1))
        i = argmin_tiebreak(s, masked_row_sums(d2, mask))
        mask = mask.at[i].set(False)
        acc = acc + g[i]
    return acc / m


@register("mda")
def mda(g, f):
    """Minimum-diameter averaging [32, 76, 91]: average of the (n-f)-subset
    with smallest diameter.  O(C(n, f)) — static combinatorics, n <= 32."""
    n = g.shape[0]
    combos = np.asarray(list(itertools.combinations(range(n), n - f)))
    if len(combos) > 200_000:
        raise ValueError(f"MDA infeasible for n={n}, f={f}")
    d2 = pairwise_sq_dists(g)
    sub = d2[combos[:, :, None], combos[:, None, :]]   # (C, n-f, n-f)
    diam = jnp.max(sub, axis=(1, 2))
    # equal-diameter subsets tie STRUCTURALLY (different removals that
    # leave the same bottleneck pair): break by subset perimeter, not by
    # enumeration order (see argmin_tiebreak)
    best = jnp.asarray(combos)[
        argmin_tiebreak(diam, jnp.sum(sub, axis=(1, 2)))]
    return jnp.mean(g[best], axis=0)


# ---------------------------------------------------------------------------
# coordinate-wise


@register("coordinate_median")
def coordinate_median(g, f=0):
    return jnp.median(g, axis=0)


@register("trimmed_mean")
def trimmed_mean(g, f, beta: float | None = None):
    """Drop the smallest/largest beta-fraction per coordinate [121].
    beta defaults to f/n (the minimum admissible)."""
    n = g.shape[0]
    b = int(np.ceil((beta if beta is not None else f / n) * n)) if n else 0
    b = min(b, (n - 1) // 2)
    s = jnp.sort(g, axis=0)
    kept = s[b:n - b] if b else s
    return jnp.mean(kept, axis=0)


@register("phocas")
def phocas(g, f):
    """Phocas [117]: mean of the n-f values per coordinate closest to the
    trimmed mean."""
    n = g.shape[0]
    tm = trimmed_mean(g, f)
    return _mean_closest(g, tm, n - f)


@register("mean_around_median")
def mean_around_median(g, f):
    """[116]: per-coordinate mean of the n-f values closest to the median."""
    n = g.shape[0]
    med = jnp.median(g, axis=0)
    return _mean_closest(g, med, n - f)


def _mean_closest(g, center, k):
    """Per-coordinate mean of the k values closest to ``center``."""
    dist = jnp.abs(g - center[None, :])                     # (n, d)
    neg_top, idx = jax.lax.top_k(-dist.T, k)                # (d, k) smallest
    vals = jnp.take_along_axis(g.T, idx, axis=1)            # (d, k)
    return jnp.mean(vals, axis=1)


# ---------------------------------------------------------------------------
# median based


@register("geometric_median")
def geometric_median(g, f=0, iters: int = 32, eps: float = 1e-8):
    """Weiszfeld fixed-point iteration for the geometric median [19, 21]."""
    y = jnp.mean(g, axis=0)

    def body(y, _):
        d = jnp.sqrt(jnp.sum(jnp.square(g - y[None]), axis=-1))
        w = 1.0 / jnp.maximum(d, eps)
        y = jnp.sum(w[:, None] * g, axis=0) / jnp.sum(w)
        return y, None
    y, _ = jax.lax.scan(body, y, None, length=iters)
    return y


@register("rfa")
def rfa(g, f=0, iters: int = 32, nu: float = 1e-6):
    """RFA [83]: smoothed Weiszfeld (federated robust aggregation)."""
    return geometric_median(g, f, iters=iters, eps=nu)


@register("median_of_means")
def median_of_means(g, f, num_groups: int | None = None):
    """[19]: partition into k > 2f groups, geometric median of group means."""
    n = g.shape[0]
    k = num_groups if num_groups else min(n, 2 * f + 1) if f else n
    while n % k:
        k += 1
    means = jnp.mean(g.reshape(k, n // k, -1), axis=1)
    return geometric_median(means, 0)


# ---------------------------------------------------------------------------
# norm based


@register("cge")
def cge(g, f, normalize: bool = True):
    """Comparative gradient elimination [43, 46, 49]: keep the n-f
    smallest-norm vectors.  Survey eq. (24) uses the raw sum
    (normalize=False); the practical variant averages."""
    n = g.shape[0]
    norms = jnp.linalg.norm(g, axis=-1)
    neg_top, idx = jax.lax.top_k(-norms, n - f)
    out = jnp.sum(g[idx], axis=0)
    return out / (n - f) if normalize else out


@register("cgc")
def cgc(g, f, normalize: bool = True):
    """Comparative gradient clipping: scale the f largest norms down to the
    (n-f)-th smallest norm, keep everything (survey eq. 24)."""
    n = g.shape[0]
    norms = jnp.linalg.norm(g, axis=-1)
    tau = jnp.sort(norms)[n - f - 1]
    scale = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
    out = jnp.sum(scale[:, None] * g, axis=0)
    return out / n if normalize else out


# ---------------------------------------------------------------------------
# meta


@register("bulyan")
def bulyan(g, f, base: str = "krum"):
    """Bulyan [76]: (1) select n-2f vectors by iterating ``base`` (closest-to
    -output each round), (2) per coordinate, average the theta-2f values
    closest to the median of the selected set."""
    n = g.shape[0]
    theta = n - 2 * f
    assert theta >= 1, "Bulyan needs n > 2f (and n >= 4f+3 for guarantees)"
    base_fn = FILTERS[base]

    # unrolled (theta is static): the krum neighbour count must shrink
    # with the remaining candidate set or every score collapses to inf
    # once fewer than n - f - 1 candidates remain (see krum_scores) — the
    # old scan selected only f + 2 genuine rows and tie-broke the rest by
    # agent index
    d2 = pairwise_sq_dists(g) if base == "krum" else None
    mask = jnp.ones((n,), bool)
    sel = jnp.zeros((n,), bool)
    for it in range(theta):
        # run base filter on the still-available set (mask via +inf trick
        # for krum; generic base: weight unavailable rows to the mean)
        if base == "krum":
            s = krum_scores(d2, f, mask=mask, k=max(n - it - f - 2, 1))
            i = argmin_tiebreak(s, masked_row_sums(d2, mask))
        else:
            avail_mean = (jnp.sum(jnp.where(mask[:, None], g, 0.0), axis=0)
                          / jnp.maximum(jnp.sum(mask), 1))
            out = base_fn(jnp.where(mask[:, None], g, avail_mean[None]), f)
            d = jnp.sum(jnp.square(g - out[None]), axis=-1)
            d = jnp.where(mask, d, jnp.inf)
            i = jnp.argmin(d)
        mask = mask.at[i].set(False)
        sel = sel.at[i].set(True)

    # stage 2: coordinate-wise trimmed average around the median of selected
    beta = max(theta - 2 * f, 1)
    big = jnp.asarray(jnp.inf, g.dtype)
    med = _masked_median(g, sel)
    dist = jnp.where(sel[:, None], jnp.abs(g - med[None]), big)
    neg_top, idx = jax.lax.top_k(-dist.T, beta)     # (d, beta)
    vals = jnp.take_along_axis(g.T, idx, axis=1)
    return jnp.mean(vals, axis=1)


def _masked_median(g, mask):
    """Median over rows where mask is True (count = sum(mask), static via
    sorting with +/- inf padding)."""
    n = g.shape[0]
    cnt = jnp.sum(mask)
    big = jnp.asarray(jnp.inf, g.dtype)
    padded = jnp.where(mask[:, None], g, big)
    s = jnp.sort(padded, axis=0)
    lo = (cnt - 1) // 2
    hi = cnt // 2
    return 0.5 * (s[lo] + s[hi])


# ---------------------------------------------------------------------------
# Zeno (server-validation based, §3.3.4)


@register("zeno")
def zeno(g, f, server_grad=None, rho: float = 1e-3, lr: float = 1.0):
    """Zeno [118]: suspicion score via a server-held validation gradient v:
    score_i = lr * <v, g_i> - rho * ||g_i||^2 ; average the n-f highest."""
    assert server_grad is not None, "zeno requires server_grad"
    n = g.shape[0]
    score = lr * (g @ server_grad) - rho * jnp.sum(jnp.square(g), axis=-1)
    _, idx = jax.lax.top_k(score, n - f)
    return jnp.mean(g[idx], axis=0)


# ---------------------------------------------------------------------------
# filter combinators (survey §5.1 "future work": combinations of filters)


def compose(*names_or_fns, f_each=None):
    """Sequential composition is ill-typed ((n,d)->(d,)); instead this builds
    the *parallel ensemble*: run each filter, then output the coordinate-wise
    median of their outputs — the survey's suggested direction of applying
    multiple different filters in one algorithm."""
    fns = [FILTERS[x] if isinstance(x, str) else x for x in names_or_fns]

    def ensemble(g, f):
        outs = jnp.stack([fn(g, f) for fn in fns], axis=0)
        return jnp.median(outs, axis=0)
    return ensemble
