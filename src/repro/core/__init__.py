"""Core: the survey's technique space as composable JAX modules.

- aggregators:  the unified AggregatorSpec API — typed, stateful,
                composable robust aggregation (registry + caps + engine)
- flat:         FlatPlan — the zero-copy (n, P) arena ravel/unravel plan
                behind spec.aggregate_flat and the loops' flat pipeline
- filters:      dense reference implementations (Table 2) — the oracle
- attacks:      Byzantine behaviours (§3.1, §4.1)
- aggregation:  DEPRECATED string-dispatch shims over aggregators
- momentum:     worker momentum variance reduction (§3.3.4)
- redundancy:   gradient coding, Draco/DETOX/reactive, 2f-redundancy theory
- p2p:          decentralized (peer-to-peer) fault-tolerant DGD (§3.3.5)
- resilience:   (f,eps) / (alpha,f) / (delta_max,c) measurement (§3.5)
"""
from repro.core.aggregation import tree_aggregate
from repro.core.aggregators import (AggregatorCaps, AggregatorSpec,
                                    bucketed, clipped, list_aggregators,
                                    make_spec, register_aggregator,
                                    staleness_discounted)
from repro.core.attacks import apply_attack, get_attack, make_byzantine_mask
from repro.core.filters import FILTERS, get_filter
from repro.core.flat import FlatPlan
from repro.core.momentum import init_momentum, worker_momentum

__all__ = [
    "AggregatorCaps", "AggregatorSpec", "FlatPlan", "make_spec",
    "register_aggregator",
    "list_aggregators", "clipped", "bucketed", "staleness_discounted",
    "tree_aggregate", "apply_attack", "get_attack", "make_byzantine_mask",
    "FILTERS", "get_filter", "init_momentum", "worker_momentum",
]
