"""Resilience notations as measurement harnesses — survey §3.5.

(f, eps)-resilience [68]: deterministic-algorithm output within eps of the
true (honest-aggregate) minimum — measured directly on quadratic systems.

(alpha, f)-Byzantine resilience [6]: a property of an aggregation rule under
iid vectors — estimated by Monte Carlo: (i) E<V, g> >= (1 - sin(alpha)) ||g||^2
and a bounded-moments condition.

(delta_max, c)-robust aggregator [60]: E||V - mean(honest)||^2 <= c*delta*rho^2
— the constant c is estimated empirically over attacks.

Both Monte-Carlo estimators take either a registered aggregator name or an
:class:`~repro.core.aggregators.AggregatorSpec`, and run ALL trials inside
one jitted vmap (sample -> attack -> aggregate batched over the trial axis)
instead of re-dispatching the filter trial-by-trial in a Python loop — same
per-trial RNG stream as the historical loop, ~trials× fewer dispatches."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregators import AggregatorSpec, make_spec
from repro.core.attacks import get_attack, make_byzantine_mask
from repro.core.redundancy.properties import quadratic_argmin


def measure_f_eps(output, Hs, xstars, honest_idx):
    """dist(output, argmin sum_{i in H} Q_i) — eq. (29)."""
    true_min = quadratic_argmin(np.asarray(Hs), np.asarray(xstars),
                                honest_idx)
    return float(np.linalg.norm(np.asarray(output) - true_min))


def _as_spec(name_or_spec, f: int, hyper: dict) -> AggregatorSpec:
    if isinstance(name_or_spec, AggregatorSpec):
        spec = name_or_spec
        # the trial harness corrupts f rows and splits honest rows at f —
        # a spec built for a different f would measure a configuration
        # nobody asked for
        if spec.f != f:
            raise ValueError(
                f"spec {spec.describe()} was built for f={spec.f} but the "
                f"estimator was called with f={f}")
        if hyper:
            raise ValueError(
                "pass hyper-parameters when BUILDING the spec, not to the "
                f"estimator (got {sorted(hyper)})")
        return spec
    return make_spec(name_or_spec, f=f, **hyper)


def _trial_keys(key, trials: int):
    """The exact (k1, k2) stream the historical per-trial loop produced;
    returns the advanced running key so callers can keep splitting."""
    k1s, k2s = [], []
    for _ in range(trials):
        key, k1, k2 = jax.random.split(key, 3)
        k1s.append(k1)
        k2s.append(k2)
    return jnp.stack(k1s), jnp.stack(k2s), key


@functools.partial(jax.jit, static_argnames=("spec", "attack", "hyper", "n",
                                             "d", "sigma"))
def _alpha_trials(spec, attack, hyper, k1s, k2s, mask, g_true, n, d, sigma):
    # attack passed by (name, hyper-tuple), not closure: a closure's
    # identity changes per call and would defeat the jit cache
    attack_fn = get_attack(attack, **dict(hyper))

    def one(k1, k2):
        G = g_true[None, :] + sigma * jax.random.normal(k1, (n, d))
        G = attack_fn(k2, G, mask)
        return spec.aggregate(G) @ g_true
    return jax.vmap(one)(k1s, k2s)


def estimate_alpha_f(filter_name, n: int, f: int, d: int = 32,
                     trials: int = 64, sigma: float = 0.2,
                     attack: str = "sign_flip", attack_hyper: dict = None,
                     seed: int = 0, **hyper):
    """Monte-Carlo estimate of the angle alpha of (alpha, f)-resilience:
    returns (alpha_hat_deg, ok) where ok = E<V,g> > 0 for all trials'
    average.  alpha_hat from  E<V, g> = (1 - sin alpha) ||g||^2.

    ``filter_name`` may be a registered name or an AggregatorSpec."""
    spec = _as_spec(filter_name, f, hyper)
    g_true = jnp.ones((d,)) / jnp.sqrt(d)
    mask = make_byzantine_mask(n, f)
    k1s, k2s, _ = _trial_keys(jax.random.PRNGKey(seed), trials)
    dots = np.asarray(
        _alpha_trials(spec, attack, tuple(sorted((attack_hyper or {})
                                                 .items())),
                      k1s, k2s, mask, g_true, n, d, sigma),
        dtype=np.float64)
    e_dot = float(np.mean(dots))
    ratio = e_dot / float(g_true @ g_true)
    sin_alpha = min(max(1.0 - ratio, 0.0), 1.0)
    alpha = float(np.degrees(np.arcsin(sin_alpha)))
    return alpha, e_dot > 0.0


@functools.partial(jax.jit, static_argnames=("spec", "attack", "n", "d",
                                             "f", "rho"))
def _delta_trials(spec, attack, k1s, k2s, mask, n, d, f, rho):
    attack_fn = get_attack(attack)

    def one(k1, k2):
        G = (jax.random.normal(k1, (n, d))
             * (rho / np.sqrt(2.0)) / np.sqrt(d))
        Ga = attack_fn(k2, G, mask)
        v = spec.aggregate(Ga)
        honest_mean = jnp.mean(G[f:], axis=0)
        return jnp.sum(jnp.square(v - honest_mean))
    return jax.vmap(one)(k1s, k2s)


def estimate_delta_c(filter_name, n: int, f: int, d: int = 32,
                     trials: int = 64, rho: float = 1.0,
                     attacks=("sign_flip", "alie", "ipm", "large_value"),
                     seed: int = 0, **hyper):
    """Estimate the constant c of a (delta_max, c)-robust aggregator:
    c_hat = max over attacks of  E||V - mean_honest||^2 / (delta * rho^2),
    delta = f/n.  Honest vectors: iid with pairwise E||V_i - V_j||^2 = rho^2
    (i.e. per-vector variance rho^2/2).

    ``filter_name`` may be a registered name or an AggregatorSpec."""
    spec = _as_spec(filter_name, f, hyper)
    mask = make_byzantine_mask(n, f)
    delta = f / n
    worst = 0.0
    key = jax.random.PRNGKey(seed)
    for attack in attacks:
        k1s, k2s, key = _trial_keys(key, trials)
        errs = np.asarray(
            _delta_trials(spec, attack, k1s, k2s,
                          mask, n, d, f, rho), dtype=np.float64)
        c = np.mean(errs) / max(delta * rho ** 2, 1e-12)
        worst = max(worst, float(c))
    return worst
