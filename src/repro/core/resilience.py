"""Resilience notations as measurement harnesses — survey §3.5.

(f, eps)-resilience [68]: deterministic-algorithm output within eps of the
true (honest-aggregate) minimum — measured directly on quadratic systems.

(alpha, f)-Byzantine resilience [6]: a property of an aggregation rule under
iid vectors — estimated by Monte Carlo: (i) E<V, g> >= (1 - sin(alpha)) ||g||^2
and a bounded-moments condition.

(delta_max, c)-robust aggregator [60]: E||V - mean(honest)||^2 <= c*delta*rho^2
— the constant c is estimated empirically over attacks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attacks import apply_attack, make_byzantine_mask
from repro.core.filters import FILTERS
from repro.core.redundancy.properties import quadratic_argmin


def measure_f_eps(output, Hs, xstars, honest_idx):
    """dist(output, argmin sum_{i in H} Q_i) — eq. (29)."""
    true_min = quadratic_argmin(np.asarray(Hs), np.asarray(xstars),
                                honest_idx)
    return float(np.linalg.norm(np.asarray(output) - true_min))


def estimate_alpha_f(filter_name: str, n: int, f: int, d: int = 32,
                     trials: int = 64, sigma: float = 0.2,
                     attack: str = "sign_flip", attack_hyper: dict = None,
                     seed: int = 0, **hyper):
    """Monte-Carlo estimate of the angle alpha of (alpha, f)-resilience:
    returns (alpha_hat_deg, ok) where ok = E<V,g> > 0 for all trials'
    average.  alpha_hat from  E<V, g> = (1 - sin alpha) ||g||^2."""
    from repro.core.attacks import get_attack
    key = jax.random.PRNGKey(seed)
    g_true = jnp.ones((d,)) / jnp.sqrt(d)
    fn = FILTERS[filter_name]
    attack_fn = get_attack(attack, **(attack_hyper or {}))
    mask = make_byzantine_mask(n, f)
    dots = []
    for t in range(trials):
        key, k1, k2 = jax.random.split(key, 3)
        G = g_true[None, :] + sigma * jax.random.normal(k1, (n, d))
        G = attack_fn(k2, G, mask)
        v = fn(G, f, **hyper)
        dots.append(float(v @ g_true))
    e_dot = float(np.mean(dots))
    ratio = e_dot / float(g_true @ g_true)
    sin_alpha = min(max(1.0 - ratio, 0.0), 1.0)
    alpha = float(np.degrees(np.arcsin(sin_alpha)))
    return alpha, e_dot > 0.0


def estimate_delta_c(filter_name: str, n: int, f: int, d: int = 32,
                     trials: int = 64, rho: float = 1.0,
                     attacks=("sign_flip", "alie", "ipm", "large_value"),
                     seed: int = 0, **hyper):
    """Estimate the constant c of a (delta_max, c)-robust aggregator:
    c_hat = max over attacks of  E||V - mean_honest||^2 / (delta * rho^2),
    delta = f/n.  Honest vectors: iid with pairwise E||V_i - V_j||^2 = rho^2
    (i.e. per-vector variance rho^2/2)."""
    key = jax.random.PRNGKey(seed)
    fn = FILTERS[filter_name]
    mask = make_byzantine_mask(n, f)
    delta = f / n
    worst = 0.0
    for attack in attacks:
        errs = []
        for t in range(trials):
            key, k1, k2 = jax.random.split(key, 3)
            G = (jax.random.normal(k1, (n, d))
                 * (rho / np.sqrt(2.0)) / np.sqrt(d))
            Ga = apply_attack(attack, k2, G, mask)
            v = fn(Ga, f, **hyper)
            honest_mean = jnp.mean(G[f:], axis=0)
            errs.append(float(jnp.sum(jnp.square(v - honest_mean))))
        c = np.mean(errs) / max(delta * rho ** 2, 1e-12)
        worst = max(worst, float(c))
    return worst
