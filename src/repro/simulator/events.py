"""Virtual-clock event queue: latency samples -> arrival times & staleness.

Models the asynchronous server of the survey's §4 (and the Zeno++/Kardam
staleness-aware line of work): agents compute gradients against the latest
parameter version they saw, deliveries arrive out of order, and the server
forms parameter version t+1 as soon as a *quorum* of gradients has arrived.

The simulation runs entirely on the host over a compiled
:class:`~repro.simulator.faults.FaultTrace` and produces an
:class:`AsyncTrace` of fixed-shape per-step arrays — the jitted async step
consumes one row per server step, so fault injection never causes
recompilation.

Protocol simulated (one server, n agents, virtual time in units of one base
gradient computation):

  * an agent dispatched at parameter version v computes for
    ``trace.delay[v, agent]`` virtual seconds, then its gradient arrives;
  * the server collects arrivals; when ``quorum`` of them are in (plus any
    others that arrived by the same instant), it applies update t, creating
    version t+1; contributors immediately re-dispatch against version t+1;
  * an agent that is down at its dispatch version waits until the first
    version at which it is alive (crash/recover) — or forever (permanent
    crash), leaving the quorum;
  * a dropped message is discovered at its would-be arrival instant; the
    agent retries against the then-current version (a retry is never
    re-dropped, so the virtual clock always advances);
  * a gradient older than ``max_staleness`` versions on arrival is discarded
    (bounded staleness); the agent re-dispatches fresh.

Elastic membership (``trace.roster`` — :class:`~repro.simulator.faults.Join`
/ ``Rejoin`` / ``Churn`` schedules, and the *chosen* rosters a
:class:`~repro.simulator.faults.SamplingPolicy` emits — client sampling is
just another membership schedule here): an agent absent from the roster can
neither dispatch, arrive, nor count toward quorum.  A delivery in flight
when its sender leaves the roster is discarded at the server (the agent is
gone); the agent re-dispatches fresh at its next membership version.  The
effective quorum at step t is ``min(quorum, n_live(t))`` (``quorum=None``
means the full LIVE roster), so a shrunken cluster still makes progress and
a grown one is awaited in full.

Two arrivals sharing an instant are processed in AGENT-ID order (the heap
key is ``(vtime, agent, seq)``): the outcome of a same-instant tie is
pinned by the trace alone, never by internal dispatch order.

If the quorum cannot be met (too many agents crashed or in flight), the
step is marked ``quorum_met[t] = False`` and proceeds with whatever arrived
— the training loop may then fall back to coded aggregation
(:mod:`repro.core.redundancy.coding`).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.simulator.faults import FaultTrace


@dataclass(frozen=True)
class AsyncTrace:
    """Per-server-step execution trace (all arrays fixed-shape)."""
    contrib: np.ndarray       # (steps, n) bool — gradient used in update t
    staleness: np.ndarray     # (steps, n) int64 — versions behind, contribs
    refresh: np.ndarray       # (steps, n) bool — agent dispatched at version t
    vclock: np.ndarray        # (steps,) float64 — virtual completion time
    quorum_met: np.ndarray    # (steps,) bool
    # (steps, n) bool per-step membership; None = the full static roster.
    # The training loops thread row t into the jitted step (fixed shape),
    # and elastic-n specs re-specialize their plans from its live count.
    roster: Optional[np.ndarray] = None

    @property
    def steps(self) -> int:
        return self.contrib.shape[0]

    def n_live(self, t: int) -> int:
        return (self.contrib.shape[1] if self.roster is None
                else int(self.roster[t].sum()))

    def is_synchronous(self) -> bool:
        """True iff every step is the degenerate synchronous case: all n
        agents (the full static roster) contribute a zero-staleness
        gradient computed at the current version."""
        return (bool(self.contrib.all()) and bool(self.refresh.all())
                and int(self.staleness.max(initial=0)) == 0
                and (self.roster is None or bool(self.roster.all())))

    def staleness_histogram(self):
        """{staleness value: count} over contributing deliveries."""
        vals = self.staleness[self.contrib]
        uniq, cnt = np.unique(vals, return_counts=True)
        return {int(u): int(c) for u, c in zip(uniq, cnt)}

    def summary(self) -> dict:
        """Aggregate statistics of the trace, roster-aware.

        On top of the historical means: staleness and per-step arrival
        percentiles (p50/p95/max), ``n_live`` statistics under rosters
        (min/p50 — previously the roster was silently ignored here), and
        ``live_fraction`` — the fraction of steps each agent was a roster
        member (all-ones without a roster)."""
        n = self.contrib.shape[1]
        arrived = self.contrib.sum(1)
        stal = self.staleness[self.contrib]
        live = (np.full(self.steps, n)
                if self.roster is None else self.roster.sum(1))

        def pct(v, q):
            return float(np.percentile(np.asarray(v, np.float64), q))
        return {
            "steps": int(self.steps),
            "mean_live": float(live.mean()) if self.steps else 0.0,
            "min_live": int(live.min()) if self.steps else 0,
            "live_p50": pct(live, 50) if self.steps else 0.0,
            "mean_arrived": float(arrived.mean()) if self.steps else 0.0,
            "arrived_p50": pct(arrived, 50) if self.steps else 0.0,
            "arrived_p95": pct(arrived, 95) if self.steps else 0.0,
            "min_arrived": int(arrived.min()) if self.steps else 0,
            "mean_staleness": float(stal.mean()) if stal.size else 0.0,
            "staleness_p50": pct(stal, 50) if stal.size else 0.0,
            "staleness_p95": pct(stal, 95) if stal.size else 0.0,
            "max_staleness": int(stal.max()) if stal.size else 0,
            "virtual_time": float(self.vclock[-1]) if self.steps else 0.0,
            "quorum_misses": int((~self.quorum_met).sum()),
            "staleness_hist": self.staleness_histogram(),
            "live_fraction": ([1.0] * n if self.roster is None else
                              [float(x) for x in
                               self.roster[:self.steps].mean(0)]),
        }


def poisson_arrival_times(rate: float, horizon: float, seed: int = 0,
                          t0: float = 0.0, max_events: int | None = None
                          ) -> np.ndarray:
    """Seed-deterministic Poisson-process event times on the virtual clock.

    The arrival side of a SERVING workload: requests hit the front door as
    a Poisson process of ``rate`` events per virtual second (i.i.d.
    exponential gaps), the same virtual-time axis :func:`simulate_arrivals`
    runs training deliveries on — so an offered-load sweep composes with
    the :mod:`~repro.simulator.faults` schedules driving the replicas.
    Returns the (k,) float64 sorted event times in ``[t0, t0 + horizon)``;
    ``rate <= 0`` yields no events, ``max_events`` truncates (admission
    control belongs to the consumer — see
    :class:`repro.serving.sched.RequestQueue`)."""
    if rate <= 0.0 or horizon <= 0.0:
        return np.zeros(0, np.float64)
    rng = np.random.default_rng(seed)
    # draw in chunks: E[k] = rate * horizon, pad generously, extend rarely
    times, t = [], float(t0)
    end = t0 + horizon
    while t < end and (max_events is None or len(times) < max_events):
        gaps = rng.exponential(1.0 / rate, size=max(16, int(rate * horizon)))
        for g in gaps:
            t += g
            if t >= end or (max_events is not None
                            and len(times) >= max_events):
                break
            times.append(t)
    return np.asarray(times, np.float64)


def simulate_arrivals(trace: FaultTrace, steps: int,
                      quorum: Optional[int] = None,
                      max_staleness: Optional[int] = None) -> AsyncTrace:
    """Run the virtual clock over a FaultTrace.

    quorum=None means the full live roster (fully synchronous barrier);
    quorum=k applies the update as soon as k gradients are in — capped per
    step at the live roster size, so a shrunken cluster keeps making
    progress (roster-aware quorum accounting)."""
    n = trace.n_agents
    h = trace.horizon
    assert h >= steps, (h, steps)
    q0 = n if quorum is None else max(1, min(int(quorum), n))

    contrib = np.zeros((steps, n), bool)
    staleness = np.zeros((steps, n), np.int64)
    refresh = np.zeros((steps, n), bool)
    vclock = np.zeros(steps)
    quorum_met = np.ones(steps, bool)

    # heap key (arrival_vtime, agent, seq): same-instant ties resolve by
    # AGENT ID, so the accepted set is a function of the trace alone and
    # never of internal dispatch order (seq only breaks exact re-pushes)
    heap = []                 # (arrival_vtime, agent, seq, version, immune)
    waiting = {}              # version -> [agents waiting for it to exist]
    seq = 0

    def dispatch(agent: int, vtime: float, version: int,
                 immune: bool = False):
        nonlocal seq
        v = version
        while v < steps and not (trace.alive[min(v, h - 1), agent]
                                 and trace.member(v, agent)):
            v += 1            # down or out of roster: wait to re-enter
        if v >= steps:
            return            # never returns within the horizon
        if v > version:
            waiting.setdefault(v, []).append((agent, immune))
            return
        refresh[v, agent] = True
        heapq.heappush(
            heap, (vtime + float(trace.delay[min(v, h - 1), agent]),
                   agent, seq, v, immune))
        seq += 1

    for i in range(n):
        dispatch(i, 0.0, 0)

    now = 0.0
    for t in range(steps):
        got = []
        live_t = trace.n_live(t)
        q_t = min(q0, live_t) if quorum is not None else live_t

        def receive(vt, agent, version, immune) -> bool:
            """True if the delivery is accepted into update t."""
            if trace.roster is not None and not trace.roster[
                    min(version, h - 1):min(t, h - 1) + 1, agent].all():
                # the sender left the roster at some point while its
                # gradient was in flight (its state is gone — even if it
                # already rejoined by the arrival instant): discard; it
                # re-dispatches fresh at its next membership version
                dispatch(agent, vt, t)
                return False
            if (not immune) and trace.drop[min(version, h - 1), agent]:
                dispatch(agent, vt, t, immune=True)     # retry, never re-drop
                return False
            if max_staleness is not None and t - version > max_staleness:
                dispatch(agent, vt, t)                  # too stale: recompute
                return False
            got.append((agent, version))
            return True

        while len(got) < q_t and heap:
            vt, agent, _, version, immune = heapq.heappop(heap)
            now = max(now, vt)
            receive(vt, agent, version, immune)
        # everything that arrived by the quorum instant joins the update
        while heap and heap[0][0] <= now:
            vt, agent, _, version, immune = heapq.heappop(heap)
            receive(vt, agent, version, immune)

        if len(got) < q_t or live_t == 0:
            quorum_met[t] = False
        for agent, version in got:
            contrib[t, agent] = True
            staleness[t, agent] = t - version
        vclock[t] = now
        # version t+1 now exists: contributors re-dispatch against it, and
        # recovered agents that were waiting for it wake up
        for agent, _ in got:
            dispatch(agent, now, t + 1)
        for agent, immune in waiting.pop(t + 1, ()):
            dispatch(agent, now, t + 1, immune=immune)

    roster = (None if trace.roster is None
              else trace.roster[:steps].copy())
    return AsyncTrace(contrib=contrib, staleness=staleness, refresh=refresh,
                      vclock=vclock, quorum_met=quorum_met, roster=roster)
