"""Fault-injection cluster simulator + staleness-aware async training.

Layers (each usable on its own):
  faults      — composable seed-deterministic fault schedules -> FaultTrace
  events      — virtual-clock event queue -> arrival/staleness AsyncTrace
  async_loop  — bounded-staleness training loop (sync loop = degenerate case)
"""
from repro.simulator.faults import (Churn, CrashRecover, FaultTrace, Join,
                                    MessageDrop, Partition, PermanentCrash,
                                    Rejoin, SamplingPolicy, Straggler,
                                    compile_schedule, no_faults)
from repro.simulator.events import (AsyncTrace, poisson_arrival_times,
                                    simulate_arrivals)
from repro.simulator.async_loop import (SimConfig, async_train_loop,
                                        make_async_step, plan_arrivals,
                                        staleness_weights)

__all__ = [
    "Straggler", "CrashRecover", "PermanentCrash", "MessageDrop",
    "Partition", "Join", "Rejoin", "Churn", "SamplingPolicy",
    "FaultTrace", "compile_schedule", "no_faults",
    "AsyncTrace", "simulate_arrivals", "poisson_arrival_times",
    "SimConfig", "async_train_loop", "make_async_step", "plan_arrivals",
    "staleness_weights",
]
