"""Bounded-staleness asynchronous training over the fault-injection
simulator — the execution layer for the survey's non-Byzantine fault modes
(crash/recover, stragglers, message loss) and the staleness-aware
asynchronous setting of the Zeno++/Kardam line of work.

Pipeline per server step t (one parameter version):

  1. the host reads row t of the precompiled :class:`AsyncTrace`
     (who dispatches, who delivers, how stale) — fixed shapes, so the jitted
     step compiles once regardless of the fault schedule;
  2. agents dispatching at version t compute gradients against the current
     params and write them into the in-flight buffer (their delivery may
     land many versions later);
  3. delivered gradients are aggregated with the robust filter catalogue via
     the config's :class:`~repro.core.aggregators.AggregatorSpec`
     (``spec.aggregate(sent, mask=..., weights=...)``), weighted by a
     staleness discount; stateful rules (Zeno, the delay-adaptive
     ``zeno_pp``) have their state threaded explicitly through the jitted
     step; ``impl="pallas"`` specs run the fused masked kernels
     (:mod:`repro.kernels.masked`) here — the quorum mask and discount
     weights enter the kernel as ordinary traced operands, so the step
     compiles ONCE per shape regardless of the fault schedule, and the
     threaded ``agg_state`` pytree passes through the kernel path
     untouched; if the quorum was missed (stragglers/crashes) the loop can
     fall back to Draco-style gradient coding over the same (n, P) arena
     (:func:`repro.core.redundancy.coding.flat_draco_aggregate` with the
     delivery mask; mixed-dtype trees decode leaf-wise) — under elastic
     membership the code regroups the PACKED live rows with the bucket's
     :func:`~repro.core.redundancy.coding.coding_groups` table, derived
     once per bucket at step-build time;
  4. the server optimizer applies the update, creating version t+1.

The synchronous loop is the degenerate case: with no faults every trace row
is "pure" (all n agents deliver zero-staleness gradients computed at the
current version) and the host dispatches to the *exact* synchronous
train-step from :mod:`repro.training.step`, so ``train_loop`` ==
``async_train_loop`` bit-for-bit when latency is uniform and quorum = n.

Elastic membership: when the schedule contains Join/Rejoin/Churn specs the
trace carries a per-step roster, and an elastic-n aggregator
(``make_spec(..., n=elastic(n_max, buckets=...))``) packs the LIVE agents
into per-bucket fixed-shape stacks — the rule's (n, f) plan tracks the
live roster, the roster indices are traced operands, and churn over the
bucketed range costs at most ``len(buckets)`` step compilations
(tests/test_membership_retrace.py).  A non-elastic spec under churn keeps
its n_max plan and masks departed rows (one compile, imputed ghosts).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.core.aggregators import tree_stack_ravel, tree_where_agents
from repro.core.flat import (FlatPlan, QUANT_DTYPES, fake_quantize,
                             quantize_rows)
from repro.obs.counters import count_trace
from repro.core.attacks import (get_attack, is_adaptive_attack,
                                make_adaptive_attack, make_byzantine_mask)
from repro.core.momentum import init_momentum, worker_momentum
from repro.core.redundancy.coding import (coding_groups,
                                          flat_draco_aggregate,
                                          tree_draco_aggregate)
from repro.data import label_flip
from repro.models import init_params, loss_fn
from repro.optim import apply_updates
from repro.simulator.events import AsyncTrace, simulate_arrivals
from repro.simulator.faults import compile_schedule

# NOTE: repro.training.step is imported lazily inside the factories below —
# training.loop delegates here, so a module-level import would be circular.


@dataclass(frozen=True)
class SimConfig:
    """Cluster-simulation knobs for :func:`async_train_loop`."""
    faults: tuple = ()                    # fault specs (simulator.faults)
    quorum: Optional[int] = None          # None -> n_agents (full barrier)
    max_staleness: Optional[int] = None   # None -> unbounded
    staleness_weighting: str = "poly"     # none | poly | exp
    staleness_power: float = 1.0          # poly: (1 + s)^-power
    staleness_gamma: float = 0.7          # exp: gamma^s
    base_delay: float = 1.0               # virtual time of one computation
    seed: int = 0                         # fault-schedule seed
    coded_fallback_r: int = 0             # >0: draco(r) when quorum missed


def staleness_weights(sim: SimConfig, atrace: AsyncTrace) -> np.ndarray:
    """(steps, n) float32 per-delivery weights: staleness discount on
    contributors, 0 elsewhere (the discount table itself lives in
    :func:`repro.core.aggregators.staleness_discount_table`)."""
    from repro.core.aggregators import staleness_discount_table
    s = atrace.staleness.astype(np.float64)
    w = staleness_discount_table(s, sim.staleness_weighting,
                                 sim.staleness_power, sim.staleness_gamma)
    return (w * atrace.contrib).astype(np.float32)


def plan_arrivals(sim: SimConfig, n_agents: int, steps: int) -> AsyncTrace:
    """Compile the fault schedule and run the virtual clock exactly as
    :func:`async_train_loop` will — shared so benchmarks/analysis report
    the same trace the loop executes."""
    ftrace = compile_schedule(sim.faults, n_agents, steps + 1, seed=sim.seed,
                              base_delay=sim.base_delay)
    return simulate_arrivals(ftrace, steps, quorum=sim.quorum,
                             max_staleness=sim.max_staleness)


def make_async_step(cfg, bz, optimizer, fallback_r: int = 0,
                    bucket: int | None = None, telemetry: bool = False):
    """Returns async_step(params, opt_state, momentum, buffer, agg_state,
    batch, key, refresh, contrib_w, use_coded[, roster_idx, roster_valid])
    -> (params, opt_state, momentum, buffer, agg_state, metrics).

    ``telemetry`` (static Python flag): metrics additionally carry a
    fixed-shape ``"telemetry"`` struct — the aggregator's (n,) selection
    weights, delivery mask and contribution weights
    (``spec.selection_weights``, see :mod:`repro.obs`).  ``False`` emits
    the EXACT historical jaxpr; ``True`` adds only (n,)-sized aux
    outputs — bit-identical results and the same elastic-bucket compile
    budget either way.

    ``refresh``   (n,) bool  — agents computing a fresh gradient this step;
    ``contrib_w`` (n,) f32   — staleness-discounted delivery weights
                               (0 = not delivered);
    ``agg_state`` pytree     — aggregator state (``spec.init_state``; {}
                               for stateless rules), threaded explicitly;
    ``use_coded`` () bool    — quorum missed: aggregate with the gradient
                               code over delivered rows instead of the
                               filter (requires ``fallback_r``).

    ``bucket`` (elastic membership): the step additionally takes
    ``roster_idx`` (bucket,) int32 — live agent slots, padded by repeating
    a live slot — and ``roster_valid`` (bucket,) bool — which slots are
    real.  The live rows are packed into a (bucket, ...) stack and
    aggregated by ``spec.respecialize(bucket)`` (per-bucket f and static
    plans), with pad slots masked out; both roster operands are TRACED, so
    churn within a bucket never recompiles and churn across the bucketed
    range compiles at most once per bucket."""
    from repro.training.step import tree_attack
    adaptive_name = bz.attack if is_adaptive_attack(bz.attack) else None
    attack_fn = get_attack(bz.attack, **bz.attack_hyper) \
        if bz.attack != "none" and adaptive_name is None else None
    byz_mask = make_byzantine_mask(bz.n_agents, bz.f)
    spec = bz.resolve_spec()
    if spec.staleness_aware:                 # recurses through wrappers
        # this loop already converts staleness to discount multipliers
        # (SimConfig.staleness_weighting) — a staleness_aware spec would
        # re-interpret those multipliers as round counts and INVERT the
        # discounting, so reject loudly instead of silently mis-weighting
        raise ValueError(
            f"{spec.name} consumes raw staleness counts, but the async "
            "loop passes discount multipliers — configure "
            "SimConfig.staleness_weighting and use the inner spec instead")
    # agg_dtype in QUANT_DTYPES selects the compressed-exchange pipeline:
    # per-row codes + scale sidecar quantized at ravel time, in-tile
    # dequant (see training/step.py — same contract)
    quant = bool(bz.agg_dtype) and bz.agg_dtype in QUANT_DTYPES
    if bz.agg_dtype and not quant:
        spec = spec.with_impl_hyper_if_supported(native_dtype=True)
    spec = spec.respecialize(bucket) if bucket is not None else spec
    stateful = spec.stateful
    # defense-aware attack, compiled against the spec the defense actually
    # runs — the respecialized BUCKET spec under elastic membership (the
    # adversary tracks the live (n, f) window), applied on the full
    # in-flight arena.  Attack state rides inside the agg_state slot as
    # {"agg": ..., "atk": ...} so the jitted signature is unchanged.
    adaptive = (make_adaptive_attack(adaptive_name, spec, **bz.attack_hyper)
                if adaptive_name is not None else None)
    # roster-aware gradient coding: the group table is derived HERE, at
    # step-build (respecialize) time, from the bucket capacity — lru-cached
    # per (n, r) like the trim tables, baked into the traced step as a
    # static constant.  The static path validates n % r == 0 (ValueError);
    # elastic buckets may carry a ragged trailing group.
    r_code = bz.draco_r if bz.draco_r > 0 else fallback_r
    n_agg = bucket if bucket is not None else bz.n_agents
    groups = (coding_groups(n_agg, r_code, allow_ragged=bucket is not None)
              if r_code > 0 else None)
    # zero-copy flat pipeline: dense-stack impls ravel the delivered
    # gradients ONCE per step into an (n, P) arena at the communication
    # boundary and unravel once at optimizer-apply; the coded paths ride
    # the same arena (the vote is Gram-based, the application a one-hot
    # weighted sum — kernels.pairwise/wsum)
    use_flat = spec.flat_capable

    def agent_loss(p, agent_batch):
        return loss_fn(cfg, p, agent_batch)

    def async_step(params, opt_state, momentum, buffer, agg_state, batch,
                   key, refresh, contrib_w, use_coded,
                   roster_idx=None, roster_valid=None):
        count_trace("async_step")
        atk_state = None
        if adaptive is not None:
            atk_state, agg_state = agg_state["atk"], agg_state["agg"]
        # (2) fresh gradients at the current version for dispatching agents
        losses, grads = jax.vmap(
            jax.value_and_grad(agent_loss), in_axes=(None, 0))(params, batch)
        if bz.momentum_alpha > 0.0:
            new_m, sent_now = worker_momentum(momentum, grads,
                                              bz.momentum_alpha)
            momentum = tree_where_agents(refresh, new_m, momentum)
            grads = sent_now
        buffer = tree_where_agents(refresh, grads, buffer)

        # (3) Byzantine corruption happens at delivery time, on whatever is
        # in flight — stale honest gradients stay honest, Byzantine rows are
        # arbitrary every round (matches the synchronous injection point)
        sent = buffer
        if attack_fn is not None:
            sent = tree_attack(attack_fn, key, sent, byz_mask)
        elif adaptive is not None:
            # defense-aware attacks operate on the raveled (n, P) arena —
            # min-max needs whole-row geometry, not per-leaf slices.  The
            # omniscient adversary also reads the defense's carried center
            # (state-aware threat model).
            aplan = FlatPlan.for_tree(sent)
            arows = aplan.ravel(sent, jnp.float32)
            dvec = None
            if stateful and "server_grad" in agg_state:
                dvec = tree_stack_ravel(jax.tree.map(
                    lambda l: l.astype(jnp.float32)[None],
                    agg_state["server_grad"]))[0]
            arows, atk_state = adaptive(key, arows, byz_mask, atk_state,
                                        dvec)
            sent = aplan.unravel_stack(arows)
        if bz.agg_dtype and not quant:
            sent = jax.tree.map(
                lambda l: l.astype(jnp.dtype(bz.agg_dtype)), sent)

        mask = contrib_w > 0.0
        plan = FlatPlan.for_tree(sent)
        codes = qs = arena = None
        if quant:
            # quantize the wire: codes + per-row fp32 scale.  Codes feed
            # the scaled kernels only on the plain flat path; the coded
            # vote (Gram-based, no scaled kernels) and the tree fallbacks
            # see the fake-quantized fp32 stack instead — identical
            # compressed-exchange semantics on every path.
            arena = plan.ravel(sent, jnp.float32)
            qdt = jnp.dtype(bz.agg_dtype)
            if use_flat and bz.draco_r == 0:
                if fallback_r > 0:
                    arena = fake_quantize(arena, qdt)
                else:
                    codes, qs = quantize_rows(arena, qdt)
            else:
                sent = plan.unravel_stack(fake_quantize(arena, qdt))
                arena = None
        if bucket is not None:
            w_b = jnp.where(roster_valid, contrib_w[roster_idx], 0.0)
        if bz.draco_r > 0:
            # coded regime: the repetition code already handles partial
            # delivery (vote among delivered group members); under elastic
            # membership the PACKED live rows are regrouped by the
            # bucket's table (exact in the parallel regime — every agent
            # computes the same shard).  tree_draco_aggregate rides the
            # (n, P) arena internally for uniform-dtype trees.
            if bucket is not None:
                sent_b = jax.tree.map(lambda l: l[roster_idx], sent)
                agg = tree_draco_aggregate(sent_b, bz.draco_r,
                                           mask=w_b > 0.0, groups=groups)
            else:
                agg = tree_draco_aggregate(sent, bz.draco_r, mask=mask,
                                           groups=groups)
        elif use_flat and (quant or plan.uniform_dtype is not None):
            # ONE ravel into the (n, P) arena at the communication
            # boundary; the quorum mask and staleness discounts enter the
            # masked kernels as traced operands and the single unravel
            # happens below, at optimizer-apply.  Mixed-dtype trees keep
            # the tree path: a fp32 arena would impute masked rows
            # without each leaf's native rounding (not bitwise) — except
            # under quantized exchange, which erases leaf dtypes anyway.
            if arena is None:
                arena = plan.ravel(sent)
            wire = codes if codes is not None else arena
            if bucket is not None:
                rows, rmask, rw = wire[roster_idx], w_b > 0.0, w_b
                rqs = qs[roster_idx] if qs is not None else None
            else:
                rows, rmask, rw = wire, mask, contrib_w
                rqs = qs
            vec = spec.aggregate_flat(rows, mask=rmask, weights=rw,
                                      scale=rqs,
                                      state=agg_state if stateful else None)
            if fallback_r > 0:
                # quorum missed: decode the repetition code over the SAME
                # arena rows (both candidates are (P,) fp32 — one select,
                # one unravel; under quant, rows are the fake-quantized
                # fp32 arena — codes are only cut when fallback_r == 0)
                coded = flat_draco_aggregate(rows, fallback_r, mask=rmask,
                                             groups=groups)
                vec = jnp.where(use_coded, coded, vec)
            agg = plan.unravel(vec)
        elif bucket is not None:
            # elastic membership: pack the live rows into the bucket's
            # fixed-shape stack; pad slots (repeated live rows) are masked
            # out, so the rule runs its per-bucket (n, f) plan over the
            # live roster only
            sent_b = jax.tree.map(lambda l: l[roster_idx], sent)
            agg = spec.aggregate(sent_b, mask=w_b > 0.0, weights=w_b,
                                 state=agg_state if stateful else None)
            if fallback_r > 0:
                coded = tree_draco_aggregate(sent_b, fallback_r,
                                             mask=w_b > 0.0, groups=groups)
                agg = jax.tree.map(
                    lambda a, c: jnp.where(use_coded, c.astype(a.dtype), a),
                    agg, coded)
        else:
            agg = spec.aggregate(sent, mask=mask, weights=contrib_w,
                                 state=agg_state if stateful else None)
            if fallback_r > 0:
                coded = tree_draco_aggregate(sent, fallback_r, mask=mask,
                                             groups=groups)
                agg = jax.tree.map(
                    lambda a, c: jnp.where(use_coded, c.astype(a.dtype), a),
                    agg, coded)
        telem = None
        if telemetry:
            # fixed-shape (n,) aux outputs, computed OUTSIDE the aggregate
            # call (the update above is untouched — results stay
            # bit-identical) and BEFORE the state transition (the rule
            # selected against the pre-step state)
            n = bz.n_agents
            st = agg_state if stateful else None
            mf = mask.astype(jnp.float32)
            particip = mf / jnp.maximum(jnp.sum(mf), 1.0)
            if bz.draco_r > 0:
                sel = particip          # per-group votes: delivery shares
            elif bucket is not None:
                stack_b = (arena[roster_idx]
                           if use_flat and (quant
                                            or plan.uniform_dtype is not None)
                           else jax.tree.map(lambda l: l[roster_idx], sent))
                sel_b = spec.selection_weights(stack_b, mask=w_b > 0.0,
                                               weights=w_b, state=st)
                sel = jnp.zeros((n,), jnp.float32).at[roster_idx].add(
                    jnp.where(roster_valid, sel_b, 0.0))
                if fallback_r > 0:
                    # quorum missed -> the coded vote aggregated instead
                    sel = jnp.where(use_coded, particip, sel)
            else:
                stack = (arena
                         if use_flat and (quant
                                          or plan.uniform_dtype is not None)
                         else sent)
                sel = spec.selection_weights(stack, mask=mask,
                                             weights=contrib_w, state=st)
                if fallback_r > 0:
                    # quorum missed -> the coded vote aggregated instead
                    sel = jnp.where(use_coded, particip, sel)
            telem = {"sel_w": sel, "mask": mask,
                     "contrib_w": contrib_w.astype(jnp.float32)}
        if stateful:
            agg_state = spec.update_state(agg_state, agg)
        if adaptive is not None:
            agg_state = {"agg": agg_state, "atk": atk_state}

        # (4) server-side optimizer
        updates, opt_state = optimizer.update(agg, opt_state, params)
        params = apply_updates(params, updates)

        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)))
            for l in jax.tree.leaves(agg)))
        honest = ~byz_mask
        metrics = {
            "loss": jnp.sum(losses * honest) / jnp.sum(honest),
            "loss_all": jnp.mean(losses),
            "grad_norm": gnorm,
        }
        if telem is not None:
            metrics["telemetry"] = telem
        return params, opt_state, momentum, buffer, agg_state, metrics

    return async_step


def async_train_loop(cfg, bz, optimizer, dataset, steps: int,
                     sim: Optional[SimConfig] = None, seed: int = 0,
                     log_every: int = 10, ckpt_dir: str | None = None,
                     ckpt_every: int = 0, poison_labels: bool = False,
                     jit: bool = True, params=None, log_fn=print,
                     recorder=None, telemetry: Optional[bool] = None,
                     _force_general: bool = False):
    """Returns (params, history list of metric dicts).

    sim=None (or any schedule whose trace stays synchronous) reproduces the
    historical synchronous ``train_loop`` bit-for-bit: pure steps dispatch
    to the exact synchronous train step.  ``_force_general`` routes pure
    steps through the general async path too (testing only).

    ``recorder`` (a :class:`repro.obs.recorder.Recorder`): the loop feeds
    it run metadata, per-step spans/metrics, the aggregator's selection
    telemetry, roster-delta annotations and the recompile ledger — all on
    host, between steps, so recording adds ZERO compiles and leaves
    results bit-identical.  ``telemetry`` forces the fixed-shape
    selection aux outputs on/off explicitly (default: on exactly when a
    recorder is attached)."""
    from repro.training.step import make_train_step
    sim = sim if sim is not None else SimConfig()
    n = bz.n_agents
    spec = bz.resolve_spec()
    atrace = plan_arrivals(sim, n, steps)
    roster = atrace.roster                 # (steps, n) bool | None
    el = spec.elastic_n                    # wrapper chains delegate
    if el is not None:
        if el.n_max != n:
            raise ValueError(
                f"elastic aggregator {spec.describe()} was built for "
                f"n_max={el.n_max} but the config declares "
                f"n_agents={n}")
        if bz.draco_r > 0 or sim.coded_fallback_r > 0:
            # warm the per-bucket coding group tables up front (lru-cached
            # with the step plans, same trick as the trim tables) and
            # surface a bad r at BUILD time, not mid-run
            r_code = bz.draco_r if bz.draco_r > 0 else sim.coded_fallback_r
            coding_groups(n, r_code)           # master roster: r must | n
            for b in el.buckets:
                coding_groups(int(b), r_code, allow_ragged=True)
        if roster is None:
            # membership never changes: run the concrete n_max spec (the
            # elastic master is bit-for-bit its own n_max bucket)
            bz = dataclasses.replace(bz, aggregator=spec.respecialize(n))
            spec = bz.resolve_spec()
            el = None
    stateful = spec.stateful
    adaptive = is_adaptive_attack(bz.attack)
    contrib_w = staleness_weights(sim, atrace)
    if (bz.group_size > 1 or bz.reshard) and (stateful
                                              or not atrace.is_synchronous()):
        # the general async step implements neither knob — stateful specs
        # always run it, so don't silently drop grouping/resharding
        raise NotImplementedError(
            "group_size/reshard perf knobs assume the synchronous step "
            "(synchronous delivery and a stateless aggregator)")

    key = jax.random.PRNGKey(seed)
    k_init, k_run = jax.random.split(key)
    if params is None:
        params = init_params(cfg, k_init)
    opt_state = optimizer.init(params)
    momentum = None
    if bz.momentum_alpha > 0.0:
        proto = jax.tree.map(
            lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params)
        momentum = init_momentum(proto)

    telemetry = (recorder is not None) if telemetry is None else telemetry
    if recorder is not None:
        from repro.obs.telemetry import dispatch_record
        recorder.emit("run", steps=steps, n_agents=n,
                      dispatch=dispatch_record(spec),
                      quorum=sim.quorum, max_staleness=sim.max_staleness,
                      attack=bz.attack, f=bz.f, seed=seed,
                      faults=[repr(f) for f in sim.faults])
    # stateful aggregators must observe (and update) their state every
    # step, so they always run the general path; likewise defense-aware
    # attacks (their state and the defense's center thread through the
    # async step).  The synchronous train step stays the stateless,
    # static-attack fast path.
    step_fn = (None if stateful or adaptive
               else make_train_step(cfg, bz, optimizer,
                                    telemetry=telemetry))
    # donate the in-flight gradient buffer (the step returns its updated
    # twin): on accelerator backends the buffer-sized HBM block is reused
    # in place — the flat pipeline's "donated arena"; CPU ignores
    # donation, so skip it there to keep logs clean
    donate = () if jax.default_backend() == "cpu" else (3,)
    async_fn = make_async_step(cfg, bz, optimizer,
                               fallback_r=sim.coded_fallback_r,
                               telemetry=telemetry)
    if jit:
        step_fn = jax.jit(step_fn) if step_fn is not None else None
        async_fn = jax.jit(async_fn, donate_argnums=donate)

    # elastic membership: one step function per roster BUCKET (built
    # lazily, compiled at most len(el.buckets) times over the whole run —
    # the roster operands themselves are traced, so churn within a bucket
    # reuses the bucket's single compilation)
    bucket_fns: dict = {}

    def bucket_fn(b: int):
        if b not in bucket_fns:
            fn = make_async_step(cfg, bz, optimizer, bucket=b,
                                 telemetry=telemetry)
            bucket_fns[b] = (jax.jit(fn, donate_argnums=donate) if jit
                             else fn)
        return bucket_fns[b]
    byz_mask = make_byzantine_mask(n, bz.f)
    agg_state = (spec.init_state(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))
        if stateful else {})
    if adaptive:
        # attack state bundles into the agg_state slot — the jitted step
        # signature and every call site stay unchanged.  State structure
        # is bucket-independent, so it threads across respecializations.
        agg_state = {"agg": agg_state,
                     "atk": make_adaptive_attack(
                         bz.attack, spec, **bz.attack_hyper).init_state()}

    # a step is "pure" iff it is exactly the synchronous step: the FULL
    # roster dispatches AND delivers with zero staleness
    pure = (atrace.contrib.all(1) & atrace.refresh.all(1)
            & (atrace.staleness.max(1, initial=0) == 0))
    if roster is not None:
        pure &= roster.all(1)
    if _force_general or stateful or adaptive:
        pure = np.zeros(steps, bool)

    # in-flight gradient buffer (fp32 covers every exchange dtype) and
    # refreshes deferred across update-less steps: params are unchanged
    # there, so the gradient is computed at the correct parameter version
    # (the data batch is a fresh sample from a later step index — iid-
    # equivalent, though not the literal batch of the dispatch instant)
    buffer = jax.tree.map(
        lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params)
    pending_refresh = np.zeros(n, bool)

    history = []
    t0 = time.time()
    for step in range(steps):
        k_run, k_data, k_step = jax.random.split(k_run, 3)
        batch = dataset.batch(k_data, step)
        if poison_labels:
            batch = label_flip(batch, byz_mask, cfg.vocab_size)
        arrived = int(atrace.contrib[step].sum())
        st0 = recorder.now() if recorder is not None else None
        if pure[step]:
            params, opt_state, momentum, metrics = step_fn(
                params, opt_state, momentum, batch, k_step)
        elif arrived == 0:
            # nobody delivered: version unchanged, defer this step's
            # dispatches to the next step that actually runs
            pending_refresh |= atrace.refresh[step]
            metrics = None
        else:
            refresh = atrace.refresh[step] | pending_refresh
            pending_refresh = np.zeros(n, bool)
            use_coded = bool(not atrace.quorum_met[step]
                             and sim.coded_fallback_r > 0)
            if el is not None:
                # pack the live roster into its bucket's fixed shape
                # (arrived > 0 here, and contributors are members, so the
                # roster row has at least one live agent)
                b, idx, valid = el.pack(np.flatnonzero(roster[step]))
                (params, opt_state, momentum, buffer, agg_state,
                 metrics) = bucket_fn(int(b))(
                    params, opt_state, momentum, buffer, agg_state, batch,
                    k_step, jnp.asarray(refresh),
                    jnp.asarray(contrib_w[step]), jnp.asarray(use_coded),
                    jnp.asarray(idx), jnp.asarray(valid))
            else:
                (params, opt_state, momentum, buffer, agg_state,
                 metrics) = async_fn(
                    params, opt_state, momentum, buffer, agg_state, batch,
                    k_step, jnp.asarray(refresh),
                    jnp.asarray(contrib_w[step]), jnp.asarray(use_coded))
        telem = metrics.pop("telemetry", None) if metrics else None
        if recorder is not None:
            mrec = ({k: float(v) for k, v in metrics.items()}
                    if metrics is not None else {})
            mrec["arrived"] = arrived
            mrec["n_live"] = atrace.n_live(step)
            mrec["staleness_mean"] = (
                float(atrace.staleness[step][atrace.contrib[step]].mean())
                if arrived else 0.0)
            mrec["staleness_max"] = (
                int(atrace.staleness[step][atrace.contrib[step]].max())
                if arrived else 0)
            mrec["quorum_ok"] = bool(atrace.quorum_met[step])
            if not atrace.quorum_met[step]:
                recorder.fault(step, "quorum_miss", arrived=arrived)
            recorder.step(step, t0=st0, t1=recorder.now(), metrics=mrec,
                          telemetry=telem,
                          roster=(roster[step] if roster is not None
                                  else None))
        if step % log_every == 0 or step == steps - 1:
            if metrics is None:
                m = {"loss": float("nan"), "loss_all": float("nan"),
                     "grad_norm": 0.0}
            else:
                m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.time() - t0
            m["arrived"] = arrived
            m["n_live"] = atrace.n_live(step)
            m["staleness_mean"] = (
                float(atrace.staleness[step][atrace.contrib[step]].mean())
                if arrived else 0.0)
            m["vclock"] = float(atrace.vclock[step])
            history.append(m)
            extra = ("" if pure[step] else
                     f"  arr {arrived:2d}  stal {m['staleness_mean']:.2f}")
            log_fn(f"step {step:5d}  loss {m['loss']:.4f}  "
                   f"gnorm {m['grad_norm']:.3f}{extra}")
        if ckpt_dir and ckpt_every and step and step % ckpt_every == 0:
            save(ckpt_dir, step, {"params": params, "opt": opt_state})
    if ckpt_dir:
        save(ckpt_dir, steps, {"params": params, "opt": opt_state})
    return params, history
