"""Composable, seed-deterministic fault schedules — survey §2 fault taxonomy.

The survey's fault spectrum is wider than Byzantine gradients: crash/recover
faults, permanent crashes, stragglers (slow agents), message loss, and
network partitions (§2.2–§2.3, §4).  A :class:`FaultSchedule` is a tuple of
fault *specs*; compiling it against (n_agents, horizon, seed) yields a
:class:`FaultTrace` of plain per-version arrays that both the event-driven
cluster simulator (:mod:`repro.simulator.events`) and the p2p DGD loop
(:mod:`repro.core.p2p.dgd`) consume:

  ``alive[v, i]``  agent i is up while computing the gradient it dispatches
                   at parameter version v (crash during computation is
                   modelled as not dispatching at that version);
  ``drop[v, i]``   the message dispatched at version v by agent i is lost in
                   transit (computed, never delivered — the agent retries
                   once it discovers the loss, retries are never re-dropped);
  ``delay[v, i]``  compute + network latency, in virtual-time units of one
                   base gradient computation, for the dispatch at version v;
  ``adj[v]``       (n, n) bool link mask for decentralized topologies
                   (``None`` unless a :class:`Partition` spec is present);
  ``roster[v, i]`` agent i is a MEMBER of the cluster at version v (``None``
                   unless a membership spec — :class:`Join`, :class:`Rejoin`,
                   :class:`Churn`, :class:`SamplingPolicy` — is present).
                   Membership is a stronger notion than liveness: a crashed
                   agent is still expected back and still counts toward the
                   deployment's (n, f) bookkeeping, while a non-member can
                   neither dispatch, arrive, nor count toward quorum
                   (elastic membership — agents joining/rejoining, not just
                   leaving).  :class:`SamplingPolicy` flips the roster from
                   *observed* churn to a *chosen* schedule: federated
                   client sampling emitted through the same machinery.

Everything is sampled from one ``numpy.random.default_rng(seed)`` in spec
order, so a schedule is a pure function of (specs, n, horizon, seed) — the
property the determinism tests pin down.  The arrays are host-side numpy on
purpose: the training loop indexes one row per step and feeds it to the
jitted step function as ordinary jnp inputs (fixed shapes, one compile).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


def _agent_idx(agents, n):
    return np.arange(n) if agents is None else np.asarray(agents, np.int64)


# ---------------------------------------------------------------------------
# fault specs


@dataclass(frozen=True)
class Straggler:
    """Multiplicative slowdown of compute latency (survey §2.3 "slow
    agents").  Per dispatch, with probability ``prob``, the latency is
    multiplied by a sample from ``dist``:

      lognormal — exp(sigma * N(0,1))          (heavy-ish tail, median 1)
      exp       — 1 + Exponential(scale)
      pareto    — 1 + Pareto(alpha=scale)      (heavy tail)
      constant  — scale                        (deterministic slow agent)
    """
    dist: str = "lognormal"
    scale: float = 1.0
    prob: float = 1.0
    agents: Optional[Tuple[int, ...]] = None

    def apply(self, rng, alive, drop, delay, adj, roster):
        h, n = delay.shape
        sel = _agent_idx(self.agents, n)
        shape = (h, len(sel))
        if self.dist == "lognormal":
            factor = np.exp(self.scale * rng.standard_normal(shape))
        elif self.dist == "exp":
            factor = 1.0 + rng.exponential(self.scale, shape)
        elif self.dist == "pareto":
            factor = 1.0 + rng.pareto(self.scale, shape)
        elif self.dist == "constant":
            factor = np.full(shape, self.scale)
        else:
            raise KeyError(self.dist)
        hit = rng.random(shape) < self.prob
        delay[:, sel] *= np.where(hit, factor, 1.0)


@dataclass(frozen=True)
class CrashRecover:
    """Crash/recover (fail-stop with repair, survey §2.2): while up, an agent
    crashes each version with probability ``rate``; downtime is geometric
    with mean ``mean_down`` versions."""
    rate: float = 0.05
    mean_down: float = 3.0
    agents: Optional[Tuple[int, ...]] = None

    def apply(self, rng, alive, drop, delay, adj, roster):
        h, n = alive.shape
        sel = _agent_idx(self.agents, n)
        p_up = 1.0 / max(self.mean_down, 1.0)       # geometric recovery
        for i in sel:
            up = True
            for v in range(h):
                if up:
                    if rng.random() < self.rate:
                        up = False
                else:
                    if rng.random() < p_up:
                        up = True
                alive[v, i] &= up


@dataclass(frozen=True)
class PermanentCrash:
    """Fail-stop without repair from version ``at`` onward."""
    agents: Tuple[int, ...]
    at: int = 0

    def apply(self, rng, alive, drop, delay, adj, roster):
        sel = _agent_idx(self.agents, alive.shape[1])
        alive[self.at:, sel] = False


@dataclass(frozen=True)
class MessageDrop:
    """Iid message loss: the gradient dispatched at version v is lost in
    transit with probability ``p`` (omission fault, survey §2.2)."""
    p: float = 0.1
    agents: Optional[Tuple[int, ...]] = None

    def apply(self, rng, alive, drop, delay, adj, roster):
        h, n = drop.shape
        sel = _agent_idx(self.agents, n)
        drop[:, sel] |= rng.random((h, len(sel))) < self.p


# ---------------------------------------------------------------------------
# membership (elastic roster) specs — survey §2.2's churn beyond fail-stop:
# real federated/swarm deployments have agents joining and rejoining, and
# every Table-2 guarantee is a function of the LIVE (n, f)


@dataclass(frozen=True)
class Join:
    """Agents that are not founding members: they enter the roster at
    version ``at`` and stay (barring later membership specs)."""
    agents: Tuple[int, ...]
    at: int

    def apply(self, rng, alive, drop, delay, adj, roster):
        sel = _agent_idx(self.agents, roster.shape[1])
        roster[:self.at, sel] = False


@dataclass(frozen=True)
class Rejoin:
    """A scheduled leave/rejoin cycle: members until ``leave_at``, out of
    the roster during [leave_at, rejoin_at), members again after.  A
    gradient in flight when the agent leaves is discarded (the agent is
    gone); on rejoining it dispatches fresh against the then-current
    version."""
    agents: Tuple[int, ...]
    leave_at: int
    rejoin_at: int

    def apply(self, rng, alive, drop, delay, adj, roster):
        if self.rejoin_at < self.leave_at:
            raise ValueError((self.leave_at, self.rejoin_at))
        sel = _agent_idx(self.agents, roster.shape[1])
        roster[self.leave_at:self.rejoin_at, sel] = False


@dataclass(frozen=True)
class Churn:
    """Stochastic membership churn (two-state Markov chain per agent, the
    roster-level analogue of :class:`CrashRecover`): while a member, an
    agent leaves each version with probability ``rate``; time out of the
    roster is geometric with mean ``mean_out`` versions."""
    rate: float = 0.05
    mean_out: float = 3.0
    agents: Optional[Tuple[int, ...]] = None

    def apply(self, rng, alive, drop, delay, adj, roster):
        h, n = roster.shape
        sel = _agent_idx(self.agents, n)
        p_in = 1.0 / max(self.mean_out, 1.0)        # geometric re-entry
        for i in sel:
            member = True
            for v in range(h):
                if member:
                    if rng.random() < self.rate:
                        member = False
                else:
                    if rng.random() < p_in:
                        member = True
                roster[v, i] &= member


@dataclass(frozen=True)
class SamplingPolicy:
    """Client-sampling policy (federated §4): the roster as a CHOSEN
    schedule, not an observed fault — the same move the federated
    client-sampling literature makes on top of gradient coding's
    roster-aware groups.

    Each round of ``round_len`` versions the server selects ``m`` agents
    from those still in the roster at the round's first version:

      uniform       — iid uniform without replacement (FedAvg sampling)
      staleness     — P(i) ∝ 1 / mean latency over the round: prefer FAST
                      agents (staleness-aware participation)
      contribution  — P(i) ∝ expected delivery rate over the round (alive
                      and not dropped): prefer RELIABLE agents

    Scores are read from the already-composed ``alive``/``drop``/``delay``
    arrays, so place the policy AFTER the fault specs it should react to
    (specs apply in order).  The choice is INTERSECTED into the roster:
    agents a prior membership spec removed are never chosen, and a later
    ``Churn`` can still evict a chosen agent.  ``temperature`` flattens
    (>1) or sharpens (<1) the preference.  Counts as a membership spec —
    compiling a schedule containing one allocates a roster, which the
    flight recorder logs as per-step membership deltas."""
    m: int
    policy: str = "uniform"             # uniform | staleness | contribution
    round_len: int = 1
    temperature: float = 1.0

    def apply(self, rng, alive, drop, delay, adj, roster):
        if self.m <= 0:
            raise ValueError(f"SamplingPolicy needs m >= 1, got m={self.m}")
        if self.policy not in ("uniform", "staleness", "contribution"):
            raise KeyError(self.policy)
        if self.round_len <= 0:
            raise ValueError(
                f"SamplingPolicy needs round_len >= 1, got {self.round_len}")
        h, n = roster.shape
        for t0 in range(0, h, self.round_len):
            t1 = min(t0 + self.round_len, h)
            avail = np.flatnonzero(roster[t0])
            if avail.size == 0:
                continue
            if self.policy == "uniform":
                score = np.ones(avail.size)
            elif self.policy == "staleness":
                score = 1.0 / np.maximum(
                    delay[t0:t1, avail].mean(axis=0), 1e-9)
            else:
                score = (alive[t0:t1, avail]
                         & ~drop[t0:t1, avail]).mean(axis=0) + 1e-3
            p = score ** (1.0 / max(self.temperature, 1e-6))
            p = p / p.sum()
            chosen = rng.choice(avail, size=min(self.m, avail.size),
                                replace=False, p=p)
            keep = np.zeros(n, bool)
            keep[chosen] = True
            roster[t0:t1] &= keep[None, :]


@dataclass(frozen=True)
class Partition:
    """Network partition during versions [start, end): only links within the
    same group survive.  Agents not named in any group form one implicit
    residual group."""
    groups: Tuple[Tuple[int, ...], ...]
    start: int = 0
    end: int = 10 ** 9

    def apply(self, rng, alive, drop, delay, adj, roster):
        assert adj is not None
        h, n, _ = adj.shape
        gid = np.full(n, len(self.groups), np.int64)      # residual group
        for g, members in enumerate(self.groups):
            gid[np.asarray(members, np.int64)] = g
        same = gid[:, None] == gid[None, :]
        lo, hi = max(self.start, 0), min(self.end, h)
        adj[lo:hi] &= same[None]


FAULT_SPECS = (Straggler, CrashRecover, PermanentCrash, MessageDrop,
               Partition, Join, Rejoin, Churn, SamplingPolicy)
MEMBERSHIP_SPECS = (Join, Rejoin, Churn, SamplingPolicy)


# ---------------------------------------------------------------------------
# compiled trace


@dataclass(frozen=True)
class FaultTrace:
    alive: np.ndarray                 # (horizon, n) bool
    drop: np.ndarray                  # (horizon, n) bool
    delay: np.ndarray                 # (horizon, n) float64
    adj: Optional[np.ndarray] = None  # (horizon, n, n) bool, partitions only
    seed: int = 0
    # (horizon, n) bool membership; None = the full static roster
    # (membership specs only — see the module docstring)
    roster: Optional[np.ndarray] = None

    @property
    def horizon(self) -> int:
        return self.alive.shape[0]

    @property
    def n_agents(self) -> int:
        return self.alive.shape[1]

    @property
    def base_delay(self) -> float:
        return float(np.min(self.delay)) if self.delay.size else 1.0

    def member(self, version: int, agent: int) -> bool:
        """Roster membership at ``version`` (clamped to the horizon)."""
        if self.roster is None:
            return True
        return bool(self.roster[min(version, self.horizon - 1), agent])

    def n_live(self, version: int) -> int:
        """Live roster size at ``version`` (= n_agents without churn)."""
        if self.roster is None:
            return self.n_agents
        return int(self.roster[min(version, self.horizon - 1)].sum())

    def is_trivial(self) -> bool:
        """True iff the trace can never desynchronize a quorum-n loop:
        nobody crashes, nothing drops, all latencies are equal, and the
        roster is the full static membership."""
        return (bool(self.alive.all()) and not bool(self.drop.any())
                and bool((self.delay == self.delay.flat[0]).all())
                and self.adj is None
                and (self.roster is None or bool(self.roster.all())))


def compile_schedule(specs, n_agents: int, horizon: int, seed: int = 0,
                     base_delay: float = 1.0) -> FaultTrace:
    """Sample a concrete FaultTrace from composable fault specs.

    Deterministic in (specs, n_agents, horizon, seed): one rng, consumed in
    spec order.  ``horizon`` must cover every parameter version the run can
    dispatch at (the loops use steps + 1)."""
    specs = tuple(specs or ())
    rng = np.random.default_rng(seed)
    alive = np.ones((horizon, n_agents), bool)
    drop = np.zeros((horizon, n_agents), bool)
    delay = np.full((horizon, n_agents), float(base_delay))
    adj = (np.ones((horizon, n_agents, n_agents), bool)
           if any(isinstance(s, Partition) for s in specs) else None)
    roster = (np.ones((horizon, n_agents), bool)
              if any(isinstance(s, MEMBERSHIP_SPECS) for s in specs)
              else None)
    for spec in specs:
        spec.apply(rng, alive, drop, delay, adj, roster)
    return FaultTrace(alive=alive, drop=drop, delay=delay, adj=adj,
                      seed=seed, roster=roster)


def no_faults(n_agents: int, horizon: int,
              base_delay: float = 1.0) -> FaultTrace:
    """The degenerate trace: all agents up, zero-variance latency."""
    return compile_schedule((), n_agents, horizon, seed=0,
                            base_delay=base_delay)
