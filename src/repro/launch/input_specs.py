"""ShapeDtypeStruct stand-ins for every (arch x input-shape) combination —
weak-type-correct, shardable, no device allocation.

INPUT SHAPES (assignment):
  train_4k     seq 4,096    global_batch 256   -> train_step
  prefill_32k  seq 32,768   global_batch 32    -> prefill_step
  decode_32k   seq 32,768   global_batch 128   -> decode_step (1 new token)
  long_500k    seq 524,288  global_batch 1     -> decode_step (sub-quadratic
                                                  archs only, see DESIGN.md)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import init_cache, init_params

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def shape_supported(cfg, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k decode is quadratic; "
                       "skipped per DESIGN.md")
    if shape_name == "long_500k" and cfg.is_encdec:
        return False, "enc-dec (whisper) out of domain at 500k; skipped"
    return True, ""


def _frontend_specs(cfg, lead_dims):
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "vision":
        return {"vision_embeds": sds(lead_dims + (cfg.frontend_tokens,
                                                  cfg.d_model), dt)}
    if cfg.frontend == "audio":
        return {"audio_embeds": sds(lead_dims + (cfg.encoder_seq,
                                                 cfg.d_model), dt)}
    return {}


def train_batch_specs(cfg, n_agents: int, seq_len: int = 4096,
                      global_batch: int = 256):
    assert global_batch % n_agents == 0
    b = global_batch // n_agents
    batch = {
        "tokens": sds((n_agents, b, seq_len), jnp.int32),
        "labels": sds((n_agents, b, seq_len), jnp.int32),
    }
    batch.update(_frontend_specs(cfg, (n_agents, b)))
    return batch


def serve_batch_specs(cfg, batch: int, seq_len: int):
    out = {"tokens": sds((batch, seq_len), jnp.int32)}
    out.update(_frontend_specs(cfg, (batch,)))
    return out


def params_specs(cfg):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def cache_specs(cfg, params_sds, batch_size: int, seq_len: int):
    """Decode-cache ShapeDtypeStructs via eval_shape (enc-dec caches depend
    on the encoder inputs, passed through as SDS too)."""
    batch = serve_batch_specs(cfg, batch_size, 1)
    return jax.eval_shape(
        lambda p, b: init_cache(cfg, p, batch_size, seq_len, b),
        params_sds, batch)


def input_specs(cfg, shape_name: str, n_agents: int = 16):
    """Returns (kind, specs dict) for lowering the right step function."""
    info = SHAPES[shape_name]
    kind = info["kind"]
    if kind == "train":
        return kind, {
            "batch": train_batch_specs(cfg, n_agents, info["seq_len"],
                                       info["global_batch"]),
        }
    p = params_specs(cfg)
    if kind == "prefill":
        batch = serve_batch_specs(cfg, info["global_batch"], info["seq_len"])
        cache = cache_specs(cfg, p, info["global_batch"], info["seq_len"])
        return kind, {"batch": batch, "cache": cache}
    # decode: ONE token against a seq_len cache
    cache = cache_specs(cfg, p, info["global_batch"], info["seq_len"])
    token = sds((info["global_batch"], 1), jnp.int32)
    return kind, {"token": token, "cache": cache}
