"""Render a flight-recorder trace:  python -m repro.launch.report trace.jsonl

Prints the per-agent suspicion table, staleness/quorum percentiles,
recompile ledger and rule-dispatch breakdown of a recorded run
(``train_loop(..., recorder=...)``, ``async_train_loop``,
``generate_replicated``, or ``launch.train --record``).  ``--perfetto``
additionally exports the Chrome-trace JSON that ``chrome://tracing`` /
ui.perfetto.dev load."""
from __future__ import annotations

import argparse
import json


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.report",
        description="Render a repro.obs flight-recorder trace (JSONL).")
    ap.add_argument("trace", help="trace JSONL written by a Recorder")
    ap.add_argument("--top", type=int, default=None,
                    help="only the TOP most-suspicious agents")
    ap.add_argument("--perfetto", default=None, metavar="OUT_JSON",
                    help="also export a Chrome-trace/Perfetto JSON")
    args = ap.parse_args(argv)

    from repro.obs.recorder import chrome_trace, read_trace
    from repro.obs.report import render_report

    events = read_trace(args.trace)
    print(render_report(events, top=args.top))
    if args.perfetto:
        with open(args.perfetto, "w") as fh:
            json.dump(chrome_trace(events), fh)
        print(f"\nperfetto trace written to {args.perfetto}")


if __name__ == "__main__":
    main()
