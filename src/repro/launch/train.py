"""Training launcher.

CPU smoke scale:
  PYTHONPATH=src python -m repro.launch.train --arch paper-100m --steps 200 \
      --filter trimmed_mean --attack sign_flip --f 3

On a real TPU slice the same entry point runs under the production mesh
(--mesh pod) with the sharded train step — the dry-run proves those programs
compile for 256/512 chips.
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--n-agents", type=int, default=8)
    ap.add_argument("--f", type=int, default=1)
    ap.add_argument("--filter", default="trimmed_mean")
    ap.add_argument("--impl", default="fused",
                    choices=["fused", "gather", "pallas", "auto"])
    ap.add_argument("--attack", default="none")
    ap.add_argument("--attack-scale", type=float, default=None)
    ap.add_argument("--momentum-alpha", type=float, default=0.0)
    ap.add_argument("--draco-r", type=int, default=0)
    # client sampling: the roster as a CHOSEN schedule (simulator
    # SamplingPolicy) — the spec goes elastic so the aggregation runs the
    # sampled roster's per-bucket plans, and --record logs the membership
    # deltas per step
    ap.add_argument("--sample-policy", default="none",
                    choices=["none", "uniform", "staleness", "contribution"],
                    help="per-round client sampling into the roster")
    ap.add_argument("--sample-m", type=int, default=0,
                    help="clients sampled per round (default n_agents//2)")
    ap.add_argument("--sample-round", type=int, default=1,
                    help="versions per sampling round")
    ap.add_argument("--elastic-buckets", type=int, default=3,
                    help="elastic-n bucket count used with --sample-policy")
    ap.add_argument("--quorum", type=int, default=None,
                    help="async quorum (default: the full live roster)")
    ap.add_argument("--poison-labels", action="store_true")
    ap.add_argument("--regime", default="iid",
                    choices=["iid", "noniid", "parallel"])
    ap.add_argument("--optimizer", default="adamw", choices=["sgd", "adamw"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--per-agent-batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--history-out", default=None)
    ap.add_argument("--record", default=None, metavar="TRACE_JSONL",
                    help="write a flight-recorder trace (repro.obs) here; "
                    "render it with `python -m repro.launch.report`")
    ap.add_argument("--perfetto", default=None, metavar="TRACE_JSON",
                    help="with --record: also export a Chrome-trace/"
                    "Perfetto JSON of the run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core.aggregators import elastic, make_spec
    from repro.data import SyntheticLM
    from repro.optim import adamw, constant, diminishing, sgd
    from repro.simulator import SamplingPolicy, SimConfig
    from repro.training import ByzantineConfig, train_loop

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))
    if args.draco_r and args.regime != "parallel":
        args.regime = "parallel"       # coding requires identical shards

    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                     n_agents=args.n_agents,
                     per_agent_batch=args.per_agent_batch,
                     regime=args.regime)
    opt = (adamw(constant(args.lr)) if args.optimizer == "adamw"
           else sgd(diminishing(args.lr), momentum=0.9))
    ah = {}
    if args.attack_scale is not None:
        ah = {"scale": args.attack_scale}
    # the spec is built ONCE here (hyper validated, static plans warmed)
    # and passed through every layer — no string re-dispatch downstream.
    # Under --sample-policy the spec goes elastic: the sampled roster
    # packs into per-bucket plans (and the coded paths regroup per
    # bucket), compiling at most once per bucket.
    sim = None
    n_spec = args.n_agents
    if args.sample_policy != "none":
        m = args.sample_m if args.sample_m > 0 else max(args.n_agents // 2, 1)
        sim = SimConfig(
            faults=(SamplingPolicy(m=m, policy=args.sample_policy,
                                   round_len=args.sample_round),),
            quorum=args.quorum, seed=args.seed)
        n_spec = elastic(args.n_agents, buckets=args.elastic_buckets)
    elif args.quorum is not None:
        sim = SimConfig(quorum=args.quorum, seed=args.seed)
    spec = make_spec(args.filter, f=args.f, impl=args.impl, n=n_spec)
    bz = ByzantineConfig(
        n_agents=args.n_agents, f=args.f, aggregator=spec,
        attack=args.attack, attack_hyper=ah,
        momentum_alpha=args.momentum_alpha, draco_r=args.draco_r)

    recorder = None
    if args.record:
        from repro.obs import Recorder
        recorder = Recorder(args.record, meta={"cli": "launch.train",
                                               "arch": args.arch})

    params, history = train_loop(
        cfg, bz, opt, ds, steps=args.steps, seed=args.seed,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 2, 1),
        poison_labels=args.poison_labels, sim=sim, recorder=recorder)

    if recorder is not None:
        recorder.close()
        print(f"trace written to {args.record}")
        if args.perfetto:
            print(f"perfetto trace written to "
                  f"{recorder.dump_chrome_trace(args.perfetto)}")
    if args.history_out:
        with open(args.history_out, "w") as fh:
            json.dump(history, fh, indent=1)
    print(f"final loss {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
