"""Trip-count-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of its
trip count, so scan-over-layers programs (everything here) under-report
FLOPs/bytes/collectives by ~num_layers.  This module parses the optimized HLO
module text, builds the computation call graph, extracts loop trip counts
from while-condition constants, and accumulates:

  * flops             — 2 * prod(result dims) * prod(contracting dims) per
                        dot/convolution, weighted by execution count;
  * result_bytes      — sum of op result-shape bytes (HBM-traffic proxy),
                        counted at call-site level (fusions = one result);
  * collective_bytes  — per collective kind, result-shape bytes, weighted.

Validated against unrolled-vs-scanned twins in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1, "s4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")
_CALLED = re.compile(
    r"(?:to_apply|body|condition|calls|branch_computations)="
    r"(?:\{([^}]*)\}|%?([\w\.\-]+))")
_CONST_INT = re.compile(r"=\s*s32\[\]\s+constant\((\d+)\)")


def _shape_list(seg: str):
    return [(dt, [int(x) for x in dims.split(",")] if dims else [])
            for dt, dims in _SHAPE_TOKEN.findall(seg)]


def _shape_bytes(seg: str) -> int:
    total = 0
    for dt, dims in _shape_list(seg):
        if dt not in _DTYPE_BYTES:
            continue
        cnt = 1
        for d in dims:
            cnt *= d
        total += cnt * _DTYPE_BYTES[dt]
    return total


class HloModule:
    def __init__(self, text: str):
        self.computations = {}          # name -> list of op dicts
        self.shapes_by_comp = {}        # comp -> {op name -> shape segment}
        self.entry = None
        self._parse(text)

    @staticmethod
    def _logical_lines(text: str):
        """Merge wrapped op lines: newer XLA printers break long tuple
        shapes across physical lines (continuations carry /*index=N*/
        comments and never contain ' = '), which would hide the op name —
        most damagingly ``while(...)`` — from the line regex."""
        out = []
        for raw in text.splitlines():
            stripped = raw.strip()
            if not stripped:
                continue
            is_new = (" = " in stripped or stripped == "}"
                      or stripped.endswith("{") or not out)
            if is_new:
                out.append(raw.rstrip())
            else:
                out[-1] = out[-1] + " " + stripped
        return out

    def _parse(self, text: str):
        cur = None
        for raw in self._logical_lines(text):
            line = raw.rstrip()
            stripped = line.strip()
            if not stripped:
                continue
            # computation header: at column 0 (or ENTRY), "name (params) ->
            # result {".  Param lists may contain nested parens.
            if (not raw.startswith(" ") and stripped.endswith("{")
                    and " -> " in stripped and " = " not in stripped):
                m = _COMP_HEADER.match(stripped)
                if m:
                    cur = m.group(2)
                    self.computations[cur] = []
                    self.shapes_by_comp = getattr(self, "shapes_by_comp", {})
                    self.shapes_by_comp[cur] = {}
                    if m.group(1):
                        self.entry = cur
                    continue
            if stripped == "}":
                cur = None
                continue
            if cur is None:
                continue
            om = _OP_LINE.match(line)
            if not om:
                continue
            name, shape_seg, opname, rest = om.groups()
            called = []
            for g1, g2 in _CALLED.findall(rest):
                if g1:
                    called += [c.strip().lstrip("%") for c in g1.split(",")]
                elif g2:
                    called.append(g2)
            self.computations[cur].append({
                "name": name, "shape": shape_seg, "op": opname,
                "rest": rest, "called": called,
            })
            self.shapes_by_comp[cur][name] = shape_seg

    # ------------------------------------------------------------------
    def _result_elems_and_shape(self, op):
        shapes = _shape_list(op["shape"])
        return shapes

    @staticmethod
    def _args_segment(rest: str) -> str:
        """The operand list of an op call: everything up to the closing
        paren that matches the one consumed by the op-line regex."""
        depth = 0
        for idx, ch in enumerate(rest):
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                if depth == 0:
                    return rest[:idx]
                depth -= 1
        return rest

    @staticmethod
    def _split_operands(args: str):
        """Split on top-level commas only — inline operand shapes like
        ``f32[2,64,128]{2,1,0}`` contain commas inside brackets/braces."""
        parts, cur, depth = [], [], 0
        for ch in args:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append("".join(cur))
                cur = []
            else:
                cur.append(ch)
        if cur:
            parts.append("".join(cur))
        return parts

    def _operand_shape(self, comp_name, op, idx):
        """Shape string of the idx-th operand: inline if printed, else look
        up the operand name in this computation's op table."""
        parts = self._split_operands(self._args_segment(op["rest"]))
        if idx >= len(parts):
            return None
        part = parts[idx]
        if _SHAPE_TOKEN.search(part):
            return part
        mn = _OPERAND_NAME.search(part)
        if mn:
            return self.shapes_by_comp.get(comp_name, {}).get(mn.group(1))
        return None

    def _dot_flops(self, comp_name, op):
        """2 * prod(result) * prod(contracting dims of lhs)."""
        res_shapes = _shape_list(op["shape"])
        if not res_shapes:
            return 0
        _, rdims = res_shapes[0]
        result = 1
        for d in rdims:
            result *= d
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op["rest"])
        lhs_seg = self._operand_shape(comp_name, op, 0)
        if mc and lhs_seg:
            lhs = _shape_list(lhs_seg)
            if lhs:
                _, lhs_dims = lhs[0]
                contract = 1
                for i in (int(x) for x in mc.group(1).split(",") if x):
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
                return 2 * result * contract
        if op["op"] == "convolution":
            k_seg = self._operand_shape(comp_name, op, 1)
            if k_seg:
                ks = _shape_list(k_seg)
                if ks:
                    k = 1
                    for d in ks[0][1]:
                        k *= d
                    return 2 * result * k
        return 2 * result        # unknown: at least count result writes

    def trip_count(self, cond_name: str) -> int:
        """Loop trip count from the while-condition's integer constant
        (scan conditions compare the induction variable against the length).
        Falls back to 1 when no constant is found."""
        ops = self.computations.get(cond_name, [])
        text = "\n".join(o["name"] + " = " + o["shape"] + " " + o["op"]
                         + "(" + o["rest"] for o in ops)
        consts = [int(m.group(1))
                  for m in re.finditer(r"constant\((\d+)\)", text)]
        if not consts:
            return 1
        return max(max(consts), 1)

    # ------------------------------------------------------------------
    def analyze(self):
        """Walk from ENTRY, multiplying execution weights through whiles."""
        flops = 0.0
        result_bytes = 0.0
        coll = defaultdict(float)
        coll_counts = defaultdict(float)
        bytes_by_op = defaultdict(float)
        seen_stack = []

        def walk(comp_name, weight, count_bytes):
            nonlocal flops, result_bytes
            if comp_name not in self.computations:
                return
            if comp_name in seen_stack:       # recursion guard
                return
            seen_stack.append(comp_name)
            for op in self.computations[comp_name]:
                o = op["op"]
                if o in ("dot", "convolution"):
                    flops += weight * self._dot_flops(comp_name, op)
                base = None
                for c in COLLECTIVES:
                    if o == c or o == c + "-start":
                        base = c
                        break
                if base:
                    b = _shape_bytes(op["shape"])
                    coll[base] += weight * b
                    coll_counts[base] += weight
                if count_bytes and o not in ("parameter", "constant",
                                             "get-tuple-element", "tuple",
                                             "bitcast"):
                    if o == "dynamic-update-slice":
                        # in-place on hardware: traffic = the update slice,
                        # not the full aliased buffer (scan carries would
                        # otherwise count L x full-stack bytes)
                        seg = self._operand_shape(comp_name, op, 1)
                        b = _shape_bytes(seg or "")
                    else:
                        b = _shape_bytes(op["shape"])
                    result_bytes += weight * b
                    bytes_by_op[o] += weight * b
                if o == "while":
                    body = cond = None
                    mb = re.search(r"body=%?([\w\.\-]+)", op["rest"])
                    mcnd = re.search(r"condition=%?([\w\.\-]+)", op["rest"])
                    if mb and mcnd:
                        trips = self.trip_count(mcnd.group(1))
                        walk(mb.group(1), weight * trips, count_bytes)
                        walk(mcnd.group(1), weight * trips, False)
                elif o in ("fusion", "call", "custom-call", "map"):
                    for c in op["called"]:
                        # descend for dots; bytes counted at call-site result
                        walk(c, weight, False)
                elif o == "conditional":
                    for c in op["called"]:
                        walk(c, weight, count_bytes)
                elif o in ("reduce", "sort", "scatter", "select-and-scatter",
                           "reduce-window"):
                    pass                      # tiny applied computations
            seen_stack.pop()

        walk(self.entry, 1.0, True)
        top = dict(sorted(bytes_by_op.items(), key=lambda kv: -kv[1])[:12])
        return {
            "flops": flops,
            "result_bytes": result_bytes,
            "collective_bytes": dict(coll),
            "collective_counts": {k: int(v) for k, v in coll_counts.items()},
            "collective_bytes_total": float(sum(coll.values())),
            "bytes_by_op": top,
        }


def analyze_hlo_text(text: str):
    return HloModule(text).analyze()
