"""Production mesh construction (TPU v5e pods).

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, multi_pod: bool = False):
    """Tiny mesh for CPU integration tests (8 host devices)."""
    if multi_pod:
        return jax.make_mesh((2, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
