"""Serving launcher: batched prefill + greedy decode demo.

  PYTHONPATH=src python -m repro.launch.serve --arch paper-100m --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import generate

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.zeros(
            (args.batch, cfg.frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.frontend == "audio":
        batch["audio_embeds"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    out = generate(cfg, params, batch, args.new_tokens)
    print("generated token ids:")
    for row in out.tolist():
        print(" ", row)


if __name__ == "__main__":
    main()
