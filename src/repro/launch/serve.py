"""Serving launcher: plain decode, replicated f-of-r decode, or the
continuous-batching scheduler — with flight-recorder attachment.

  # plain batched prefill + greedy decode demo
  PYTHONPATH=src python -m repro.launch.serve --arch paper-100m --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16

  # f-of-r replicated decode through the robust vote
  PYTHONPATH=src python -m repro.launch.serve --arch paper-100m --smoke \
      --replicas 5 --f 2 --aggregator coordinate_median --record t.jsonl

  # the serving control plane: Poisson arrivals through the scheduler,
  # early commit + suspicion-driven eviction, then the suspicion report
  PYTHONPATH=src python -m repro.launch.serve --arch paper-100m --smoke \
      --sched --replicas 5 --f 2 --rate 0.8 --requests 12 \
      --deadline 2.0 --record t.jsonl
  PYTHONPATH=src python -m repro.launch.report t.jsonl
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    # replicated decode (r > 1 switches the engine)
    ap.add_argument("--replicas", type=int, default=1,
                    help="decode replicas r (1 = plain single-model)")
    ap.add_argument("--f", type=int, default=1,
                    help="tolerated Byzantine replicas")
    ap.add_argument("--aggregator", default="coordinate_median",
                    help="robust rule voting the per-step logits")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="write a flight-recorder JSONL trace here "
                         "(render it with `python -m repro.launch.report`)")
    # scheduler mode (implies --replicas)
    ap.add_argument("--sched", action="store_true",
                    help="drive the continuous-batching scheduler with a "
                         "Poisson workload instead of one fixed batch")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="sched: request arrivals per virtual second")
    ap.add_argument("--requests", type=int, default=8,
                    help="sched: number of requests in the workload")
    ap.add_argument("--deadline", type=float, default=None,
                    help="sched: early-commit SLO deadline (virtual s)")
    ap.add_argument("--no-early-commit", action="store_true",
                    help="sched: always run the full quorum vote")
    ap.add_argument("--evict-window", type=int, default=0,
                    help="sched: >0 attaches a SuspicionPolicy with this "
                         "zero-selection eviction window")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import generate, generate_replicated

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)

    recorder = None
    if args.record:
        from repro.obs.recorder import Recorder
        recorder = Recorder(args.record, meta={"launcher": "serve"})

    if args.sched:

        from repro.core.aggregators import make_spec
        from repro.serving.sched import (ReplicatedScheduler,
                                         SuspicionPolicy, poisson_requests)
        r = max(args.replicas, 2 * args.f + 1)
        stack = jax.tree.map(lambda l: jnp.stack([l] * r), params)
        spec = make_spec(args.aggregator, f=args.f, n=r)
        policy = (SuspicionPolicy(r, args.f, window=args.evict_window)
                  if args.evict_window > 0 else None)
        cap = args.prompt_len + args.new_tokens
        sched = ReplicatedScheduler(
            cfg, stack, spec, seq_capacity=cap,
            slot_buckets=(2, 4, 8), deadline=args.deadline,
            early_commit=not args.no_early_commit,
            policy=policy, recorder=recorder)
        reqs = poisson_requests(
            args.rate, args.requests / max(args.rate, 1e-9), seed=args.seed,
            vocab_size=cfg.vocab_size,
            prompt_lens=(args.prompt_len // 2, args.prompt_len),
            new_tokens=(args.new_tokens,), max_requests=args.requests)
        sched.submit_all(reqs)
        metrics = sched.run()
        print(f"scheduler: {spec.describe()} over r={r} replicas")
        for req in reqs:
            print(f"  req {req.rid} (T={req.prompt_len}, "
                  f"t={req.arrival:.2f}): {req.out}")
        for k, v in metrics.summary().items():
            print(f"  {k}: {v:.4g}" if isinstance(v, float)
                  else f"  {k}: {v}")
        if policy is not None and policy.events:
            print("  roster events:", policy.events)
    elif args.replicas > 1:
        from repro.core.aggregators import make_spec
        r = args.replicas
        stack = jax.tree.map(lambda l: jnp.stack([l] * r), params)
        spec = make_spec(args.aggregator, f=args.f, n=r)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
        out = generate_replicated(cfg, stack, batch, args.new_tokens, spec,
                                  recorder=recorder)
        print(f"replicated ({spec.describe()}, r={r}) token ids:")
        for row in out.tolist():
            print(" ", row)
    else:
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
        if cfg.frontend == "vision":
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        if cfg.frontend == "audio":
            batch["audio_embeds"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.dtype))
        out = generate(cfg, params, batch, args.new_tokens)
        print("generated token ids:")
        for row in out.tolist():
            print(" ", row)

    if recorder is not None:
        recorder.close()
        print(f"trace written to {recorder.path} "
              f"({len(recorder.events)} events) — render with "
              f"`python -m repro.launch.report {recorder.path}`")


if __name__ == "__main__":
    main()
