import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run entry point.

Lowers + compiles the production program for every (architecture x input
shape) on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh, printing
memory_analysis / cost_analysis and writing JSON artifacts consumed by the
roofline benchmark (benchmarks/roofline.py) and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=[None, "train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--mode", default=None, choices=[None, "ddp", "fsdp"])
    ap.add_argument("--filter", default=None,
                    help="gradient filter for train_4k (default trimmed_mean)")
    ap.add_argument("--impl", default=None,
                    choices=[None, "fused", "gather", "pallas", "auto"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    # §Perf variant knobs
    ap.add_argument("--group-size", type=int, default=0,
                    help="median-of-means grouping [19] for train_4k")
    ap.add_argument("--agg-dtype", default="",
                    help="cast exchanged gradients (e.g. bfloat16)")
    ap.add_argument("--reshard", action="store_true",
                    help="reshard grad stacks before coordinate filters")
    ap.add_argument("--cache-layout", default="headdim",
                    choices=["headdim", "seq"],
                    help="decode KV-cache sharding layout")
    ap.add_argument("--remat", action="store_true",
                    help="per-layer activation checkpointing (train)")
    ap.add_argument("--moe-dispatch", action="store_true",
                    help="capacity-sharded MoE dispatch (prefill)")
    args = ap.parse_args()

    # imports AFTER the XLA_FLAGS pin
    from repro.configs import ASSIGNED_ARCHS
    from repro.launch.dryrun_lib import run_combo
    from repro.launch.input_specs import SHAPES
    from repro.training.step import ByzantineConfig

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    bz = None
    if (args.filter or args.impl or args.group_size or args.agg_dtype
            or args.reshard or args.remat):
        from repro.core.aggregators import make_spec
        bz = lambda multi: ByzantineConfig(
            n_agents=32 if multi else 16,
            f=7 if multi else 3,
            aggregator=make_spec(args.filter or "trimmed_mean",
                                 f=7 if multi else 3,
                                 impl=args.impl or "fused",
                                 n=32 if multi else 16),
            group_size=args.group_size or 1,
            agg_dtype=args.agg_dtype,
            reshard=args.reshard,
            remat=args.remat)

    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                try:
                    run_combo(arch, shape, multi, out_dir=args.out,
                              mode=args.mode,
                              bz=bz(multi) if bz else None, tag=args.tag,
                              skip_existing=args.skip_existing,
                              cache_layout=args.cache_layout,
                              moe_dispatch=args.moe_dispatch)
                except Exception as e:      # record, keep sweeping
                    import json as _json
                    import os as _os
                    mesh_name = "pod512" if multi else "pod256"
                    nm = f"{arch}_{shape}_{mesh_name}"
                    nm += f"_{args.tag}" if args.tag else ""
                    _os.makedirs(args.out, exist_ok=True)
                    with open(_os.path.join(args.out, nm + ".json"),
                              "w") as fh:
                        _json.dump({"arch": arch, "shape": shape,
                                    "mesh": mesh_name,
                                    "error": repr(e)[:2000]}, fh, indent=1)
                    print(f"[dryrun] ERROR {nm}: {repr(e)[:200]}")


if __name__ == "__main__":
    main()
