"""Dry-run machinery: lower + compile every (arch x shape x mesh) combination
and extract the roofline terms from the compiled artifact.

Separated from dryrun.py so tests can import it under a small host-device
count; dryrun.py (the production entry point) pins XLA_FLAGS to 512 devices
as its first two lines.
"""
from __future__ import annotations

import json
import os
import re
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import active_params, get_config, num_params
from repro.distributed.sharding import (agent_axes, batch_pspec, cache_pspecs,
                                        grads_pspecs, param_pspecs)
from repro.launch import mesh as mesh_lib
from repro.launch.input_specs import (SHAPES, input_specs, params_specs,
                                      shape_supported)
from repro.models import decode_step, prefill
from repro.optim import diminishing, sgd
from repro.training.step import ByzantineConfig, make_train_step

FSDP_THRESHOLD = 20e9


def sharding_mode(cfg) -> str:
    return "fsdp" if num_params(cfg) >= FSDP_THRESHOLD else "ddp"


def _ns(mesh, tree_pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def _opt_pspecs(opt_sds, params_ps):
    """Optimizer-state specs: momentum/adam moments mirror the param specs."""
    def walk(sub):
        if isinstance(sub, dict):
            return {k: (params_ps if k in ("mu", "m", "v") else walk(v))
                    for k, v in sub.items()}
        return P()
    return walk(opt_sds)


# ---------------------------------------------------------------------------
# lowering per kind


def lower_train(cfg, mesh, multi_pod: bool, bz: ByzantineConfig,
                mode: str | None = None):
    mode = mode or sharding_mode(cfg)
    kind, specs = input_specs(cfg, "train_4k", n_agents=bz.n_agents)
    params_sds = params_specs(cfg)
    opt = sgd(diminishing(0.1))          # paper-faithful DGD/BGD server step
    opt_sds = jax.eval_shape(opt.init, params_sds)
    key_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))

    params_ps = param_pspecs(params_sds, mode, mesh)
    opt_ps = _opt_pspecs(opt_sds, params_ps)
    batch_ps = jax.tree.map(
        lambda l: batch_pspec(multi_pod, extra_dims=l.ndim - 1),
        specs["batch"])

    step = make_train_step(cfg, bz, opt, mesh_sizes=dict(mesh.shape))
    metrics_ps = {"loss": P(), "loss_all": P(), "grad_norm": P()}
    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, params_ps), _ns(mesh, opt_ps), None,
                      _ns(mesh, batch_ps), NamedSharding(mesh, P())),
        out_shardings=(_ns(mesh, params_ps), _ns(mesh, opt_ps), None,
                       _ns(mesh, metrics_ps)),
    )
    with mesh:
        lowered = jitted.lower(params_sds, opt_sds, None, specs["batch"],
                               key_sds)
    return lowered


def _dispatch_ctx(cfg, mesh, multi_pod: bool, enabled: bool):
    """MoE dispatch sharding hint (§Perf pair C)."""
    import contextlib

    from repro.distributed.context import moe_dispatch_sharding
    if not enabled or not cfg.num_experts:
        return contextlib.nullcontext()
    ax = agent_axes(multi_pod)
    ax = ax[0] if len(ax) == 1 else ax
    ep = cfg.num_experts % mesh.shape["model"] == 0
    return moe_dispatch_sharding(ax, ep, dict(mesh.shape))


def lower_prefill(cfg, mesh, multi_pod: bool, moe_dispatch: bool = False):
    _, specs = input_specs(cfg, "prefill_32k")
    params_sds = params_specs(cfg)
    params_ps = param_pspecs(params_sds, "ddp", mesh)
    ax = agent_axes(multi_pod)
    ax = ax[0] if len(ax) == 1 else ax
    batch_ps = jax.tree.map(
        lambda l: P(ax, *([None] * (l.ndim - 1))), specs["batch"])
    cache_ps = cache_pspecs(specs["cache"], multi_pod, mesh)

    def step(params, batch, cache):
        return prefill(cfg, params, batch, cache)

    vocab_ax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    logits_ps = P(ax, vocab_ax)
    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, params_ps), _ns(mesh, batch_ps),
                      _ns(mesh, cache_ps)),
        out_shardings=(NamedSharding(mesh, logits_ps), _ns(mesh, cache_ps)),
    )
    with mesh, _dispatch_ctx(cfg, mesh, multi_pod, moe_dispatch):
        lowered = jitted.lower(params_sds, specs["batch"], specs["cache"])
    return lowered


def lower_decode(cfg, mesh, multi_pod: bool, shape_name: str,
                 cache_layout: str = "headdim"):
    _, specs = input_specs(cfg, shape_name)
    params_sds = params_specs(cfg)
    params_ps = param_pspecs(params_sds, "ddp", mesh)
    ax = agent_axes(multi_pod)
    ax = ax[0] if len(ax) == 1 else ax
    B = specs["token"].shape[0]
    tok_ps = P(ax if B > 1 else None, None)
    cache_ps = cache_pspecs(specs["cache"], multi_pod, mesh,
                            layout=cache_layout)

    def step(params, token, cache):
        return decode_step(cfg, params, token, cache)

    vocab_ax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    logits_ps = P(ax if B > 1 else None, vocab_ax)
    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, params_ps), NamedSharding(mesh, tok_ps),
                      _ns(mesh, cache_ps)),
        out_shardings=(NamedSharding(mesh, logits_ps), _ns(mesh, cache_ps)),
    )
    with mesh:
        lowered = jitted.lower(params_sds, specs["token"], specs["cache"])
    return lowered


def lower_combo(cfg, shape_name: str, mesh, multi_pod: bool,
                bz: ByzantineConfig | None = None, mode: str | None = None,
                cache_layout: str = "headdim", moe_dispatch: bool = False):
    kind = SHAPES[shape_name]["kind"]
    if kind == "train":
        n_default = 32 if multi_pod else 16
        bz = bz or ByzantineConfig(n_agents=n_default,
                                   f=(n_default - 1) // 4)
        return lower_train(cfg, mesh, multi_pod, bz, mode)
    if kind == "prefill":
        return lower_prefill(cfg, mesh, multi_pod, moe_dispatch=moe_dispatch)
    return lower_decode(cfg, mesh, multi_pod, shape_name,
                        cache_layout=cache_layout)


# ---------------------------------------------------------------------------
# compiled-artifact analysis

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        cnt = 1
        if dims:
            for d in dims.split(","):
                cnt *= int(d)
        total += cnt * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum RESULT-shape bytes of every collective op in the optimized HLO
    (async *-start counted once; *-done skipped)."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        lhs, rhs = s.split(" = ", 1)
        m = re.match(r"(\([^)]*\)|\S+)\s+(%?[\w-]+)\(", rhs)
        if not m:
            continue
        shape_seg, opname = m.group(1), m.group(2).lstrip("%")
        base = None
        for c in _COLLECTIVES:
            if opname == c or opname == c + "-start":
                base = c
                break
        if base is None:
            continue
        out[base] += _shape_bytes(shape_seg)
        counts[base] += 1
    return out, counts


def analyze(lowered, compiled, wall: dict):
    """Primary metrics come from the trip-count-aware HLO analyzer
    (repro.launch.hlo_cost) — XLA's cost_analysis counts while bodies once,
    under-reporting scanned-layer programs by ~num_layers.  The raw XLA
    numbers are kept under *_xla for reference."""
    from repro.launch.hlo_cost import analyze_hlo_text
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost = dict(cost or {})
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = int(v)
    except Exception as e:              # CPU backend may not support it
        mem["error"] = str(e)
    text = compiled.as_text()
    hlo = analyze_hlo_text(text)
    return {
        "flops": float(hlo["flops"]),
        "bytes_accessed": float(hlo["result_bytes"]),
        "collective_bytes": hlo["collective_bytes"],
        "collective_counts": hlo["collective_counts"],
        "collective_bytes_total": float(hlo["collective_bytes_total"]),
        "bytes_by_op": hlo.get("bytes_by_op", {}),
        "flops_xla": float(cost.get("flops", -1.0)),
        "bytes_accessed_xla": float(cost.get("bytes accessed", -1.0)),
        "memory": mem,
        "hlo_chars": len(text),
        **wall,
    }


def model_flops(cfg, shape_name: str) -> float:
    """6·N·D (train) / 2·N_active per generated token (decode) /
    2·N_active·tokens (prefill)."""
    info = SHAPES[shape_name]
    n_act = active_params(cfg)
    tokens = info["global_batch"] * info["seq_len"]
    if info["kind"] == "train":
        return 6.0 * n_act * tokens
    if info["kind"] == "prefill":
        return 2.0 * n_act * tokens
    return 2.0 * n_act * info["global_batch"]       # one token per request


def roofline_terms(record, n_chips: int):
    """Three roofline terms in seconds from a dry-run record.

    flops / bytes from cost_analysis are for the PER-DEVICE partitioned
    module; collective bytes likewise.  Terms:
      compute    = flops_per_device / peak
      memory     = bytes_per_device / HBM_bw
      collective = collective_bytes_per_device / (3 links * ICI_bw)
    """
    comp = record["flops"] / mesh_lib.PEAK_FLOPS_BF16
    memt = record["bytes_accessed"] / mesh_lib.HBM_BW
    coll = record["collective_bytes_total"] / (3 * mesh_lib.ICI_BW)
    terms = {"compute_s": comp, "memory_s": memt, "collective_s": coll}
    dom = max(terms, key=terms.get)
    return {**terms, "dominant": dom}


# ---------------------------------------------------------------------------
# the full run


def run_combo(arch: str, shape_name: str, multi_pod: bool,
              out_dir: str = "artifacts/dryrun", mode: str | None = None,
              bz: ByzantineConfig | None = None, mesh=None,
              tag: str = "", verbose: bool = True,
              skip_existing: bool = False, cache_layout: str = "headdim",
              moe_dispatch: bool = False):
    cfg = get_config(arch)
    ok, why = shape_supported(cfg, shape_name)
    mesh_name = "pod512" if multi_pod else "pod256"
    name = f"{arch}_{shape_name}_{mesh_name}" + (f"_{tag}" if tag else "")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as fh:
            rec = json.load(fh)
        if "error" not in rec:
            if verbose:
                print(f"[dryrun] cached {name}")
            return rec
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": why}
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=1)
        if verbose:
            print(f"[dryrun] SKIP {name}: {why}")
        return rec

    if mesh is None:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))

    t0 = time.time()
    lowered = lower_combo(cfg, shape_name, mesh, multi_pod, bz=bz, mode=mode,
                          cache_layout=cache_layout,
                          moe_dispatch=moe_dispatch)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    rec = analyze(lowered, compiled,
                  {"lower_s": t1 - t0, "compile_s": t2 - t1})
    rec.update(arch=arch, shape=shape_name, mesh=mesh_name,
               n_chips=n_chips, kind=SHAPES[shape_name]["kind"],
               params=num_params(cfg), active_params=active_params(cfg),
               model_flops=model_flops(cfg, shape_name),
               sharding_mode=mode or sharding_mode(cfg), tag=tag)
    rec["roofline"] = roofline_terms(rec, n_chips)
    rec["useful_flops_ratio"] = (
        rec["model_flops"] / n_chips / rec["flops"]
        if rec["flops"] > 0 else None)
    with open(path, "w") as fh:
        json.dump(rec, fh, indent=1)
    if verbose:
        r = rec["roofline"]
        print(f"[dryrun] {name}: compile {rec['compile_s']:.1f}s  "
              f"flops/dev {rec['flops']:.3e}  "
              f"coll {rec['collective_bytes_total']/1e6:.1f}MB  "
              f"dominant {r['dominant']}")
    return rec
