from repro.training.step import ByzantineConfig, make_train_step
from repro.training.loop import train_loop
from repro.simulator.async_loop import SimConfig, async_train_loop

__all__ = ["ByzantineConfig", "make_train_step", "train_loop",
           "SimConfig", "async_train_loop"]
