from repro.training.step import ByzantineConfig, make_train_step
from repro.training.loop import train_loop

__all__ = ["ByzantineConfig", "make_train_step", "train_loop"]
