"""Byzantine-robust distributed training step.

Maps the survey's server-based BGD framework (Algorithm 2) onto an SPMD TPU
program:

  1. the global batch is split along the leading AGENT axis (agents =
     data-parallel ranks; batch leaves are (n_agents, per_agent, ...));
  2. per-agent gradients are computed with vmap(grad) — agent axis sharded
     over the mesh's data axes;
  3. Byzantine behaviour is *injected* by rewriting the gradients of the f
     adversarial agents (SPMD-uniform where on the agent index — semantically
     identical to f agents sending arbitrary vectors, line 11 of Alg. 2);
  4. a gradient filter aggregates across the agent axis (eq. 17) —
     ``impl="gather"`` reproduces the survey's server literally,
     ``impl="fused"`` uses the stats->weights decomposition,
     ``impl="pallas"`` runs the rule's tiled TPU kernels, and
     ``impl="auto"`` picks pallas where the rule's caps match an
     available kernel (see repro.core.aggregators);
  5. the server-side optimizer applies the filtered update.

Worker momentum (§3.3.4 variance reduction) and Draco-style coded
aggregation (§3.3.3) slot in between (2) and (4).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.aggregators import AggregatorSpec, make_spec
from repro.core.attacks import get_attack, make_byzantine_mask
from repro.core.flat import (FlatPlan, QUANT_DTYPES, fake_quantize,
                             quantize_rows)
from repro.core.momentum import worker_momentum
from repro.obs.counters import count_trace
from repro.core.redundancy.coding import coding_groups, tree_draco_aggregate
from repro.models import loss_fn
from repro.optim import apply_updates


@dataclass(frozen=True)
class ByzantineConfig:
    n_agents: int = 16
    f: int = 3
    # robust aggregation: EITHER a first-class spec (preferred) ...
    aggregator: Optional[AggregatorSpec] = None
    # ... or the legacy string triple, resolved to a spec by resolve_spec()
    filter_name: str = "trimmed_mean"
    filter_hyper: dict = field(default_factory=dict)
    # fused | gather | pallas | auto ("auto" upgrades kernelized rules to
    # the Pallas path; the default stays "fused" so existing configs keep
    # their historical sharding-aware program bit-for-bit)
    impl: str = "fused"
    attack: str = "none"
    attack_hyper: dict = field(default_factory=dict)
    momentum_alpha: float = 0.0         # 0 = raw gradients
    draco_r: int = 0                    # >0 = coded aggregation instead
    remat: bool = False
    # ---- §Perf knobs (EXPERIMENTS.md) ----
    # >1: median-of-means grouping [19] — group-mean the sent gradients in
    # g groups of group_size BEFORE filtering (psum inside mesh subgroups
    # instead of gathering all n agent stacks).
    group_size: int = 1
    # cast the exchanged gradients to this dtype before aggregation
    # (beyond-paper quantized exchange; fp32 re-accumulated after):
    agg_dtype: str = ""                 # "" = keep native
    # reshard the (n, ...) gradient stack so the agent axis is replicated
    # and the parameter dims are sharded over BOTH mesh axes before the
    # coordinate-wise filter (beyond-paper collective schedule):
    reshard: bool = False

    def __post_init__(self):
        # the repetition code's shape contract, checked at CONFIG time —
        # the historical bare assert inside the aggregate vanished under
        # python -O and let a bad r reach a silently wrong reshape
        if self.draco_r:
            coding_groups(self.n_agents, self.draco_r)

    def resolve_spec(self) -> AggregatorSpec:
        """The aggregator actually used by the training loops: the explicit
        ``aggregator`` spec if set, else the legacy string triple compiled
        to a spec (hyper validated here, at config time).

        An explicit spec must agree with the config's threat model — a
        spec built for a different f (or n) would make the defense
        silently weaker than the configured attack."""
        if self.aggregator is not None:
            spec = self.aggregator
            if spec.f != self.f:
                raise ValueError(
                    f"aggregator {spec.describe()} was built for "
                    f"f={spec.f} but the config declares f={self.f} — "
                    "build the spec with the same Byzantine budget")
            if spec.n is not None and spec.n != self.n_agents:
                raise ValueError(
                    f"aggregator {spec.describe()} was built for "
                    f"n={spec.n} but the config declares "
                    f"n_agents={self.n_agents}")
            return spec
        return make_spec(self.filter_name, f=self.f, impl=self.impl,
                         n=self.n_agents, **self.filter_hyper)


def tree_attack(attack_fn, key, grads, byz_mask):
    """Apply a gradient attack leaf-wise (all implemented attacks are
    coordinate-decomposable, so leaf-wise == flat-wise)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, l in zip(keys, leaves):
        n = l.shape[0]
        flat = l.reshape(n, -1).astype(jnp.float32)
        out.append(attack_fn(k, flat, byz_mask).reshape(l.shape).astype(
            l.dtype))
    return jax.tree.unflatten(treedef, out)


def _group_mean(grads, group_size: int):
    """Median-of-means stage 1 [19]: mean of the *sent* gradients within
    consecutive groups (aligned with mesh data-axis subgroups, so XLA lowers
    it to subgroup reductions instead of a full agent-stack gather).

    Intentionally NOT the `bucketed` composition wrapper: here the group
    mean must run BEFORE the reshard sharding constraint so the measured
    collective schedule applies to the grouped (k, ...) stack; standalone
    users should prefer ``aggregators.bucketed(spec, group_size)``."""
    def leaf(l):
        n = l.shape[0]
        k = n // group_size
        return jnp.mean(
            l.astype(jnp.float32).reshape((k, group_size) + l.shape[1:]),
            axis=1).astype(l.dtype)
    return jax.tree.map(leaf, grads)


def _reshard_specs(grads, mesh_sizes):
    """Specs that replicate the agent axis and shard parameter dims over
    both mesh axes (first two dims that divide), for the coordinate-wise
    filter's local sort."""
    from jax.sharding import PartitionSpec as P

    def leaf(l):
        axes_left = ["data", "model"]
        dims = [None]                    # agent axis replicated
        for d in l.shape[1:]:
            placed = None
            if axes_left and d % mesh_sizes.get(axes_left[0], 1) == 0:
                placed = axes_left.pop(0)
            dims.append(placed)
        return P(*dims)
    return jax.tree.map(leaf, grads)


def make_train_step(cfg, bz: ByzantineConfig, optimizer,
                    mesh_sizes: dict | None = None,
                    bucket: int | None = None, telemetry: bool = False):
    """Returns train_step(params, opt_state, momentum, batch, key[,
    roster_idx, roster_valid]) -> (params, opt_state, momentum, metrics).

    ``telemetry`` (static Python flag): metrics additionally carry a
    fixed-shape ``"telemetry"`` struct — the aggregator's (n,) selection
    weights, delivery mask and contribution weights
    (``spec.selection_weights``, see :mod:`repro.obs`).  ``False`` emits
    the EXACT historical jaxpr (bit-identical results, same compile
    count); ``True`` adds only (n,)-sized aux outputs, so the compile
    budget is unchanged either way.

    ``bucket`` (elastic membership): per-agent gradients are still computed
    for the full n_agents batch, but aggregation runs over the LIVE roster
    packed into a (bucket,)-row stack — ``roster_idx`` (bucket,) int32 are
    the live slots (padded by repeating a live slot), ``roster_valid``
    (bucket,) bool marks the real ones.  The spec is re-specialized to the
    bucket's (n, f) plan; both roster operands are traced, so membership
    churn compiles at most once per bucket.  ``bucket=None`` is exactly the
    historical n-static step, bit-for-bit."""
    from repro.core.attacks import is_adaptive_attack
    if is_adaptive_attack(bz.attack):
        raise NotImplementedError(
            f"{bz.attack} is a defense-aware attack — run it through the "
            "async loop (repro.simulator.async_loop threads attack state "
            "and the defense's center alongside aggregator state)")
    attack_fn = get_attack(bz.attack, **bz.attack_hyper) \
        if bz.attack != "none" else None
    byz_mask = make_byzantine_mask(bz.n_agents, bz.f)
    spec = bz.resolve_spec()
    if spec.stateful:
        raise NotImplementedError(
            f"{spec.name} is stateful — run it through the async loop "
            "(repro.simulator.async_loop threads aggregator state)")
    # agg_dtype in QUANT_DTYPES (int8 / float8_e4m3fn) selects the
    # compressed-exchange pipeline: the fp32 arena is quantized per-row
    # with a scale sidecar right after ravel (core.flat.quantize_rows)
    # and the kernels dequantize inside the tile — NOT a tree-wide cast
    # (astype(int8) would truncate gradients to garbage)
    quant = bool(bz.agg_dtype) and bz.agg_dtype in QUANT_DTYPES
    if bz.agg_dtype and not quant:
        # sort/exchange in agg_dtype wherever the rule supports it —
        # reaches through composition wrappers to the executing rule
        # (weighted rules accumulate their statistics in fp32 regardless;
        # the pallas path, like gather, accumulates fp32 and ignores it)
        spec = spec.with_impl_hyper_if_supported(native_dtype=True)
    if bucket is not None:
        if bz.group_size > 1 or bz.reshard:
            raise NotImplementedError(
                "group_size/reshard are positional over the static "
                "roster — not supported with elastic membership")
        spec = spec.respecialize(bucket)
    if bz.group_size > 1:
        k = bz.n_agents // bz.group_size
        spec = spec.with_f_capped(max((k - 1) // 2, 0))
    # roster-aware gradient coding: the bucket's group table is derived
    # HERE, at step-build (respecialize) time — lru-cached per (n, r) like
    # the trim tables, a static constant of the traced step.  The packed
    # live rows are regrouped positionally (exact in the parallel regime).
    groups = (coding_groups(bucket if bucket is not None else bz.n_agents,
                            bz.draco_r, allow_ragged=bucket is not None)
              if bz.draco_r > 0 else None)
    # zero-copy flat pipeline: dense-stack impls ravel the gradients ONCE
    # into an (n, P) arena right after the communication boundary and
    # unravel ONCE at optimizer-apply — the aggregation dispatch never
    # touches a pytree.  The coded path rides the same arena (inside
    # tree_draco_aggregate for uniform-dtype trees).  reshard stays on the
    # tree path: its whole point is a leaf-wise sharding constraint the
    # flattening would erase.
    use_flat = spec.flat_capable and not bz.reshard

    def agent_loss(p, agent_batch):
        return loss_fn(cfg, p, agent_batch)

    def train_step(params, opt_state, momentum, batch, key,
                   roster_idx=None, roster_valid=None):
        count_trace("train_step")
        # (2) per-agent gradients — agent axis on the data mesh axes.
        # bz.remat = PER-LAYER activation checkpointing inside the scan
        # (whole-loss jax.checkpoint leaves the scan's stacked residuals in
        # place — measured in EXPERIMENTS.md §Perf pair A iteration A5)
        import contextlib

        from repro.distributed.context import layer_remat
        ctx = layer_remat(True) if bz.remat else contextlib.nullcontext()
        with ctx:
            losses, grads = jax.vmap(
                jax.value_and_grad(agent_loss), in_axes=(None, 0))(
                    params, batch)

        # variance reduction: agents send momentum, not raw gradients
        if bz.momentum_alpha > 0.0:
            momentum, grads = worker_momentum(momentum, grads,
                                              bz.momentum_alpha)

        # (3) Byzantine injection at the communication boundary
        if attack_fn is not None:
            grads = tree_attack(attack_fn, key, grads, byz_mask)

        # (4) robust aggregation via the AggregatorSpec (+ §Perf variants)
        if bz.agg_dtype and not quant:
            grads = jax.tree.map(
                lambda l: l.astype(jnp.dtype(bz.agg_dtype)), grads)
        if bz.group_size > 1:
            grads = _group_mean(grads, bz.group_size)
        if bz.reshard and mesh_sizes:
            grads = jax.lax.with_sharding_constraint(
                grads, _reshard_specs(grads, mesh_sizes))
        plan = FlatPlan.for_tree(grads)
        codes = qs = None
        if quant:
            # quantize the wire: per-row codes + fp32 scale sidecar.  The
            # pre-quantization f32 arena is kept ONLY as a local for
            # telemetry (it exists anyway — it's what was quantized);
            # the aggregate itself sees codes + scale.  Paths without a
            # scale-aware entry point (coded votes, reshard, non-flat
            # specs) see the fake-quantized stack instead, so every path
            # has identical compressed-exchange semantics.
            arena = plan.ravel(grads, jnp.float32)
            if use_flat and bz.draco_r == 0:
                codes, qs = quantize_rows(arena, jnp.dtype(bz.agg_dtype))
            else:
                grads = plan.unravel_stack(
                    fake_quantize(arena, jnp.dtype(bz.agg_dtype)))
        if bz.draco_r > 0:
            if bucket is not None:
                # elastic membership: regroup the packed live rows with
                # the bucket's table; pad slots are masked out of the vote
                live = jax.tree.map(lambda l: l[roster_idx], grads)
                agg = tree_draco_aggregate(live, bz.draco_r,
                                           mask=roster_valid, groups=groups)
            else:
                agg = tree_draco_aggregate(grads, bz.draco_r, groups=groups)
        elif codes is not None:
            # compressed flat path: codes on the wire, per-row scale as a
            # sidecar operand — the scaled kernels dequantize in-tile (no
            # (n, P) f32 copy; mixed-dtype trees are fine here since the
            # exchange dtype erases per-leaf dtypes anyway)
            if bucket is not None:
                vec = spec.aggregate_flat(codes[roster_idx],
                                          mask=roster_valid,
                                          scale=qs[roster_idx])
            else:
                vec = spec.aggregate_flat(codes, scale=qs)
            agg = plan.unravel(vec)
        elif use_flat and plan.uniform_dtype is not None:
            # zero-copy: ONE ravel into the (n, P) arena here, the
            # aggregation runs on the arena, and the single unravel below
            # happens at optimizer-apply — plan offsets are precomputed
            # (FlatPlan is cached per tree structure), so the dispatch
            # itself moves no model-sized memory.  Mixed-dtype trees keep
            # the tree path: flattening them would impute masked rows at
            # fp32 instead of each leaf's native rounding (not bitwise).
            arena = plan.ravel(grads)
            if bucket is not None:
                vec = spec.aggregate_flat(arena[roster_idx],
                                          mask=roster_valid)
            else:
                vec = spec.aggregate_flat(arena)
            agg = plan.unravel(vec)
        elif bucket is not None:
            # elastic membership: the rule sees only the live roster,
            # packed into the bucket's fixed-shape stack (pad slots are
            # repeated live rows, masked out under the documented masked
            # semantics)
            live = jax.tree.map(lambda l: l[roster_idx], grads)
            agg = spec.aggregate(live, mask=roster_valid)
        else:
            agg = spec.aggregate(grads)

        # (5) server-side optimizer
        updates, opt_state = optimizer.update(agg, opt_state, params)
        params = apply_updates(params, updates)

        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)))
            for l in jax.tree.leaves(agg)))
        honest = ~byz_mask
        metrics = {
            "loss": jnp.sum(losses * honest) / jnp.sum(honest),
            "loss_all": jnp.mean(losses),
            "grad_norm": gnorm,
        }
        if telemetry:
            # fixed-shape (n,) aux outputs computed OUTSIDE the aggregate
            # call — the update above is untouched, so results stay
            # bit-identical with telemetry on
            n = bz.n_agents
            if bz.draco_r > 0:
                # the repetition code votes per group: per-agent
                # attribution is uniform participation over the live roster
                m_full = (jnp.zeros((n,), bool).at[roster_idx].max(
                    roster_valid) if bucket is not None
                    else jnp.ones((n,), bool))
                mf = m_full.astype(jnp.float32)
                sel = mf / jnp.maximum(jnp.sum(mf), 1.0)
            elif bucket is not None:
                # quantized runs attribute weights on the PRE-quantization
                # f32 arena (it exists anyway — it is what was quantized);
                # observability must not add a dequantized (n, P) copy
                flat_stack = codes is not None or (
                    use_flat and plan.uniform_dtype is not None)
                stack = (arena[roster_idx] if flat_stack
                         else jax.tree.map(lambda l: l[roster_idx], grads))
                sel_b = spec.selection_weights(stack, mask=roster_valid)
                sel = jnp.zeros((n,), jnp.float32).at[roster_idx].add(
                    jnp.where(roster_valid, sel_b, 0.0))
                m_full = jnp.zeros((n,), bool).at[roster_idx].max(
                    roster_valid)
            else:
                flat_stack = codes is not None or (
                    use_flat and plan.uniform_dtype is not None)
                stack = arena if flat_stack else grads
                sel = spec.selection_weights(stack)
                m_full = jnp.ones((n,), bool)
                if bz.group_size > 1:
                    # rules ran on the k group means: attribute each
                    # group's weight evenly to its members
                    sel = jnp.repeat(sel, bz.group_size) / bz.group_size
            metrics["telemetry"] = {
                "sel_w": sel, "mask": m_full,
                "contrib_w": m_full.astype(jnp.float32)}
        return params, opt_state, momentum, metrics

    return train_step
