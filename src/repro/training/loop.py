"""Host-side training loop: data -> (jit) train_step -> metrics/checkpoints.

Since the simulator subsystem landed, the synchronous loop is the degenerate
case of the asynchronous one (:mod:`repro.simulator.async_loop`): zero
latency variance and quorum = n make every trace row "pure", and the async
host loop dispatches pure rows to the exact synchronous train step — so this
wrapper is bit-for-bit the historical ``train_loop``.  Pass a ``sim=``
:class:`~repro.simulator.async_loop.SimConfig` to inject crashes,
stragglers, message loss, or bounded-staleness asynchrony.

Robust aggregation flows through the config's
:class:`~repro.core.aggregators.AggregatorSpec` (``bz.aggregator``, or the
legacy ``filter_name``/``filter_hyper``/``impl`` triple resolved via
``bz.resolve_spec()``); stateful specs (zeno, zeno_pp) are routed through
the async loop's general path, which threads their state."""
from __future__ import annotations

from repro.simulator.async_loop import SimConfig, async_train_loop


def train_loop(cfg, bz, optimizer, dataset, steps: int, seed: int = 0,
               log_every: int = 10, ckpt_dir: str | None = None,
               ckpt_every: int = 0, poison_labels: bool = False,
               jit: bool = True, params=None, log_fn=print,
               sim: SimConfig | None = None, recorder=None,
               telemetry: bool | None = None):
    """Returns (params, history list of metric dicts).

    ``recorder``/``telemetry``: flight-recorder hooks (see
    :mod:`repro.obs` and ``async_train_loop``) — recording runs on host
    between steps, so results stay bit-identical and no extra compiles
    happen."""
    return async_train_loop(cfg, bz, optimizer, dataset, steps, sim=sim,
                            seed=seed, log_every=log_every,
                            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                            poison_labels=poison_labels, jit=jit,
                            params=params, log_fn=log_fn,
                            recorder=recorder, telemetry=telemetry)
