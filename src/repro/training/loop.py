"""Host-side training loop: data -> (jit) train_step -> metrics/checkpoints."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.core.attacks import make_byzantine_mask
from repro.core.momentum import init_momentum
from repro.data import label_flip
from repro.models import init_params
from repro.training.step import make_train_step


def train_loop(cfg, bz, optimizer, dataset, steps: int, seed: int = 0,
               log_every: int = 10, ckpt_dir: str | None = None,
               ckpt_every: int = 0, poison_labels: bool = False,
               jit: bool = True, params=None, log_fn=print):
    """Returns (params, history list of metric dicts)."""
    key = jax.random.PRNGKey(seed)
    k_init, k_run = jax.random.split(key)
    if params is None:
        params = init_params(cfg, k_init)
    opt_state = optimizer.init(params)
    momentum = None
    if bz.momentum_alpha > 0.0:
        proto = jax.tree.map(
            lambda p: jnp.zeros((bz.n_agents,) + p.shape, jnp.float32),
            params)
        momentum = init_momentum(proto)

    step_fn = make_train_step(cfg, bz, optimizer)
    if jit:
        step_fn = jax.jit(step_fn)
    byz_mask = make_byzantine_mask(bz.n_agents, bz.f)

    history = []
    t0 = time.time()
    for step in range(steps):
        k_run, k_data, k_step = jax.random.split(k_run, 3)
        batch = dataset.batch(k_data, step)
        if poison_labels:
            batch = label_flip(batch, byz_mask, cfg.vocab_size)
        params, opt_state, momentum, metrics = step_fn(
            params, opt_state, momentum, batch, k_step)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.time() - t0
            history.append(m)
            log_fn(f"step {step:5d}  loss {m['loss']:.4f}  "
                   f"gnorm {m['grad_norm']:.3f}")
        if ckpt_dir and ckpt_every and step and step % ckpt_every == 0:
            save(ckpt_dir, step, {"params": params, "opt": opt_state})
    if ckpt_dir:
        save(ckpt_dir, steps, {"params": params, "opt": opt_state})
    return params, history
