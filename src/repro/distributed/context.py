"""Lowering-time sharding hints for model code.

Model code is mesh-agnostic; launchers set this context so perf-critical
blocks (MoE dispatch) can pin the partitioning the SPMD partitioner won't
find on its own.  No-op when unset (single-device tests/training)."""
from __future__ import annotations

import contextlib

_MOE_DISPATCH = {"axes": None, "expert_parallel": False, "sizes": {}}


@contextlib.contextmanager
def moe_dispatch_sharding(axes, expert_parallel: bool, sizes: dict):
    """axes: mesh axis name (or tuple) for the capacity dim of the MoE
    dispatch buffer; expert_parallel: shard the expert dim over "model";
    sizes: mesh axis-name -> size (for divisibility checks)."""
    old = dict(_MOE_DISPATCH)
    _MOE_DISPATCH.update(axes=axes, expert_parallel=expert_parallel,
                         sizes=dict(sizes))
    try:
        yield
    finally:
        _MOE_DISPATCH.update(old)


def get_moe_dispatch():
    return (_MOE_DISPATCH["axes"], _MOE_DISPATCH["expert_parallel"],
            _MOE_DISPATCH["sizes"])


_LAYER_REMAT = {"on": False}


@contextlib.contextmanager
def layer_remat(on: bool = True):
    """Wrap every scan-layer body in jax.checkpoint: residuals become the
    layer inputs only; attention probs / MoE activations are recomputed in
    the backward scan (whole-loss jax.checkpoint does NOT achieve this —
    scan still stacks per-layer residuals; measured in §Perf pair A)."""
    old = _LAYER_REMAT["on"]
    _LAYER_REMAT["on"] = on
    try:
        yield
    finally:
        _LAYER_REMAT["on"] = old


def layer_remat_on() -> bool:
    return _LAYER_REMAT["on"]
