"""Sharding rules: param/grad/batch/cache PartitionSpecs from tree paths.

Mesh axes
  single pod : ("data", "model")            — 16 x 16 = 256 chips
  multi pod  : ("pod", "data", "model")     — 2 x 16 x 16 = 512 chips

The Byzantine *agent* axis maps onto the data-parallel axes: agents =
pod x data ranks.  Tensor/expert parallelism uses "model".

Modes
  ddp  — params replicated over data axes, sharded over "model"
  fsdp — params additionally sharded over "data" (ZeRO-3-ish); XLA inserts
         the per-layer all-gathers.

Every rule is a CANDIDATE LIST: the first spec whose axis sizes divide the
leaf's dimensions (given the mesh) is used — e.g. Mixtral's 8 experts cannot
be expert-parallel over model=16, so its experts fall back to tensor-parallel
d_ff sharding; Mamba2-130m's fused in_proj (output 3352) falls back to
input-dim (row-parallel) sharding.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def agent_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _fs(mode):
    """The axis params are sharded over in fsdp mode (None in ddp)."""
    return "data" if mode == "fsdp" else None


def _rules(mode):
    fs = _fs(mode)
    col = [P(fs, "model"), P(None, "model"), P("model", None), P()]
    row = [P("model", fs), P("model", None), P(None, "model"), P()]
    vec = [P("model"), P()]
    return {
        # embeddings / heads
        ("embed",): [P("model", fs), P(None, "model"), P()],
        ("lm_head",): [P(fs, "model"), P("model", None), P()],
        ("frontend_proj",): col,
        # attention
        ("attn", "wq"): col, ("attn", "wk"): col, ("attn", "wv"): col,
        ("attn", "wo"): row,
        ("attn", "bq"): vec, ("attn", "bk"): vec, ("attn", "bv"): vec,
        ("cross", "wq"): col, ("cross", "wk"): col, ("cross", "wv"): col,
        ("cross", "wo"): row,
        ("cross", "bq"): vec, ("cross", "bk"): vec, ("cross", "bv"): vec,
        # dense mlp
        ("mlp", "w_gate"): col, ("mlp", "w_up"): col,
        ("mlp", "w_down"): row,
        ("mlp", "w_in"): col, ("mlp", "w_out"): row,
        # moe: expert-parallel first, tensor-parallel fallback
        ("moe", "router"): [P()],
        ("moe", "w_gate"): [P("model", fs, None), P(None, fs, "model"), P()],
        ("moe", "w_up"): [P("model", fs, None), P(None, fs, "model"), P()],
        ("moe", "w_down"): [P("model", None, fs), P(None, "model", fs),
                            P(None, "model", None), P()],
        ("shared", "w_gate"): col, ("shared", "w_up"): col,
        ("shared", "w_down"): row,
        # ssm
        ("ssm", "in_proj"): [P(fs, "model"), P("model", None),
                             P(None, "model"), P()],
        ("ssm", "conv_w"): [P(None, "model"), P()],
        ("ssm", "conv_b"): vec,
        ("ssm", "A_log"): [P()], ("ssm", "dt_bias"): [P()],
        ("ssm", "D_skip"): [P()],
        ("ssm", "norm_scale"): vec,
        ("ssm", "out_proj"): row,
        # norms
        ("attn_norm",): [P()], ("mlp_norm",): [P()], ("cross_norm",): [P()],
        ("final_norm",): [P()], ("norm",): [P()],
    }


def _axis_size(axis, axis_sizes):
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= axis_sizes.get(a, 1)
        return n
    return axis_sizes.get(axis, 1)


def _divides(spec, shape, axis_sizes) -> bool:
    for dim, axis in zip(shape[-len(spec):] if spec else (), spec):
        sz = _axis_size(axis, axis_sizes)
        if sz > 1 and dim % sz:
            return False
    return True


def _pad(spec, ndim):
    pad = ndim - len(spec)
    if pad > 0:
        return P(*([None] * pad + list(spec)))
    if pad < 0:
        return P(*list(spec)[-ndim:]) if ndim else P()
    return spec


def _match(path_names, rules):
    for suffix, specs in rules.items():
        if tuple(path_names[-len(suffix):]) == suffix:
            return specs
    return None


def _mesh_sizes(mesh):
    if mesh is None:
        return {}
    return dict(mesh.shape)


def _leaf_spec(path, leaf, mode, axis_sizes, lead=()):
    names = [str(p.key) for p in path if hasattr(p, "key")]
    candidates = _match(names, _rules(mode)) or [P()]
    for spec in candidates:
        padded = _pad(spec, leaf.ndim - len(lead))
        full = P(*lead, *padded)
        if not axis_sizes or _divides(full, leaf.shape, axis_sizes):
            return full
    return P(*lead, *([None] * (leaf.ndim - len(lead))))


def param_pspecs(params, mode: str = "ddp", mesh=None):
    """PartitionSpec pytree matching ``params``."""
    sizes = _mesh_sizes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, mode, sizes), params)


def grads_pspecs(params, multi_pod: bool = False, mesh=None):
    """Per-agent gradient stacks: leading agent axis over the data axes;
    param dims keep their model-axis (ddp) sharding."""
    ax = agent_axes(multi_pod)
    ax = ax[0] if len(ax) == 1 else ax
    sizes = _mesh_sizes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, l: _leaf_spec(path, l, "ddp", sizes, lead=(ax,)),
        params)


def batch_pspec(multi_pod: bool = False, extra_dims: int = 2):
    """Batches shaped (n_agents, per_agent, ...): agent axis on data axes."""
    ax = agent_axes(multi_pod)
    ax = ax[0] if len(ax) == 1 else ax
    return P(ax, *([None] * extra_dims))


def cache_pspecs(cache, multi_pod: bool = False, mesh=None,
                 layout: str = "headdim"):
    """KV/SSM caches: batch dim over data axes; a model-axis dim chosen with
    divisibility fallbacks (kv-heads -> head_dim; ssm-heads -> head_dim).

    Layouts (leading layer-stack dim possible):
      kv k/v:    (L, B, C, K, hd)
      ssm state: (L, B, h, p, n)
      ssm conv:  (L, B, k, conv_dim)
    long_500k decode has B=1: the batch axis stays unsharded then."""
    ax = agent_axes(multi_pod)
    ax = ax[0] if len(ax) == 1 else ax
    sizes = _mesh_sizes(mesh)

    def pick(shape, candidates):
        for spec in candidates:
            if not sizes or _divides(spec, shape, sizes):
                return spec
        return P(*([None] * len(shape)))

    def leaf(path, l):
        names = [str(p.key) for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        if l.ndim == 0 or name not in ("k", "v", "state", "conv"):
            return P()
        stacked = (l.ndim == 5) if name in ("k", "v", "state") \
            else (l.ndim == 4)
        body = l.shape[1:] if stacked else l.shape
        b_ax = ax if body[0] > 1 else None
        if name in ("k", "v"):
            if layout == "seq":
                # shard the cache-length dim: softmax over shards reduces to
                # cheap scalar all-reduces instead of score-tensor psums
                cands = [P(b_ax, "model", None, None),
                         P(b_ax, None, "model", None),
                         P(b_ax, None, None, "model"),
                         P(b_ax, None, None, None)]
            else:
                cands = [P(b_ax, None, "model", None),
                         P(b_ax, None, None, "model"),
                         P(b_ax, None, None, None)]
        elif name == "state":
            cands = [P(b_ax, "model", None, None),
                     P(b_ax, None, "model", None),
                     P(b_ax, None, None, None)]
        else:
            cands = [P(b_ax, None, "model"), P(b_ax, None, None)]
        spec = pick(body, cands)
        return P(None, *spec) if stacked else spec
    return jax.tree_util.tree_map_with_path(leaf, cache)
