from repro.distributed.sharding import (agent_axes, batch_pspec, grads_pspecs,
                                        param_pspecs)

__all__ = ["param_pspecs", "grads_pspecs", "batch_pspec", "agent_axes"]
