"""Pallas TPU kernels: the full selection family on the (n, n) Gram.

:mod:`repro.kernels.pairwise` reduces the O(n^2 d) work of the
distance-based filters to one tiled MXU pass; what remains is the O(n^2)
*selection* — Krum scores + argmin, CGE's smallest-norm top-k, multi-Krum's
top-m, and the shrinking-candidate iterative selections of m-Krum and
Bulyan.  These fit in a single VMEM block, so each runs as one grid-step
kernel producing either (n,) application weights (Krum/CGE) or an (n,)
int32 selection ORDER (position each row was picked at, sentinel = not
picked) that :func:`repro.kernels.wsum.ordered_apply` accumulates in
exactly the dense reference's summation order — that order-match is what
makes the multi-row rules bit-for-bit with ``impl="gather"``.

The iterative kernels honor the shrinking-candidate contract of
``repro.core.filters.dense.krum_scores``: the neighbour count k shrinks
with the remaining candidate set (k = remaining - f - 2, clamped) and
exact fp score ties break by the full-degree secondary score then first
index (``argmin_tiebreak``), so the membership-conformance permutation
invariants hold on the kernel path unmodified.

Bulyan's coordinate stage (:func:`bulyan_coord`) is also fused: median of
the selected set + mean of the beta closest values per coordinate run
inside the tile via iterative first-index min-extraction — no (n, d)
distance or sorted copy ever reaches HBM.

No ``jnp.sort`` / ``top_k`` inside the kernels: ordering is computed with a
static odd-even transposition network (rows of the distance matrix) and
exact comparison-rank selection with first-index tie-breaking — the same
selection ``jax.lax.top_k`` / ``argmin`` produce, so the chosen rows match
the dense reference bit-for-bit whenever the scores are not exactly tied.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.coord_stats import _sort_network


def _rank(values, ascending: bool = True):
    """Exact comparison rank with first-index tie-break: rank[i] = number
    of j that order strictly before i.  Matches argmin / top_k(-v) order.

    NaN scores (an inf-coordinate adversarial gradient turns the whole d2
    row NaN) are ordered LAST: NaN compares False against everything, so
    without the rewrite every NaN row would get rank 0 and the "one-hot"
    selection would silently become multi-hot — handing the adversary
    exactly the multi-row average the rule exists to prevent."""
    worst = jnp.float32(jnp.inf) if ascending else -jnp.float32(jnp.inf)
    values = jnp.where(jnp.isnan(values), worst, values)
    n = values.shape[0]
    i = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)   # row = candidate i
    j = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    vi, vj = values[:, None], values[None, :]
    before = (vj < vi) if ascending else (vj > vi)
    before = before | ((vj == vi) & (j < i))
    return jnp.sum(before.astype(jnp.int32), axis=1)     # (n,)


def _eye_and_diag(gr):
    """(n, n) bool identity + the Gram diagonal as a (n,) vector, without
    a gather — THE one copy of the diagonal-extraction trick."""
    n = gr.shape[0]
    eye = (jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
           == jax.lax.broadcasted_iota(jnp.int32, (n, n), 1))
    return eye, jnp.sum(jnp.where(eye, gr, 0.0), axis=1)


def _d2_from_gram(gr):
    eye, sq = _eye_and_diag(gr)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gr, 0.0)
    # NaN distances (inf - inf against a non-finite adversary row) order
    # LAST, like _rank's score policy: one NaN would otherwise DUPLICATE
    # through the sort network's min/max pairs and poison every finite
    # row's score.  Exact no-op on finite stacks.
    d2 = jnp.where(jnp.isnan(d2), jnp.float32(jnp.inf), d2)
    return jnp.where(eye, jnp.float32(jnp.inf), d2)      # self excluded


def _krum_select_kernel(gram_ref, out_ref, *, f):
    """(n, n) Gram -> (1, n) one-hot weights of the Krum minimizer."""
    gr = gram_ref[...].astype(jnp.float32)
    n = gr.shape[0]
    d2 = _d2_from_gram(gr)
    # per-row ascending sort of distances-to-others via the same static
    # network the coordinate kernels use (columns = rows of d2)
    srt = _sort_network(d2.T)                            # (n, n) cols sorted
    k = max(n - f - 2, 1)
    scores = jnp.sum(srt[:k], axis=0)                    # (n,)
    out_ref[...] = (_rank(scores) == 0).astype(jnp.float32)[None]


def _cge_select_kernel(gram_ref, out_ref, *, n_keep):
    """(n, n) Gram -> (1, n) {0,1} mask of the n_keep smallest-norm rows
    (norms off the Gram diagonal) — CGE's comparative elimination."""
    gr = gram_ref[...].astype(jnp.float32)
    _, sq = _eye_and_diag(gr)
    norms = jnp.sqrt(jnp.maximum(sq, 0.0))
    out_ref[...] = (_rank(norms) < n_keep).astype(jnp.float32)[None]


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def krum_select(gr, f: int, *, interpret: bool = True):
    """gr: (n, n) Gram -> (n,) one-hot fp32 Krum selection weights."""
    n = gr.shape[0]
    return pl.pallas_call(
        functools.partial(_krum_select_kernel, f=f),
        grid=(1,),
        in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(gr)[0]


@functools.partial(jax.jit, static_argnames=("n_keep", "interpret"))
def cge_select(gr, n_keep: int, *, interpret: bool = True):
    """gr: (n, n) Gram -> (n,) {0,1} fp32 keep-mask of the n_keep
    smallest-norm rows (unnormalized: the caller divides after the sum,
    exactly like the dense reference)."""
    n = gr.shape[0]
    return pl.pallas_call(
        functools.partial(_cge_select_kernel, n_keep=n_keep),
        grid=(1,),
        in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(gr)[0]


# ---------------------------------------------------------------------------
# selection ORDERS — (n,) int32, order[i] = position row i was picked at
# (in [0, k)), sentinel n if not picked.  The application stage replays
# the picks in this order, matching the dense reference's summation order
# bit-for-bit (jnp.mean over a top_k gather sums rank-ascending; the
# iterative rules sum pick-ascending).


def _multi_krum_order_kernel(gram_ref, out_ref, *, f, m):
    """multi-Krum: ONE score pass (classic k = n - f - 2), the m smallest
    scores selected simultaneously — order = score rank, exactly
    ``jax.lax.top_k(-scores, m)``'s output order."""
    gr = gram_ref[...].astype(jnp.float32)
    n = gr.shape[0]
    srt = _sort_network(_d2_from_gram(gr).T)
    k = max(min(n - f - 2, n - 1), 1)
    scores = jnp.sum(srt[:k], axis=0)
    rank = _rank(scores)
    out_ref[...] = jnp.where(rank < m, rank, n).astype(jnp.int32)[None]


def _iterative_order_kernel(gram_ref, out_ref, *, f, k_total):
    """Shrinking-candidate iterative Krum selection (m-Krum's m picks,
    Bulyan's theta picks): per round, Krum scores over the remaining
    candidate set with the SHRINKING neighbour count
    k = remaining - f - 2 (clamped), exact fp ties broken by the
    full-degree score then first index — the
    ``D.krum_scores``/``D.argmin_tiebreak`` contract, replicated
    comparison-for-comparison so the kernel picks exactly the dense
    reference's rows (the membership suite's permutation invariance
    depends on it)."""
    gr = gram_ref[...].astype(jnp.float32)
    n = gr.shape[0]
    big = jnp.float32(jnp.inf)
    eye, sq = _eye_and_diag(gr)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gr, 0.0)
    d2 = jnp.where(jnp.isnan(d2), big, d2)         # NaN orders last
    d2 = jnp.where(eye, 0.0, d2)                   # raw d2 (tie-break base)
    d2_self = jnp.where(eye, big, d2)              # self excluded for scores
    cand = jnp.ones((n,), bool)
    order = jnp.full((n,), n, jnp.int32)
    for it in range(k_total):
        k = max(min(max(n - it - f - 2, 1), n - 1), 1)
        srt = _sort_network(jnp.where(cand[None, :], d2_self, big).T)
        s = jnp.sum(srt[:k], axis=0)
        s = jnp.where(jnp.isnan(s), big, s)          # NaN orders last
        key = jnp.where(cand, s, big)
        sec = jnp.sum(jnp.where(cand[None, :] & ~eye, d2, 0.0), axis=1)
        sec = jnp.where(jnp.isnan(sec), big, sec)
        # candidate-CONSTRAINED argmin_tiebreak: every comparison set is
        # intersected with `cand`, so even an all-inf round (NaN-poisoned
        # adversary) picks a genuine candidate instead of re-picking a
        # removed row by index order; on finite data this is exactly
        # D.argmin_tiebreak (removed rows carry +inf primary AND
        # secondary there, so they never win a finite comparison)
        tied = (key == jnp.min(key)) & cand
        sec_eff = jnp.where(tied, sec, big)
        pool = tied & (sec_eff == jnp.min(sec_eff))
        pick = pool & (jnp.cumsum(pool.astype(jnp.int32)) == 1)
        order = jnp.where(pick, it, order)
        cand = cand & ~pick
    out_ref[...] = order[None]


def _order_call(kernel, gr, *, interpret):
    n = gr.shape[0]
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(gr)[0]


@functools.partial(jax.jit, static_argnames=("f", "m", "interpret"))
def multi_krum_order(gr, f: int, m: int, *, interpret: bool = True):
    """(n, n) Gram -> (n,) int32 order of the m smallest-score rows."""
    return _order_call(
        functools.partial(_multi_krum_order_kernel, f=f, m=m), gr,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("f", "k_total", "interpret"))
def iterative_order(gr, f: int, k_total: int, *, interpret: bool = True):
    """(n, n) Gram -> (n,) int32 pick order of ``k_total`` shrinking-k
    iterative Krum selections (m-Krum / Bulyan stage 1)."""
    return _order_call(
        functools.partial(_iterative_order_kernel, f=f, k_total=k_total),
        gr, interpret=interpret)


# ---------------------------------------------------------------------------
# Bulyan stage 2: fused per-coordinate trimmed average around the median
# of the selected set — tiled over d, selection mask pinned; the masked
# variant fuses the mean-imputation preamble (imputation-free quorum path)


def _impute_tile(x, m, mean):
    """The one imputation preamble: absent rows replaced by the
    precomputed (T,) mean slice (repro.kernels.pairwise.imputed_mean —
    bit-for-bit with the tree-level engine and kernels/masked.py)."""
    return jnp.where(m[:, None] > 0.5, x, mean[None])


def _bulyan_stage2(x, sel, *, theta, beta, exact):
    """x: (n, T) fp32, sel: (n,) bool with exactly theta True.  Median of
    the selected rows via the sort network (+inf padding), then the mean
    of the beta selected values closest to it per coordinate — closeness
    ties by first index, summation in closeness order: exactly the dense
    reference's ``top_k`` + ``take_along_axis`` + ``mean``."""
    n = x.shape[0]
    big = jnp.float32(jnp.inf)
    padded = jnp.where(sel[:, None], x, big)
    s = _sort_network(padded)
    if exact:
        s = jax.lax.optimization_barrier(s)
    med = 0.5 * (s[(theta - 1) // 2] + s[theta // 2])
    dist = jnp.where(sel[:, None], jnp.abs(x - med[None]), big)
    avail = jnp.broadcast_to(sel[:, None], dist.shape)
    rows = []
    for _ in range(beta):
        cur = jnp.where(avail, dist, big)
        is_min = cur == jnp.min(cur, axis=0)[None]
        first = is_min & (jnp.cumsum(is_min.astype(jnp.int32), axis=0) == 1)
        rows.append(jnp.sum(jnp.where(first, x, 0.0), axis=0))
        avail = avail & ~first
    stk = jnp.stack(rows, axis=0)
    if exact:
        stk = jax.lax.optimization_barrier(stk)
    # the reference is jnp.mean: divisor stays a visible constant so the
    # kernel gets the same reciprocal-multiply strength reduction
    # (true_div=False in kernels/wsum.py terms)
    return jnp.sum(stk, axis=0) / beta


def _bulyan_coord_kernel(g_ref, sel_ref, out_ref, *, theta, beta, exact):
    x = g_ref[...].astype(jnp.float32)
    sel = sel_ref[...][0] > 0.5
    out_ref[...] = _bulyan_stage2(x, sel, theta=theta, beta=beta,
                                  exact=exact)[None]


def _masked_bulyan_coord_kernel(g_ref, mask_ref, mean_ref, sel_ref, out_ref,
                                *, theta, beta, exact):
    x = _impute_tile(g_ref[...], mask_ref[...][0], mean_ref[...][0])
    sel = sel_ref[...][0] > 0.5
    out_ref[...] = _bulyan_stage2(x.astype(jnp.float32), sel, theta=theta,
                                  beta=beta, exact=exact)[None]


@functools.partial(jax.jit, static_argnames=("theta", "f", "interpret"))
def bulyan_coord(g, sel, theta: int, f: int, *, interpret: bool = True):
    """g: (n, d), sel: (n,) {0,1} f32 (theta rows selected) -> (d,) fp32
    Bulyan coordinate stage.  d must be a multiple of TILE_D."""
    from repro.kernels.tiling import TILE_D, block_d
    n, d = g.shape
    assert d % TILE_D == 0, d
    beta = max(theta - 2 * f, 1)
    w = block_d(d, interpret)
    out = pl.pallas_call(
        functools.partial(_bulyan_coord_kernel, theta=theta, beta=beta,
                          exact=interpret),
        grid=(d // w,),
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g, sel.astype(jnp.float32).reshape(1, n))
    return out[0]


@functools.partial(jax.jit, static_argnames=("theta", "f", "interpret"))
def masked_bulyan_coord(g, mask, mean, sel, theta: int, f: int, *,
                        interpret: bool = True):
    """Imputation-fused Bulyan coordinate stage: g stays native dtype,
    absent rows are imputed inside the tile from the precomputed (d,)
    ``mean`` (no (n, d) imputed copy)."""
    from repro.kernels.tiling import TILE_D, block_d
    n, d = g.shape
    assert d % TILE_D == 0, d
    beta = max(theta - 2 * f, 1)
    w = block_d(d, interpret)
    out = pl.pallas_call(
        functools.partial(_masked_bulyan_coord_kernel, theta=theta,
                          beta=beta, exact=interpret),
        grid=(d // w,),
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, w), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g, mask.astype(jnp.float32).reshape(1, n), mean.reshape(1, d),
      sel.astype(jnp.float32).reshape(1, n))
    return out[0]
