"""Pallas TPU kernel: fused Krum / CGE selection on the (n, n) Gram.

:mod:`repro.kernels.pairwise` reduces the O(n^2 d) work of the
distance-based filters to one tiled MXU pass; what remains is the O(n^2)
*selection* — Krum scores + argmin, CGE's smallest-norm top-k.  These fit in
a single VMEM block, so each runs as one grid-step kernel producing the
(n,) application weights that :mod:`repro.kernels.wsum` then applies.

No ``jnp.sort`` / ``top_k`` inside the kernels: ordering is computed with a
static odd-even transposition network (rows of the distance matrix) and
exact comparison-rank selection with first-index tie-breaking — the same
selection ``jax.lax.top_k`` / ``argmin`` produce, so the chosen rows match
the dense reference bit-for-bit whenever the scores are not exactly tied.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.coord_stats import _sort_network


def _rank(values, ascending: bool = True):
    """Exact comparison rank with first-index tie-break: rank[i] = number
    of j that order strictly before i.  Matches argmin / top_k(-v) order.

    NaN scores (an inf-coordinate adversarial gradient turns the whole d2
    row NaN) are ordered LAST: NaN compares False against everything, so
    without the rewrite every NaN row would get rank 0 and the "one-hot"
    selection would silently become multi-hot — handing the adversary
    exactly the multi-row average the rule exists to prevent."""
    worst = jnp.float32(jnp.inf) if ascending else -jnp.float32(jnp.inf)
    values = jnp.where(jnp.isnan(values), worst, values)
    n = values.shape[0]
    i = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)   # row = candidate i
    j = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    vi, vj = values[:, None], values[None, :]
    before = (vj < vi) if ascending else (vj > vi)
    before = before | ((vj == vi) & (j < i))
    return jnp.sum(before.astype(jnp.int32), axis=1)     # (n,)


def _eye_and_diag(gr):
    """(n, n) bool identity + the Gram diagonal as a (n,) vector, without
    a gather — THE one copy of the diagonal-extraction trick."""
    n = gr.shape[0]
    eye = (jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
           == jax.lax.broadcasted_iota(jnp.int32, (n, n), 1))
    return eye, jnp.sum(jnp.where(eye, gr, 0.0), axis=1)


def _d2_from_gram(gr):
    eye, sq = _eye_and_diag(gr)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gr, 0.0)
    return jnp.where(eye, jnp.float32(jnp.inf), d2)      # self excluded


def _krum_select_kernel(gram_ref, out_ref, *, f):
    """(n, n) Gram -> (1, n) one-hot weights of the Krum minimizer."""
    gr = gram_ref[...].astype(jnp.float32)
    n = gr.shape[0]
    d2 = _d2_from_gram(gr)
    # per-row ascending sort of distances-to-others via the same static
    # network the coordinate kernels use (columns = rows of d2)
    srt = _sort_network(d2.T)                            # (n, n) cols sorted
    k = max(n - f - 2, 1)
    scores = jnp.sum(srt[:k], axis=0)                    # (n,)
    out_ref[...] = (_rank(scores) == 0).astype(jnp.float32)[None]


def _cge_select_kernel(gram_ref, out_ref, *, n_keep):
    """(n, n) Gram -> (1, n) {0,1} mask of the n_keep smallest-norm rows
    (norms off the Gram diagonal) — CGE's comparative elimination."""
    gr = gram_ref[...].astype(jnp.float32)
    _, sq = _eye_and_diag(gr)
    norms = jnp.sqrt(jnp.maximum(sq, 0.0))
    out_ref[...] = (_rank(norms) < n_keep).astype(jnp.float32)[None]


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def krum_select(gr, f: int, *, interpret: bool = True):
    """gr: (n, n) Gram -> (n,) one-hot fp32 Krum selection weights."""
    n = gr.shape[0]
    return pl.pallas_call(
        functools.partial(_krum_select_kernel, f=f),
        grid=(1,),
        in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(gr)[0]


@functools.partial(jax.jit, static_argnames=("n_keep", "interpret"))
def cge_select(gr, n_keep: int, *, interpret: bool = True):
    """gr: (n, n) Gram -> (n,) {0,1} fp32 keep-mask of the n_keep
    smallest-norm rows (unnormalized: the caller divides after the sum,
    exactly like the dense reference)."""
    n = gr.shape[0]
    return pl.pallas_call(
        functools.partial(_cge_select_kernel, n_keep=n_keep),
        grid=(1,),
        in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(gr)[0]
