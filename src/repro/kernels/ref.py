"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def coord_sort_ref(g):
    return jnp.sort(g.astype(jnp.float32), axis=0)


def gram_ref(g):
    x = g.astype(jnp.float32)
    return x @ x.T


def weighted_sum_ref(w, g):
    return w.astype(jnp.float32) @ g.astype(jnp.float32)


def median_from_sorted(s):
    n = s.shape[0]
    return 0.5 * (s[(n - 1) // 2] + s[n // 2])


def trimmed_mean_from_sorted(s, b: int):
    n = s.shape[0]
    kept = s[b:n - b] if b else s
    return jnp.mean(kept, axis=0)


def masked_impute_ref(g, mask, wn):
    """Mean-imputed stack, arithmetic mirroring the engine's masked path:
    fp32 weighted mean of arrived rows -> native-dtype round trip ->
    row-select.  Oracle for kernels/masked.py."""
    xf = g.astype(jnp.float32)
    mean = jnp.sum(xf * wn.astype(jnp.float32)[:, None],
                   axis=0).astype(g.dtype)
    return jnp.where(mask.astype(bool)[:, None], g, mean[None])


def masked_stat_ref(g, mask, wn, stat: str, b: int = 0):
    """(d,) fp32 oracle for masked_coord_stat."""
    s = jnp.sort(masked_impute_ref(g, mask, wn).astype(jnp.float32), axis=0)
    if stat == "median":
        return median_from_sorted(s)
    if stat == "trimmed_mean":
        return trimmed_mean_from_sorted(s, b)
    raise KeyError(stat)


def krum_select_ref(g, f: int):
    """(n,) one-hot Krum selection oracle (dense scores + argmin)."""
    import jax

    from repro.core.filters.dense import krum_scores, pairwise_sq_dists
    s = krum_scores(pairwise_sq_dists(g.astype(jnp.float32)), f)
    return jax.nn.one_hot(jnp.argmin(s), g.shape[0], dtype=jnp.float32)


def cge_select_ref(g, n_keep: int):
    """(n,) {0,1} smallest-norm keep-mask oracle (top_k selection)."""
    import jax
    norms = jnp.linalg.norm(g.astype(jnp.float32), axis=-1)
    _, idx = jax.lax.top_k(-norms, n_keep)
    return jnp.zeros((g.shape[0],), jnp.float32).at[idx].set(1.0)
