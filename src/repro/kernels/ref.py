"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def coord_sort_ref(g):
    return jnp.sort(g.astype(jnp.float32), axis=0)


def gram_ref(g):
    x = g.astype(jnp.float32)
    return x @ x.T


def weighted_sum_ref(w, g):
    return w.astype(jnp.float32) @ g.astype(jnp.float32)


def median_from_sorted(s):
    n = s.shape[0]
    return 0.5 * (s[(n - 1) // 2] + s[n // 2])


def trimmed_mean_from_sorted(s, b: int):
    n = s.shape[0]
    kept = s[b:n - b] if b else s
    return jnp.mean(kept, axis=0)
