"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def coord_sort_ref(g):
    return jnp.sort(g.astype(jnp.float32), axis=0)


def gram_ref(g):
    x = g.astype(jnp.float32)
    return x @ x.T


def weighted_sum_ref(w, g):
    return w.astype(jnp.float32) @ g.astype(jnp.float32)


def median_from_sorted(s):
    n = s.shape[0]
    return 0.5 * (s[(n - 1) // 2] + s[n // 2])


def trimmed_mean_from_sorted(s, b: int):
    n = s.shape[0]
    kept = s[b:n - b] if b else s
    return jnp.mean(kept, axis=0)


def masked_impute_ref(g, mask, wn):
    """Mean-imputed stack, arithmetic mirroring the engine's masked path
    for the PAIRWISE rule family: fp32 weighted mean of arrived rows ->
    native-dtype round trip -> row-select.  Oracle for the Gram-based
    masked kernels.  The coordinate-wise rules do NOT use this law — the
    delivered mean is not robust, so a mean-imputed ghost row lands
    inside the trim window under attack; they use the arrived-window
    statistics below instead."""
    xf = g.astype(jnp.float32)
    mean = jnp.sum(xf * wn.astype(jnp.float32)[:, None],
                   axis=0).astype(g.dtype)
    return jnp.where(mask.astype(bool)[:, None], g, mean[None])


def arrived_stat_from_sorted(s, mask, stat: str, b: int = 0):
    """Order statistic over the ARRIVED rows only.

    ``s``: (n, t) fp32, per-coordinate ascending sort of the stack with
    absent rows replaced by +inf (they occupy the top ``n - cnt`` ranks
    of every column, so the arrived values sit in ranks ``[0, cnt)``).
    The kept rank window is computed from the traced arrived count:

      * ``median``        — ranks ``[(cnt-1)//2, cnt - (cnt-1)//2)``
        (one rank when cnt is odd, the two middle ranks when even — the
        window mean IS the median);
      * ``trimmed_mean``  — ranks ``[b', cnt - b')`` with
        ``b' = min(b, (cnt-1)//2)``: the per-side trim clamps so the
        window never empties; below ``2b + 1`` arrivals the statistic
        degrades gracefully to the median of the arrived rows.

    The window indicator depends only on the scalar count, so the whole
    statistic is one sort + one masked reduce — fixed shapes, traced
    mask, no recompiles.  Zero arrivals return an exact 0 (the engine's
    zero-total guard scales the update to 0 anyway)."""
    import jax
    n = s.shape[0]
    cnt = jnp.sum(mask.astype(jnp.float32) > 0.5).astype(jnp.int32)
    if stat == "median":
        lo = (cnt - 1) // 2
    elif stat == "trimmed_mean":
        lo = jnp.minimum(jnp.int32(b), (cnt - 1) // 2)
    else:
        raise KeyError(stat)
    lo = jnp.maximum(lo, 0)
    hi = cnt - lo
    ranks = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)
    keep = (ranks >= lo) & (ranks < hi)
    width = jnp.maximum(hi - lo, 1).astype(jnp.float32)
    out = jnp.sum(jnp.where(keep, s, 0.0), axis=0) / width
    return jnp.where(cnt > 0, out, 0.0)


def masked_stat_ref(g, mask, wn, stat: str, b: int = 0):
    """(d,) fp32 oracle for masked_coord_stat: the arrived-window law
    (absent rows are +inf sort sentinels, never statistics)."""
    mb = mask.astype(bool)
    s = jnp.sort(jnp.where(mb[:, None], g.astype(jnp.float32), jnp.inf),
                 axis=0)
    return arrived_stat_from_sorted(s, mask, stat, b)


def arrived_mean_closest_ref(g, mask, stat: str, f: int):
    """(d,) fp32: the phocas / mean_around_median trust window over the
    ARRIVED rows only.

    Two count-windowed stages, both rank-indexed by the traced arrived
    count (fixed shapes, no recompiles):

      1. center — :func:`arrived_stat_from_sorted` on the +inf-sentinel
         sort (``trimmed_mean`` with b=f for phocas, ``median`` for
         mean_around_median);
      2. window — per coordinate, the ``k = clip(cnt - f, 1, cnt)``
         arrived values closest to the center, averaged.  Absent rows get
         +inf distances (their garbage never enters the distance, the
         ranking, or the sum — rank gating is a where-select, so inf/NaN
         cannot leak through a zero weight).

    Below ``f + 1`` arrivals the window degrades gracefully to the single
    closest arrived value; zero arrivals return an exact 0 (the engine's
    zero-total guard scales the update to 0 anyway)."""
    import jax
    mb = mask.astype(bool)
    xf = g.astype(jnp.float32)
    s = jnp.sort(jnp.where(mb[:, None], xf, jnp.inf), axis=0)
    b = f if stat == "trimmed_mean" else 0
    center = arrived_stat_from_sorted(s, mask, stat, b=b)
    cnt = jnp.sum(mask.astype(jnp.float32) > 0.5).astype(jnp.int32)
    k = jnp.clip(cnt - jnp.int32(f), 1, jnp.maximum(cnt, 1))
    dist = jnp.where(mb[:, None], jnp.abs(xf - center[None]), jnp.inf)
    order = jnp.argsort(dist, axis=0)           # stable: ties keep row order
    ranks = jnp.argsort(order, axis=0)
    keep = ranks < k
    out = jnp.sum(jnp.where(keep, xf, 0.0), axis=0) / k.astype(jnp.float32)
    return jnp.where(cnt > 0, out, 0.0)


def masked_sign_vote_ref(g, mask):
    """(d,) fp32 oracle for masked_sign_vote: majority vote over the
    arrived rows only (absent rows cast no vote)."""
    votes = jnp.sign(g.astype(jnp.float32)) * mask.astype(jnp.float32)[:, None]
    return jnp.sign(jnp.sum(votes, axis=0))


def krum_select_ref(g, f: int):
    """(n,) one-hot Krum selection oracle (dense scores + argmin)."""
    import jax

    from repro.core.filters.dense import krum_scores, pairwise_sq_dists
    s = krum_scores(pairwise_sq_dists(g.astype(jnp.float32)), f)
    return jax.nn.one_hot(jnp.argmin(s), g.shape[0], dtype=jnp.float32)


def cge_select_ref(g, n_keep: int):
    """(n,) {0,1} smallest-norm keep-mask oracle (top_k selection)."""
    import jax
    norms = jnp.linalg.norm(g.astype(jnp.float32), axis=-1)
    _, idx = jax.lax.top_k(-norms, n_keep)
    return jnp.zeros((g.shape[0],), jnp.float32).at[idx].set(1.0)
