from repro.kernels.ops import (kernel_cge, kernel_coordinate_median,
                               kernel_krum, kernel_pairwise_sq_dists,
                               kernel_trimmed_mean)

__all__ = ["kernel_coordinate_median", "kernel_trimmed_mean", "kernel_krum",
           "kernel_cge", "kernel_pairwise_sq_dists"]
