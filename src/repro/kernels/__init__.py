from repro.kernels.dispatch import (default_interpret, pallas_aggregate,
                                    pallas_masked_aggregate,
                                    pallas_masked_supported,
                                    pallas_scaled_aggregate,
                                    pallas_scaled_masked_aggregate,
                                    pallas_scaled_supported,
                                    pallas_supported)
from repro.kernels.ops import (kernel_bulyan, kernel_bulyan_masked,
                               kernel_cge, kernel_cge_masked,
                               kernel_coordinate_median, kernel_krum,
                               kernel_krum_masked, kernel_m_krum,
                               kernel_m_krum_masked, kernel_mda,
                               kernel_mda_masked, kernel_multi_krum,
                               kernel_multi_krum_masked,
                               kernel_pairwise_sq_dists,
                               kernel_trimmed_mean)
from repro.kernels.wsum import clipped_weighted_sum

__all__ = ["kernel_coordinate_median", "kernel_trimmed_mean", "kernel_krum",
           "kernel_cge", "kernel_multi_krum", "kernel_m_krum", "kernel_mda",
           "kernel_bulyan", "kernel_krum_masked", "kernel_cge_masked",
           "kernel_multi_krum_masked", "kernel_m_krum_masked",
           "kernel_mda_masked", "kernel_bulyan_masked",
           "kernel_pairwise_sq_dists", "clipped_weighted_sum",
           "pallas_aggregate", "pallas_masked_aggregate",
           "pallas_scaled_aggregate", "pallas_scaled_masked_aggregate",
           "pallas_supported", "pallas_masked_supported",
           "pallas_scaled_supported", "default_interpret"]
