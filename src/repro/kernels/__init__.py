from repro.kernels.dispatch import (default_interpret, pallas_aggregate,
                                    pallas_masked_aggregate,
                                    pallas_masked_supported, pallas_supported)
from repro.kernels.ops import (kernel_cge, kernel_coordinate_median,
                               kernel_krum, kernel_pairwise_sq_dists,
                               kernel_trimmed_mean)

__all__ = ["kernel_coordinate_median", "kernel_trimmed_mean", "kernel_krum",
           "kernel_cge", "kernel_pairwise_sq_dists",
           "pallas_aggregate", "pallas_masked_aggregate",
           "pallas_supported", "pallas_masked_supported",
           "default_interpret"]
