"""Pallas TPU kernel: Gram matrix of the agent-gradient stack.

Krum / multi-Krum / MDA / Bulyan need all pairwise squared distances
||g_i - g_j||^2 = ||g_i||^2 + ||g_j||^2 - 2 <g_i, g_j>.  On GPU the surveyed
systems loop over pairs; on TPU the inner products are one MXU matmul
(n x d)(d x n) — the kernel tiles the huge d axis into VMEM blocks and
accumulates the (n, n) Gram in fp32 across grid steps (output block pinned
at (0, 0), revisited every step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import TILE_D, block_d


def _gram_kernel(g_ref, out_ref):
    i = pl.program_id(0)
    x = g_ref[...].astype(jnp.float32)            # (n, T)
    part = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (n, n) on the MXU

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("interpret",))
def gram(g, *, interpret: bool = True):
    """g: (n, d) -> (n, n) fp32 Gram.  d must be a multiple of TILE_D."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w = block_d(d, interpret)
    return pl.pallas_call(
        _gram_kernel,
        grid=(d // w,),
        in_specs=[pl.BlockSpec((n, w), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(g)
