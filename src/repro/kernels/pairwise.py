"""Pallas TPU kernel: Gram matrix of the agent-gradient stack.

Krum / multi-Krum / MDA / Bulyan need all pairwise squared distances
||g_i - g_j||^2 = ||g_i||^2 + ||g_j||^2 - 2 <g_i, g_j>.  On GPU the surveyed
systems loop over pairs; on TPU the inner products are one MXU matmul
(n x d)(d x n) — the kernel tiles the huge d axis into VMEM blocks and
accumulates the (n, n) Gram in fp32 across grid steps (output block pinned
at (0, 0), revisited every step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import TILE_D, block_d


def _gram_kernel(g_ref, out_ref):
    i = pl.program_id(0)
    x = g_ref[...].astype(jnp.float32)            # (n, T)
    part = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (n, n) on the MXU

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("interpret",))
def gram(g, *, interpret: bool = True):
    """g: (n, d) -> (n, n) fp32 Gram.  d must be a multiple of TILE_D."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w = block_d(d, interpret)
    return pl.pallas_call(
        _gram_kernel,
        grid=(d // w,),
        in_specs=[pl.BlockSpec((n, w), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(g)


def imputed_mean(g, wn):
    """(d,) imputation value of the masked pairwise family: fp32 weighted
    mean of the arrived rows (wn zero elsewhere), round-tripped through
    the stack's native dtype — THE one copy of the arithmetic, identical
    to the tree-level engine's.  Output-sized (d,), so sharing it across
    the Gram / selection / application kernels keeps the path
    imputation-free (no (n, d) copy) while computing the mean once."""
    return jnp.sum(g.astype(jnp.float32) * wn.astype(jnp.float32)[:, None],
                   axis=0).astype(g.dtype)


def _masked_gram_kernel(g_ref, mask_ref, mean_ref, out_ref):
    """Gram of the MEAN-IMPUTED stack, imputation fused into the tile
    (the kernels/masked.py trick applied to the pairwise path): absent
    rows are replaced inside the tile by the precomputed (T,) mean slice,
    so the (n, d) imputed copy never exists and mask/weights stay traced
    operands (fault schedules never recompile)."""
    i = pl.program_id(0)
    x = g_ref[...]                                   # (n, T) native dtype
    m = mask_ref[...][0]                             # (n,) f32, 1 = arrived
    mean = mean_ref[...][0]                          # (T,) native dtype
    xi = jnp.where(m[:, None] > 0.5, x, mean[None]).astype(jnp.float32)
    part = jax.lax.dot_general(
        xi, xi, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (n, n) on the MXU

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_gram(g, mask, wn, mean=None, *, interpret: bool = True):
    """g: (n, d) any dtype, mask: (n,) {0,1} f32, wn: (n,) f32 normalized
    weights -> (n, n) fp32 Gram of the mean-imputed stack.  ``mean``: the
    (d,) :func:`imputed_mean` (computed here when None — pass it in to
    share one mean across a kernel pipeline).  d must be a multiple of
    TILE_D (the dispatch layer pads)."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    if mean is None:
        mean = imputed_mean(g, wn)
    w = block_d(d, interpret)
    return pl.pallas_call(
        _masked_gram_kernel,
        grid=(d // w,),
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, w), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(g, mask.astype(jnp.float32).reshape(1, n), mean.reshape(1, d))
