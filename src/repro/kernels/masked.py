"""Pallas TPU kernel: masked / weighted coordinate order statistics.

The async training loop aggregates over a *varying subset* of agents every
server step (quorum masks from the fault simulator) with per-agent staleness
discounts.  The engine's masked semantics for coordinate-wise rules
(:func:`repro.core.aggregators._masked_aggregate`) are: the order statistic
over the ARRIVED rows only — absent rows enter the per-coordinate sort as
+inf sentinels and the kept rank window is computed from the traced arrived
count (:func:`repro.kernels.ref.arrived_stat_from_sorted`), then the result
is scaled by the mean arrived weight.  Mean-imputing the absent rows (the
pre-PR-9 law, still used by the pairwise Gram kernels) is NOT robust: the
delivered mean is attack-contaminated, so the imputed ghost rows land
inside the trim window and a single straggler lets a large_value attack
straight through trimmed_mean/coordinate_median.  The sentinel law keeps
everything the old one bought — one fused VMEM pass per sort tile, no
(n, d) copy, mask/weights as traced operands so a fault schedule never
recompiles — while restoring the f-of-arrived breakdown bound.

Arithmetic is shared with the tree-level engine path (fp32 sentinel select
-> fp32 sort -> arrived-window reduce, one helper in kernels/ref.py), so
fp32 results are bit-for-bit with the ``impl="gather"`` reference —
tests/test_kernels_parity.py is the proof.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.coord_stats import _sort_network, stat_from_sorted
from repro.kernels.tiling import TILE_D, block_d


def _masked_stat_kernel(g_ref, mask_ref, wn_ref, out_ref, *, stat, b,
                        exact):
    del wn_ref                                       # weights scale outside
    x = g_ref[...]                                   # (n, T) native dtype
    m = mask_ref[...][0]                             # (n,) f32, 1 = arrived
    # absent rows become +inf sort sentinels: they occupy the top ranks of
    # every column and the arrived-count window below never reaches them
    sent = jnp.where(m[:, None] > 0.5, x.astype(jnp.float32), jnp.inf)
    s = _sort_network(sent)
    if exact:
        # see coord_stats._coord_stat_kernel: pin the reduce order so the
        # fp32 result is bit-for-bit with the tree-level sentinel path
        s = jax.lax.optimization_barrier(s)
    from repro.kernels import ref
    out_ref[...] = ref.arrived_stat_from_sorted(s, m, stat, b)[None]


def _sign_vote_kernel(g_ref, out_ref):
    # sign-compress + majority vote in one pass: the per-coordinate sum of
    # signs is exact in fp32 for any realistic n (integers < 2^24), so the
    # vote is bitwise identical across impls by construction
    s = jnp.sign(g_ref[...].astype(jnp.float32))     # (n, T) in {-1, 0, 1}
    out_ref[...] = jnp.sign(jnp.sum(s, axis=0))[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sign_vote(g, *, interpret: bool = True):
    """g: (n, d) any dtype (fp32 arena or int8/fp8 codes — sign is
    invariant under the positive per-row dequant scale) -> (d,) fp32
    majority vote.  d must be a multiple of TILE_D."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w = block_d(d, interpret)
    out = pl.pallas_call(
        _sign_vote_kernel,
        grid=(d // w,),
        in_specs=[pl.BlockSpec((n, w), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g)
    return out[0]


def _masked_sign_vote_kernel(g_ref, mask_ref, wn_ref, out_ref):
    del wn_ref                                       # weights scale outside
    m = mask_ref[...][0]
    # arrived rows vote, absent rows cast NO vote — an imputed ghost vote
    # would carry the sign of the (attack-contaminated) delivered mean
    s = jnp.sign(g_ref[...].astype(jnp.float32)) * m[:, None]
    out_ref[...] = jnp.sign(jnp.sum(s, axis=0))[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_sign_vote(g, mask, wn, *, interpret: bool = True):
    """Majority vote over the arrived rows only (the engine's masked law
    for the sign family), fused per tile."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w = block_d(d, interpret)
    out = pl.pallas_call(
        _masked_sign_vote_kernel,
        grid=(d // w,),
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g, mask.astype(jnp.float32).reshape(1, n),
      wn.astype(jnp.float32).reshape(1, n))
    return out[0]


# ---------------------------------------------------------------------------
# scaled variants: the arena holds int8/fp8 CODES plus a per-row fp32 scale
# sidecar (core.flat.quantize_rows); dequantization happens INSIDE the tile
# (codes.astype(f32) * scale[:, None] per VMEM block — exactly
# core.flat.dequantize_rows' arithmetic, so parity vs the engine-level
# dequant copy is bitwise) and the dequantized (n, d) stack never exists
# outside VMEM.  The masked variants use the same arrived-window sentinel
# law as the plain kernels above: dequantize, push absent rows to +inf,
# one sort, one count-windowed reduce.


def _scaled_stat_kernel(g_ref, sc_ref, out_ref, *, stat, b, exact):
    sc = sc_ref[...][0]                              # (n,) f32
    xf = g_ref[...].astype(jnp.float32) * sc[:, None]
    s = _sort_network(xf)
    if exact:
        s = jax.lax.optimization_barrier(s)
    out_ref[...] = stat_from_sorted(s, stat, b)[None]


@functools.partial(jax.jit, static_argnames=("stat", "b", "interpret"))
def scaled_coord_stat(g, scale, stat: str, b: int = 0, *,
                      interpret: bool = True):
    """g: (n, d) quantized codes, scale: (n,) fp32 per-row dequant scale
    -> (d,) fp32 order statistic over the dequantized stack, dequant fused
    into the sort tile."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w = block_d(d, interpret)
    out = pl.pallas_call(
        functools.partial(_scaled_stat_kernel, stat=stat, b=b,
                          exact=interpret),
        grid=(d // w,),
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g, scale.astype(jnp.float32).reshape(1, n))
    return out[0]


def _scaled_masked_stat_kernel(g_ref, sc_ref, mask_ref, wn_ref, out_ref, *,
                               stat, b, exact):
    del wn_ref                                       # weights scale outside
    sc = sc_ref[...][0]
    m = mask_ref[...][0]
    xf = g_ref[...].astype(jnp.float32) * sc[:, None]
    sent = jnp.where(m[:, None] > 0.5, xf, jnp.inf)
    s = _sort_network(sent)
    if exact:
        s = jax.lax.optimization_barrier(s)
    from repro.kernels import ref
    out_ref[...] = ref.arrived_stat_from_sorted(s, m, stat, b)[None]


@functools.partial(jax.jit, static_argnames=("stat", "b", "interpret"))
def scaled_masked_coord_stat(g, scale, mask, wn, stat: str, b: int = 0, *,
                             interpret: bool = True):
    """Masked order statistic over a quantized arena: in-tile dequant,
    +inf sentinels for absent rows, fused sort + arrived-window reduce."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w = block_d(d, interpret)
    out = pl.pallas_call(
        functools.partial(_scaled_masked_stat_kernel, stat=stat, b=b,
                          exact=interpret),
        grid=(d // w,),
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g, scale.astype(jnp.float32).reshape(1, n),
      mask.astype(jnp.float32).reshape(1, n),
      wn.astype(jnp.float32).reshape(1, n))
    return out[0]


def _scaled_masked_sign_kernel(g_ref, sc_ref, mask_ref, wn_ref, out_ref):
    del wn_ref                                       # weights scale outside
    sc = sc_ref[...][0]
    m = mask_ref[...][0]
    xf = g_ref[...].astype(jnp.float32) * sc[:, None]
    s = jnp.sign(xf) * m[:, None]
    out_ref[...] = jnp.sign(jnp.sum(s, axis=0))[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def scaled_masked_sign_vote(g, scale, mask, wn, *, interpret: bool = True):
    """Masked majority vote over a quantized arena: arrived rows vote,
    absent rows cast none.  The per-row dequant scale is sign-neutral
    (scales are non-negative), but the dequant is kept so the kernel's
    arithmetic matches the engine's dequantized fp32 reference
    bit-for-bit."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w = block_d(d, interpret)
    out = pl.pallas_call(
        _scaled_masked_sign_kernel,
        grid=(d // w,),
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g, scale.astype(jnp.float32).reshape(1, n),
      mask.astype(jnp.float32).reshape(1, n),
      wn.astype(jnp.float32).reshape(1, n))
    return out[0]


@functools.partial(jax.jit, static_argnames=("stat", "b", "interpret"))
def masked_coord_stat(g, mask, wn, stat: str, b: int = 0, *,
                      interpret: bool = True):
    """g: (n, d) any dtype, mask: (n,) {0,1} f32, wn: (n,) f32 normalized
    weights -> (d,) fp32 statistic over the arrived rows.  d must be
    a multiple of TILE_D (the dispatch layer pads)."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w = block_d(d, interpret)
    out = pl.pallas_call(
        functools.partial(_masked_stat_kernel, stat=stat, b=b,
                          exact=interpret),
        grid=(d // w,),
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g, mask.astype(jnp.float32).reshape(1, n),
      wn.astype(jnp.float32).reshape(1, n))
    return out[0]
