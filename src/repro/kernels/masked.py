"""Pallas TPU kernel: masked / weighted coordinate order statistics.

The async training loop aggregates over a *varying subset* of agents every
server step (quorum masks from the fault simulator) with per-agent staleness
discounts.  The engine's masked semantics for coordinate-wise rules
(:func:`repro.core.aggregators._masked_aggregate`) are: impute absent rows
with the weighted mean of the arrived rows, run the rule on the imputed
fixed-shape stack, scale by the mean arrived weight.  This kernel fuses the
imputation INTO the sort tile, so the masked path costs one VMEM pass —
no imputed (n, d) copy is ever materialized — and the mask/weights arrive
as ordinary traced operands, so a fault schedule never recompiles the step.

Arithmetic is kept identical to the tree-level engine path (fp32 weighted
mean -> cast to the stack's native dtype -> select -> fp32 sort -> stat),
so fp32 results are bit-for-bit with the ``impl="gather"`` reference —
tests/test_kernels_parity.py is the proof.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.coord_stats import _sort_network, stat_from_sorted
from repro.kernels.tiling import TILE_D, block_d


def _masked_stat_kernel(g_ref, mask_ref, wn_ref, out_ref, *, stat, b,
                        exact):
    x = g_ref[...]                                   # (n, T) native dtype
    m = mask_ref[...][0]                             # (n,) f32, 1 = arrived
    wn = wn_ref[...][0]                              # (n,) f32, sums to 1
    xf = x.astype(jnp.float32)
    # weighted mean of the arrived rows (wn is zero elsewhere) — same
    # mult-then-axis-0-reduce the tree path uses, then the same round trip
    # through the stack's native dtype
    mean = jnp.sum(xf * wn[:, None], axis=0).astype(x.dtype)   # (T,)
    imputed = jnp.where(m[:, None] > 0.5, x, mean[None])
    s = _sort_network(imputed.astype(jnp.float32))
    if exact:
        # see coord_stats._coord_stat_kernel: pin the reduce order so the
        # fp32 result is bit-for-bit with the tree-level imputation path
        s = jax.lax.optimization_barrier(s)
    out_ref[...] = stat_from_sorted(s, stat, b)[None]


@functools.partial(jax.jit, static_argnames=("stat", "b", "interpret"))
def masked_coord_stat(g, mask, wn, stat: str, b: int = 0, *,
                      interpret: bool = True):
    """g: (n, d) any dtype, mask: (n,) {0,1} f32, wn: (n,) f32 normalized
    weights -> (d,) fp32 statistic over the mean-imputed stack.  d must be
    a multiple of TILE_D (the dispatch layer pads)."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w = block_d(d, interpret)
    out = pl.pallas_call(
        functools.partial(_masked_stat_kernel, stat=stat, b=b,
                          exact=interpret),
        grid=(d // w,),
        in_specs=[
            pl.BlockSpec((n, w), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g, mask.astype(jnp.float32).reshape(1, n),
      wn.astype(jnp.float32).reshape(1, n))
    return out[0]
