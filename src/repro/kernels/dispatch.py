"""Caps-driven kernel dispatch: rule name -> Pallas implementation.

The bridge between the :class:`~repro.core.aggregators.AggregatorSpec`
engine and the kernel layer.  A Table-2 rule is *kernelizable* when its
declared capabilities are coordinate-wise (per-coordinate order statistics
-> :mod:`repro.kernels.coord_stats` / :mod:`repro.kernels.masked`) or
Gram-derivable (pairwise distances / norms -> :mod:`repro.kernels.pairwise`
+ :mod:`repro.kernels.select` + :mod:`repro.kernels.wsum`).  The tables
below are the single source of truth the spec builder queries at
``make_spec`` time to auto-select ``impl="pallas"``.

Every entry has the same contract as the dense gather path it replaces:
input is the fp32 (n, P) raveled gradient stack (masked variants take the
native-dtype stack plus traced mask/weights), output is the (P,) fp32
aggregate, numerically interchangeable with ``impl="gather"`` —
bit-for-bit for the order-statistic and single-selection rules, selection-
identical with ulp-level application rounding for averaged selections
(CGE) — proven case by case in tests/test_kernels_parity.py.

``interpret`` resolution: kernels compile to real Mosaic kernels on TPU
backends and fall back to interpret mode (pure-jax evaluation of the SAME
kernel bodies) everywhere else, so CPU CI runs the code path production
runs — override per call for debugging.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.coord_stats import coord_stat
from repro.kernels.masked import (masked_coord_stat, masked_sign_vote,
                                  scaled_coord_stat,
                                  scaled_masked_coord_stat,
                                  scaled_masked_sign_vote, sign_vote)
from repro.kernels.ops import (_pad_d, kernel_bulyan, kernel_bulyan_masked,
                               kernel_cge, kernel_cge_masked, kernel_krum,
                               kernel_krum_masked, kernel_m_krum,
                               kernel_m_krum_masked, kernel_mda,
                               kernel_mda_masked, kernel_multi_krum,
                               kernel_multi_krum_masked)
from repro.kernels.wsum import (scaled_sparse_masked_weighted_mean,
                                sparse_masked_weighted_mean)

_INTERPRET = None


def default_interpret() -> bool:
    """True (interpret mode) unless running on a real TPU backend."""
    global _INTERPRET
    if _INTERPRET is None:
        _INTERPRET = jax.default_backend() != "tpu"
    return _INTERPRET


def _trim_b(n: int, f: int, hyper: dict) -> int:
    from repro.core.aggregators import trim_count          # lazy: no cycle
    return trim_count(n, f, hyper.get("beta"))


# ---------------------------------------------------------------------------
# synchronous rules: (stack fp32 (n, P), f, hyper, interpret) -> (P,) fp32


def _median(stack, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return coord_stat(gp, "median", interpret=interpret)[:d]


def _trimmed_mean(stack, f, hyper, interpret):
    gp, d = _pad_d(stack)
    b = _trim_b(stack.shape[0], f, hyper)
    return coord_stat(gp, "trimmed_mean", b=b, interpret=interpret)[:d]


def _krum(stack, f, hyper, interpret):
    # gram -> fused selection -> one-hot weighted sum (exactly the
    # selected row's bits); ops.kernel_krum is THE one pipeline copy
    return kernel_krum(stack, f, interpret=interpret)


def _cge(stack, f, hyper, interpret):
    return kernel_cge(stack, f, normalize=hyper.get("normalize", True),
                      interpret=interpret)


def _multi_krum(stack, f, hyper, interpret):
    return kernel_multi_krum(stack, f, m=hyper.get("m", 2),
                             interpret=interpret)


def _m_krum(stack, f, hyper, interpret):
    return kernel_m_krum(stack, f, m=hyper.get("m", 2), interpret=interpret)


def _mda(stack, f, hyper, interpret):
    return kernel_mda(stack, f, interpret=interpret)


def _bulyan(stack, f, hyper, interpret):
    # only the classic krum base is Gram-derivable; make_spec gates the
    # pallas impl on hyper, so a non-krum base never reaches this table
    assert hyper.get("base", "krum") == "krum", hyper
    return kernel_bulyan(stack, f, interpret=interpret)


def _sign_sgd(stack, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return sign_vote(gp, interpret=interpret)[:d]


def _sparse_mean(stack, f, hyper, interpret):
    # plain = every row live with unit weight; padded columns are all-zero
    # (nobody "sent" them) and slice away
    n = stack.shape[0]
    gp, d = _pad_d(stack)
    ones = jnp.ones((n,), jnp.float32)
    return sparse_masked_weighted_mean(gp, ones, ones,
                                       interpret=interpret)[:d]


PALLAS_RULES = {
    "coordinate_median": _median,
    "trimmed_mean": _trimmed_mean,
    "krum": _krum,
    "cge": _cge,
    "multi_krum": _multi_krum,
    "m_krum": _m_krum,
    "mda": _mda,
    "bulyan": _bulyan,
    "sign_sgd": _sign_sgd,
    "sparse_mean": _sparse_mean,
}

# rules whose flat_fn fuses its own kernel stages instead of fitting the
# stateless (stack, f, hyper) contract above: centered_clip's fixed-point
# loop carries the server center across iterations, so only its
# model-sized multiply-accumulate rides a kernel
# (wsum.clipped_weighted_sum) — requested with an explicit
# ``impl="pallas"`` (``auto`` keeps the dense flat body: the kernel
# changes the reduce association, so opting in is a numerics decision)
FLAT_SELF_KERNELED = {"centered_clip"}


# ---------------------------------------------------------------------------
# masked / weighted rules: fused masked variants (async quorums) —
# the coordinate statistics impute inside the sort tile, the selection
# family inside the Gram/application tiles (no masked (n, d) copy is ever
# materialized; the coordinate-wise kernels use the arrived-window
# sentinel law, the Gram kernels the mean-imputed law — see
# kernels/masked.py)


def _masked_median(stack, mask, wn, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return masked_coord_stat(gp, mask, wn, "median",
                             interpret=interpret)[:d]


def _masked_trimmed_mean(stack, mask, wn, f, hyper, interpret):
    gp, d = _pad_d(stack)
    b = _trim_b(stack.shape[0], f, hyper)
    return masked_coord_stat(gp, mask, wn, "trimmed_mean", b=b,
                             interpret=interpret)[:d]


def _masked_krum(stack, mask, wn, f, hyper, interpret):
    return kernel_krum_masked(stack, mask, wn, f, interpret=interpret)


def _masked_cge(stack, mask, wn, f, hyper, interpret):
    return kernel_cge_masked(stack, mask, wn, f,
                             normalize=hyper.get("normalize", True),
                             interpret=interpret)


def _masked_multi_krum(stack, mask, wn, f, hyper, interpret):
    return kernel_multi_krum_masked(stack, mask, wn, f,
                                    m=hyper.get("m", 2),
                                    interpret=interpret)


def _masked_m_krum(stack, mask, wn, f, hyper, interpret):
    return kernel_m_krum_masked(stack, mask, wn, f, m=hyper.get("m", 2),
                                interpret=interpret)


def _masked_mda(stack, mask, wn, f, hyper, interpret):
    return kernel_mda_masked(stack, mask, wn, f, interpret=interpret)


def _masked_bulyan(stack, mask, wn, f, hyper, interpret):
    assert hyper.get("base", "krum") == "krum", hyper
    return kernel_bulyan_masked(stack, mask, wn, f, interpret=interpret)


def _masked_sign_sgd(stack, mask, wn, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return masked_sign_vote(gp, mask, wn, interpret=interpret)[:d]


def _masked_sparse_mean(stack, mask, wn, f, hyper, interpret):
    # the wn slot carries RAW mask-folded row weights (dataset sizes), not
    # the normalized w/tot the imputing rules take — sparse_mean's law is
    # invariant under global weight scaling, so both conventions agree
    gp, d = _pad_d(stack)
    return sparse_masked_weighted_mean(gp, mask, wn,
                                       interpret=interpret)[:d]


PALLAS_MASKED_RULES = {
    "coordinate_median": _masked_median,
    "trimmed_mean": _masked_trimmed_mean,
    "krum": _masked_krum,
    "cge": _masked_cge,
    "multi_krum": _masked_multi_krum,
    "m_krum": _masked_m_krum,
    "mda": _masked_mda,
    "bulyan": _masked_bulyan,
    "sign_sgd": _masked_sign_sgd,
    "sparse_mean": _masked_sparse_mean,
}


# ---------------------------------------------------------------------------
# scaled rules: the arena holds int8/fp8 codes + a per-row fp32 dequant
# scale sidecar (core.flat.quantize_rows); these kernels dequantize INSIDE
# the tile, so no dequantized (n, P) copy is ever materialized (jaxpr-gated
# in tests/test_kernels_parity.py).  Rules without an entry here pay an
# engine-level dequant copy (aggregators._flat_dequant warns once).


def _scaled_median(stack, qs, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return scaled_coord_stat(gp, qs, "median", interpret=interpret)[:d]


def _scaled_trimmed_mean(stack, qs, f, hyper, interpret):
    gp, d = _pad_d(stack)
    b = _trim_b(stack.shape[0], f, hyper)
    return scaled_coord_stat(gp, qs, "trimmed_mean", b=b,
                             interpret=interpret)[:d]


def _scaled_sign_sgd(stack, qs, f, hyper, interpret):
    # sign(code * scale) == sign(code): scales are strictly positive, so
    # the plain vote kernel reads the codes directly — zero dequant cost
    gp, d = _pad_d(stack)
    return sign_vote(gp, interpret=interpret)[:d]


def _scaled_sparse_mean(stack, qs, f, hyper, interpret):
    n = stack.shape[0]
    gp, d = _pad_d(stack)
    ones = jnp.ones((n,), jnp.float32)
    return scaled_sparse_masked_weighted_mean(gp, qs, ones, ones,
                                              interpret=interpret)[:d]


PALLAS_SCALED_RULES = {
    "coordinate_median": _scaled_median,
    "trimmed_mean": _scaled_trimmed_mean,
    "sign_sgd": _scaled_sign_sgd,
    "sparse_mean": _scaled_sparse_mean,
}


def _scaled_masked_median(stack, qs, mask, wn, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return scaled_masked_coord_stat(gp, qs, mask, wn, "median",
                                    interpret=interpret)[:d]


def _scaled_masked_trimmed_mean(stack, qs, mask, wn, f, hyper, interpret):
    gp, d = _pad_d(stack)
    b = _trim_b(stack.shape[0], f, hyper)
    return scaled_masked_coord_stat(gp, qs, mask, wn, "trimmed_mean", b=b,
                                    interpret=interpret)[:d]


def _scaled_masked_sign_sgd(stack, qs, mask, wn, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return scaled_masked_sign_vote(gp, qs, mask, wn,
                                   interpret=interpret)[:d]


def _scaled_masked_sparse_mean(stack, qs, mask, wn, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return scaled_sparse_masked_weighted_mean(gp, qs, mask, wn,
                                              interpret=interpret)[:d]


PALLAS_SCALED_MASKED_RULES = {
    "coordinate_median": _scaled_masked_median,
    "trimmed_mean": _scaled_masked_trimmed_mean,
    "sign_sgd": _scaled_masked_sign_sgd,
    "sparse_mean": _scaled_masked_sparse_mean,
}


# ---------------------------------------------------------------------------
# entry points the spec engine calls


def pallas_supported(name: str) -> bool:
    return name in PALLAS_RULES


def pallas_masked_supported(name: str) -> bool:
    return name in PALLAS_MASKED_RULES


def pallas_scaled_supported(name: str) -> bool:
    """True iff ``name`` dequantizes a quantized (codes + per-row scale)
    arena inside its kernel tiles (both sync and masked variants)."""
    return (name in PALLAS_SCALED_RULES
            and name in PALLAS_SCALED_MASKED_RULES)


@functools.partial(jax.jit,
                   static_argnames=("name", "f", "hyper", "interpret"))
def pallas_aggregate(name: str, stack, f: int, hyper: tuple = (), *,
                     interpret: bool | None = None):
    """stack: fp32 (n, P) -> (P,) fp32 via the rule's Pallas kernels.
    ``hyper`` is the spec's sorted static hyper tuple."""
    itp = default_interpret() if interpret is None else interpret
    return PALLAS_RULES[name](stack, f, dict(hyper), itp)


@functools.partial(jax.jit,
                   static_argnames=("name", "f", "hyper", "interpret"))
def pallas_masked_aggregate(name: str, stack, mask, wn, f: int,
                            hyper: tuple = (), *,
                            interpret: bool | None = None):
    """Mean-imputed masked statistic; mask/wn are TRACED (n,) operands, so
    per-step fault masks never retrigger compilation."""
    itp = default_interpret() if interpret is None else interpret
    return PALLAS_MASKED_RULES[name](stack, mask, wn, f, dict(hyper), itp)


@functools.partial(jax.jit,
                   static_argnames=("name", "f", "hyper", "interpret"))
def pallas_scaled_aggregate(name: str, stack, qscale, f: int,
                            hyper: tuple = (), *,
                            interpret: bool | None = None):
    """stack: quantized (n, P) codes, qscale: (n,) fp32 per-row dequant
    scale -> (P,) fp32 aggregate, dequantization fused into the tiles."""
    itp = default_interpret() if interpret is None else interpret
    return PALLAS_SCALED_RULES[name](stack, qscale, f, dict(hyper), itp)


@functools.partial(jax.jit,
                   static_argnames=("name", "f", "hyper", "interpret"))
def pallas_scaled_masked_aggregate(name: str, stack, qscale, mask, wn,
                                   f: int, hyper: tuple = (), *,
                                   interpret: bool | None = None):
    """Masked variant of :func:`pallas_scaled_aggregate` — qscale, mask
    and wn are all TRACED (n,) operands (fault masks and per-step scales
    never retrigger compilation)."""
    itp = default_interpret() if interpret is None else interpret
    return PALLAS_SCALED_MASKED_RULES[name](stack, qscale, mask, wn, f,
                                            dict(hyper), itp)
