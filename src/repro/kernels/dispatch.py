"""Caps-driven kernel dispatch: rule name -> Pallas implementation.

The bridge between the :class:`~repro.core.aggregators.AggregatorSpec`
engine and the kernel layer.  A Table-2 rule is *kernelizable* when its
declared capabilities are coordinate-wise (per-coordinate order statistics
-> :mod:`repro.kernels.coord_stats` / :mod:`repro.kernels.masked`) or
Gram-derivable (pairwise distances / norms -> :mod:`repro.kernels.pairwise`
+ :mod:`repro.kernels.select` + :mod:`repro.kernels.wsum`).  The tables
below are the single source of truth the spec builder queries at
``make_spec`` time to auto-select ``impl="pallas"``.

Every entry has the same contract as the dense gather path it replaces:
input is the fp32 (n, P) raveled gradient stack (masked variants take the
native-dtype stack plus traced mask/weights), output is the (P,) fp32
aggregate, numerically interchangeable with ``impl="gather"`` —
bit-for-bit for the order-statistic and single-selection rules, selection-
identical with ulp-level application rounding for averaged selections
(CGE) — proven case by case in tests/test_kernels_parity.py.

``interpret`` resolution: kernels compile to real Mosaic kernels on TPU
backends and fall back to interpret mode (pure-jax evaluation of the SAME
kernel bodies) everywhere else, so CPU CI runs the code path production
runs — override per call for debugging.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.coord_stats import coord_stat
from repro.kernels.masked import masked_coord_stat
from repro.kernels.ops import _pad_d, kernel_cge, kernel_krum

_INTERPRET = None


def default_interpret() -> bool:
    """True (interpret mode) unless running on a real TPU backend."""
    global _INTERPRET
    if _INTERPRET is None:
        _INTERPRET = jax.default_backend() != "tpu"
    return _INTERPRET


def _trim_b(n: int, f: int, hyper: dict) -> int:
    from repro.core.aggregators import trim_count          # lazy: no cycle
    return trim_count(n, f, hyper.get("beta"))


# ---------------------------------------------------------------------------
# synchronous rules: (stack fp32 (n, P), f, hyper, interpret) -> (P,) fp32


def _median(stack, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return coord_stat(gp, "median", interpret=interpret)[:d]


def _trimmed_mean(stack, f, hyper, interpret):
    gp, d = _pad_d(stack)
    b = _trim_b(stack.shape[0], f, hyper)
    return coord_stat(gp, "trimmed_mean", b=b, interpret=interpret)[:d]


def _krum(stack, f, hyper, interpret):
    # gram -> fused selection -> one-hot weighted sum (exactly the
    # selected row's bits); ops.kernel_krum is THE one pipeline copy
    return kernel_krum(stack, f, interpret=interpret)


def _cge(stack, f, hyper, interpret):
    return kernel_cge(stack, f, normalize=hyper.get("normalize", True),
                      interpret=interpret)


PALLAS_RULES = {
    "coordinate_median": _median,
    "trimmed_mean": _trimmed_mean,
    "krum": _krum,
    "cge": _cge,
}


# ---------------------------------------------------------------------------
# masked / weighted rules: fused mean-imputation variants (async quorums)


def _masked_median(stack, mask, wn, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return masked_coord_stat(gp, mask, wn, "median",
                             interpret=interpret)[:d]


def _masked_trimmed_mean(stack, mask, wn, f, hyper, interpret):
    gp, d = _pad_d(stack)
    b = _trim_b(stack.shape[0], f, hyper)
    return masked_coord_stat(gp, mask, wn, "trimmed_mean", b=b,
                             interpret=interpret)[:d]


PALLAS_MASKED_RULES = {
    "coordinate_median": _masked_median,
    "trimmed_mean": _masked_trimmed_mean,
}


# ---------------------------------------------------------------------------
# entry points the spec engine calls


def pallas_supported(name: str) -> bool:
    return name in PALLAS_RULES


def pallas_masked_supported(name: str) -> bool:
    return name in PALLAS_MASKED_RULES


@functools.partial(jax.jit,
                   static_argnames=("name", "f", "hyper", "interpret"))
def pallas_aggregate(name: str, stack, f: int, hyper: tuple = (), *,
                     interpret: bool | None = None):
    """stack: fp32 (n, P) -> (P,) fp32 via the rule's Pallas kernels.
    ``hyper`` is the spec's sorted static hyper tuple."""
    itp = default_interpret() if interpret is None else interpret
    return PALLAS_RULES[name](stack, f, dict(hyper), itp)


@functools.partial(jax.jit,
                   static_argnames=("name", "f", "hyper", "interpret"))
def pallas_masked_aggregate(name: str, stack, mask, wn, f: int,
                            hyper: tuple = (), *,
                            interpret: bool | None = None):
    """Mean-imputed masked statistic; mask/wn are TRACED (n,) operands, so
    per-step fault masks never retrigger compilation."""
    itp = default_interpret() if interpret is None else interpret
    return PALLAS_MASKED_RULES[name](stack, mask, wn, f, dict(hyper), itp)
