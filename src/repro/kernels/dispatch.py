"""Caps-driven kernel dispatch: rule name -> Pallas implementation.

The bridge between the :class:`~repro.core.aggregators.AggregatorSpec`
engine and the kernel layer.  A Table-2 rule is *kernelizable* when its
declared capabilities are coordinate-wise (per-coordinate order statistics
-> :mod:`repro.kernels.coord_stats` / :mod:`repro.kernels.masked`) or
Gram-derivable (pairwise distances / norms -> :mod:`repro.kernels.pairwise`
+ :mod:`repro.kernels.select` + :mod:`repro.kernels.wsum`).  The tables
below are the single source of truth the spec builder queries at
``make_spec`` time to auto-select ``impl="pallas"``.

Every entry has the same contract as the dense gather path it replaces:
input is the fp32 (n, P) raveled gradient stack (masked variants take the
native-dtype stack plus traced mask/weights), output is the (P,) fp32
aggregate, numerically interchangeable with ``impl="gather"`` —
bit-for-bit for the order-statistic and single-selection rules, selection-
identical with ulp-level application rounding for averaged selections
(CGE) — proven case by case in tests/test_kernels_parity.py.

``interpret`` resolution: kernels compile to real Mosaic kernels on TPU
backends and fall back to interpret mode (pure-jax evaluation of the SAME
kernel bodies) everywhere else, so CPU CI runs the code path production
runs — override per call for debugging.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.coord_stats import coord_stat
from repro.kernels.masked import masked_coord_stat
from repro.kernels.ops import (_pad_d, kernel_bulyan, kernel_bulyan_masked,
                               kernel_cge, kernel_cge_masked, kernel_krum,
                               kernel_krum_masked, kernel_m_krum,
                               kernel_m_krum_masked, kernel_mda,
                               kernel_mda_masked, kernel_multi_krum,
                               kernel_multi_krum_masked)

_INTERPRET = None


def default_interpret() -> bool:
    """True (interpret mode) unless running on a real TPU backend."""
    global _INTERPRET
    if _INTERPRET is None:
        _INTERPRET = jax.default_backend() != "tpu"
    return _INTERPRET


def _trim_b(n: int, f: int, hyper: dict) -> int:
    from repro.core.aggregators import trim_count          # lazy: no cycle
    return trim_count(n, f, hyper.get("beta"))


# ---------------------------------------------------------------------------
# synchronous rules: (stack fp32 (n, P), f, hyper, interpret) -> (P,) fp32


def _median(stack, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return coord_stat(gp, "median", interpret=interpret)[:d]


def _trimmed_mean(stack, f, hyper, interpret):
    gp, d = _pad_d(stack)
    b = _trim_b(stack.shape[0], f, hyper)
    return coord_stat(gp, "trimmed_mean", b=b, interpret=interpret)[:d]


def _krum(stack, f, hyper, interpret):
    # gram -> fused selection -> one-hot weighted sum (exactly the
    # selected row's bits); ops.kernel_krum is THE one pipeline copy
    return kernel_krum(stack, f, interpret=interpret)


def _cge(stack, f, hyper, interpret):
    return kernel_cge(stack, f, normalize=hyper.get("normalize", True),
                      interpret=interpret)


def _multi_krum(stack, f, hyper, interpret):
    return kernel_multi_krum(stack, f, m=hyper.get("m", 2),
                             interpret=interpret)


def _m_krum(stack, f, hyper, interpret):
    return kernel_m_krum(stack, f, m=hyper.get("m", 2), interpret=interpret)


def _mda(stack, f, hyper, interpret):
    return kernel_mda(stack, f, interpret=interpret)


def _bulyan(stack, f, hyper, interpret):
    # only the classic krum base is Gram-derivable; make_spec gates the
    # pallas impl on hyper, so a non-krum base never reaches this table
    assert hyper.get("base", "krum") == "krum", hyper
    return kernel_bulyan(stack, f, interpret=interpret)


PALLAS_RULES = {
    "coordinate_median": _median,
    "trimmed_mean": _trimmed_mean,
    "krum": _krum,
    "cge": _cge,
    "multi_krum": _multi_krum,
    "m_krum": _m_krum,
    "mda": _mda,
    "bulyan": _bulyan,
}


# ---------------------------------------------------------------------------
# masked / weighted rules: fused mean-imputation variants (async quorums) —
# the coordinate statistics impute inside the sort tile, the selection
# family inside the Gram/application tiles (imputation-free: the imputed
# (n, d) stack is never materialized anywhere)


def _masked_median(stack, mask, wn, f, hyper, interpret):
    gp, d = _pad_d(stack)
    return masked_coord_stat(gp, mask, wn, "median",
                             interpret=interpret)[:d]


def _masked_trimmed_mean(stack, mask, wn, f, hyper, interpret):
    gp, d = _pad_d(stack)
    b = _trim_b(stack.shape[0], f, hyper)
    return masked_coord_stat(gp, mask, wn, "trimmed_mean", b=b,
                             interpret=interpret)[:d]


def _masked_krum(stack, mask, wn, f, hyper, interpret):
    return kernel_krum_masked(stack, mask, wn, f, interpret=interpret)


def _masked_cge(stack, mask, wn, f, hyper, interpret):
    return kernel_cge_masked(stack, mask, wn, f,
                             normalize=hyper.get("normalize", True),
                             interpret=interpret)


def _masked_multi_krum(stack, mask, wn, f, hyper, interpret):
    return kernel_multi_krum_masked(stack, mask, wn, f,
                                    m=hyper.get("m", 2),
                                    interpret=interpret)


def _masked_m_krum(stack, mask, wn, f, hyper, interpret):
    return kernel_m_krum_masked(stack, mask, wn, f, m=hyper.get("m", 2),
                                interpret=interpret)


def _masked_mda(stack, mask, wn, f, hyper, interpret):
    return kernel_mda_masked(stack, mask, wn, f, interpret=interpret)


def _masked_bulyan(stack, mask, wn, f, hyper, interpret):
    assert hyper.get("base", "krum") == "krum", hyper
    return kernel_bulyan_masked(stack, mask, wn, f, interpret=interpret)


PALLAS_MASKED_RULES = {
    "coordinate_median": _masked_median,
    "trimmed_mean": _masked_trimmed_mean,
    "krum": _masked_krum,
    "cge": _masked_cge,
    "multi_krum": _masked_multi_krum,
    "m_krum": _masked_m_krum,
    "mda": _masked_mda,
    "bulyan": _masked_bulyan,
}


# ---------------------------------------------------------------------------
# entry points the spec engine calls


def pallas_supported(name: str) -> bool:
    return name in PALLAS_RULES


def pallas_masked_supported(name: str) -> bool:
    return name in PALLAS_MASKED_RULES


@functools.partial(jax.jit,
                   static_argnames=("name", "f", "hyper", "interpret"))
def pallas_aggregate(name: str, stack, f: int, hyper: tuple = (), *,
                     interpret: bool | None = None):
    """stack: fp32 (n, P) -> (P,) fp32 via the rule's Pallas kernels.
    ``hyper`` is the spec's sorted static hyper tuple."""
    itp = default_interpret() if interpret is None else interpret
    return PALLAS_RULES[name](stack, f, dict(hyper), itp)


@functools.partial(jax.jit,
                   static_argnames=("name", "f", "hyper", "interpret"))
def pallas_masked_aggregate(name: str, stack, mask, wn, f: int,
                            hyper: tuple = (), *,
                            interpret: bool | None = None):
    """Mean-imputed masked statistic; mask/wn are TRACED (n,) operands, so
    per-step fault masks never retrigger compilation."""
    itp = default_interpret() if interpret is None else interpret
    return PALLAS_MASKED_RULES[name](stack, mask, wn, f, dict(hyper), itp)
