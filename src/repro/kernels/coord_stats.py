"""Pallas TPU kernel: coordinate-wise order statistics over the agent axis.

The aggregation hot-spot of median-family gradient filters (survey: "the
median-based aggregation still dominates the training time in large-scale
settings" [18]).  Aspect ratio is extreme — n ~ 16-64 agents vs d ~ 1e8-1e11
coordinates — so the kernel tiles d into VMEM-resident (n, TILE_D) blocks and
runs an odd-even transposition sorting NETWORK along the (small, static) agent
axis: n fully-vectorized compare-exchange passes on (TILE_D,)-lane vectors.
This is the TPU-native replacement for the GPU thread-per-coordinate sort.

Two entry points:

:func:`coord_sort`
    Materializes the full sorted (n, d) stack — the historical kernel, kept
    for tests and for callers that derive several statistics from one sort.

:func:`coord_stat`
    The dispatch-path kernel: derives the order statistic (median or
    b-trimmed mean) INSIDE the tile and writes only the (1, TILE_D) result,
    so a model with d > 1e6 parameters never materializes an (n, d) sorted
    copy in HBM — the sorted stack lives and dies in VMEM, one tile at a
    time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import TILE_D, block_d


def _sort_network(x):
    """Odd-even transposition sort along axis 0 of (n, t).  n static."""
    n = x.shape[0]
    rows = [x[i] for i in range(n)]
    for p in range(n):
        start = p % 2
        for i in range(start, n - 1, 2):
            lo = jnp.minimum(rows[i], rows[i + 1])
            hi = jnp.maximum(rows[i], rows[i + 1])
            rows[i], rows[i + 1] = lo, hi
    return jnp.stack(rows, axis=0)


def _coord_sort_kernel(g_ref, out_ref):
    out_ref[...] = _sort_network(g_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def coord_sort(g, *, interpret: bool = True):
    """g: (n, d) -> sorted-per-coordinate (n, d) fp32.  d must be a multiple
    of TILE_D (ops.py pads)."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w = block_d(d, interpret)
    return pl.pallas_call(
        _coord_sort_kernel,
        grid=(d // w,),
        in_specs=[pl.BlockSpec((n, w), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(g)


def stat_from_sorted(s, stat: str, b: int = 0):
    """Order statistic from a per-coordinate-sorted (n, t) block —
    delegates to the ref.py oracles so the kernel body and the parity
    oracle are literally ONE copy of the load-bearing arithmetic
    (0.5*(lo+hi) median, jnp.mean over the kept slice: bit-for-bit with
    ``repro.core.filters.dense``)."""
    from repro.kernels import ref
    if stat == "median":
        return ref.median_from_sorted(s)
    if stat == "trimmed_mean":
        return ref.trimmed_mean_from_sorted(s, b)
    raise KeyError(stat)


def _coord_stat_kernel(g_ref, out_ref, *, stat, b, exact):
    s = _sort_network(g_ref[...].astype(jnp.float32))
    if exact:
        # interpret mode: stop XLA from reassociating the mean reduce
        # through the stacked sort-network rows — with the barrier the
        # reduce compiles exactly like the dense reference's
        # slice-of-sorted mean, making fp32 results bit-for-bit
        s = jax.lax.optimization_barrier(s)
    out_ref[...] = stat_from_sorted(s, stat, b)[None]


@functools.partial(jax.jit, static_argnames=("stat", "b", "interpret"))
def coord_stat(g, stat: str, b: int = 0, *, interpret: bool = True):
    """g: (n, d) -> (d,) fp32 order statistic (``median`` |
    ``trimmed_mean`` with per-side trim ``b``), fused sort+reduce per tile:
    the sorted stack never leaves VMEM.  d must be a multiple of TILE_D."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w = block_d(d, interpret)
    out = pl.pallas_call(
        functools.partial(_coord_stat_kernel, stat=stat, b=b,
                          exact=interpret),
        grid=(d // w,),
        in_specs=[pl.BlockSpec((n, w), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g)
    return out[0]
