"""Pallas TPU kernel: coordinate-wise order statistics over the agent axis.

The aggregation hot-spot of median-family gradient filters (survey: "the
median-based aggregation still dominates the training time in large-scale
settings" [18]).  Aspect ratio is extreme — n ~ 16-64 agents vs d ~ 1e8-1e11
coordinates — so the kernel tiles d into VMEM-resident (n, TILE_D) blocks and
runs an odd-even transposition sorting NETWORK along the (small, static) agent
axis: n fully-vectorized compare-exchange passes on (TILE_D,)-lane vectors.
This is the TPU-native replacement for the GPU thread-per-coordinate sort.

Outputs per tile: the full sorted stack, from which ops.py derives median,
trimmed mean, Phocas and mean-around-median without re-sorting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 512


def _sort_network(x):
    """Odd-even transposition sort along axis 0 of (n, t).  n static."""
    n = x.shape[0]
    rows = [x[i] for i in range(n)]
    for p in range(n):
        start = p % 2
        for i in range(start, n - 1, 2):
            lo = jnp.minimum(rows[i], rows[i + 1])
            hi = jnp.maximum(rows[i], rows[i + 1])
            rows[i], rows[i + 1] = lo, hi
    return jnp.stack(rows, axis=0)


def _coord_sort_kernel(g_ref, out_ref):
    out_ref[...] = _sort_network(g_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def coord_sort(g, *, interpret: bool = True):
    """g: (n, d) -> sorted-per-coordinate (n, d) fp32.  d must be a multiple
    of TILE_D (ops.py pads)."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    grid = (d // TILE_D,)
    return pl.pallas_call(
        _coord_sort_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, TILE_D), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, TILE_D), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(g)
