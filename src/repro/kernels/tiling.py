"""Shared tile-width policy for the aggregation kernels.

Compiled TPU kernels tile the huge d axis into VMEM-resident TILE_D-lane
blocks (n sublanes x 512 lanes, fp32).  Interpret mode has no VMEM to
respect, but it DOES pay the interpreter's per-grid-step dispatch cost
(~10 ms/step): at model scale (d ~ 1e6 -> thousands of tiles) a tiled
grid turns one aggregation into tens of seconds on CPU.  So off-TPU the
kernels run the SAME kernel body over one coarse block — identical code
path and arithmetic (the parity suite pins fp32 bit-for-bit), CPU cost
back to the plain-XLA ballpark.
"""
from __future__ import annotations

TILE_D = 512


def block_d(d: int, interpret: bool) -> int:
    """Block width along d for a padded (multiple-of-TILE_D) stack."""
    return d if interpret else TILE_D
