"""jit'd public wrappers around the Pallas kernels: padding, reshaping, and
filter-level compositions (kernel-backed median / trimmed mean / Krum / CGE).

``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware pass interpret=False (the BlockSpecs are TPU-shaped: n sublanes
x 512 lanes, fp32 accumulation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.coord_stats import TILE_D, coord_sort
from repro.kernels.pairwise import gram
from repro.kernels.wsum import weighted_sum


def _pad_d(g, fill=0.0):
    n, d = g.shape
    rem = (-d) % TILE_D
    if rem:
        g = jnp.pad(g, ((0, 0), (0, rem)), constant_values=fill)
    return g, d


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_coordinate_median(g, f=0, *, interpret: bool = True):
    gp, d = _pad_d(g)
    s = coord_sort(gp, interpret=interpret)
    return ref.median_from_sorted(s)[:d]


@functools.partial(jax.jit, static_argnames=("b", "interpret"))
def kernel_trimmed_mean(g, b: int, *, interpret: bool = True):
    gp, d = _pad_d(g)
    s = coord_sort(gp, interpret=interpret)
    return ref.trimmed_mean_from_sorted(s, b)[:d]


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_pairwise_sq_dists(g, *, interpret: bool = True):
    gp, _ = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    sq = jnp.diag(gr)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gr, 0.0)


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def kernel_krum(g, f: int, *, interpret: bool = True):
    """Krum with Pallas Gram + Pallas weighted-select."""
    from repro.core.filters.dense import krum_scores
    n = g.shape[0]
    d2 = kernel_pairwise_sq_dists(g, interpret=interpret)
    s = krum_scores(d2, f)
    w = jax.nn.one_hot(jnp.argmin(s), n)
    gp, d = _pad_d(g)
    return weighted_sum(w, gp, interpret=interpret)[:d]


@functools.partial(jax.jit, static_argnames=("f", "normalize", "interpret"))
def kernel_cge(g, f: int, normalize: bool = True, *, interpret: bool = True):
    """CGE: norms from the Gram diagonal, masked weighted sum."""
    n = g.shape[0]
    gp, d = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    norms = jnp.sqrt(jnp.maximum(jnp.diag(gr), 0.0))
    _, idx = jax.lax.top_k(-norms, n - f)
    w = jnp.zeros((n,)).at[idx].set(1.0)
    if normalize:
        w = w / (n - f)
    return weighted_sum(w, gp, interpret=interpret)[:d]
