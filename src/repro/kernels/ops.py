"""jit'd public wrappers around the Pallas kernels: padding, reshaping, and
filter-level compositions (kernel-backed median / trimmed mean / Krum / CGE).

``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware pass interpret=False (the BlockSpecs are TPU-shaped: n sublanes
x 512 lanes, fp32 accumulation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.coord_stats import TILE_D, coord_sort
from repro.kernels.pairwise import gram
from repro.kernels.wsum import weighted_sum


def _pad_d(g, fill=0.0):
    n, d = g.shape
    rem = (-d) % TILE_D
    if rem:
        g = jnp.pad(g, ((0, 0), (0, rem)), constant_values=fill)
    return g, d


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_coordinate_median(g, f=0, *, interpret: bool = True):
    gp, d = _pad_d(g)
    s = coord_sort(gp, interpret=interpret)
    return ref.median_from_sorted(s)[:d]


@functools.partial(jax.jit, static_argnames=("b", "interpret"))
def kernel_trimmed_mean(g, b: int, *, interpret: bool = True):
    gp, d = _pad_d(g)
    s = coord_sort(gp, interpret=interpret)
    return ref.trimmed_mean_from_sorted(s, b)[:d]


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_pairwise_sq_dists(g, *, interpret: bool = True):
    gp, _ = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    sq = jnp.diag(gr)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gr, 0.0)


def _drop_unselected(w, gp):
    """Zero the NON-selected rows before the weighted sum.  A rejected
    Byzantine row may carry +-inf/NaN coordinates, and 0.0 * inf = NaN
    would leak it straight into the aggregate the selection just excluded
    it from; an exact where-select costs one elementwise pass and keeps
    finite-data results bit-identical (0 * finite was already exact)."""
    return jnp.where((w > 0.0)[:, None], gp, 0.0)


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def kernel_krum(g, f: int, *, interpret: bool = True):
    """Krum, fully kernel-path: Pallas Gram -> Pallas score/argmin
    selection -> Pallas weighted-select (one-hot application is exactly
    the selected row's bits)."""
    from repro.kernels.select import krum_select
    gp, d = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    w = krum_select(gr, f, interpret=interpret)
    return weighted_sum(w, _drop_unselected(w, gp), interpret=interpret)[:d]


@functools.partial(jax.jit, static_argnames=("f", "normalize", "interpret"))
def kernel_cge(g, f: int, normalize: bool = True, *, interpret: bool = True):
    """CGE, fully kernel-path: norms off the Pallas Gram diagonal, exact
    comparison-rank top-k selection, Pallas weighted sum; normalization
    divides AFTER the sum like the dense reference."""
    from repro.kernels.select import cge_select
    n = g.shape[0]
    gp, d = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    w = cge_select(gr, n - f, interpret=interpret)
    out = weighted_sum(w, _drop_unselected(w, gp), interpret=interpret)[:d]
    return out / (n - f) if normalize else out
