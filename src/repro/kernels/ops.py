"""jit'd public wrappers around the Pallas kernels: padding, reshaping, and
filter-level compositions (kernel-backed median / trimmed mean / Krum /
multi-Krum / m-Krum / CGE / MDA / Bulyan, plain and imputation-free
masked/weighted variants).

``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware pass interpret=False (the BlockSpecs are TPU-shaped: n sublanes
x 512 lanes, fp32 accumulation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.coord_stats import TILE_D, coord_sort
from repro.kernels.pairwise import gram, imputed_mean, masked_gram
from repro.kernels.wsum import (masked_ordered_apply, masked_weighted_sum,
                                ordered_apply, weighted_sum)


def _pad_d(g, fill=0.0):
    n, d = g.shape
    rem = (-d) % TILE_D
    if rem:
        g = jnp.pad(g, ((0, 0), (0, rem)), constant_values=fill)
    return g, d


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_coordinate_median(g, f=0, *, interpret: bool = True):
    gp, d = _pad_d(g)
    s = coord_sort(gp, interpret=interpret)
    return ref.median_from_sorted(s)[:d]


@functools.partial(jax.jit, static_argnames=("b", "interpret"))
def kernel_trimmed_mean(g, b: int, *, interpret: bool = True):
    gp, d = _pad_d(g)
    s = coord_sort(gp, interpret=interpret)
    return ref.trimmed_mean_from_sorted(s, b)[:d]


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_pairwise_sq_dists(g, *, interpret: bool = True):
    gp, _ = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    sq = jnp.diag(gr)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gr, 0.0)


def _drop_unselected(w, gp):
    """Zero the NON-selected rows before the weighted sum.  A rejected
    Byzantine row may carry +-inf/NaN coordinates, and 0.0 * inf = NaN
    would leak it straight into the aggregate the selection just excluded
    it from; an exact where-select costs one elementwise pass and keeps
    finite-data results bit-identical (0 * finite was already exact)."""
    return jnp.where((w > 0.0)[:, None], gp, 0.0)


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def kernel_krum(g, f: int, *, interpret: bool = True):
    """Krum, fully kernel-path: Pallas Gram -> Pallas score/argmin
    selection -> Pallas weighted-select (one-hot application is exactly
    the selected row's bits)."""
    from repro.kernels.select import krum_select
    gp, d = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    w = krum_select(gr, f, interpret=interpret)
    return weighted_sum(w, _drop_unselected(w, gp), interpret=interpret)[:d]


@functools.partial(jax.jit, static_argnames=("f", "normalize", "interpret"))
def kernel_cge(g, f: int, normalize: bool = True, *, interpret: bool = True):
    """CGE, fully kernel-path: norms off the Pallas Gram diagonal, exact
    comparison-rank top-k selection, Pallas weighted sum (one MXU dot —
    the selected SET is bit-for-bit, the averaged application ulp-level:
    CGE keeps n - f rows, and an order-replaying accumulation would cost
    O((n-f) n T) VPU passes against the dot's single MXU pass for a rule
    whose guarantee rides on the selection, not the summation order);
    normalization divides AFTER the sum like the dense reference."""
    from repro.kernels.select import cge_select
    n = g.shape[0]
    gp, d = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    w = cge_select(gr, n - f, interpret=interpret)
    out = weighted_sum(w, _drop_unselected(w, gp), interpret=interpret)[:d]
    return out / (n - f) if normalize else out


# ---------------------------------------------------------------------------
# the full selection family: multi-Krum / m-Krum / MDA / Bulyan off the
# same Gram + selection primitives, bit-for-bit with the dense reference
# (selection-order-preserving application — see kernels/wsum.py)


@functools.partial(jax.jit, static_argnames=("f", "m", "interpret"))
def kernel_multi_krum(g, f: int, m: int = 2, *, interpret: bool = True):
    """multi-Krum: one Krum score pass, the m smallest averaged in score
    order (exactly the dense ``jnp.mean(g[top_k_idx], axis=0)``)."""
    from repro.kernels.select import multi_krum_order
    gp, d = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    order = multi_krum_order(gr, f, m, interpret=interpret)
    # jnp.mean reference -> divisor stays a visible constant (true_div=False)
    return ordered_apply(order, gp, m, div=m, true_div=False,
                         interpret=interpret)[:d]


@functools.partial(jax.jit, static_argnames=("f", "m", "interpret"))
def kernel_m_krum(g, f: int, m: int = 2, *, interpret: bool = True):
    """m-Krum (iterative): scores recomputed after each removal with the
    SHRINKING neighbour count, picks accumulated sequentially (the dense
    reference's unrolled ``acc = acc + g[i]`` chain)."""
    from repro.kernels.select import iterative_order
    gp, d = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    order = iterative_order(gr, f, m, interpret=interpret)
    return ordered_apply(order, gp, m, chain=True, div=m,
                         interpret=interpret)[:d]


def _mda_order(d2, n: int, f: int):
    """MDA subset selection on the (n, n) squared distances: the static
    (n-f)-subset table is enumerated once per (n, f)
    (aggregators.mda_combos), the diameter argmin (ties by subset
    perimeter, then enumeration order — D.argmin_tiebreak) runs as plain
    O(C(n, f)) jnp with no d dependence; only the Gram and the
    application touch the model-sized stack.  NaN diameters (non-finite
    adversary rows) order LAST like the selection kernels' _rank."""
    from repro.core.aggregators import mda_combos          # lazy: no cycle
    from repro.core.filters.dense import argmin_tiebreak
    combos = mda_combos(n, f)
    sub = d2[combos[:, :, None], combos[:, None, :]]
    diam = jnp.max(sub, axis=(1, 2))
    diam = jnp.where(jnp.isnan(diam), jnp.inf, diam)
    per = jnp.sum(sub, axis=(1, 2))
    per = jnp.where(jnp.isnan(per), jnp.inf, per)
    best = jnp.asarray(combos)[argmin_tiebreak(diam, per)]
    return jnp.full((n,), n, jnp.int32).at[best].set(
        jnp.arange(n - f, dtype=jnp.int32))


def _d2_from_gram_jnp(gr):
    """(n, n) Gram -> squared distances, diagonal exactly 0 (the Gram
    diagonal IS the squared norm, so the cancellation is exact).  NaN
    distances (inf - inf against a non-finite adversary) order last —
    exact no-op on finite stacks."""
    sq = jnp.diag(gr)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gr, 0.0)
    return jnp.where(jnp.isnan(d2), jnp.inf, d2)


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def kernel_mda(g, f: int, *, interpret: bool = True):
    """Minimum-diameter averaging off the Pallas Gram: subset selection is
    d-free jnp on the (n, n) distances, the selected rows averaged in
    index order (the dense ``jnp.mean(g[best], axis=0)``)."""
    n = g.shape[0]
    gp, d = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    order = _mda_order(_d2_from_gram_jnp(gr), n, f)
    return ordered_apply(order, gp, n - f, div=n - f, true_div=False,
                         interpret=interpret)[:d]


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def kernel_bulyan(g, f: int, *, interpret: bool = True):
    """Bulyan: theta = n - 2f shrinking-k iterative Krum selections on the
    Gram, then the fused per-coordinate trimmed-average-around-the-median
    stage — no (n, d) sorted/distance copy ever leaves the tile."""
    from repro.kernels.select import bulyan_coord, iterative_order
    n = g.shape[0]
    theta = n - 2 * f
    assert theta >= 1, "Bulyan needs n > 2f (and n >= 4f+3 for guarantees)"
    gp, d = _pad_d(g)
    gr = gram(gp, interpret=interpret)
    order = iterative_order(gr, f, theta, interpret=interpret)
    sel = (order < theta).astype(jnp.float32)
    return bulyan_coord(gp, sel, theta, f, interpret=interpret)[:d]


# ---------------------------------------------------------------------------
# imputation-free masked/weighted variants: the Gram, the selection AND the
# application all impute inside their tiles (kernels/masked.py trick), so
# the quorum path never materializes the imputed (n, d) stack and
# mask/weights stay traced operands


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def kernel_krum_masked(g, mask, wn, f: int, *, interpret: bool = True):
    """Masked Krum = Krum over the mean-imputed stack (gather law),
    imputation-free: the one-hot imputing weighted sum returns exactly
    the selected imputed row's bits."""
    from repro.kernels.select import krum_select
    gp, d = _pad_d(g)
    mean = imputed_mean(gp, wn)          # (d,) — computed ONCE, shared
    gr = masked_gram(gp, mask, wn, mean, interpret=interpret)
    w = krum_select(gr, f, interpret=interpret)
    return masked_weighted_sum(w, gp, mask, mean,
                               interpret=interpret)[:d]


@functools.partial(jax.jit, static_argnames=("f", "normalize", "interpret"))
def kernel_cge_masked(g, mask, wn, f: int, normalize: bool = True, *,
                      interpret: bool = True):
    """Imputation-free masked CGE (selection bitwise, application via the
    imputing MXU dot — the plain kernel's ulp-level contract)."""
    from repro.kernels.select import cge_select
    n = g.shape[0]
    gp, d = _pad_d(g)
    mean = imputed_mean(gp, wn)
    gr = masked_gram(gp, mask, wn, mean, interpret=interpret)
    w = cge_select(gr, n - f, interpret=interpret)
    out = masked_weighted_sum(w, gp, mask, mean, interpret=interpret)[:d]
    return out / (n - f) if normalize else out


@functools.partial(jax.jit, static_argnames=("f", "m", "interpret"))
def kernel_multi_krum_masked(g, mask, wn, f: int, m: int = 2, *,
                             interpret: bool = True):
    from repro.kernels.select import multi_krum_order
    gp, d = _pad_d(g)
    mean = imputed_mean(gp, wn)
    gr = masked_gram(gp, mask, wn, mean, interpret=interpret)
    order = multi_krum_order(gr, f, m, interpret=interpret)
    return masked_ordered_apply(order, gp, mask, mean, m, div=m,
                                true_div=False, interpret=interpret)[:d]


@functools.partial(jax.jit, static_argnames=("f", "m", "interpret"))
def kernel_m_krum_masked(g, mask, wn, f: int, m: int = 2, *,
                         interpret: bool = True):
    from repro.kernels.select import iterative_order
    gp, d = _pad_d(g)
    mean = imputed_mean(gp, wn)
    gr = masked_gram(gp, mask, wn, mean, interpret=interpret)
    order = iterative_order(gr, f, m, interpret=interpret)
    return masked_ordered_apply(order, gp, mask, mean, m, chain=True,
                                div=m, interpret=interpret)[:d]


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def kernel_mda_masked(g, mask, wn, f: int, *, interpret: bool = True):
    n = g.shape[0]
    gp, d = _pad_d(g)
    mean = imputed_mean(gp, wn)
    gr = masked_gram(gp, mask, wn, mean, interpret=interpret)
    order = _mda_order(_d2_from_gram_jnp(gr), n, f)
    return masked_ordered_apply(order, gp, mask, mean, n - f, div=n - f,
                                true_div=False, interpret=interpret)[:d]


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def kernel_bulyan_masked(g, mask, wn, f: int, *, interpret: bool = True):
    from repro.kernels.select import iterative_order, masked_bulyan_coord
    n = g.shape[0]
    theta = n - 2 * f
    assert theta >= 1, "Bulyan needs n > 2f (and n >= 4f+3 for guarantees)"
    gp, d = _pad_d(g)
    mean = imputed_mean(gp, wn)
    gr = masked_gram(gp, mask, wn, mean, interpret=interpret)
    order = iterative_order(gr, f, theta, interpret=interpret)
    sel = (order < theta).astype(jnp.float32)
    return masked_bulyan_coord(gp, mask, mean, sel, theta, f,
                               interpret=interpret)[:d]
