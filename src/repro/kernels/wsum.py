"""Pallas TPU kernel: weighted sum of agent gradients, out = w^T G.

The application stage of every weights-decomposable filter (Krum selection,
CGE mask, CGC clip scales, MDA subset, Draco votes): given per-agent weights
w (n,), produce sum_i w_i g_i without materializing a gathered copy — fused
per VMEM tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import TILE_D, block_d


def _wsum_kernel(w_ref, g_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)            # (1, n)
    x = g_ref[...].astype(jnp.float32)            # (n, T)
    out_ref[...] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (1, T)


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_sum(w, g, *, interpret: bool = True):
    """w: (n,), g: (n, d) -> (d,) fp32.  d multiple of TILE_D."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w_blk = block_d(d, interpret)
    out = pl.pallas_call(
        _wsum_kernel,
        grid=(d // w_blk,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, w_blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, w_blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(w.reshape(1, n), g)
    return out[0]
