"""Pallas TPU kernels: the application stage of the selection filters.

:func:`weighted_sum` is the classic out = w^T G (Krum's one-hot, CGC clip
scales, Draco votes): given per-agent weights w (n,), produce sum_i w_i g_i
without materializing a gathered copy — fused per VMEM tile.

:func:`ordered_apply` replays a selection ORDER (from
:mod:`repro.kernels.select`): rows are one-hot-extracted and summed in the
order the rule picked them — ``chain=False`` stacks the k rows and reduces
(bit-for-bit with the dense reference's ``jnp.mean(g[top_k_idx], axis=0)``
— the optimization_barrier pins the reduce against reassociation through
the stack), ``chain=True`` adds them sequentially (bit-for-bit with
m-Krum's unrolled ``acc = acc + g[i]`` loop).  The one-hot extraction also
where-zeroes every non-selected row, so a rejected Byzantine row carrying
+-inf/NaN coordinates cannot leak 0*inf = NaN into the aggregate.

:func:`masked_weighted_sum` / :func:`masked_ordered_apply` are the
imputation-FREE variants: the stack stays native dtype and absent rows
are never even built — live selections read the raw rows, ghost
selections contribute the precomputed (d,) imputed mean
(repro.kernels.pairwise.imputed_mean) — algebraically and bitwise the
weighted sum over the imputed stack, without the (n, d) copy the
historical masked path materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import TILE_D, block_d


def _wsum_kernel(w_ref, g_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)            # (1, n)
    x = g_ref[...].astype(jnp.float32)            # (n, T)
    out_ref[...] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (1, T)


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_sum(w, g, *, interpret: bool = True):
    """w: (n,), g: (n, d) -> (d,) fp32.  d multiple of TILE_D."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w_blk = block_d(d, interpret)
    out = pl.pallas_call(
        _wsum_kernel,
        grid=(d // w_blk,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, w_blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, w_blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(w.reshape(1, n), g)
    return out[0]


def _masked_wsum_kernel(w_ref, g_ref, mask_ref, mean_ref, out_ref):
    """Imputation-free w^T over the VIRTUALLY imputed stack: instead of
    materializing imputed rows even tile-locally, live selected rows are
    dotted raw (drop-unselected like the plain kernel) and ghost
    selections contribute their total weight times the precomputed mean —
    algebraically the same sum, and exactly the selected imputed row's
    bits for a one-hot w (0-terms are literal zeros, the ghost term is
    where-gated so 0 * inf cannot leak)."""
    w = w_ref[...][0].astype(jnp.float32)            # (n,)
    x = g_ref[...]
    live = mask_ref[...][0] > 0.5
    mean = mean_ref[...][0].astype(jnp.float32)      # (T,)
    w_live = jnp.where(live, w, 0.0)
    xf = jnp.where((w_live > 0.0)[:, None], x.astype(jnp.float32), 0.0)
    out = jax.lax.dot_general(
        w_live[None], xf, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0]        # (T,)
    ghost_w = jnp.sum(jnp.where(live, 0.0, w))
    out_ref[...] = (out + jnp.where(ghost_w > 0.0, ghost_w * mean,
                                    0.0))[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_weighted_sum(w, g, mask, mean, *, interpret: bool = True):
    """w: (n,) NON-NEGATIVE selection weights, g: (n, d) native dtype,
    mean: (d,) imputation value (repro.kernels.pairwise.imputed_mean) ->
    (d,) fp32 weighted sum over the MEAN-IMPUTED stack (imputation fused
    per tile; mask/mean traced).  d multiple of TILE_D.

    PRECONDITION: w >= 0 — the 0*inf guards gate rows on w > 0, so a
    negative weight would be silently dropped, not subtracted (the
    selection callers pass one-hot / {0,1} sets; signed weight vectors
    need the plain :func:`weighted_sum` on an imputed stack instead)."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w_blk = block_d(d, interpret)
    out = pl.pallas_call(
        _masked_wsum_kernel,
        grid=(d // w_blk,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, w_blk), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, w_blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, w_blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(w.reshape(1, n), g, mask.astype(jnp.float32).reshape(1, n),
      mean.reshape(1, d))
    return out[0]


def _cclip_wsum_kernel(lam_ref, g_ref, v_ref, out_ref):
    """One centered-clip fixed-point step per tile:

        out = v + sum_i lam_i (x_i - v) = (1 - sum_i lam_i) v + lam^T X

    with lam_i = w_i/tot * min(1, tau/||x_i - v||) precomputed by the
    caller (the clip radius needs the FULL row norm — a cross-tile
    reduction — so the scalar stage stays outside; the model-sized
    multiply-accumulate is what fuses here).  Rows are gated on lam > 0,
    so a dead row carrying inf/NaN cannot leak through 0 * x."""
    lam = lam_ref[...][0].astype(jnp.float32)         # (n,)
    x = g_ref[...]
    v = v_ref[...][0].astype(jnp.float32)             # (T,)
    xf = jnp.where((lam > 0.0)[:, None], x.astype(jnp.float32), 0.0)
    acc = jax.lax.dot_general(
        lam[None], xf, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0]         # (T,)
    out_ref[...] = ((1.0 - jnp.sum(lam)) * v + acc)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def clipped_weighted_sum(lam, g, v, *, interpret: bool = True):
    """lam: (n,) NON-NEGATIVE clip-folded weights, g: (n, d) native dtype,
    v: (d,) fp32 current center -> (d,) fp32 updated center
    ``v + sum_i lam_i (g_i - v)`` — the application stage of one
    centered-clipping iteration (Karimireddy et al. momentum clipping),
    fused per tile without materializing the (n, d) difference stack.
    d multiple of TILE_D."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w_blk = block_d(d, interpret)
    out = pl.pallas_call(
        _cclip_wsum_kernel,
        grid=(d // w_blk,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, w_blk), lambda i: (0, i)),
            pl.BlockSpec((1, w_blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, w_blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(lam.astype(jnp.float32).reshape(1, n), g,
      v.astype(jnp.float32).reshape(1, d))
    return out[0]


def _sparse_mean_body(xf, cw):
    """Per-coordinate weighted mean over the rows that SENT the
    coordinate: cw is the per-coordinate weight ((coord != 0) * row
    weight), the where-gate keeps an unsent inf/NaN coordinate from
    leaking through 0 * x, and a coordinate nobody sent yields an
    explicit 0 update (zero-total guard) — identical arithmetic to
    repro.core.aggregators._sparse_mean_law, the gather oracle."""
    num = jnp.sum(jnp.where(cw > 0.0, xf, 0.0) * cw, axis=0)
    den = jnp.sum(cw, axis=0)
    return jnp.where(den > 0.0, num / jnp.where(den > 0.0, den, 1.0), 0.0)


def _sparse_wmean_kernel(g_ref, mask_ref, w_ref, out_ref):
    x = g_ref[...]
    live = mask_ref[...][0] > 0.5
    w = jnp.where(live, w_ref[...][0].astype(jnp.float32), 0.0)   # (n,)
    xf = x.astype(jnp.float32)
    cw = (xf != 0.0).astype(jnp.float32) * w[:, None]
    out_ref[...] = _sparse_mean_body(xf, cw)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_masked_weighted_mean(g, mask, w, *, interpret: bool = True):
    """g: (n, d) native dtype, mask: (n,) {0,1}, w: (n,) row weights
    (dataset sizes — any positive scaling; the law is scale-invariant) ->
    (d,) fp32 sparse/dropout-aware mean: each coordinate is averaged over
    the LIVE rows that actually sent it (coord != 0), weighted by
    ``(coord_sent) * w``.  Absent rows never vote — there is no
    imputation (a dropped-out coordinate is information-free, unlike a
    straggler's stale full row).  d multiple of TILE_D."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w_blk = block_d(d, interpret)
    out = pl.pallas_call(
        _sparse_wmean_kernel,
        grid=(d // w_blk,),
        in_specs=[
            pl.BlockSpec((n, w_blk), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w_blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g, mask.astype(jnp.float32).reshape(1, n),
      w.astype(jnp.float32).reshape(1, n))
    return out[0]


def _scaled_sparse_wmean_kernel(g_ref, sc_ref, mask_ref, w_ref, out_ref):
    # quantized codes: code == 0 iff the dequantized coordinate == 0
    # (scales are strictly positive), so the sent-pattern survives
    # quantization and the in-tile dequant feeds the same law
    x = g_ref[...]
    sc = sc_ref[...][0]
    live = mask_ref[...][0] > 0.5
    w = jnp.where(live, w_ref[...][0].astype(jnp.float32), 0.0)
    xf = x.astype(jnp.float32) * sc[:, None]
    cw = (xf != 0.0).astype(jnp.float32) * w[:, None]
    out_ref[...] = _sparse_mean_body(xf, cw)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def scaled_sparse_masked_weighted_mean(g, scale, mask, w, *,
                                       interpret: bool = True):
    """Sparse mean over a quantized arena: in-tile dequant (per-row fp32
    scale sidecar), then :func:`sparse_masked_weighted_mean`'s law."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w_blk = block_d(d, interpret)
    out = pl.pallas_call(
        _scaled_sparse_wmean_kernel,
        grid=(d // w_blk,),
        in_specs=[
            pl.BlockSpec((n, w_blk), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w_blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g, scale.astype(jnp.float32).reshape(1, n),
      mask.astype(jnp.float32).reshape(1, n),
      w.astype(jnp.float32).reshape(1, n))
    return out[0]


def _accumulate_rows(rows, *, chain, div, true_div, exact):
    """Summation + division stage shared by the ordered applications.

    ``chain`` picks between the dense references' two summation shapes
    (reduce over a gather vs an unrolled add loop).  ``true_div`` mirrors
    the reference's DIVISION compilation: XLA strength-reduces division
    by a compile-time constant into a reciprocal multiply (~1 ulp off for
    non-power-of-2 divisors).  A ``jnp.mean``-based reference compiles
    sum+div as one composite and GETS that rewrite — leave the constant
    visible (true_div=False) so the kernel gets it too; an explicit
    ``out / m`` reference dispatches a standalone true division — pin the
    divisor behind a barrier (true_div=True) so the rewrite cannot see
    the constant."""
    if chain:
        acc = jnp.zeros_like(rows[0])
        for row in rows:
            acc = acc + row
        out = acc
    else:
        stk = jnp.stack(rows, axis=0)
        if exact:
            stk = jax.lax.optimization_barrier(stk)
        out = jnp.sum(stk, axis=0)
    if div is not None:
        den = jnp.float32(div)
        if true_div and exact:
            den = jax.lax.optimization_barrier(den)
        out = out / den
    return out


def _ordered_apply_kernel(ord_ref, g_ref, out_ref, *, k, chain, div,
                          true_div, exact):
    order = ord_ref[...][0]                        # (n,) int32
    x = g_ref[...].astype(jnp.float32)             # (n, T)
    rows = [jnp.sum(jnp.where((order == r)[:, None], x, 0.0), axis=0)
            for r in range(k)]
    out_ref[...] = _accumulate_rows(rows, chain=chain, div=div,
                                    true_div=true_div, exact=exact)[None]


def _masked_ordered_apply_kernel(ord_ref, g_ref, mask_ref, mean_ref,
                                 out_ref, *, k, chain, div, true_div,
                                 exact):
    """Ordered application over the VIRTUALLY imputed stack: each rank is
    at most one row — a live rank contributes its raw row (exact one-hot
    extract + literal-zero mean term), a ghost rank contributes exactly
    the precomputed mean's bits — so no imputed tile is ever built and
    parity with the impute-then-extract arithmetic is bitwise."""
    order = ord_ref[...][0]
    x = g_ref[...].astype(jnp.float32)
    live = mask_ref[...][0] > 0.5
    mean = mean_ref[...][0].astype(jnp.float32)
    rows = []
    for r in range(k):
        sel = order == r
        row = jnp.sum(jnp.where((sel & live)[:, None], x, 0.0), axis=0)
        ghost = jnp.sum((sel & ~live).astype(jnp.float32)) > 0.0
        rows.append(row + jnp.where(ghost, mean, 0.0))
    out_ref[...] = _accumulate_rows(rows, chain=chain, div=div,
                                    true_div=true_div, exact=exact)[None]


@functools.partial(jax.jit,
                   static_argnames=("k", "chain", "div", "true_div",
                                    "interpret"))
def ordered_apply(order, g, k: int, *, chain: bool = False,
                  div: float | None = None, true_div: bool = True,
                  interpret: bool = True):
    """order: (n,) int32 pick order (sentinel >= k ignored), g: (n, d) ->
    (d,) fp32: the k picked rows summed in pick order, divided by ``div``
    (None = no division; ``true_div`` picks the reference's division
    compilation — see _ordered_accumulate).  d multiple of TILE_D."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w_blk = block_d(d, interpret)
    out = pl.pallas_call(
        functools.partial(_ordered_apply_kernel, k=k, chain=chain, div=div,
                          true_div=true_div, exact=interpret),
        grid=(d // w_blk,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, w_blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, w_blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(order.reshape(1, n).astype(jnp.int32), g)
    return out[0]


@functools.partial(jax.jit,
                   static_argnames=("k", "chain", "div", "true_div",
                                    "interpret"))
def masked_ordered_apply(order, g, mask, mean, k: int, *,
                         chain: bool = False, div: float | None = None,
                         true_div: bool = True, interpret: bool = True):
    """Imputation-fused :func:`ordered_apply`: g stays native dtype and
    absent rows are imputed inside the tile from the precomputed (d,)
    ``mean`` (mask/mean are traced operands)."""
    n, d = g.shape
    assert d % TILE_D == 0, d
    w_blk = block_d(d, interpret)
    out = pl.pallas_call(
        functools.partial(_masked_ordered_apply_kernel, k=k, chain=chain,
                          div=div, true_div=true_div, exact=interpret),
        grid=(d // w_blk,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, w_blk), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, w_blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, w_blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(order.reshape(1, n).astype(jnp.int32), g,
      mask.astype(jnp.float32).reshape(1, n), mean.reshape(1, d))
    return out[0]
