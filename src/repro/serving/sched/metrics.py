"""SLO metrics for the serving scheduler: latency percentiles, throughput.

Everything here is host-side numpy over the scheduler's virtual clock —
the same units the simulator's fault schedules use (one base replica
decode = 1.0 virtual second), so the latency distributions are a
function of the workload + fault schedule alone, reproducible bit-for-
bit across machines.  The two quantities the SLO story turns on:

  * **token latency** — committed-token time minus the instant the
    token's decode step started (plus, for a first token, the time the
    request spent queued + prefilling).  Early commit cuts exactly this:
    a token commits at the (f+1)-th consistent replica arrival instead
    of the slowest live replica's.
  * **throughput** — committed tokens per virtual second over the span
    from first admission to last commit.

``summary()`` mirrors :meth:`repro.simulator.events.AsyncTrace.summary`:
one flat dict of floats, percentile keys spelled ``p50``/``p95``.
"""
from __future__ import annotations

import numpy as np


def _pct(values, q) -> float:
    return float(np.percentile(np.asarray(values, np.float64), q))


class ServingMetrics:
    """Accumulates per-request / per-token events; renders one summary.

    The scheduler calls the hooks; consumers read :meth:`summary` (or the
    raw lists, every one a plain python list of floats/ints).
    """

    def __init__(self):
        self.token_latencies: list[float] = []   # per committed token
        self.ttft: list[float] = []              # arrival -> first token
        self.request_latencies: list[float] = []  # arrival -> last token
        self.early_commits = 0
        self.full_votes = 0
        self.committed_tokens = 0
        self.completed_requests = 0
        self.admitted_requests = 0
        self.evictions = 0
        self.reinstatements = 0
        self.t_first: float | None = None
        self.t_last: float | None = None

    # -- hooks the scheduler drives -------------------------------------
    def admit(self, req, now: float) -> None:
        self.admitted_requests += 1
        if self.t_first is None or now < self.t_first:
            self.t_first = now

    def commit(self, req, t_commit: float, latency: float,
               early: bool) -> None:
        """One committed token for ``req`` at virtual time ``t_commit``,
        ``latency`` virtual seconds after its decode step started."""
        self.committed_tokens += 1
        self.token_latencies.append(float(latency))
        if early:
            self.early_commits += 1
        else:
            self.full_votes += 1
        if len(req.out) == 1:                     # this was the first token
            self.ttft.append(float(t_commit - req.arrival))
        self.t_last = float(t_commit)

    def finish(self, req, now: float) -> None:
        self.completed_requests += 1
        self.request_latencies.append(float(now - req.arrival))

    def evict(self, replica: int, step: int) -> None:
        self.evictions += 1

    def reinstate(self, replica: int, step: int) -> None:
        self.reinstatements += 1

    # -- rendering -------------------------------------------------------
    def summary(self) -> dict:
        lat = self.token_latencies
        span = ((self.t_last - self.t_first)
                if self.t_first is not None and self.t_last is not None
                and self.t_last > self.t_first else 0.0)
        total = self.early_commits + self.full_votes
        return {
            "committed_tokens": int(self.committed_tokens),
            "completed_requests": int(self.completed_requests),
            "admitted_requests": int(self.admitted_requests),
            "throughput_tokens_per_vsec": (
                self.committed_tokens / span if span > 0 else 0.0),
            "token_latency_p50": _pct(lat, 50) if lat else 0.0,
            "token_latency_p95": _pct(lat, 95) if lat else 0.0,
            "token_latency_max": float(max(lat)) if lat else 0.0,
            "ttft_p50": _pct(self.ttft, 50) if self.ttft else 0.0,
            "ttft_p95": _pct(self.ttft, 95) if self.ttft else 0.0,
            "request_latency_p50": (_pct(self.request_latencies, 50)
                                    if self.request_latencies else 0.0),
            "request_latency_p95": (_pct(self.request_latencies, 95)
                                    if self.request_latencies else 0.0),
            "early_commit_fraction": (self.early_commits / total
                                      if total else 0.0),
            "full_votes": int(self.full_votes),
            "evictions": int(self.evictions),
            "reinstatements": int(self.reinstatements),
            "virtual_span": float(span),
        }


__all__ = ["ServingMetrics"]
