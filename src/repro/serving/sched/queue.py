"""Request queue + admission control for the serving scheduler.

A :class:`Request` is one generation stream: prompt tokens, a decode
budget, and its virtual arrival time.  The :class:`RequestQueue` is the
front door — FIFO over ARRIVED requests (arrival times live on the same
virtual clock the replica fault schedules run on), with a bounded
pending depth as admission control: a request submitted while
``max_pending`` are already waiting is REJECTED at the door (the
load-shedding answer to overload — queueing it would only grow tail
latency without bound; the bench sweeps offered load past saturation to
show exactly that knee).

:func:`poisson_requests` turns a
:func:`repro.simulator.events.poisson_arrival_times` stream into a
seed-deterministic request workload (prompt lengths and decode budgets
drawn from small caller-given menus — the scheduler compiles one prefill
per DISTINCT prompt length, so a menu, not a continuum).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Request:
    """One generation stream: ``tokens`` (T,) int32 prompt, decode budget,
    virtual arrival time.  ``out`` collects the committed token ids."""
    rid: int
    tokens: np.ndarray
    max_new_tokens: int
    arrival: float = 0.0
    # filled by the scheduler as the stream progresses
    out: list = field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens


class RequestQueue:
    """FIFO of pending requests with bounded-depth admission control.

    ``submit`` returns False (and drops the request) when ``max_pending``
    requests are already queued; ``poll(now)`` hands back every queued
    request whose arrival time has passed, in arrival order.  The queue
    never reorders: continuous batching happens downstream, in the
    scheduler's slot table.
    """

    def __init__(self, max_pending: Optional[int] = None):
        self.max_pending = max_pending
        self._q: deque = deque()
        self.submitted = 0
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, req: Request) -> bool:
        self.submitted += 1
        if self.max_pending is not None and len(self._q) >= self.max_pending:
            self.rejected += 1
            return False
        self._q.append(req)
        return True

    def submit_all(self, reqs) -> int:
        """Submit in order; returns how many were admitted."""
        return sum(1 for r in reqs if self.submit(r))

    def poll(self, now: float, limit: Optional[int] = None) -> list:
        """Pop queued requests with ``arrival <= now`` (FIFO), at most
        ``limit`` of them (None = all arrived)."""
        out = []
        while self._q and self._q[0].arrival <= now \
                and (limit is None or len(out) < limit):
            out.append(self._q.popleft())
        return out

    def peek_arrival(self) -> Optional[float]:
        """Arrival time of the head request (None when empty) — the
        scheduler fast-forwards its virtual clock here when idle."""
        return self._q[0].arrival if self._q else None


def poisson_requests(rate: float, horizon: float, seed: int = 0, *,
                     vocab_size: int, prompt_lens=(8,), new_tokens=(8,),
                     max_requests: Optional[int] = None) -> list:
    """A seed-deterministic Poisson request workload.

    Arrival times come from
    :func:`repro.simulator.events.poisson_arrival_times` (``rate``
    requests per virtual second over ``horizon``); per request, prompt
    length and decode budget are drawn uniformly from the ``prompt_lens``
    / ``new_tokens`` menus and prompt tokens uniformly from the vocab —
    all from one :class:`numpy.random.Generator` seeded with ``seed``, so
    a (rate, horizon, seed) triple names one exact workload across
    benchmark runs."""
    from repro.simulator.events import poisson_arrival_times
    times = poisson_arrival_times(rate, horizon, seed=seed,
                                  max_events=max_requests)
    rng = np.random.default_rng(seed + 1)
    reqs = []
    for i, t in enumerate(times):
        T = int(rng.choice(np.asarray(prompt_lens)))
        reqs.append(Request(
            rid=i,
            tokens=rng.integers(0, vocab_size, size=T).astype(np.int32),
            max_new_tokens=int(rng.choice(np.asarray(new_tokens))),
            arrival=float(t)))
    return reqs


__all__ = ["Request", "RequestQueue", "poisson_requests"]
