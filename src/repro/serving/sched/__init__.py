"""Serving control plane: continuous batching over replicated decode.

What the layer is: :func:`repro.serving.engine.generate_replicated`
fault-tolerantly decodes ONE stream; this package schedules MANY.  A
:class:`~repro.serving.sched.queue.RequestQueue` admits Poisson (or
hand-built) request arrivals on the simulator's virtual clock; the
:class:`~repro.serving.sched.scheduler.ReplicatedScheduler` runs them
through a padded slot batch (per-row decode positions, batch-size
buckets — churn costs at most ``len(slot_buckets)`` compiles), commits
each token either EARLY (first f+1 bitwise-consistent live replicas)
or by the full masked-aggregation vote at the SLO deadline, and lets a
:class:`~repro.serving.sched.policy.SuspicionPolicy` evict replicas
whose selection weight pins at zero — all while every stream's tokens
stay bit-identical to what ``generate_replicated`` would emit for that
request alone (<= f corruption; pinned by
``tests/test_serving_chaos.py``).

Quick start::

    from repro.serving.sched import (ReplicatedScheduler, SuspicionPolicy,
                                     poisson_requests)
    sched = ReplicatedScheduler(cfg, params_stack, spec,
                                slot_buckets=(2, 4), seq_capacity=32,
                                deadline=2.0, delays=trace.delay,
                                policy=SuspicionPolicy(r, f))
    sched.submit_all(poisson_requests(0.5, 40.0, seed=0,
                                      vocab_size=cfg.vocab_size))
    print(sched.run().summary())

Module map: ``queue`` (requests, admission control, workloads),
``scheduler`` (slot slab + early commit — the control loop),
``policy`` (live suspicion -> roster), ``metrics`` (virtual-clock SLO
accounting).  The load benchmark lives in
``benchmarks/bench_serving.py``.
"""
from repro.serving.sched.metrics import ServingMetrics
from repro.serving.sched.policy import SuspicionPolicy
from repro.serving.sched.queue import (Request, RequestQueue,
                                       poisson_requests)
from repro.serving.sched.scheduler import ReplicatedScheduler

__all__ = [
    "Request", "RequestQueue", "poisson_requests",
    "ReplicatedScheduler", "SuspicionPolicy", "ServingMetrics",
]
