"""Continuous-batching scheduler for replicated Byzantine-tolerant decode.

:func:`~repro.serving.engine.generate_replicated` is f-of-r fault-
tolerant for ONE request; this is the control plane that runs MANY
concurrent generation streams through the same replicated vote.  The
three moving parts:

**Continuous batching over a padded slot batch.**  Active requests live
in slots of a fixed-capacity batch; streams join and retire mid-decode.
The batch capacity is drawn from ``slot_buckets`` (the PR 4 elastic-
bucket trick applied to the batch dimension B): the jitted replicated
decode step is compiled once per bucket — request churn costs at most
``len(slot_buckets)`` compilations EVER, counted by ``obs.counters``
site ``sched_decode`` — and requests at different depths coexist in one
batch via per-row decode positions (``cache["pos"]`` as a (B,) vector —
see :func:`repro.models.attention.decode_attention`).  Joining requests
are prefilled at their exact prompt length (one ``sched_prefill``
compile per distinct length; padding a prompt would change its bits)
and their cache rows spliced into the slot slab; retired slots are
repacked out when the active set fits a smaller bucket.  Slot rows are
bit-independent, so every stream's tokens are EXACTLY the tokens
``generate_replicated`` emits for that request alone — the conformance
contract ``tests/test_serving_chaos.py`` pins.

**SLO-aware early commit.**  Replicas finish a decode step at different
virtual times (``delays``, e.g. a :class:`~repro.simulator.faults.
FaultTrace` delay matrix).  Instead of always waiting for the slowest
live replica and running the full robust aggregation, a slot's token is
committed as soon as the first ``f + 1`` live replicas AGREE BITWISE on
the argmax — by the approximate-consensus bound (Liu, Gupta & Vaidya,
arXiv:2101.09337), any f+1 agreeing replicas contain an honest one, and
honest replicas are deterministic, so the early token equals the full-
quorum token whenever at most f replicas are corrupt.  A slot that
cannot reach f+1 consistency by ``deadline`` virtual seconds falls back
to the full masked-aggregation vote over all live replicas (the exact
:class:`~repro.serving.agreement.Agreement` program the engine runs —
never a third copy), committing at the slowest live arrival.  Both paths
are bit-identical to ``generate_replicated`` under <= f corruption;
beyond f, f+1 COLLUDING replicas that answer fastest can steer an early
commit — the tolerance bound is tight, and the chaos suite demonstrates
the break.

**Suspicion-driven roster policy.**  With a
:class:`~repro.serving.sched.policy.SuspicionPolicy` attached, every
step's (r,) per-replica selection weights are streamed through the
recorder (:meth:`~repro.obs.recorder.Recorder.subscribe`) into the
policy, which evicts replicas whose selection rate pins at zero and
folds cooled-off standbys back in; evicted replicas keep decoding as
warm standbys (their caches advance with the agreed tokens) so
reinstatement is instantly consistent.  On steps where every slot
commits early the aggregation never runs — the telemetry row is then
the argmax-agreement share (the fraction of slots whose committed token
the replica reproduced), which pins corrupted replicas at zero just the
same.

Virtual-time accounting (latency percentiles, throughput, early-commit
fraction) lands in :class:`~repro.serving.sched.metrics.ServingMetrics`;
the load benchmark (``benchmarks/bench_serving.py``) sweeps Poisson
offered load x fault rate over this scheduler.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.obs.counters import count_trace
from repro.serving.agreement import Agreement
from repro.serving.sched.metrics import ServingMetrics
from repro.serving.sched.queue import Request, RequestQueue


# ---------------------------------------------------------------------------
# slot-slab cache helpers: per-row decode positions + batch-axis surgery


def _is_pos(path) -> bool:
    return getattr(path[-1], "key", None) == "pos"


def vectorize_cache_pos(cache, batch: int):
    """Turn every scalar ``pos`` leaf into a per-row vector.

    Appends a (batch,) axis to each ``pos`` leaf (top-level and the
    per-layer stacks alike), broadcasting the current value — the form
    :func:`repro.models.attention.decode_attention` treats as per-row
    decode positions.  Non-``pos`` leaves pass through untouched."""
    def fn(path, leaf):
        if _is_pos(path):
            return jnp.broadcast_to(leaf[..., None], leaf.shape + (batch,))
        return leaf
    return jax.tree_util.tree_map_with_path(fn, cache)


def slot_axes(make_cache):
    """Locate the slot (batch) axis of every cache leaf.

    ``make_cache(B)`` builds the (possibly replica-stacked) vectorized
    cache for B slots; comparing the B=1 and B=2 shape trees finds, per
    leaf, the single axis that scales with B — family-agnostic (KV
    rings, SSM states, conv tails and pos vectors all resolve without
    naming them)."""
    s1 = jax.eval_shape(lambda: make_cache(1))
    s2 = jax.eval_shape(lambda: make_cache(2))

    def ax(a, b):
        d = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(d) != 1:
            raise ValueError(
                f"cannot locate slot axis: shapes {a.shape} vs {b.shape}")
        return d[0]
    return jax.tree.map(ax, s1, s2)


def slab_grow(slab, axes, extra: int):
    """Append ``extra`` zero slots along each leaf's slot axis."""
    def pad(a, ax):
        pw = [(0, 0)] * a.ndim
        pw[ax] = (0, extra)
        return jnp.pad(a, pw)
    return jax.tree.map(pad, slab, axes)


def slab_take(slab, axes, idx):
    """Reorder/shrink: keep slot rows ``idx`` (exact copies, bit-safe)."""
    idx = jnp.asarray(np.asarray(idx, np.int32))
    return jax.tree.map(lambda a, ax: jnp.take(a, idx, axis=ax),
                        slab, axes)


def slab_write(slab, axes, rows, slots):
    """Splice ``rows`` (a cache with len(slots) slot rows) into ``slab``
    at slot indices ``slots`` (exact copies, bit-safe)."""
    slots = jnp.asarray(np.asarray(slots, np.int32))

    def w(a, r, ax):
        ix = (slice(None),) * ax + (slots,)
        return a.at[ix].set(r)
    return jax.tree.map(w, slab, rows, axes)


# ---------------------------------------------------------------------------
# the scheduler


class ReplicatedScheduler:
    """Continuous batching of replicated decode streams (see module doc).

    ``cfg``/``params_stack``: arch + replica-stacked params ((r, ...)
    leaves, as for ``generate_replicated``).  ``aggregator``: the
    :class:`~repro.core.aggregators.AggregatorSpec` voting each step
    (static, or elastic over replica rosters).  ``slot_buckets``:
    ascending batch capacities; the largest bounds concurrent streams.
    ``seq_capacity``: per-slot cache capacity (prompt + budget of every
    admitted request must fit).  ``early_commit``/``deadline``: the SLO
    policy — commit on first f+1 bitwise-consistent live replicas,
    falling back to the full vote when consistency is not reached within
    ``deadline`` virtual seconds (None = wait as long as it takes).
    ``delays``: per-replica decode-step latencies — an (steps, r) array
    (e.g. ``FaultTrace.delay``) or ``fn(step) -> (r,)``; default: every
    replica takes ``base_step_time``.  ``fault_hook(step, logits)``:
    the replica-boundary corruption point, same contract as the engine's.
    ``policy``: a :class:`SuspicionPolicy` driving the voting roster;
    ``recorder``/``telemetry``: flight-recorder hooks (a policy without
    a recorder gets an in-memory one).
    """

    def __init__(self, cfg, params_stack, aggregator, *,
                 slot_buckets=(2, 4, 8), seq_capacity: int = 64,
                 early_commit: bool = True, deadline: float | None = None,
                 delays=None, base_step_time: float = 1.0,
                 fault_hook=None, policy=None, recorder=None,
                 telemetry: bool | None = None, jit: bool = True,
                 queue: RequestQueue | None = None):
        if getattr(cfg, "is_encdec", False) or getattr(
                cfg, "frontend", "none") not in (None, "none", "text"):
            raise NotImplementedError(
                "the scheduler serves token-frontend decoder-only archs; "
                "encoder-decoder / vision / audio requests carry per-"
                "request encoder state the slot slab does not hold yet")
        buckets = tuple(int(b) for b in slot_buckets)
        if not buckets or list(buckets) != sorted(set(buckets)) \
                or buckets[0] < 1:
            raise ValueError(
                f"slot_buckets must be ascending positive ints, "
                f"got {slot_buckets}")
        self.cfg = cfg
        self.params_stack = params_stack
        self.spec = aggregator
        self.buckets = buckets
        self.seq_capacity = int(seq_capacity)
        self.early_commit = bool(early_commit)
        self.deadline = deadline
        self.delays = delays
        self.base_step_time = float(base_step_time)
        self.fault_hook = fault_hook
        self.jit = bool(jit)
        self.r = jax.tree.leaves(params_stack)[0].shape[0]

        el = getattr(aggregator, "elastic_n", None)
        if el is not None and el.n_max != self.r:
            raise ValueError(
                f"elastic aggregator {aggregator.describe()} was built for "
                f"n_max={el.n_max} but params_stack has {self.r} replicas")

        self.policy = policy
        if policy is not None and recorder is None:
            from repro.obs.recorder import Recorder
            recorder = Recorder()                 # in-memory event bus
        self.recorder = recorder
        if telemetry is None:
            telemetry = recorder is not None or policy is not None
        self.telemetry = bool(telemetry)
        if policy is not None:
            policy.attach(recorder)
        self.agreement = Agreement(aggregator, telemetry=self.telemetry,
                                   jit=self.jit, site="sched_agree")

        self.queue = queue if queue is not None else RequestQueue()
        self.metrics = ServingMetrics()
        self.clock = 0.0
        self.step_idx = 0
        self.bucket = buckets[0]
        self.slots: list[Request | None] = [None] * self.bucket
        self.cur_token = np.zeros(self.bucket, np.int32)
        self._dec: dict = {}
        self._pre: dict = {}
        self._axes = slot_axes(self._make_slab)
        self.slab = self._make_slab(self.bucket)
        if recorder is not None:
            from repro.obs.telemetry import dispatch_record
            recorder.emit("run", engine="sched", replicas=self.r,
                          slot_buckets=list(buckets),
                          seq_capacity=self.seq_capacity,
                          early_commit=self.early_commit,
                          deadline=self.deadline,
                          dispatch=dispatch_record(aggregator))

    # -- slab / program construction ------------------------------------
    def _make_slab(self, B: int):
        def one(p):
            return init_cache(self.cfg, p, B, self.seq_capacity,
                              {"tokens": jnp.zeros((B, 1), jnp.int32)})
        return vectorize_cache_pos(jax.vmap(one)(self.params_stack), B)

    def _decode_fn(self, B: int):
        if B not in self._dec:
            def dec(pstack, token, slab):
                count_trace("sched_decode")

                def one(p, c):
                    return decode_step(self.cfg, p, token, c)
                return jax.vmap(one)(pstack, slab)
            self._dec[B] = jax.jit(dec) if self.jit else dec
        return self._dec[B]

    def _prefill_fn(self, T: int):
        if T not in self._pre:
            def pf(pstack, tokens):               # tokens (1, T) int32
                count_trace("sched_prefill")
                batch = {"tokens": tokens}

                def one(p):
                    c = init_cache(self.cfg, p, 1, self.seq_capacity, batch)
                    return prefill(self.cfg, p, batch, c)
                return jax.vmap(one)(pstack)
            self._pre[T] = jax.jit(pf) if self.jit else pf
        return self._pre[T]

    # -- roster / timing helpers ----------------------------------------
    def _live(self) -> np.ndarray:
        if self.policy is not None:
            return np.asarray(self.policy.roster, bool).copy()
        return np.ones(self.r, bool)

    def _step_delays(self, step: int) -> np.ndarray:
        if self.delays is None:
            d = np.full(self.r, self.base_step_time)
        elif callable(self.delays):
            d = np.asarray(self.delays(step), np.float64)
        else:
            arr = np.asarray(self.delays, np.float64)
            d = arr[min(step, len(arr) - 1)]
        d = np.asarray(d, np.float64).copy()
        # omission faults ride the roster/policy, not infinite delays —
        # clamp so the full-vote wait stays finite
        bad = ~np.isfinite(d)
        if bad.any():
            d[bad] = max(1.0, np.max(d[~bad], initial=1.0)) * 100.0
        return d

    def _f_eff(self, n_live: int) -> int:
        if self.agreement.elastic is not None:
            return int(self.spec.respecialize(n_live).f)
        return int(self.spec.f)

    def _commit_walk(self, amax: np.ndarray, live: np.ndarray,
                     d: np.ndarray, q: int):
        """Earliest f+1 bitwise-consistent commit per slot.

        ``amax`` (r, B) per-replica fp32 argmax tokens; walks live
        replicas in arrival order ((delay, id) — same-instant ties pin
        to replica id, as in the simulator's event queue).  Returns
        ``(t_star, tok_star)``: per slot, the virtual delay at which
        some token value reached ``q`` consistent supporters (inf when
        consistency is never reached) and that token."""
        B = amax.shape[1]
        t_star = np.full(B, np.inf)
        tok_star = np.full(B, -1, np.int64)
        if q < 1:
            return t_star, tok_star
        counts: list[dict] = [{} for _ in range(B)]
        remaining = set(range(B))
        order = sorted(np.flatnonzero(live), key=lambda i: (d[i], i))
        for i in order:
            for b in list(remaining):
                tk = int(amax[i, b])
                c = counts[b]
                c[tk] = c.get(tk, 0) + 1
                if c[tk] >= q:
                    t_star[b] = d[i]
                    tok_star[b] = tk
                    remaining.discard(b)
            if not remaining:
                break
        return t_star, tok_star

    # -- request intake --------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admission-controlled submit (False = rejected at the door)."""
        if req.prompt_len + req.max_new_tokens > self.seq_capacity:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + budget "
                f"{req.max_new_tokens} exceeds seq_capacity "
                f"{self.seq_capacity}")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: empty decode budget")
        return self.queue.submit(req)

    def submit_all(self, reqs) -> int:
        return sum(1 for r in reqs if self.submit(r))

    # -- slot management -------------------------------------------------
    def _active_ids(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _resize_to(self, n_needed: int) -> None:
        """Move the slab to the smallest bucket holding ``n_needed``."""
        target = next(b for b in self.buckets if b >= n_needed)
        if target == self.bucket:
            return
        if target > self.bucket:
            extra = target - self.bucket
            self.slab = slab_grow(self.slab, self._axes, extra)
            self.slots += [None] * extra
            self.cur_token = np.concatenate(
                [self.cur_token, np.zeros(extra, np.int32)])
        else:                                     # repack actives, shrink
            keep = self._active_ids()
            free = [i for i in range(self.bucket) if self.slots[i] is None]
            idx = (keep + free)[:target]
            self.slab = slab_take(self.slab, self._axes, idx)
            self.slots = [self.slots[i] for i in idx]
            self.cur_token = self.cur_token[np.asarray(idx, int)]
        self.bucket = target

    def _commit_tokens(self, logits, active: list[int], now: float,
                       phase: str):
        """Agree on this step's token for every ``active`` slot.

        Returns (per-slot token dict, latest commit time, telemetry row).
        ``logits`` is the post-fault-hook (r, B, V) stack; ``phase`` only
        labels the recorder event ("decode" | "prefill")."""
        live = self._live()
        d = self._step_delays(self.step_idx)
        la = np.asarray(logits, np.float32) if logits.dtype != jnp.float32 \
            else np.asarray(logits)
        amax = la.argmax(axis=-1)                 # (r, B) fp32 argmax
        n_live = int(live.sum())
        q = self._f_eff(n_live) + 1
        t_star, tok_star = self._commit_walk(amax, live, d, q)
        limit = np.inf if self.deadline is None else float(self.deadline)
        full_wait = float(d[live].max()) if n_live else 0.0

        tokens: dict[int, int] = {}
        times: dict[int, float] = {}
        early: dict[int, bool] = {}
        fallback = [b for b in active
                    if not (self.early_commit and t_star[b] <= limit)]
        vote_tok, vote_telem = None, None
        if fallback:
            out = self.agreement.vote(logits, live if self.policy is not None
                                      else None)
            if self.telemetry:
                vote_tok, vote_telem = out
            else:
                vote_tok = out
            vote_tok = np.asarray(vote_tok)
        for b in active:
            if b in fallback:
                tokens[b] = int(vote_tok[b])
                times[b] = now + full_wait
                early[b] = False
            else:
                tokens[b] = int(tok_star[b])
                times[b] = now + float(t_star[b])
                early[b] = True

        telem = None
        if self.telemetry:
            if vote_telem is not None:
                telem = {k: np.asarray(v) for k, v in vote_telem.items()}
            else:
                # all-early step: the vote never ran — replica shares are
                # argmax agreement over the committed slots
                agree_frac = np.zeros(self.r, np.float64)
                if active:
                    hits = np.stack([amax[:, b] == tokens[b]
                                     for b in active], axis=1)
                    agree_frac = np.where(live, hits.mean(axis=1), 0.0)
                telem = {"sel_w": agree_frac.astype(np.float32),
                         "mask": live, "contrib_w": live.astype(np.float32)}
        return tokens, times, early, telem, live

    # -- the step --------------------------------------------------------
    def step(self) -> bool:
        """One scheduler step: decode actives, then admit arrivals.

        Returns False when there was nothing to do AND nothing is queued
        (the drain condition)."""
        now = self.clock
        active = self._active_ids()
        if not active and len(self.queue) == 0:
            return False
        if not active:
            nxt = self.queue.peek_arrival()
            if nxt is None:
                return False
            now = max(now, float(nxt))            # idle: fast-forward

        t_end = now
        telem, live = None, self._live()
        if active:
            dec = self._decode_fn(self.bucket)
            tok = jnp.asarray(self.cur_token[:, None])
            logits, self.slab = dec(self.params_stack, tok, self.slab)
            if self.fault_hook is not None:
                logits = self.fault_hook(self.step_idx, logits)
            tokens, times, early, telem, live = self._commit_tokens(
                logits, active, now, "decode")
            for b in active:
                req = self.slots[b]
                req.out.append(tokens[b])
                self.cur_token[b] = tokens[b]
                self.metrics.commit(req, times[b], times[b] - now,
                                    early[b])
                t_end = max(t_end, times[b])
                if req.done:
                    self.metrics.finish(req, times[b])
                    self.slots[b] = None
                    self.cur_token[b] = 0
        else:
            t_end = now + 0.0

        # admissions: arrivals by ``now`` join during this step (their
        # prefill overlaps the decode), decode from the NEXT step on
        n_active = len(self._active_ids())
        staged = self.queue.poll(now, limit=self.buckets[-1] - n_active)
        if staged or n_active != len(active):
            self._resize_to(max(n_active + len(staged), 1))
        for req in staged:
            slot = self.slots.index(None)
            t_first = self._admit(req, slot, now)
            t_end = max(t_end, t_first)

        if self.recorder is not None:
            m = {"active": len(self._active_ids()),
                 "queued": len(self.queue), "bucket": self.bucket,
                 "clock": self.clock,
                 "n_live": int(np.asarray(live).sum())}
            self.recorder.step(self.step_idx, metrics=m, telemetry=telem,
                               roster=live)
        self.clock = max(self.clock, t_end,
                         now + (self.base_step_time if active else 0.0))
        self.step_idx += 1
        return True

    def _admit(self, req: Request, slot: int, now: float) -> float:
        """Prefill ``req`` into ``slot`` and commit its first token."""
        pf = self._prefill_fn(req.prompt_len)
        tokens = jnp.asarray(np.asarray(req.tokens, np.int32)[None, :])
        logits, rows = pf(self.params_stack, tokens)  # (r, 1, V), cache
        if self.fault_hook is not None:
            logits = self.fault_hook(self.step_idx, logits)
        tokens_d, times_d, early_d, _, _ = self._commit_tokens(
            logits, [0], now, "prefill")
        tok0 = tokens_d[0]
        self.metrics.admit(req, now)
        req.out.append(tok0)
        self.metrics.commit(req, times_d[0], times_d[0] - now, early_d[0])
        self.slots[slot] = req
        self.cur_token[slot] = tok0
        self.slab = slab_write(self.slab, self._axes,
                               vectorize_cache_pos(rows, 1), [slot])
        if req.done:                               # budget of exactly 1
            self.metrics.finish(req, times_d[0])
            self.slots[slot] = None
            self.cur_token[slot] = 0
        return times_d[0]

    # -- driving ---------------------------------------------------------
    def run(self, max_steps: int | None = None) -> ServingMetrics:
        """Step until the queue and slot table drain (or ``max_steps``)."""
        steps = 0
        while max_steps is None or steps < max_steps:
            if not self.step():
                break
            steps += 1
        if self.recorder is not None:
            self.recorder.emit("note", message="sched drained",
                               steps=self.step_idx,
                               **{k: v for k, v in
                                  self.metrics.summary().items()
                                  if isinstance(v, (int, float))})
        return self.metrics


__all__ = ["ReplicatedScheduler", "vectorize_cache_pos", "slot_axes",
           "slab_grow", "slab_take", "slab_write"]
