"""Suspicion-driven replica roster policy (the PR 6 named follow-up).

The flight recorder already derives per-agent *suspicion* from selection
-weight telemetry (:func:`repro.obs.telemetry.suspicion_scores`) — but
only after the run, from the JSONL.  This module closes the loop LIVE:
a :class:`SuspicionPolicy` subscribes to the scheduler's
:class:`~repro.obs.recorder.Recorder` stream
(:meth:`~repro.obs.recorder.Recorder.subscribe`) and maintains, per
replica, the streak of consecutive delivered steps whose selection
weight pinned at zero.  A robust rule that keeps excluding a replica's
logits is evidence against that replica — when the streak reaches
``window``, the replica is EVICTED from the voting roster.

Eviction is a roster decision, not a teardown: the scheduler keeps
advancing an evicted replica's cache with the agreed tokens (the warm-
standby semantics ``generate_replicated`` established for rosters), so
after ``cooloff`` steps the policy folds the standby back in — if it is
still corrupt the selection weights re-pin at zero and it is re-evicted;
if it was transient (bit-flip, recovered host) it rejoins the vote
instantly consistent.  ``min_live`` (default ``2 f + 1`` — the classic
robust-aggregation quorum) floors the roster: the policy never evicts
below the count the aggregation rule needs to tolerate f, no matter how
suspicious the stragglers look.

The policy is a pure event consumer — it never touches a trace, and the
scheduler reads ``policy.roster`` between steps.  It composes with any
event source that emits recorder-shaped ``step`` events carrying
``telemetry.sel_w`` / ``telemetry.mask``, so it can equally be driven by
a recorded JSONL replay (``for ev in read_trace(p): policy.on_event(ev)``).
"""
from __future__ import annotations

import numpy as np


class SuspicionPolicy:
    """Live roster controller over ``n_replicas`` voting replicas.

    ``window``: consecutive zero-selection delivered steps before
    eviction; ``cooloff``: steps an evicted replica sits out before being
    reinstated as a warm standby; ``min_live``: roster floor (None ->
    ``2 * f + 1``); ``eps``: selection-share threshold under which a
    delivered step counts as "not selected".
    """

    def __init__(self, n_replicas: int, f: int, *, window: int = 8,
                 cooloff: int = 16, min_live: int | None = None,
                 eps: float = 1e-9):
        if n_replicas < 1:
            raise ValueError(f"need at least one replica, got {n_replicas}")
        self.n = int(n_replicas)
        self.f = int(f)
        self.window = int(window)
        self.cooloff = int(cooloff)
        self.min_live = (2 * self.f + 1 if min_live is None
                         else int(min_live))
        self.eps = float(eps)
        self.roster = np.ones(self.n, bool)       # the scheduler reads this
        self.zero_streak = np.zeros(self.n, np.int64)
        self.evicted_at = np.full(self.n, -1, np.int64)
        self.events: list[dict] = []              # eviction/reinstate log
        self._unsubscribe = None

    # -- wiring ----------------------------------------------------------
    def attach(self, recorder) -> "SuspicionPolicy":
        """Subscribe to a live Recorder event stream; returns self."""
        self._unsubscribe = recorder.subscribe(self.on_event)
        return self

    def detach(self) -> None:
        if self._unsubscribe is not None:
            self._unsubscribe()
            self._unsubscribe = None

    # -- the event consumer ---------------------------------------------
    def on_event(self, ev: dict) -> None:
        if ev.get("kind") != "step" or not ev.get("telemetry"):
            return
        t = ev["telemetry"]
        sel = np.asarray(t.get("sel_w", ()), np.float64)
        mask = np.asarray(t.get("mask", ()), bool)
        if sel.shape != (self.n,) or mask.shape != (self.n,):
            return                               # not a replica-vote event
        step = int(ev.get("step", len(self.events)))
        self._update(sel, mask, step)

    def _update(self, sel: np.ndarray, mask: np.ndarray, step: int) -> None:
        # selection shares over the delivered set (rules whose weights sum
        # below 1 — cgc attenuation — compare on the same baseline)
        tot = float(np.where(mask, sel, 0.0).sum())
        share = np.where(mask, sel, 0.0) / max(tot, 1e-30)
        delivered = mask & self.roster
        zero = delivered & (share <= self.eps)
        self.zero_streak = np.where(zero, self.zero_streak + 1,
                                    np.where(delivered, 0,
                                             self.zero_streak))
        # reinstate cooled-off standbys first (the roster floor below
        # then sees the refreshed live count)
        for i in np.flatnonzero(~self.roster):
            if step - self.evicted_at[i] >= self.cooloff:
                self.roster[i] = True
                self.zero_streak[i] = 0
                self.evicted_at[i] = -1
                self.events.append({"kind": "reinstate", "replica": int(i),
                                    "step": step})
        # evict pinned-at-zero replicas, most-suspicious first, floored
        order = np.argsort(-self.zero_streak)
        for i in order:
            if (self.roster[i] and self.zero_streak[i] >= self.window
                    and int(self.roster.sum()) > self.min_live):
                self.roster[i] = False
                self.evicted_at[i] = step
                self.events.append({"kind": "evict", "replica": int(i),
                                    "step": step,
                                    "streak": int(self.zero_streak[i])})

    # -- inspection ------------------------------------------------------
    @property
    def n_live(self) -> int:
        return int(self.roster.sum())

    def describe(self) -> dict:
        return {
            "roster": self.roster.tolist(),
            "zero_streak": self.zero_streak.tolist(),
            "window": self.window, "cooloff": self.cooloff,
            "min_live": self.min_live,
            "evictions": sum(1 for e in self.events
                             if e["kind"] == "evict"),
            "reinstatements": sum(1 for e in self.events
                                  if e["kind"] == "reinstate"),
        }


__all__ = ["SuspicionPolicy"]
