"""The ONE replica-agreement builder shared by the serving paths.

Both :func:`repro.serving.engine.generate_replicated` (one request,
lock-step replicas) and the continuous-batching scheduler
(:mod:`repro.serving.sched`) robustly aggregate a per-step (r, B, V)
logits stack over the replica axis and argmax the result.  Historically
the engine carried two copies of that logic (``_agree_of`` for the
static/masked path, ``make_agree_bucket`` for elastic rosters); this
module is the extraction, so the scheduler does not grow a third copy
and pad strategy / telemetry scatter / count-site accounting can never
diverge between the serving paths.

:class:`Agreement` exposes three layers, outermost first:

  ``vote(logits, member)``   dispatch on the live-roster mask: no mask ->
                             the full-stack program; mask + static spec ->
                             the masked program (mask is a traced operand,
                             ONE compile); mask + elastic spec -> pack the
                             live rows into their bucket and run the
                             bucket's respecialized program (<=
                             ``len(buckets)`` compiles, cached here);
  ``full(logits, member)``   the jitted full/masked agreement;
  ``bucket(b)``              the jitted per-bucket agreement (packed
                             ``(logits, idx, valid)`` signature, telemetry
                             scattered back to the full (r,) roster).

With ``telemetry=True`` every program additionally returns the
aggregator's (r,) selection weights over replicas (see
:meth:`~repro.core.aggregators.AggregatorSpec.selection_weights`) as a
fixed-shape aux dict; ``telemetry=False`` keeps the EXACT historical
agreement jaxpr.  Every trace of an agreement program counts against
``site`` in :mod:`repro.obs.counters` (the engine keeps its historical
``"serving_agree"`` site; the scheduler uses ``"sched_agree"``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.counters import count_trace


class Agreement:
    """Jitted replica-agreement programs for one AggregatorSpec.

    Build once per serving loop; per-bucket programs are compiled lazily
    and cached on the instance, so a roster that revisits a bucket never
    re-traces.  See the module docstring for the three entry points.
    """

    def __init__(self, spec, *, telemetry: bool = False, jit: bool = True,
                 site: str = "serving_agree"):
        self.spec = spec
        self.telemetry = bool(telemetry)
        self.jit = bool(jit)
        self.site = str(site)
        # wrapper chains delegate elasticity to their inner rule
        self.elastic = getattr(spec, "elastic_n", None)
        self._full = self._agree_of(spec)
        if jit:
            self._full = jax.jit(self._full)
        self._buckets: dict = {}

    # -- program builders ------------------------------------------------
    def _flat_agree(self, spec, logits_stack, mask=None):
        # zero-copy agreement: a logits stack is already one dense leaf,
        # so the flat path is a free (r, B*V) reshape into the arena the
        # kernels consume — no tree plumbing per decode step.  Specs
        # without a flat path (fused / wrapper / stateful) keep the tree
        # engine.
        r, B, V = logits_stack.shape
        vec = spec.aggregate_flat(
            logits_stack.astype(jnp.float32).reshape(r, B * V), mask=mask)
        return vec.reshape(B, V)

    def _agree_of(self, spec):
        use_flat = getattr(spec, "flat_capable", False)
        telemetry = self.telemetry
        site = self.site

        def agree(logits_stack, member=None):      # member: (r,) bool traced
            count_trace(site)
            if use_flat:
                agg = self._flat_agree(spec, logits_stack, mask=member)
            else:
                agg = spec.aggregate(logits_stack.astype(jnp.float32),
                                     mask=member)
            tok = jnp.argmax(agg, axis=-1).astype(jnp.int32)
            if not telemetry:                      # static: same jaxpr as
                return tok                         # the pre-obs engine
            rr = logits_stack.shape[0]
            fstack = logits_stack.astype(jnp.float32).reshape(rr, -1)
            sel = spec.selection_weights(fstack, mask=member)
            m = (jnp.ones((rr,), bool) if member is None
                 else member.astype(bool))
            return tok, {"sel_w": sel.astype(jnp.float32), "mask": m,
                         "contrib_w": m.astype(jnp.float32)}
        return agree

    def _make_bucket(self, b: int):
        spec_b = self.spec.respecialize(b)
        agree_packed = self._agree_of(spec_b)
        telemetry = self.telemetry

        def agree_b(logits_stack, idx, valid):     # idx (b,) i32, valid (b,)
            out = agree_packed(logits_stack[idx], valid)
            if not telemetry:
                return out
            tok, t = out                           # scatter back to (r,)
            rr = logits_stack.shape[0]
            sel = jnp.zeros((rr,), jnp.float32).at[idx].add(
                jnp.where(valid, t["sel_w"], 0.0))
            m = jnp.zeros((rr,), bool).at[idx].max(valid)
            return tok, {"sel_w": sel, "mask": m,
                         "contrib_w": m.astype(jnp.float32)}
        return jax.jit(agree_b) if self.jit else agree_b

    # -- entry points ----------------------------------------------------
    def full(self, logits_stack, member=None):
        """The full/masked agreement program (member: traced (r,) bool)."""
        if member is None:
            return self._full(logits_stack)
        return self._full(logits_stack, member)

    def bucket(self, b: int):
        """The packed agreement program of elastic bucket ``b`` (cached)."""
        if b not in self._buckets:
            self._buckets[b] = self._make_bucket(b)
        return self._buckets[b]

    def vote(self, logits_stack, member=None):
        """Dispatch one agreement step on a host-side live-roster mask.

        ``member``: None (full static roster) or an (r,) bool array-like.
        Returns the committed (B,) token — with ``telemetry=True``, a
        ``(token, {sel_w, mask, contrib_w})`` pair, aux shapes always
        (r,) regardless of the bucket that served the vote."""
        if member is None:
            return self._full(logits_stack)
        member = np.asarray(member, bool)
        live = np.flatnonzero(member)
        if len(live) == 0:
            raise ValueError("agreement vote with no live replicas")
        if self.elastic is None:
            return self._full(logits_stack, jnp.asarray(member))
        b, idx, valid = self.elastic.pack(live)
        return self.bucket(b)(logits_stack, jnp.asarray(idx),
                              jnp.asarray(valid))


__all__ = ["Agreement"]
