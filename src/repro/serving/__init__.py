from repro.serving.agreement import Agreement
from repro.serving.engine import (generate, generate_replicated,
                                  make_decode_step, make_prefill_step)

__all__ = ["make_prefill_step", "make_decode_step", "generate",
           "generate_replicated", "Agreement"]
