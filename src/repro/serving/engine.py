"""Serving path: batched prefill + single-token decode steps.

``decode_32k`` / ``long_500k`` dry-run shapes lower ``decode_step`` — ONE new
token against a seq_len-sized KV (ring) / SSM-state cache.  Ring caches bound
the 500k-context cache to the attention window for SWA archs; SSM state is
O(1) — see DESIGN.md for the per-arch applicability.

:func:`generate_replicated` extends the survey's fault model to SERVING: r
model replicas decode in lock-step and every step's logits are robustly
aggregated with an :class:`~repro.core.aggregators.AggregatorSpec`, so up
to ``spec.f`` corrupted replicas (bit-flipped weights, poisoned checkpoint,
hostile host) cannot steer the sampled token — the serving-side analogue of
robust gradient aggregation, and the hook the fault-injection schedules
chaos-test."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.serving.agreement import Agreement


def make_prefill_step(cfg):
    def prefill_step(params, batch, cache):
        return prefill(cfg, params, batch, cache)
    return prefill_step


def make_decode_step(cfg, sample: str = "greedy", temperature: float = 1.0):
    def decode(params, token, cache, key=None):
        logits, cache = decode_step(cfg, params, token, cache)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1)
        else:
            nxt = jax.random.categorical(
                key, logits.astype(jnp.float32) / temperature, axis=-1)
        return nxt.astype(jnp.int32)[:, None], logits, cache
    return decode


def generate(cfg, params, prompt_batch, max_new_tokens: int,
             seq_capacity: int | None = None, sample: str = "greedy",
             key=None, jit: bool = True):
    """Host loop: prefill the prompt, then decode max_new_tokens greedily.
    Returns (B, max_new_tokens) int32."""
    B, T = prompt_batch["tokens"].shape
    cap = seq_capacity or (T + max_new_tokens)
    cache = init_cache(cfg, params, B, cap, prompt_batch)
    pre = make_prefill_step(cfg)
    dec = make_decode_step(cfg, sample=sample)
    if jit:
        pre = jax.jit(pre)
        dec = jax.jit(dec, static_argnames=())
    logits, cache = pre(params, prompt_batch, cache)
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [token]
    key = key if key is not None else jax.random.PRNGKey(0)
    for i in range(max_new_tokens - 1):
        key, sub = jax.random.split(key)
        token, logits, cache = dec(params, token, cache,
                                   sub if sample != "greedy" else None)
        out.append(token)
    return jnp.concatenate(out, axis=1)


def generate_replicated(cfg, params_stack, prompt_batch,
                        max_new_tokens: int, aggregator,
                        seq_capacity: int | None = None, jit: bool = True,
                        fault_hook=None, roster=None, recorder=None,
                        telemetry: bool | None = None):
    """Byzantine-fault-tolerant greedy decoding over r model replicas.

    ``params_stack``: params pytree with a leading replica axis (r, ...) —
    e.g. ``jax.tree.map(lambda *ls: jnp.stack(ls), *replica_params)``.
    ``aggregator``: an :class:`~repro.core.aggregators.AggregatorSpec`; the
    per-step (r, B, V) logit stack is aggregated over the replica axis, so
    any ``spec.f`` corrupted replicas are filtered before argmax, and every
    replica's cache advances with the agreed token.

    ``fault_hook(step, logits_stack) -> logits_stack``: optional
    fault-injection point at the replica communication boundary — called
    on every decode step (step 0 = prefill) BEFORE aggregation, it models
    replicas emitting corrupted logits (bit-flipped weights, hostile
    hosts, lost messages).  The fault-schedule chaos tests
    (tests/test_serving_chaos.py) drive it with compiled
    :class:`~repro.simulator.faults.FaultTrace` rows; per-replica caches
    still advance with the *agreed* token, matching a real deployment
    where the decode loop is trusted and only replica outputs are not.

    ``roster``: optional (steps, r) bool membership schedule (elastic
    replica sets — e.g. ``FaultTrace.roster`` from a Join/Rejoin/Churn
    schedule; row 0 gates the prefill logits).  A non-member replica's
    logits are EXCLUDED from agreement — bit-for-bit, its emissions
    cannot steer the stream — while its cache still advances with the
    agreed token (a warm standby), so a replica that joins or rejoins
    mid-decode is instantly consistent and folds straight into the f-of-r
    vote.  The roster row is a traced operand; with an elastic-n
    ``aggregator`` (``make_spec(..., n=elastic(r, buckets=...))``) the
    live rows are packed per bucket and the rule's (n, f) plan tracks the
    live replica count, costing at most ``len(buckets)`` agreement
    compilations per call.

    ``recorder``/``telemetry``: flight-recorder hooks (see
    :mod:`repro.obs`).  With a recorder attached (or ``telemetry=True``)
    the agreement step additionally emits the aggregator's (r,) selection
    weights over replicas as a fixed-shape aux output, and every decode
    step is logged as a recorder event (step 0 = prefill).  Telemetry off
    keeps the EXACT historical agreement jaxpr; recording runs on host
    between steps — the token stream is bit-identical either way.

    Returns (B, max_new_tokens) int32, identical to :func:`generate` on the
    clean params when <= f replicas are corrupted at every step and the
    rule tolerates f.
    """
    B, T = prompt_batch["tokens"].shape
    cap = seq_capacity or (T + max_new_tokens)
    telemetry = (recorder is not None) if telemetry is None else telemetry

    def rep_prefill(p):
        cache = init_cache(cfg, p, B, cap, prompt_batch)
        return prefill(cfg, p, prompt_batch, cache)

    def rep_decode(p, token, cache):
        return decode_step(cfg, p, token, cache)

    vpre = jax.vmap(rep_prefill)
    vdec = jax.vmap(rep_decode, in_axes=(0, None, 0))

    if jit:
        vpre = jax.jit(vpre)
        vdec = jax.jit(vdec)

    # the shared agreement builder (also used by the sched subsystem) —
    # full/masked/elastic-bucket dispatch, telemetry scatter, count site
    ag = Agreement(aggregator, telemetry=telemetry, jit=jit)
    el = ag.elastic
    r = jax.tree.leaves(params_stack)[0].shape[0]
    if el is not None and el.n_max != r:
        raise ValueError(
            f"elastic aggregator {aggregator.describe()} was built for "
            f"n_max={el.n_max} but params_stack has {r} replicas")
    if recorder is not None:
        from repro.obs.telemetry import dispatch_record
        recorder.emit("run", engine="generate_replicated", replicas=r,
                      max_new_tokens=max_new_tokens,
                      dispatch=dispatch_record(aggregator))

    def agree_step(step, logits):
        if roster is None:
            return ag.vote(logits)
        member = np.asarray(roster[min(step, len(roster) - 1)], bool)
        if not member.any():
            raise ValueError(f"roster at step {step} has no live replicas")
        return ag.vote(logits, member)

    def agreed(step, logits):
        st0 = recorder.now() if recorder is not None else None
        out = agree_step(step, logits)
        token, telem = out if telemetry else (out, None)
        if recorder is not None:
            recorder.step(step, t0=st0, t1=recorder.now(),
                          telemetry=telem,
                          roster=(roster[min(step, len(roster) - 1)]
                                  if roster is not None else None))
        return token

    logits, caches = vpre(params_stack)
    if fault_hook is not None:
        logits = fault_hook(0, logits)
    token = agreed(0, logits)[:, None]
    out = [token]
    for step in range(1, max_new_tokens):
        logits, caches = vdec(params_stack, token, caches)
        if fault_hook is not None:
            logits = fault_hook(step, logits)
        token = agreed(step, logits)[:, None]
        out.append(token)
    return jnp.concatenate(out, axis=1)
