"""Serving path: batched prefill + single-token decode steps.

``decode_32k`` / ``long_500k`` dry-run shapes lower ``decode_step`` — ONE new
token against a seq_len-sized KV (ring) / SSM-state cache.  Ring caches bound
the 500k-context cache to the attention window for SWA archs; SSM state is
O(1) — see DESIGN.md for the per-arch applicability.

:func:`generate_replicated` extends the survey's fault model to SERVING: r
model replicas decode in lock-step and every step's logits are robustly
aggregated with an :class:`~repro.core.aggregators.AggregatorSpec`, so up
to ``spec.f`` corrupted replicas (bit-flipped weights, poisoned checkpoint,
hostile host) cannot steer the sampled token — the serving-side analogue of
robust gradient aggregation, and the hook the fault-injection schedules
chaos-test."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import decode_step, init_cache, prefill


def make_prefill_step(cfg):
    def prefill_step(params, batch, cache):
        return prefill(cfg, params, batch, cache)
    return prefill_step


def make_decode_step(cfg, sample: str = "greedy", temperature: float = 1.0):
    def decode(params, token, cache, key=None):
        logits, cache = decode_step(cfg, params, token, cache)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1)
        else:
            nxt = jax.random.categorical(
                key, logits.astype(jnp.float32) / temperature, axis=-1)
        return nxt.astype(jnp.int32)[:, None], logits, cache
    return decode


def generate(cfg, params, prompt_batch, max_new_tokens: int,
             seq_capacity: int | None = None, sample: str = "greedy",
             key=None, jit: bool = True):
    """Host loop: prefill the prompt, then decode max_new_tokens greedily.
    Returns (B, max_new_tokens) int32."""
    B, T = prompt_batch["tokens"].shape
    cap = seq_capacity or (T + max_new_tokens)
    cache = init_cache(cfg, params, B, cap, prompt_batch)
    pre = make_prefill_step(cfg)
    dec = make_decode_step(cfg, sample=sample)
    if jit:
        pre = jax.jit(pre)
        dec = jax.jit(dec, static_argnames=())
    logits, cache = pre(params, prompt_batch, cache)
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [token]
    key = key if key is not None else jax.random.PRNGKey(0)
    for i in range(max_new_tokens - 1):
        key, sub = jax.random.split(key)
        token, logits, cache = dec(params, token, cache,
                                   sub if sample != "greedy" else None)
        out.append(token)
    return jnp.concatenate(out, axis=1)


def generate_replicated(cfg, params_stack, prompt_batch,
                        max_new_tokens: int, aggregator,
                        seq_capacity: int | None = None, jit: bool = True,
                        fault_hook=None):
    """Byzantine-fault-tolerant greedy decoding over r model replicas.

    ``params_stack``: params pytree with a leading replica axis (r, ...) —
    e.g. ``jax.tree.map(lambda *ls: jnp.stack(ls), *replica_params)``.
    ``aggregator``: an :class:`~repro.core.aggregators.AggregatorSpec`; the
    per-step (r, B, V) logit stack is aggregated over the replica axis, so
    any ``spec.f`` corrupted replicas are filtered before argmax, and every
    replica's cache advances with the agreed token.

    ``fault_hook(step, logits_stack) -> logits_stack``: optional
    fault-injection point at the replica communication boundary — called
    on every decode step (step 0 = prefill) BEFORE aggregation, it models
    replicas emitting corrupted logits (bit-flipped weights, hostile
    hosts, lost messages).  The fault-schedule chaos tests
    (tests/test_serving_chaos.py) drive it with compiled
    :class:`~repro.simulator.faults.FaultTrace` rows; per-replica caches
    still advance with the *agreed* token, matching a real deployment
    where the decode loop is trusted and only replica outputs are not.

    Returns (B, max_new_tokens) int32, identical to :func:`generate` on the
    clean params when <= f replicas are corrupted at every step and the
    rule tolerates f.
    """
    B, T = prompt_batch["tokens"].shape
    cap = seq_capacity or (T + max_new_tokens)

    def rep_prefill(p):
        cache = init_cache(cfg, p, B, cap, prompt_batch)
        return prefill(cfg, p, prompt_batch, cache)

    def rep_decode(p, token, cache):
        return decode_step(cfg, p, token, cache)

    vpre = jax.vmap(rep_prefill)
    vdec = jax.vmap(rep_decode, in_axes=(0, None, 0))

    def agree(logits_stack):                       # (r, B, V) -> (B,) token
        agg = aggregator.aggregate(logits_stack.astype(jnp.float32))
        return jnp.argmax(agg, axis=-1).astype(jnp.int32)

    if jit:
        vpre = jax.jit(vpre)
        vdec = jax.jit(vdec)
        agree = jax.jit(agree)

    logits, caches = vpre(params_stack)
    if fault_hook is not None:
        logits = fault_hook(0, logits)
    token = agree(logits)[:, None]
    out = [token]
    for step in range(1, max_new_tokens):
        logits, caches = vdec(params_stack, token, caches)
        if fault_hook is not None:
            logits = fault_hook(step, logits)
        token = agree(logits)[:, None]
        out.append(token)
    return jnp.concatenate(out, axis=1)
