"""Pytree checkpointing (single-controller: gathers to host, npz on disk).

Layout: <dir>/step_<n>.npz with arrays keyed by their tree path; structure is
recovered against a like-structured prototype (restore(like=...)) so no
pickling of treedefs is needed — robust across refactors that keep key names.
"""
from __future__ import annotations

import os
import re

import jax
import numpy as np


def _path_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def to_np(leaf):
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":    # ml_dtypes (bf16 etc.) -> fp32
            arr = np.asarray(leaf, np.float32)
        return arr
    arrays = {_path_key(path): to_np(leaf) for path, leaf in flat}
    out = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = out + ".tmp"
    with open(tmp, "wb") as fh:
        np.savez(fh, **arrays)
    os.replace(tmp, out)
    return out


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like, step: int | None = None):
    """Restore into the structure (and dtypes) of ``like``."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    data = np.load(os.path.join(ckpt_dir, f"step_{step:08d}.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, proto in flat:
        key = _path_key(path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(proto.shape):
            raise ValueError(
                f"shape mismatch for {key}: {arr.shape} vs {proto.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=proto.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves), step
