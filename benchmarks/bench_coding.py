"""Benchmark 3 — gradient coding (§3.3.3): Draco/DETOX aggregation cost and
exact-recovery property vs plain mean and a robust filter; reactive-redundancy
amortized overhead vs check probability q.

``python benchmarks/bench_coding.py`` writes ``BENCH_coding.json``
(``--smoke`` for the CI lane) with the two comparisons this PR's decode
rework targets: the TREE entry point vs the flat ARENA path it now rides
(same vote law, one Gram + one weighted-sum kernel vs per-leaf work), and
ELASTIC bucket-packed rosters vs the STATIC full roster (per-bucket
group tables re-derived host-side — the trim-table trick — so the coded
decode pays no shape churn).  ``run(quick)`` feeds the
``benchmarks/run.py`` CSV harness.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core.filters import FILTERS
from repro.core.redundancy import (detox_aggregate, draco_aggregate,
                                   init_reactive)
from repro.core.redundancy.coding import (coding_groups,
                                          flat_draco_aggregate,
                                          tree_draco_aggregate)
from repro.core.redundancy.reactive import (check_and_aggregate,
                                            plain_aggregate)


def _timed(fn, iters=20):
    fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def _coded(n, r, d, key, corrupt=True):
    """(stack, ref): identical replicas per group, 1 fault per full group."""
    true = jax.random.normal(key, (n // r, d))
    g = jnp.repeat(true, r, axis=0)
    if corrupt:
        g = g.at[jnp.arange(0, n, r)].set(1e5)
    return g, jnp.mean(true, axis=0)


def run(quick: bool = True):
    rows = []
    n, r, d = 16, 4, 65536
    key = jax.random.PRNGKey(0)
    g, ref = _coded(n, r, d, key)

    jd = jax.jit(lambda x: draco_aggregate(x, r))
    err = float(jnp.max(jnp.abs(jd(g) - ref)))
    rows.append({"bench": "coding", "name": f"draco_r{r}_n{n}_d{d}",
                 "us_per_call": round(_timed(
                     lambda: jd(g).block_until_ready()), 1),
                 "derived": f"recovery_err={err:.2e};exact={err < 1e-4}"})

    # detox needs a REAL bucket hierarchy: k = n2/r = 9 voted gradients
    # -> b = 3 buckets of 3 at f=1 (k=4 now raises — zero breakdown)
    n2 = 36
    g2, ref2 = _coded(n2, r, d, key)
    jdx = jax.jit(lambda x: detox_aggregate(x, r, f=1))
    err = float(jnp.max(jnp.abs(jdx(g2) - ref2)))
    rows.append({"bench": "coding", "name": f"detox_r{r}_n{n2}_d{d}",
                 "us_per_call": round(_timed(
                     lambda: jdx(g2).block_until_ready()), 1),
                 "derived": f"recovery_err={err:.2e}"})

    jm = jax.jit(lambda x: FILTERS["mean"](x, 0))
    rows.append({"bench": "coding", "name": f"plain_mean_n{n}_d{d}",
                 "us_per_call": round(_timed(
                     lambda: jm(g).block_until_ready()), 1),
                 "derived": "baseline (no fault tolerance)"})

    # reactive redundancy: amortized cost model  E[cost] = plain + q * check
    true = jax.random.normal(key, (n // r, d))    # same draw as _coded
    t_plain = _timed(lambda: plain_aggregate(
        g, init_reactive(n)).block_until_ready())
    state = init_reactive(n)
    t_check = _timed(lambda: check_and_aggregate(
        g, state, lambda i: true[i // r]), iters=5)
    for q in (0.05, 0.2):
        rows.append({
            "bench": "coding", "name": f"reactive_q{q}",
            "us_per_call": round(t_plain + q * t_check, 1),
            "derived": (f"plain={t_plain:.0f}us;check={t_check:.0f}us;"
                        f"amortized_overhead={q * t_check / t_plain:.2f}x"),
        })
    return rows


def main(out: str = "BENCH_coding.json", smoke: bool = False, seed: int = 0):
    n, r = 16, 4
    d = 16384 if smoke else 262144
    iters = 5 if smoke else 20
    key = jax.random.PRNGKey(seed)
    g, ref = _coded(n, r, d, key)
    rows = []

    # --- tree vs arena: the tree entry point RIDES the arena (FlatPlan
    # ravel -> one Gram + one masked weighted sum -> unravel), so the gap
    # is pure ravel/unravel overhead and the outputs agree per column
    jflat = jax.jit(lambda x: flat_draco_aggregate(x, r))
    vec = jflat(g)
    err = float(jnp.max(jnp.abs(vec - ref)))
    rows.append({"section": "decode_path", "name": "arena", "n": n, "r": r,
                 "d": d, "us_per_call": round(_timed(
                     lambda: jflat(g).block_until_ready(), iters), 1),
                 "recovery_err": err})
    split = 3 * d // 4
    tree = {"w": g[:, :split].reshape(n, -1, 64), "b": g[:, split:]}
    jtree = jax.jit(lambda t: tree_draco_aggregate(t, r))
    outt = jtree(tree)
    parity = float(max(
        jnp.max(jnp.abs(outt["w"].reshape(-1) - vec[:split])),
        jnp.max(jnp.abs(outt["b"] - vec[split:]))))
    rows.append({"section": "decode_path", "name": "tree", "n": n, "r": r,
                 "d": d, "us_per_call": round(_timed(
                     lambda: jax.block_until_ready(jtree(tree)), iters), 1),
                 "tree_vs_arena_err": parity})

    # --- elastic vs static roster: bucket-packed decodes with per-bucket
    # group tables (ragged trailer allowed); the static full roster is the
    # b = n row
    for b in (n, 12, 10):
        groups = coding_groups(b, r, allow_ragged=True)
        xb = g[:b]
        jb = jax.jit(lambda x, gr=groups: flat_draco_aggregate(
            x, r, groups=gr))
        rows.append({
            "section": "roster", "name": "static" if b == n else "bucket",
            "n": n, "live": b, "r": r, "d": d,
            "ragged_trailer": bool(b % r),
            "us_per_call": round(_timed(
                lambda: jb(xb).block_until_ready(), iters), 1)})

    from repro.obs.provenance import provenance
    results = {"bench": "coding", "n": n, "r": r, "d": d, "seed": seed,
               "smoke": bool(smoke), "rows": rows,
               "provenance": provenance()}
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"{'section':<12}{'name':<8}{'live':>5}{'us/call':>10}  notes")
    for row in rows:
        notes = "; ".join(f"{k}={v}" for k, v in row.items()
                          if k not in ("section", "name", "n", "live", "r",
                                       "d", "us_per_call"))
        print(f"{row['section']:<12}{row['name']:<8}"
              f"{row.get('live', row['n']):>5}"
              f"{row['us_per_call']:>10.1f}  {notes}")
    print(f"wrote {out}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_coding.json")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(args.out, args.smoke, args.seed)
