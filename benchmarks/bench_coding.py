"""Benchmark 3 — gradient coding (§3.3.3): Draco/DETOX aggregation cost and
exact-recovery property vs plain mean and a robust filter; reactive-redundancy
amortized overhead vs check probability q."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.filters import FILTERS
from repro.core.redundancy import (detox_aggregate, draco_aggregate,
                                   init_reactive)
from repro.core.redundancy.reactive import (check_and_aggregate,
                                            plain_aggregate)


def _timed(fn, iters=20):
    fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = True):
    rows = []
    n, r, d = 16, 4, 65536
    key = jax.random.PRNGKey(0)
    k = n // r
    true = jax.random.normal(key, (k, d))
    g = jnp.repeat(true, r, axis=0)
    g = g.at[jnp.arange(0, n, r)].set(1e5)        # 1 fault per group
    ref = jnp.mean(true, axis=0)

    jd = jax.jit(lambda x: draco_aggregate(x, r))
    err = float(jnp.max(jnp.abs(jd(g) - ref)))
    rows.append({"bench": "coding", "name": f"draco_r{r}_n{n}_d{d}",
                 "us_per_call": round(_timed(
                     lambda: jd(g).block_until_ready()), 1),
                 "derived": f"recovery_err={err:.2e};exact={err < 1e-4}"})

    jdx = jax.jit(lambda x: detox_aggregate(x, r, f=1))
    err = float(jnp.max(jnp.abs(jdx(g) - ref)))
    rows.append({"bench": "coding", "name": f"detox_r{r}_n{n}_d{d}",
                 "us_per_call": round(_timed(
                     lambda: jdx(g).block_until_ready()), 1),
                 "derived": f"recovery_err={err:.2e}"})

    jm = jax.jit(lambda x: FILTERS["mean"](x, 0))
    rows.append({"bench": "coding", "name": f"plain_mean_n{n}_d{d}",
                 "us_per_call": round(_timed(
                     lambda: jm(g).block_until_ready()), 1),
                 "derived": "baseline (no fault tolerance)"})

    # reactive redundancy: amortized cost model  E[cost] = plain + q * check
    t_plain = _timed(lambda: plain_aggregate(
        g, init_reactive(n)).block_until_ready())
    state = init_reactive(n)
    t_check = _timed(lambda: check_and_aggregate(
        g, state, lambda i: true[i // r]), iters=5)
    for q in (0.05, 0.2):
        rows.append({
            "bench": "coding", "name": f"reactive_q{q}",
            "us_per_call": round(t_plain + q * t_check, 1),
            "derived": (f"plain={t_plain:.0f}us;check={t_check:.0f}us;"
                        f"amortized_overhead={q * t_check / t_plain:.2f}x"),
        })
    return rows
