"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the artifacts.

  PYTHONPATH=src python -m benchmarks.gen_experiments [--tag TAG]

Prints markdown to stdout (pasted/refreshed into EXPERIMENTS.md)."""
from __future__ import annotations

import argparse

from benchmarks.bench_roofline import load_records


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x):
    return f"{x:.2e}"


def dryrun_table(recs):
    print("| arch | shape | mesh | mode | compile | flops/dev | "
          "bytes/dev | coll/dev (AG/AR/AA/CP) | temp mem/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        nm = f"{r['arch']} | {r['shape']} | {r['mesh']}"
        if "skipped" in r:
            print(f"| {nm} | - | - | - | - | SKIP: {r['skipped'][:48]} | - |")
            continue
        if "error" in r:
            print(f"| {nm} | - | - | - | - | ERROR | - |")
            continue
        cb = r["collective_bytes"]
        coll = "/".join(fmt_bytes(cb.get(k, 0)) for k in
                        ("all-gather", "all-reduce", "all-to-all",
                         "collective-permute"))
        tmp = r.get("memory", {}).get("temp_size_in_bytes")
        print(f"| {nm} | {r.get('sharding_mode', '-')} "
              f"| {r.get('compile_s', 0):.0f}s "
              f"| {r['flops']:.2e} | {fmt_bytes(r['bytes_accessed'])} "
              f"| {coll} | {fmt_bytes(tmp)} |")


def roofline_table(recs):
    print("| arch | shape | mesh | compute s | memory s | collective s | "
          "dominant | useful ratio | model GFLOPs/chip |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        nm = f"{r['arch']} | {r['shape']} | {r['mesh']}"
        if "skipped" in r or "error" in r:
            continue
        rf = r["roofline"]
        ur = r.get("useful_flops_ratio")
        mf = r["model_flops"] / r["n_chips"] / 1e9
        print(f"| {nm} | {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
              f"| {fmt_s(rf['collective_s'])} "
              f"| {rf['dominant'].replace('_s', '')} "
              f"| {ur:.2f} | {mf:.1f} |" if ur is not None else
              f"| {nm} | - | - | - | - | - | - |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default=None)
    ap.add_argument("--section", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    recs = load_records(tag=args.tag)
    if args.section in ("dryrun", "both"):
        print("### Dry-run table\n")
        dryrun_table(recs)
        print()
    if args.section in ("roofline", "both"):
        print("### Roofline table\n")
        roofline_table(recs)


if __name__ == "__main__":
    main()
