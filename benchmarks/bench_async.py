"""Benchmark 6 — asynchronous training under fault injection.

Three fault profiles on the same smoke-scale LM:

  uniform    — no faults, full barrier (the synchronous baseline);
  stragglers — lognormal slowdowns, quorum 6/8 with bounded staleness;
  chaos      — stragglers + crash/recover + message loss, quorum 4/8.

Per profile: wall-clock steps/sec (jitted, host-dispatched), virtual-time
per step (the simulated cluster's wall clock), staleness histogram, final
loss.  A membership-churn series (PR 4) additionally sweeps churn rates
and compares the bucketed elastic spec against the naive one-plan-per-
live-count re-jit baseline — steps/sec and RECOMPILE COUNT per run.
``python benchmarks/bench_async.py`` writes ``BENCH_async.json``;
``run.py`` consumes :func:`run` like every other bench section.
"""
from __future__ import annotations

import json
import time

from repro.configs import get_config
from repro.core.aggregators import elastic, frac, make_spec
from repro.core.tracecount import TRACE_COUNTS
from repro.data import SyntheticLM
from repro.optim import adamw, constant
from repro.simulator import (Churn, CrashRecover, MessageDrop, SimConfig,
                             Straggler, async_train_loop, plan_arrivals)
from repro.training import ByzantineConfig

PROFILES = {
    "uniform": SimConfig(),
    "stragglers": SimConfig(
        faults=(Straggler(dist="lognormal", scale=0.8),),
        quorum=6, max_staleness=3, seed=0),
    "chaos": SimConfig(
        faults=(Straggler(dist="lognormal", scale=0.6),
                CrashRecover(rate=0.1, mean_down=2.0),
                MessageDrop(p=0.1)),
        quorum=4, max_staleness=4, seed=0),
}

# the delay-adaptive Zeno++-style score filter on the straggler profile —
# a stateful aggregator flowing through the same spec API + state threading
ZENO_PP_PROFILE = ("stragglers+zeno_pp", PROFILES["stragglers"],
                   make_spec("zeno_pp", f=2, xi=0.5, ema=0.2, n=8))


def bench_profile(name: str, sim: SimConfig, steps: int, aggregator=None):
    cfg = get_config("paper-100m-smoke").replace(vocab_size=64,
                                                 dtype="float32")
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8,
                     per_agent_batch=2)
    spec = aggregator or make_spec("trimmed_mean", f=2, n=8)
    bz = ByzantineConfig(n_agents=8, f=2, aggregator=spec,
                         attack="sign_flip")
    # warm-up run compiles both step functions so the timed run is steady
    async_train_loop(cfg, bz, adamw(constant(3e-3)), ds, steps=2, sim=sim,
                     log_every=2, log_fn=lambda *_: None)
    t0 = time.perf_counter()
    _, hist = async_train_loop(cfg, bz, adamw(constant(3e-3)), ds,
                               steps=steps, sim=sim, log_every=steps,
                               log_fn=lambda *_: None)
    wall = time.perf_counter() - t0

    # the same planning call the loop itself makes (seeded -> same trace)
    s = plan_arrivals(sim, bz.n_agents, steps).summary()
    return {
        "profile": name,
        "steps": steps,
        "steps_per_sec": steps / wall,
        "virtual_time_per_step": s["virtual_time"] / steps,
        "mean_arrived": s["mean_arrived"],
        "mean_staleness": s["mean_staleness"],
        "staleness_hist": s["staleness_hist"],
        "quorum_misses": s["quorum_misses"],
        "final_loss": hist[-1]["loss"],
    }


CHURN_RATES = (0.0, 0.05, 0.2)
ELASTIC_BUCKETS = (4, 6, 8)                   # the bucketed elastic spec
NAIVE_BUCKETS = tuple(range(4, 9))            # one plan per live count


def bench_churn(rate: float, steps: int, buckets) -> dict:
    """One membership-churn run: steps/sec + how many times the jitted
    steps (async per-bucket + sync fast path) actually compiled."""
    cfg = get_config("paper-100m-smoke").replace(vocab_size=64,
                                                 dtype="float32")
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_agents=8,
                     per_agent_batch=2)
    spec = make_spec("trimmed_mean", f=frac(0.25),
                     n=elastic(8, buckets=buckets))
    bz = ByzantineConfig(n_agents=8, f=2, aggregator=spec,
                         attack="sign_flip")
    sim = SimConfig(faults=(Churn(rate=rate, mean_out=2.0,
                                  agents=(0, 1, 2, 3)),),
                    quorum=4, seed=0)
    before = (TRACE_COUNTS["async_step"], TRACE_COUNTS["train_step"])
    t0 = time.perf_counter()
    _, hist = async_train_loop(cfg, bz, adamw(constant(3e-3)), ds,
                               steps=steps, sim=sim, log_every=steps,
                               log_fn=lambda *_: None)
    wall = time.perf_counter() - t0
    recompiles = ((TRACE_COUNTS["async_step"] - before[0])
                  + (TRACE_COUNTS["train_step"] - before[1]))
    s = plan_arrivals(sim, 8, steps).summary()
    return {
        "churn_rate": rate,
        "buckets": list(buckets),
        "steps": steps,
        "steps_per_sec": steps / wall,
        "recompiles": recompiles,
        "mean_live": s["mean_live"],
        "final_loss": hist[-1]["loss"],
    }


def churn_series(steps: int) -> list[dict]:
    rows = []
    for rate in CHURN_RATES:
        for label, buckets in (("elastic", ELASTIC_BUCKETS),
                               ("naive_rejit", NAIVE_BUCKETS)):
            r = bench_churn(rate, steps, buckets)
            r["variant"] = label
            rows.append(r)
    return rows


def run(quick: bool = True):
    """run.py harness entry point: CSV rows."""
    steps = 20 if quick else 100
    rows = []
    runs = [(n, s, None) for n, s in PROFILES.items()] + [ZENO_PP_PROFILE]
    for name, sim, agg in runs:
        r = bench_profile(name, sim, steps, aggregator=agg)
        rows.append({
            "bench": "async",
            "name": name,
            "us_per_call": 1e6 / r["steps_per_sec"],
            "derived": (f"vtime/step={r['virtual_time_per_step']:.2f} "
                        f"stal={r['mean_staleness']:.2f} "
                        f"loss={r['final_loss']:.3f}"),
        })
    if not quick:
        # 6 extra training runs (3 rates x 2 variants) — full runs only;
        # the quick harness pass stays within its historical budget
        for r in churn_series(steps):
            rows.append({
                "bench": "async",
                "name": f"churn{r['churn_rate']}+{r['variant']}",
                "us_per_call": 1e6 / r["steps_per_sec"],
                "derived": (f"recompiles={r['recompiles']} "
                            f"live={r['mean_live']:.1f} "
                            f"loss={r['final_loss']:.3f}"),
            })
    return rows


def main(out: str = "BENCH_async.json", steps: int = 40):
    steps = max(1, steps)
    runs = [(n, s, None) for n, s in PROFILES.items()] + [ZENO_PP_PROFILE]
    results = {name: bench_profile(name, sim, steps, aggregator=agg)
               for name, sim, agg in runs}
    results["churn"] = churn_series(steps)
    from repro.obs.provenance import provenance
    results["provenance"] = provenance()
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    for name, r in results.items():
        if name in ("churn", "provenance"):
            continue
        print(f"{name:12s} {r['steps_per_sec']:8.2f} steps/s  "
              f"vtime/step {r['virtual_time_per_step']:6.2f}  "
              f"stal {r['mean_staleness']:.2f}  loss {r['final_loss']:.3f}")
    for r in results["churn"]:
        print(f"churn {r['churn_rate']:<4} {r['variant']:12s} "
              f"{r['steps_per_sec']:8.2f} steps/s  "
              f"recompiles {r['recompiles']:2d}  "
              f"live {r['mean_live']:.1f}  loss {r['final_loss']:.3f}")
    print(f"wrote {out}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_async.json")
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()
    main(args.out, args.steps)
