"""Benchmark 5 — §Roofline: read the dry-run artifacts and emit the per
(arch x shape x mesh) three-term roofline table (deliverable g).

Terms (seconds, per device):
  compute    = HLO_FLOPs / peak_FLOP/s          (197 TF bf16, v5e)
  memory     = HLO_bytes / HBM_bw               (819 GB/s)
  collective = collective_bytes / (3 * 50 GB/s) (ICI links)

Plus MODEL_FLOPS = 6*N*D (train) / 2*N_active (decode) and the
useful-compute ratio MODEL_FLOPS / (chips * HLO_FLOPs)."""
from __future__ import annotations

import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


def load_records(art_dir=ART_DIR, tag=None):
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as fh:
            r = json.load(fh)
        rtag = r.get("tag", "")
        if (tag or "") != rtag:
            continue
        recs.append(r)
    return recs


def run(quick: bool = True):
    rows = []
    for r in load_records():
        name = f"{r['arch']}|{r['shape']}|{r['mesh']}"
        if "skipped" in r:
            rows.append({"bench": "roofline", "name": name,
                         "us_per_call": 0.0,
                         "derived": f"SKIPPED:{r['skipped'][:60]}"})
            continue
        if "error" in r:
            rows.append({"bench": "roofline", "name": name,
                         "us_per_call": -1.0,
                         "derived": f"ERROR:{r['error'][:80]}"})
            continue
        rf = r["roofline"]
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        ratio = r.get("useful_flops_ratio")
        rows.append({
            "bench": "roofline", "name": name,
            "us_per_call": round(bound * 1e6, 1),      # roofline step time
            "derived": (f"compute={rf['compute_s']:.2e}s;"
                        f"memory={rf['memory_s']:.2e}s;"
                        f"collective={rf['collective_s']:.2e}s;"
                        f"dominant={rf['dominant']};"
                        f"useful_ratio="
                        + (f"{ratio:.2f}" if ratio else "n/a")),
        })
    if not rows:
        rows.append({"bench": "roofline", "name": "no_artifacts",
                     "us_per_call": -1.0,
                     "derived": "run repro.launch.dryrun first"})
    return rows
